"""Batched serving demo: slot-based continuous batching over decode_step.

Drives ``ServeEngine`` directly to show the per-slot position vectors at
work: requests with *staggered* lengths release their slots at different
ticks, and a request admitted mid-stream starts at pos=0 while its
neighbors keep decoding at pos>0 — the admission pattern the old shared
scalar ``pos`` could not serve.

  PYTHONPATH=src python examples/serve_demo.py
"""
import sys

sys.path.insert(0, "src")


def main():
    import jax
    import numpy as np

    from repro.configs import get_config, smoke_config
    from repro.launch.serve import Request, ServeEngine
    from repro.models import init_params

    cfg = smoke_config(get_config("hymba-1.5b"))    # hybrid attn+SSM decode
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, batch_slots=3, max_len=64)

    rng = np.random.default_rng(0)

    def make_request(rid, max_new):
        return Request(rid=rid,
                       prompt=list(rng.integers(0, cfg.vocab_size, size=4)),
                       max_new_tokens=max_new)

    # staggered lengths, exactly filling the 3 slots (queue left empty so
    # the next submission is genuinely the next admission)
    reqs = [make_request(i, max_new=6 + 6 * i) for i in range(3)]
    for r in reqs:
        engine.submit(r)

    # run until the first request completes and its slot frees
    while not any(r.done for r in reqs):
        engine.tick()
    mid_positions = [s.pos for s in engine.slots if s.request is not None]
    assert any(p > 0 for p in mid_positions), \
        "expected neighbors still decoding mid-stream"

    # admit a NEW request mid-stream: it enters the freed slot at pos=0
    # on the next tick while the others continue at their own positions
    late = make_request(99, max_new=8)
    engine.submit(late)
    engine.tick()
    late_slot = next(s for s in engine.slots if s.request is late)
    positions = sorted(s.pos for s in engine.slots if s.request is not None)
    print(f"after mid-stream admission, active slot positions: {positions}")
    assert late_slot.pos == 1 and late_slot.pos < max(positions), \
        "late request should decode at its own position, trailing the rest"

    engine.run()
    for r in reqs + [late]:
        assert r.done
        assert len(r.generated) == r.max_new_tokens, \
            (r.rid, len(r.generated), r.max_new_tokens)
        print(f"request {r.rid}: {len(r.generated)} tokens: "
              f"{r.generated[:8]}...")

    # slot-state isolation: the mid-stream request must decode exactly as
    # it would alone (the reused slot's KV *and* recurrent SSM state were
    # reset at admission; greedy decode is deterministic)
    solo_engine = ServeEngine(cfg, params, batch_slots=3, max_len=64)
    solo = Request(rid=late.rid, prompt=list(late.prompt),
                   max_new_tokens=late.max_new_tokens)
    solo_engine.submit(solo)
    solo_engine.run()
    assert solo.generated == late.generated, \
        ("mid-stream admission leaked slot state", solo.generated,
         late.generated)

    print("\nall 4 requests served through 3 slots, one admitted "
          "mid-stream\ninto a reused slot (per-slot position vectors + "
          "per-slot state reset;\nits tokens match a solo run exactly).")


if __name__ == "__main__":
    main()
