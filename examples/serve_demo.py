"""Batched serving demo: slot-based continuous batching over decode_step.

  PYTHONPATH=src python examples/serve_demo.py
"""
import sys

sys.path.insert(0, "src")


def main():
    from repro.launch.serve import main as serve_main

    out = serve_main(["--arch", "hymba-1.5b",     # hybrid attn+SSM decode
                      "--requests", "6", "--slots", "3",
                      "--max-new", "12", "--max-len", "64"])
    assert len(out) == 6 and all(len(v) == 12 for v in out.values())
    print("\nall 6 requests served through 3 slots (continuous batching).")


if __name__ == "__main__":
    main()
