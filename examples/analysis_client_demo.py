"""Networked diagnosis demo: LeoClient against a live `--serve` front-end.

Exercises the full serving contract from the outside:

  1. health — wait for ``/readyz`` (fresh server processes take a moment
     to bind);
  2. round trips — single-backend and fan-out diagnoses over the wire,
     plus a pipelined ``diagnose_batch``;
  3. backpressure — with ``--expect-shed`` (run the server with
     ``--slots 1 --max-queue 1``) a burst of concurrent requests must
     observe at least one 429 shed, and the client's backoff must still
     land every diagnosis;
  4. telemetry — dump ``/metrics`` (optionally to ``--metrics-out`` for
     the CI lane to grep).

Start a server, then point this at it:

  PYTHONPATH=src python -m repro.launch.analysis_server \\
      --serve 0 --slots 1 --max-queue 1 --port-file /tmp/leo.port &
  PYTHONPATH=src python examples/analysis_client_demo.py \\
      --port $(cat /tmp/leo.port) --expect-shed
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core.service import AnalyzeRequest          # noqa: E402
from repro.serve import LeoClient                      # noqa: E402


def demo_traces(n):
    # imported lazily: repro.launch pulls jax via its package __init__,
    # and the demo builders are plain string templates
    from repro.launch.analysis_server import demo_hlo
    return [demo_hlo(seed=i, n=128 + 32 * (i % 3)) for i in range(n)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--requests", type=int, default=6,
                    help="burst size for the batch phase")
    ap.add_argument("--rounds", type=int, default=1,
                    help="repeat the pipelined burst this many times "
                         "(the worker-kill CI lane SIGKILLs a pool "
                         "worker mid-run; every request must still "
                         "complete via the client's retry path)")
    ap.add_argument("--expect-shed", action="store_true",
                    help="fail unless the burst observes >= 1 429 shed "
                         "(run the server with --slots 1 --max-queue 1)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the server's /metrics text here at the end")
    args = ap.parse_args(argv)

    traces = demo_traces(max(2, args.requests // 2))
    with LeoClient(host=args.host, port=args.port, max_retries=8,
                   backoff_base_seconds=0.05) as client:
        if not client.wait_ready(15.0):
            print("server never became ready", file=sys.stderr)
            return 1

        print("-- single round trip --")
        diag = client.diagnose(traces[0], backend="tpu_v5e")
        top = diag.root_causes[0]["instruction"] if diag.root_causes else "-"
        print(f"[{diag.backend}] est {diag.estimated_step_seconds*1e6:.1f} "
              f"us, top root cause: {top}")

        print("-- cross-vendor fan-out --")
        fanout = client.diagnose(traces[0],
                                 backends=["tpu_v5e", "amd_mi300a"])
        for name, d in sorted(fanout.items()):
            print(f"[{name}] est {d.estimated_step_seconds*1e6:.1f} us")

        print(f"-- pipelined burst of {args.requests} "
              f"x {args.rounds} round(s) --")
        reqs = [AnalyzeRequest(hlo_text=traces[i % len(traces)],
                               backend="tpu_v5e")
                for i in range(args.requests)]
        for round_no in range(args.rounds):
            diags = client.diagnose_batch(reqs,
                                          max_connections=args.requests)
            if len(diags) != len(reqs):
                print(f"round {round_no}: {len(diags)}/{len(reqs)} "
                      f"diagnoses back", file=sys.stderr)
                return 1
        print(f"{args.rounds * len(reqs)} diagnoses back; "
              f"client stats: {client.stats}")

        sheds = client.stats["sheds_seen"]
        if args.expect_shed and sheds == 0:
            print("expected >= 1 shed (429) during the burst but saw "
                  "none — is the server running with --slots 1 "
                  "--max-queue 1?", file=sys.stderr)
            return 1
        if sheds:
            print(f"backpressure observed: {sheds} shed(s), all retried "
                  f"to completion")

        metrics = client.metrics_text()
        if args.metrics_out:
            with open(args.metrics_out, "w") as f:
                f.write(metrics)
            print(f"wrote /metrics to {args.metrics_out}")
        else:
            wanted = ("leo_requests_total", "leo_sheds_total",
                      "leo_queue_depth")
            print("-- /metrics (excerpt) --")
            for line in metrics.splitlines():
                if line.startswith(wanted):
                    print(line)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
