"""Observation-1 demo (paper §VI-A, MASS3DEA): the SAME kernel exhibits
different bottlenecks on different backends, and LEO explains each.

We analyze one compiled program on three TPU hardware models whose
FLOP:HBM:ICI ratios differ (v5e / v5p / v4 playing the roles of
NVIDIA/AMD/Intel in the paper) and print each backend's dominant stall
class, root cause, and recommended fix.

  PYTHONPATH=src python examples/crossvendor_divergence.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp


def kernel(table, idx, w1, w2):
    """An embedding-heavy MLP: gather -> matmul -> gelu -> matmul."""
    x = table[idx]                                      # (B, D) gather
    h = jax.nn.gelu(x @ w1)                             # (B, F)
    return (h @ w2).sum()


def main():
    from repro.core import HARDWARE_MODELS, analyze_hlo
    from repro.core.report import recommendations

    key = jax.random.PRNGKey(0)
    # sized on the compute/memory knife edge: ~34 GFLOP of matmul vs
    # ~134 MB of gathered table rows — narrow-HBM parts tip one way,
    # fat-HBM parts the other
    table = jax.random.normal(key, (500_000, 1024), jnp.bfloat16)
    idx = jax.random.randint(key, (65_536,), 0, 500_000)
    w1 = jax.random.normal(key, (1024, 96), jnp.bfloat16)
    w2 = jax.random.normal(key, (96, 1024), jnp.bfloat16)

    hlo = jax.jit(kernel).lower(table, idx, w1, w2).compile().as_text()

    from repro.core import compute_roofline, parse_hlo
    module = parse_hlo(hlo)
    print(f"{'backend':<10s} {'est. time':>10s} {'compute':>9s} "
          f"{'memory':>9s} {'mem/comp':>9s}  diagnosis")
    for name, hw in HARDWARE_MODELS.items():
        an = analyze_hlo(hlo, hw=hw)
        rl = compute_roofline(parse_hlo(hlo), hw, chips=1, label=name)
        diagnosed = list(an.blame.self_blame) + \
            list(an.blame.occupancy_blame)
        label = max(diagnosed, key=lambda s: s.cycles).subcategory \
            if diagnosed else "dependency stalls"
        print(f"{name:<10s} {an.estimated_step_seconds*1e6:>8.1f}us "
              f"{rl.compute_s*1e6:>7.1f}us {rl.memory_s*1e6:>7.1f}us "
              f"{rl.memory_s/max(rl.compute_s,1e-12):>8.2f}x  {label}")

    print("\nSame HLO, three backends: on v5e the gathered table rows cost "
          "~3x the matmul\ntime; on v5p's fat HBM the ratio collapses toward "
          "parity — the bottleneck\nbalance shifts with the backend, which "
          "is the paper's Observation 1. LEO's\ndiagnosis names the gather "
          "as the actionable cause on every backend, and the\nfix "
          "(coalesce/tile the table access) transfers — the paper's "
          "Observation 2\n('regular access patterns admit portable "
          "optimizations').")


if __name__ == "__main__":
    main()
