"""Observation-1 demo (paper §VI-A, MASS3DEA): the SAME kernel exhibits
different bottlenecks on different backends, and LEO explains each.

One ``LeoService.compare_backends`` call fans the compiled program across
every registered backend — three TPU generations plus NVIDIA-, AMD- and
Intel-class descriptors whose FLOP:HBM:interconnect ratios genuinely differ
— concurrently over the service thread pool, parsing the HLO exactly once
(single-flighted).  Each row prints the vendor's dominant stall in its
*native* profiler vocabulary (CUPTI / rocprofiler / Level Zero / xplane),
the way the paper's §II-D taxonomy maps back out.

  PYTHONPATH=src python examples/crossvendor_divergence.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp


def kernel(table, idx, w1, w2):
    """An embedding-heavy MLP: gather -> matmul -> gelu -> matmul."""
    x = table[idx]                                      # (B, D) gather
    h = jax.nn.gelu(x @ w1)                             # (B, F)
    return (h @ w2).sum()


def main():
    from repro.core import LeoService, compute_roofline

    key = jax.random.PRNGKey(0)
    # sized on the compute/memory knife edge: ~34 GFLOP of matmul vs
    # ~134 MB of gathered table rows — narrow-HBM parts tip one way,
    # fat-HBM parts the other
    table = jax.random.normal(key, (500_000, 1024), jnp.bfloat16)
    idx = jax.random.randint(key, (65_536,), 0, 500_000)
    w1 = jax.random.normal(key, (1024, 96), jnp.bfloat16)
    w2 = jax.random.normal(key, (96, 1024), jnp.bfloat16)

    hlo = jax.jit(kernel).lower(table, idx, w1, w2).compile().as_text()

    # the serving entry point: concurrent fan-out over the thread pool,
    # with the session's single-flight caches keeping one parse
    service = LeoService()
    per_backend = service.compare_backends(hlo)
    print(f"parsed {service.stats.parse_misses} time(s) for "
          f"{len(per_backend)} backends, concurrently "
          f"({service.stats.parse_hits} cache hits)\n")

    print(f"{'backend':<14s} {'vendor':<7s} {'est. time':>10s} "
          f"{'compute':>9s} {'memory':>9s} {'mem/comp':>9s}  "
          f"diagnosis (native counter)")
    for name, an in per_backend.items():
        rl = compute_roofline(an.module, an.hw, chips=1, label=name)
        diagnosed = list(an.blame.self_blame) + \
            list(an.blame.occupancy_blame)
        if diagnosed:
            top = max(diagnosed, key=lambda s: s.cycles)
            label = top.subcategory
        else:
            label = "dependency stalls"
        # the same diagnosis in the vendor profiler's own vocabulary
        stalled = an.profile.top_stalled(1)
        native = an.backend.native_stall_name(stalled[0].dominant_stall) \
            if stalled else "-"
        print(f"{name:<14s} {an.backend.vendor:<7s} "
              f"{an.estimated_step_seconds*1e6:>8.1f}us "
              f"{rl.compute_s*1e6:>7.1f}us {rl.memory_s*1e6:>7.1f}us "
              f"{rl.memory_s/max(rl.compute_s,1e-12):>8.2f}x  "
              f"{label} ({native})")

    # §III-E resource pressure: which finite vendor sync resources the
    # program's async traffic actually consumed (and whether it ever
    # oversubscribed them — "peak 6/6 in flight" is the strategist's cue).
    print("\nsync-resource pressure (finite §III-E resources per vendor):")
    for name, an in per_backend.items():
        if an.sync_pressure is None:
            continue
        used = [p for p in an.sync_pressure.pools if p["acquisitions"]]
        if not used:
            print(f"{name:<14s} no async sync traffic")
            continue
        cells = []
        for p in used:
            cell = f"{p['label']}: peak {p['peak_in_flight']}/{p['capacity']}"
            if p["evictions"]:
                cell += (f" — {p['evictions']} oversubscription(s), "
                         f"{p['contention_cycles']:,.0f} cyc serialized")
            cells.append(cell)
        print(f"{name:<14s} " + "; ".join(cells))

    print("\nSame HLO, six backends, one parse: the gathered table rows "
          "dominate on\nnarrow-HBM parts (tpu_v5e), collapse toward parity "
          "on fat-HBM parts\n(amd_mi300a, tpu_v5p), and the bottleneck "
          "balance shifts per vendor —\nthe paper's Observation 1.  LEO "
          "names the gather as the actionable cause\non every backend, so "
          "the fix (coalesce/tile the table access) transfers —\n"
          "Observation 2 ('regular access patterns admit portable "
          "optimizations').")

    copy_storm_demo(service)


def copy_storm_demo(service) -> None:
    """The §III-E headline: 8 in-flight async copies oversubscribe some
    vendors' finite sync resources and sail through others', so the SAME
    program gets a different top blame class per vendor."""
    from repro.launch.analysis_server import copy_storm_hlo
    print("\n--- copy storm: 8 async copies in flight at once ---")
    print(f"{'backend':<14s} {'resource pool':<28s} {'pressure':<12s} "
          f"top stall (native)")
    for name, diag in service.diagnose_fanout(copy_storm_hlo()).items():
        top = diag.top_stalls[0]["breakdown"]
        dominant = max(top, key=top.get)
        used = [p for p in diag.sync_resources["pools"]
                if p["acquisitions"]]
        pool = used[0] if used else None
        label = pool["label"] if pool else "-"
        pressure = (f"{pool['peak_in_flight']}/{pool['capacity']}"
                    + ("!" * min(pool["evictions"], 3)) if pool else "-")
        print(f"{name:<14s} {label:<28s} {pressure:<12s} "
              f"{dominant} ({diag.stall_taxonomy[dominant]})")
    print("8 copies > NVIDIA's 6 named barriers and AMD's 2 waitcnt "
          "counters, but\n< Intel's 16 SWSB tokens and the TPUs' 32 async "
          "contexts: the contended\nvendors serialize (oldest-(M-N) rule) "
          "and their diagnosis names the exact\nresource instance consumed "
          "— three GPU vendors, three top blame classes.")


if __name__ == "__main__":
    main()
