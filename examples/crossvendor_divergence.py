"""Observation-1 demo (paper §VI-A, MASS3DEA): the SAME kernel exhibits
different bottlenecks on different backends, and LEO explains each.

One ``LeoService.compare_backends`` call fans the compiled program across
every registered backend — three TPU generations plus NVIDIA-, AMD- and
Intel-class descriptors whose FLOP:HBM:interconnect ratios genuinely differ
— concurrently over the service thread pool, parsing the HLO exactly once
(single-flighted).  Each row prints the vendor's dominant stall in its
*native* profiler vocabulary (CUPTI / rocprofiler / Level Zero / xplane),
the way the paper's §II-D taxonomy maps back out.

  PYTHONPATH=src python examples/crossvendor_divergence.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp


def kernel(table, idx, w1, w2):
    """An embedding-heavy MLP: gather -> matmul -> gelu -> matmul."""
    x = table[idx]                                      # (B, D) gather
    h = jax.nn.gelu(x @ w1)                             # (B, F)
    return (h @ w2).sum()


def main():
    from repro.core import LeoService, compute_roofline

    key = jax.random.PRNGKey(0)
    # sized on the compute/memory knife edge: ~34 GFLOP of matmul vs
    # ~134 MB of gathered table rows — narrow-HBM parts tip one way,
    # fat-HBM parts the other
    table = jax.random.normal(key, (500_000, 1024), jnp.bfloat16)
    idx = jax.random.randint(key, (65_536,), 0, 500_000)
    w1 = jax.random.normal(key, (1024, 96), jnp.bfloat16)
    w2 = jax.random.normal(key, (96, 1024), jnp.bfloat16)

    hlo = jax.jit(kernel).lower(table, idx, w1, w2).compile().as_text()

    # the serving entry point: concurrent fan-out over the thread pool,
    # with the session's single-flight caches keeping one parse
    service = LeoService()
    per_backend = service.compare_backends(hlo)
    print(f"parsed {service.stats.parse_misses} time(s) for "
          f"{len(per_backend)} backends, concurrently "
          f"({service.stats.parse_hits} cache hits)\n")

    print(f"{'backend':<14s} {'vendor':<7s} {'est. time':>10s} "
          f"{'compute':>9s} {'memory':>9s} {'mem/comp':>9s}  "
          f"diagnosis (native counter)")
    for name, an in per_backend.items():
        rl = compute_roofline(an.module, an.hw, chips=1, label=name)
        diagnosed = list(an.blame.self_blame) + \
            list(an.blame.occupancy_blame)
        if diagnosed:
            top = max(diagnosed, key=lambda s: s.cycles)
            label = top.subcategory
        else:
            label = "dependency stalls"
        # the same diagnosis in the vendor profiler's own vocabulary
        stalled = an.profile.top_stalled(1)
        native = an.backend.native_stall_name(stalled[0].dominant_stall) \
            if stalled else "-"
        print(f"{name:<14s} {an.backend.vendor:<7s} "
              f"{an.estimated_step_seconds*1e6:>8.1f}us "
              f"{rl.compute_s*1e6:>7.1f}us {rl.memory_s*1e6:>7.1f}us "
              f"{rl.memory_s/max(rl.compute_s,1e-12):>8.2f}x  "
              f"{label} ({native})")

    # §III-E resource pressure: which finite vendor sync resources the
    # program's async traffic actually consumed (and whether it ever
    # oversubscribed them — "peak 6/6 in flight" is the strategist's cue).
    print("\nsync-resource pressure (finite §III-E resources per vendor):")
    for name, an in per_backend.items():
        if an.sync_pressure is None:
            continue
        used = [p for p in an.sync_pressure.pools if p["acquisitions"]]
        if not used:
            print(f"{name:<14s} no async sync traffic")
            continue
        cells = []
        for p in used:
            cell = f"{p['label']}: peak {p['peak_in_flight']}/{p['capacity']}"
            if p["evictions"]:
                cell += (f" — {p['evictions']} oversubscription(s), "
                         f"{p['contention_cycles']:,.0f} cyc serialized")
            cells.append(cell)
        print(f"{name:<14s} " + "; ".join(cells))

    print("\nSame HLO, six backends, one parse: the gathered table rows "
          "dominate on\nnarrow-HBM parts (tpu_v5e), collapse toward parity "
          "on fat-HBM parts\n(amd_mi300a, tpu_v5p), and the bottleneck "
          "balance shifts per vendor —\nthe paper's Observation 1.  LEO "
          "names the gather as the actionable cause\non every backend, so "
          "the fix (coalesce/tile the table access) transfers —\n"
          "Observation 2 ('regular access patterns admit portable "
          "optimizations').")

    copy_storm_demo(service)
    wide_ops_demo(service)
    occupancy_demo(service)
    advice_demo(service)


def copy_storm_demo(service) -> None:
    """The §III-E headline: in-flight async copies oversubscribe some
    vendors' finite sync resources and sail through others' — and under
    the multi-stream issue model, pool *scope* decides: NVIDIA's named
    barriers are CTA-shared (all 4 warp schedulers fight over B1-B6)
    while AMD's waitcnt counters are per-wave (each SIMD queue owns its
    own vmcnt/lgkmcnt), so the 12-copy storm contends on every AMD queue
    but spreads where an 8-copy storm would fit."""
    from repro.launch.analysis_server import copy_storm_hlo
    print("\n--- copy storm: 12 async copies in flight at once ---")
    print(f"{'backend':<14s} {'resource pool':<28s} {'scope':<7s} "
          f"{'pressure':<12s} top stall (native)")
    for name, diag in service.diagnose_fanout(copy_storm_hlo(12)).items():
        top = diag.top_stalls[0]["breakdown"]
        dominant = max(top, key=top.get)
        used = [p for p in diag.sync_resources["pools"]
                if p["acquisitions"]]
        pool = used[0] if used else None
        label = pool["label"] if pool else "-"
        scope = pool.get("scope", "-") if pool else "-"
        pressure = (f"{pool['peak_in_flight']}/{pool['capacity']}"
                    + ("!" * min(pool["evictions"], 3)) if pool else "-")
        print(f"{name:<14s} {label:<28s} {scope:<7s} {pressure:<12s} "
              f"{dominant} ({diag.stall_taxonomy[dominant]})")
    print("12 copies > NVIDIA's 6 CTA-shared barriers and > AMD's per-"
          "wave 2-counter\nfiles (3 copies per SIMD queue), but < Intel's "
          "per-thread 16 SWSB tokens\nand the TPUs' 32 async contexts: "
          "contended vendors serialize (oldest-\n(M-N) rule) and the "
          "diagnosis names the exact instance — down to the\nqueue "
          "(`q2:vmcnt`) for per-queue pools.")


def wide_ops_demo(service) -> None:
    """The multi-stream payoff: 12 dependency-free op chains are ready at
    t=0, so throughput is bounded by the issue fabric alone — narrow
    4-queue parts charge `not_selected`/`pipe_busy` scheduler-contention
    cycles the single-stream model structurally could not emit, Intel's
    16 ports issue the front cleanly, and the in-order TPU VLIW stream
    never arbitrates at all."""
    from repro.launch.analysis_server import wide_ops_hlo
    print("\n--- wide ops: 12 independent chains vs the issue fabric ---")
    print(f"{'backend':<14s} {'issue model':<22s} {'not_selected':>12s} "
          f"{'pipe_busy':>10s}  top stall (native)")
    for name, diag in service.diagnose_fanout(wide_ops_hlo()).items():
        top = diag.top_stalls[0]["breakdown"]
        dominant = max(top, key=top.get)
        ip = diag.issue_pressure
        model = (f"{ip['queues']}q x {ip['width']}w "
                 f"{ip['policy'][:6]}")
        print(f"{name:<14s} {model:<22s} "
              f"{ip['not_selected_cycles']:>12,.0f} "
              f"{ip['pipe_busy_cycles']:>10,.0f}  "
              f"{dominant} ({diag.stall_taxonomy[dominant]})")
    print("Same program, three scheduler stories: NVIDIA's greedy "
          "arbiter loses to\nother-pipe work (not_selected), AMD's "
          "static SIMD rotation queues same-pipe\nchains (pipe_busy), "
          "and wide/in-order parts show neither — divergence the\n"
          "single-stream sampler could never produce.")


def occupancy_demo(service) -> None:
    """The PR-9 wave-residency story on the same storm: engaging each
    part's *native* occupancy (``DiagnoseOptions(occupancy=True)`` →
    ``Backend.with_occupancy()``) yields a different verdict per vendor —
    AMD's queue-scoped waitcnt counters let 4 wavefronts hide the copy
    latency decisively, Intel's 2 threads of hiding credit run dry
    (stalls reclassify as ``occupancy_limited``), NVIDIA's 8 warps
    *share* the device-scope named barriers so residency backfires, and
    the TPUs have no residency knob at all."""
    from repro.core import DiagnoseOptions
    from repro.launch.analysis_server import copy_storm_hlo
    print("\n--- wave occupancy: the same storm under native residency ---")
    print(f"{'backend':<14s} {'residency':<22s} {'hidden':<14s} "
          f"{'speedup':>8s}  top occupancy-limited wait")
    storm = copy_storm_hlo(12)
    plain = service.diagnose_fanout(storm)
    engaged = service.diagnose_fanout(
        storm, options=DiagnoseOptions(occupancy=True))
    for name, diag in engaged.items():
        occ = diag.occupancy
        if not occ.get("recorded"):
            print(f"{name:<14s} {'single-wave (no knob)':<22s} "
                  f"{'-':<14s} {1.0:>7.2f}x  -")
            continue
        residency = f"W={occ['waves']} ({occ['limiter']})"
        hidden = (f"{occ['hidden_fraction']:.0%} of "
                  f"{occ['hidden_cycles'] + occ['exposed_cycles']:,.0f}cyc")
        speedup = (plain[name].estimated_step_seconds
                   / diag.estimated_step_seconds)
        blame = occ.get("blame") or []
        if blame:
            top = max(blame, key=lambda b: b["exposed_cycles"])
            leak = (f"{top['consumer']} <- {top['blocker']} "
                    f"({top['exposed_cycles']:,.0f}cyc exposed)")
        else:
            leak = "(everything hidden)"
        print(f"{name:<14s} {residency:<22s} {hidden:<14s} "
              f"{speedup:>7.2f}x  {leak}")
    print("One knob, three verdicts: decisive on AMD (queue-scoped "
          "counters, free\nwaves), marginal on Intel (credit runs dry — "
          "the leak is named, line by\nline), harmful on NVIDIA (8 warps "
          "share 6 device-scope barriers) — which\nis why `raise_"
          "occupancy` advice is priced by replay per part, never\n"
          "handed out as generic prose.")


def advice_demo(service) -> None:
    """Observation 2's converse, closed by the PR-7 advisor: where access
    patterns are *irregular* (a 48-copy storm against finite, differently
    shaped sync files), the fix does NOT transfer — each vendor's top
    what-if-replayed advice is a different mutation, each priced by
    rerunning the virtual sampler against the mutated machine."""
    from repro.core import DiagnoseOptions
    from repro.launch.analysis_server import copy_storm_hlo
    print("\n--- what-if advisor: same 48-copy storm, a different fix "
          "per vendor ---")
    print(f"{'backend':<14s} {'top rule':<28s} {'mutation':<28s} "
          f"{'speedup':>8s} {'conf':>5s}")
    fanned = service.diagnose_fanout(copy_storm_hlo(48),
                                     options=DiagnoseOptions(advise=True))
    for name, diag in fanned.items():
        adv = diag.advice
        if not adv.get("recorded") or not adv.get("items"):
            print(f"{name:<14s} (no profitable mutation found)")
            continue
        top = adv["items"][0]
        mut = dict(top["mutation"])
        kind = mut.pop("kind")
        knobs = ", ".join(f"{k}={v}" for k, v in sorted(mut.items()))
        print(f"{name:<14s} {top['rule']:<28s} "
              f"{kind + ('(' + knobs + ')' if knobs else ''):<28s} "
              f"{top['modeled_speedup']:>7.2f}x "
              f"{top['confidence']:>5.2f}")
    print("Three vendors, three different top fixes for one program: "
          "batch the\nbarrier allocations where 6 CTA-shared slots thrash "
          "(NVIDIA), raise\nresidency where 4 free wavefront slots hide "
          "the waits (AMD), and re-tree\nthe serial reduction where "
          "16 SBIDs never contend and issue is the\nbottleneck (Intel) — "
          "each speedup is a replay, not a heuristic.")


if __name__ == "__main__":
    main()
