"""LEO-guided optimization loop (paper §V-B protocol, HipKittens §VI-D).

1. Compile a baseline kernel; 2. LEO diagnoses the root cause; 3. apply the
fix the diagnosis implicates; 4. re-measure.  Two demonstrations:

  * an XLA-level kernel (the LTIMES strided contraction), and
  * a Pallas kernel pair (rmsnorm baseline vs DMA-pipelined) where LEO's
    jaxpr front-end traces mem_waitcnt edges through the kernel's DMA
    semaphores — the HipKittens case-study analogue.

  PYTHONPATH=src python examples/analyze_and_optimize.py
"""
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax
import jax.numpy as jnp


def main():
    from benchmarks.harness import analyze_variant
    from benchmarks.workloads import _make_ltimes
    from repro.core import LeoSession, from_function, EdgeKind

    session = LeoSession(default_backend="tpu_v5e")

    print("=== 1. XLA kernel: LTIMES (strided 3-tensor contraction) ===")
    w = _make_ltimes("LTIMES")
    base = analyze_variant(w.baseline, "tpu_v5e")
    print(f"baseline: {base.seconds*1e3:.3f} ms  root={base.root_cause}")
    for r in base.recs[:2]:
        print(f"  LEO: [{r.action}] {r.reason[:80]}")
    opt = analyze_variant(w.optimized, "tpu_v5e")
    print(f"optimized: {opt.seconds*1e3:.3f} ms  "
          f"speedup {base.seconds/opt.seconds:.2f}x")

    print("\n=== 2. Pallas kernel: rmsnorm baseline vs DMA-pipelined ===")
    from repro.kernels.rmsnorm import rmsnorm_baseline, rmsnorm_pipelined

    x = jnp.zeros((256, 512), jnp.bfloat16)
    scale = jnp.ones((512,), jnp.float32)
    for name, fn in (("baseline", rmsnorm_baseline),
                     ("pipelined", rmsnorm_pipelined)):
        module = from_function(
            lambda a, b, f=fn: f(a, b, interpret=True), x, scale)
        an = session.analyze(module)
        wc = [e for e in an.graph.edges if e.kind is EdgeKind.MEM_WAITCNT]
        print(f"{name:>9s}: est {an.estimated_step_seconds*1e6:8.2f} us, "
              f"{len(wc)} mem_waitcnt edges "
              f"({'split-counter double buffering visible to LEO' if wc else 'no explicit DMA — implicit pipeline'})")

    print("\nLEO traces the pipelined kernel's dma_start/dma_wait semaphore "
          "pairs\n(the AMD s_waitcnt analogue) and attributes any exposed "
          "wait to the\noldest in-flight copies — §III-E, reproduced on "
          "Pallas kernels.")


if __name__ == "__main__":
    main()
