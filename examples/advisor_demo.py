"""What-if advisor walkthrough (the PR-7 subsystem, paper §VII).

Three acts, each on the async copy-storm fixture:

1. **Counterfactual replay** — clone the model, apply one declarative
   ``Mutation`` (grow a sync pool, coalesce the barrier tags, re-tree
   the serial reduction), rerun the virtual sampler, and price the
   change as a modeled speedup.  The null mutation must reproduce the
   baseline ``StallProfile`` byte-for-byte — that identity check is the
   engine's correctness anchor and runs first.
2. **Evidence -> advice** — the rule catalog reads the diagnosed
   sync/issue pressure, proposes candidate mutations in each vendor's
   native vocabulary (``bar.sync`` vs ``s_waitcnt`` vs SBIDs), and the
   advisor replays every candidate and ranks by speedup x confidence.
3. **Advisor-guided search** — the same candidates seed a what-if
   hill-climb that reaches the blind search's best mutation in a
   fraction of the replays (the GPA-style "estimate-backed optimizer"
   loop).

  PYTHONPATH=src python examples/advisor_demo.py            # full tour
  PYTHONPATH=src python examples/advisor_demo.py --smoke    # CI lane
"""
import argparse
import sys

sys.path.insert(0, "src")


def identity_act(module, backends) -> None:
    from repro.advisor import Identity, WhatIfEngine, profile_fingerprint
    print("--- act 1: the identity replay (engine correctness anchor) ---")
    for name in backends:
        from repro.core import get_backend
        engine = WhatIfEngine(module, get_backend(name))
        base = profile_fingerprint(engine.baseline())
        replay = profile_fingerprint(engine.replay(Identity()).profile)
        assert replay == base, (
            f"{name}: identity replay diverged from baseline "
            f"({replay[:12]} != {base[:12]})")
        print(f"{name:<14s} baseline sha256 {base[:16]}… == identity "
              f"replay ({engine.replays} sampler runs)")
    print()


def advice_act(module, backends) -> dict:
    from repro.advisor import Advisor
    from repro.core import get_backend
    print("--- act 2: evidence-matched, replay-priced advice ---")
    reports = {}
    for name in backends:
        reports[name] = Advisor().report(module, get_backend(name))
    print(f"{'backend':<14s} {'rules':>5s} {'replays':>7s}  ranked advice "
          f"(speedup x confidence = score)")
    for name, rep in reports.items():
        if not rep.advice:
            print(f"{name:<14s} {rep.rules_matched:>5d} "
                  f"{rep.candidates_replayed:>7d}  (nothing profitable)")
            continue
        for i, a in enumerate(rep.advice):
            lead = (f"{name:<14s} {rep.rules_matched:>5d} "
                    f"{rep.candidates_replayed:>7d}" if i == 0
                    else " " * 28)
            print(f"{lead}  #{i + 1} {a.rule}: "
                  f"{a.modeled_speedup:.3f}x x {a.confidence:.2f} "
                  f"= {a.score:.3f}")
        print(f"{'':<28s}  -> {rep.top.description}")
    assert any(rep.top and rep.top.modeled_speedup > 1.0
               for rep in reports.values()), \
        "no backend produced profitable advice on the storm"
    print()
    return reports


def search_act(hlo_text, backends, *, budget, seed) -> None:
    from repro.launch.hillclimb import run_whatif
    print("--- act 3: advisor-guided vs blind what-if search ---")
    for name in backends:
        out = run_whatif(name, mode="both", budget=budget, seed=seed,
                         hlo_text=hlo_text)
        blind, guided = out["blind"], out["guided"]
        assert guided["best_speedup"] >= blind["best_speedup"] - 1e-9, \
            f"{name}: guided search lost to blind"
        print(f"{name:<14s} blind best {blind['best_speedup']:.3f}x in "
              f"{blind['evaluations']} replays "
              f"(found at #{blind['evaluations_to_best']}); guided "
              f"matched it in {guided['evaluations']}")
    print()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="trimmed CI lane: one GPU vendor per act, a "
                         "12-copy storm, and a small search budget")
    ap.add_argument("--copies", type=int, default=None,
                    help="async copies in the storm fixture "
                         "(default: 48 full / 12 smoke)")
    ap.add_argument("--seed", type=int, default=0,
                    help="search-shuffle seed (act 3 reproducibility)")
    args = ap.parse_args(argv)

    from repro.core import parse_hlo
    from repro.launch.analysis_server import copy_storm_hlo

    copies = args.copies or (12 if args.smoke else 48)
    backends = ("nvidia_gh200",) if args.smoke else \
        ("nvidia_gh200", "amd_mi300a", "intel_pvc")
    budget = 8 if args.smoke else 16
    hlo = copy_storm_hlo(copies)
    module = parse_hlo(hlo)
    print(f"fixture: {copies}-copy async storm feeding one serial "
          f"reduction; backends: {', '.join(backends)}\n")

    identity_act(module, backends)
    advice_act(module, backends)
    search_act(hlo, backends, budget=budget, seed=args.seed)
    print("advisor demo OK: identity replay byte-identical, advice "
          "profitable and\nreplay-priced, guided search no worse than "
          "blind at the same budget.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
