"""Advice-to-HLO rewrite walkthrough (the PR-8 subsystem).

The diagnose -> advise -> transform -> verify loop, closed, on the
48-copy async storm — three acts:

1. **Round-trip + identity** — the printer's guarantee in action:
   ``parse(emit(m)) == m``, and the identity rewrite's re-analysis is
   byte-identical to the baseline profile (the fingerprint anchor every
   other rewrite is judged against).
2. **A different applied rewrite per GPU vendor** — the same storm
   lowers to *different* HLO text per backend: NVIDIA-class batches
   barrier tags (``sync_tag`` coalescing), AMD-class falls back from
   its hardware-only pool advice to software tag coalescing at the
   waitcnt group size, Intel-class rebalances the serial reduction into
   a log-depth tree.  Each rewrite ships a structural-equivalence
   certificate.
3. **Predicted vs realized** — every rewritten text is re-analyzed
   through the full pipeline; the realized speedup must deliver >= 80%
   of what the advisor's what-if replay predicted (it typically
   delivers 100%+).

  PYTHONPATH=src python examples/rewrite_demo.py            # full tour
  PYTHONPATH=src python examples/rewrite_demo.py --smoke    # CI lane
"""
import argparse
import sys

sys.path.insert(0, "src")


def roundtrip_act(hlo, module, backends) -> None:
    from repro.advisor import Identity, WhatIfEngine, profile_fingerprint
    from repro.core import get_backend, parse_hlo
    from repro.core.sampler import VirtualSampler
    from repro.rewrite import apply_rewrite, emit_hlo
    print("--- act 1: round-trip + identity fingerprints ---")
    assert parse_hlo(emit_hlo(module)) == module, \
        "parse(emit(m)) != m on the storm fixture"
    print(f"parse(emit(m)) == m on {sum(1 for _ in module.all_instructions())}"
          f"-instruction storm module")
    identity = apply_rewrite(module, Identity())
    assert identity.hlo_text == hlo, "identity rewrite changed the text"
    for name in backends:
        b = get_backend(name)
        base = profile_fingerprint(
            WhatIfEngine(module, b).baseline())
        re_analyzed = profile_fingerprint(
            VirtualSampler(identity.module, b.hw, sync=b.sync).run())
        assert re_analyzed == base, f"{name}: identity re-analysis diverged"
        print(f"{name:<14s} identity rewrite re-analysis sha256 "
              f"{base[:16]}… == baseline")
    print()


def divergence_act(hlo, backends, *, top_k) -> dict:
    from repro.rewrite import RewriteLoop
    print("--- act 2: a different applied rewrite per GPU vendor ---")
    reports = {}
    for name in backends:
        reports[name] = RewriteLoop(top_k=top_k).run(hlo, name)
    print(f"{'backend':<14s} {'source':<14s} applied rewrite "
          f"(certificate)")
    signatures = set()
    for name, rep in reports.items():
        best = rep.best
        if best is None:
            print(f"{name:<14s} (no applicable rewrite)")
            continue
        mut = best.mutation
        bits = ", ".join(f"{k}={v}" for k, v in mut.items()
                         if k not in ("kind", "parts") and v is not None)
        sig = (mut.get("kind"), bits)
        signatures.add(sig)
        print(f"{name:<14s} {best.source:<14s} {mut.get('kind')}"
              f"({bits}) [{best.certificate['declared']}]")
        if best.refusal:
            print(f"{'':<14s} {'':<14s} (original advice refused: "
                  f"{best.refusal['code']} — "
                  f"{best.refusal['mutation_kind']})")
    if len(reports) >= 3:
        assert len(signatures) >= 3, (
            f"expected a distinct rewrite per GPU vendor, "
            f"got {signatures}")
    print()
    return reports


def verify_act(reports) -> None:
    print("--- act 3: predicted vs realized (full re-analysis) ---")
    print(f"{'backend':<14s} {'predicted':>9s} {'realized':>9s} "
          f"{'fraction':>8s}")
    for name, rep in reports.items():
        for o in rep.outcomes:
            print(f"{name:<14s} {o.predicted_speedup:>8.3f}x "
                  f"{o.realized_speedup:>8.3f}x "
                  f"{o.realized_fraction:>7.0%}")
            assert o.realized_fraction >= 0.8, (
                f"{name}/{o.rule}: realized only "
                f"{o.realized_fraction:.0%} of the predicted gain")
    print()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="trimmed CI lane: two GPU vendors, a 12-copy "
                         "storm")
    ap.add_argument("--copies", type=int, default=None,
                    help="async copies in the storm fixture "
                         "(default: 48 full / 12 smoke)")
    ap.add_argument("--top-k", type=int, default=2,
                    help="advice items the loop lowers per backend")
    args = ap.parse_args(argv)

    from repro.core import parse_hlo
    from repro.launch.analysis_server import copy_storm_hlo

    copies = args.copies or (12 if args.smoke else 48)
    backends = ("nvidia_gh200", "intel_pvc") if args.smoke else \
        ("nvidia_gh200", "amd_mi300a", "intel_pvc")
    hlo = copy_storm_hlo(copies)
    module = parse_hlo(hlo)
    print(f"fixture: {copies}-copy async storm feeding one serial "
          f"reduction; backends: {', '.join(backends)}\n")

    roundtrip_act(hlo, module, backends)
    reports = divergence_act(hlo, backends, top_k=args.top_k)
    verify_act(reports)
    print("rewrite demo OK: text round-trips, identity is byte-stable, "
          "each vendor\ngets its own equivalence-checked rewrite, and "
          "re-analysis realizes >= 80%\nof every predicted speedup.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
