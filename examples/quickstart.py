"""Quickstart: train a reduced model end-to-end on CPU, checkpoint it, and
run LEO root-cause analysis on the compiled train step.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")


def main():
    from repro.launch.train import main as train_main

    result = train_main([
        "--arch", "qwen2-0.5b", "--smoke",
        "--steps", "100", "--batch", "8", "--seq", "64",
        "--checkpoint-dir", "/tmp/repro_quickstart_ckpt",
        "--checkpoint-every", "50",
    ])
    print(f"\nloss: {result['first_loss']:.3f} -> {result['final_loss']:.3f}")
    assert result["final_loss"] < result["first_loss"], "training regressed"

    # LEO on the compiled step: where would this program stall on a v5e?
    import jax
    from repro.core import LeoService
    from repro.configs import get_config, smoke_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import build

    mesh = make_host_mesh()
    with mesh:
        cfg, state, _, pipeline, step_fn = build(
            "qwen2-0.5b", True, 8, 64, mesh)
        compiled = step_fn.lower(state, pipeline.device_batch(0)).compile()
    service = LeoService()
    an = service.analyze(compiled.as_text(), backend="tpu_v5e")
    print("\n=== LEO analysis of the compiled train step ===")
    print(an.summary())
    print("per-pass timing: " + ", ".join(
        f"{name}={secs*1e3:.1f}ms" for name, secs in an.pass_seconds.items()))
    if an.chains:
        print("\ntop dependency chain:")
        print(an.chains[0].describe())

    # the serializable Diagnosis: what a queue/agent consumer receives
    diag = service.diagnose(compiled.as_text(), backend="tpu_v5e")
    payload = diag.to_json()
    print(f"\nDiagnosis payload: {len(payload)} bytes of JSON "
          f"(schema v{diag.schema_version}); markdown preview:\n")
    print("\n".join(diag.to_markdown().splitlines()[:8]))


if __name__ == "__main__":
    main()
