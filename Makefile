# Test lanes.
#
#   make tier1        — the full tier-1 verify command (what CI and the
#                       release gate run; includes the ~80s substrate
#                       train/serve loops)
#   make quick        — tier-1 minus tests marked `slow` (substrate
#                       end-to-end drivers); the faster inner-loop lane
#   make bench        — the paper-table benchmark suite (not a test gate)
#   make serve-smoke  — the serving entry points end-to-end: continuous-
#                       batching decode demo (mid-stream admission) plus
#                       the queue-driven analysis server (cold run, then a
#                       second process against the warm disk cache)
#   make sync-smoke   — the SyncModel lane: scoreboard semantics/property
#                       tests plus the per-backend divergence goldens
#                       (resource-pressure snapshots incl. the copy-storm
#                       cross-vendor blame divergence and the wide-ops
#                       issue-contention divergence)
#   make bench-smoke  — the perf-trajectory lane: trimmed deterministic
#                       benchmark subset; emits BENCH_pr10.json, appends
#                       the run's geomeans to the committed
#                       benchmarks/trajectory.json, and fails on >10%
#                       geomean-step-time regression vs the committed
#                       benchmarks/baseline.json, on the advisor
#                       overhead gate (advise=True < 3x the plain
#                       pipeline per GPU backend), on the rewrite
#                       overhead gate (rewrite=True < 4x), on the
#                       occupancy overhead gate (occupancy=True < 5x),
#                       or on the serving-throughput gate (--workers 4
#                       must sustain >= 2x the --workers 1 RPS on a
#                       parse-heavy stream; ratio enforced on >= 4-CPU
#                       machines, clean SIGTERM drains everywhere)
#   make advisor-smoke— the what-if advisor lane: the advisor demo's
#                       three acts (identity replay, replay-priced
#                       advice, guided-vs-blind search) plus the advisor
#                       unit tests and the advice-divergence golden
#                       (also under the CI golden-drift gate)
#   make rewrite-smoke— the advice-to-HLO rewrite lane: the rewrite
#                       demo's three acts (printer round-trip + identity
#                       fingerprints, per-vendor applied rewrites with
#                       equivalence certificates, predicted-vs-realized
#                       >= 80%) plus the rewrite unit tests and the
#                       rewrite-divergence golden (also under the CI
#                       golden-drift gate)
#   make occupancy-smoke — the wave-residency lane: occupancy model +
#                       sampler unit tests (W=1 byte-parity anchor,
#                       hidden/exposed conservation) plus the
#                       occupancy-divergence golden — the same storm
#                       must verdict decisive/marginal/harmful on
#                       AMD/Intel/NVIDIA (also under the CI
#                       golden-drift gate)
#   make net-smoke    — the networked-serving lane: start `--serve` on an
#                       ephemeral port with a 1-slot/1-deep queue, run the
#                       client demo against it (which must observe a 429
#                       shed and retry through it), grep /metrics for
#                       served traffic, then SIGTERM and gate on a clean
#                       drain; a second block reruns the demo against a
#                       `--workers 2` pre-forked pool, SIGKILLs one
#                       worker mid-run (every request must still
#                       complete via the client's retry path), gates on
#                       the supervisor respawning it, and on a rolling
#                       SIGTERM drain exiting 0

PY := python
PYTEST_FLAGS := -x -q

export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: tier1 quick bench serve-smoke sync-smoke bench-smoke net-smoke \
	advisor-smoke rewrite-smoke occupancy-smoke

tier1:
	$(PY) -m pytest $(PYTEST_FLAGS)

quick:
	$(PY) -m pytest $(PYTEST_FLAGS) -m "not slow"

bench:
	$(PY) -m benchmarks.run

bench-smoke:
	$(PY) -m benchmarks.bench_smoke --out BENCH_pr10.json

advisor-smoke:
	$(PY) examples/advisor_demo.py --smoke
	$(PY) -m pytest $(PYTEST_FLAGS) tests/test_advisor.py \
		tests/test_advisor_divergence.py

rewrite-smoke:
	$(PY) examples/rewrite_demo.py --smoke
	$(PY) -m pytest $(PYTEST_FLAGS) tests/test_rewrite.py \
		tests/test_rewrite_divergence.py

occupancy-smoke:
	$(PY) -m pytest $(PYTEST_FLAGS) tests/test_issuemodel.py \
		tests/test_occupancy_divergence.py

sync-smoke:
	$(PY) -m pytest $(PYTEST_FLAGS) tests/test_syncmodel.py \
		tests/test_issuemodel.py tests/test_backend_divergence.py

# The decode demo is chained into the same && sequence as the analysis-
# server runs: if it fails, the whole recipe's exit status carries the
# failure (it used to sit on its own recipe line, where an intervening
# `make -k` / prefix edit could silently drop its status before the
# cache block ran).
serve-smoke:
	CACHE=$$(mktemp -d) && \
	$(PY) examples/serve_demo.py && \
	$(PY) -m repro.launch.analysis_server --smoke --requests 8 --slots 3 \
		--backends all --cache-dir $$CACHE && \
	$(PY) -m repro.launch.analysis_server --smoke --requests 8 --slots 3 \
		--backends all --cache-dir $$CACHE; \
	status=$$?; rm -rf $$CACHE; exit $$status

# Server under a deliberately tiny admission config (1 slot, 1-deep
# queue) so the demo's burst MUST shed; the demo exits nonzero if no 429
# was observed, the grep gates on /metrics reporting served traffic, and
# `wait` after SIGTERM gates on the drain path exiting 0.
net-smoke:
	WORK=$$(mktemp -d); \
	$(PY) -m repro.launch.analysis_server --serve 0 --slots 1 \
		--max-queue 1 --cache-dir $$WORK/cache \
		--port-file $$WORK/port & \
	SRV=$$!; \
	for i in $$(seq 1 150); do [ -s $$WORK/port ] && break; \
		sleep 0.1; done; \
	if [ ! -s $$WORK/port ]; then echo "server never bound"; \
		kill $$SRV 2>/dev/null; rm -rf $$WORK; exit 1; fi; \
	$(PY) examples/analysis_client_demo.py --port $$(cat $$WORK/port) \
		--expect-shed --metrics-out $$WORK/metrics.prom; \
	status=$$?; \
	if [ $$status -eq 0 ]; then \
		grep -Eq 'leo_requests_total\{[^}]*\} [1-9]' $$WORK/metrics.prom \
		|| { echo "no served traffic in /metrics"; status=1; }; \
	fi; \
	kill -TERM $$SRV; \
	wait $$SRV || { echo "server did not drain cleanly"; status=1; }; \
	rm -rf $$WORK; exit $$status
	@echo "-- pool lane: --workers 2, SIGKILL one worker mid-run --"
	WORK=$$(mktemp -d); status=0; \
	$(PY) -m repro.launch.analysis_server --serve 0 --workers 2 \
		--slots 2 --max-queue 16 --cache-dir $$WORK/cache \
		--port-file $$WORK/port & \
	SRV=$$!; \
	for i in $$(seq 1 300); do [ -s $$WORK/port ] && break; \
		sleep 0.1; done; \
	if [ ! -s $$WORK/port ]; then echo "pool never bound"; \
		kill $$SRV 2>/dev/null; rm -rf $$WORK; exit 1; fi; \
	$(PY) examples/analysis_client_demo.py --port $$(cat $$WORK/port) \
		--rounds 6 & \
	DEMO=$$!; \
	sleep 1; \
	WPID=$$(pgrep -P $$SRV | head -1); \
	if [ -n "$$WPID" ]; then kill -9 $$WPID; \
		else echo "no worker to kill"; status=1; fi; \
	wait $$DEMO \
		|| { echo "client saw errors across the worker kill"; status=1; }; \
	for i in $$(seq 1 150); do \
		[ $$(pgrep -P $$SRV | wc -l) -ge 2 ] && break; sleep 0.1; done; \
	[ $$(pgrep -P $$SRV | wc -l) -ge 2 ] \
		|| { echo "supervisor did not respawn the killed worker"; \
		status=1; }; \
	kill -TERM $$SRV; \
	wait $$SRV || { echo "pool did not drain cleanly"; status=1; }; \
	rm -rf $$WORK; exit $$status
