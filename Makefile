# Test lanes.
#
#   make tier1        — the full tier-1 verify command (what CI and the
#                       release gate run; includes the ~80s substrate
#                       train/serve loops)
#   make quick        — tier-1 minus tests marked `slow` (substrate
#                       end-to-end drivers); the faster inner-loop lane
#   make bench        — the paper-table benchmark suite (not a test gate)
#   make serve-smoke  — the serving entry points end-to-end: continuous-
#                       batching decode demo (mid-stream admission) plus
#                       the queue-driven analysis server (cold run, then a
#                       second process against the warm disk cache)
#   make sync-smoke   — the SyncModel lane: scoreboard semantics/property
#                       tests plus the per-backend divergence goldens
#                       (resource-pressure snapshots incl. the copy-storm
#                       cross-vendor blame divergence and the wide-ops
#                       issue-contention divergence)
#   make bench-smoke  — the perf-trajectory lane: trimmed deterministic
#                       benchmark subset; emits BENCH_pr4.json and fails
#                       on >10% geomean-step-time regression vs the
#                       committed benchmarks/baseline.json

PY := python
PYTEST_FLAGS := -x -q

export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: tier1 quick bench serve-smoke sync-smoke bench-smoke

tier1:
	$(PY) -m pytest $(PYTEST_FLAGS)

quick:
	$(PY) -m pytest $(PYTEST_FLAGS) -m "not slow"

bench:
	$(PY) -m benchmarks.run

bench-smoke:
	$(PY) -m benchmarks.bench_smoke --output BENCH_pr4.json

sync-smoke:
	$(PY) -m pytest $(PYTEST_FLAGS) tests/test_syncmodel.py \
		tests/test_issuemodel.py tests/test_backend_divergence.py

# The decode demo is chained into the same && sequence as the analysis-
# server runs: if it fails, the whole recipe's exit status carries the
# failure (it used to sit on its own recipe line, where an intervening
# `make -k` / prefix edit could silently drop its status before the
# cache block ran).
serve-smoke:
	CACHE=$$(mktemp -d) && \
	$(PY) examples/serve_demo.py && \
	$(PY) -m repro.launch.analysis_server --smoke --requests 8 --slots 3 \
		--backends all --cache-dir $$CACHE && \
	$(PY) -m repro.launch.analysis_server --smoke --requests 8 --slots 3 \
		--backends all --cache-dir $$CACHE; \
	status=$$?; rm -rf $$CACHE; exit $$status
