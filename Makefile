# Test lanes.
#
#   make tier1   — the full tier-1 verify command (what CI and the release
#                  gate run; includes the ~80s substrate train/serve loops)
#   make quick   — tier-1 minus tests marked `slow` (substrate end-to-end
#                  drivers); the faster inner-loop lane
#   make bench   — the paper-table benchmark suite (not a test gate)

PY := python
PYTEST_FLAGS := -x -q

export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: tier1 quick bench

tier1:
	$(PY) -m pytest $(PYTEST_FLAGS)

quick:
	$(PY) -m pytest $(PYTEST_FLAGS) -m "not slow"

bench:
	$(PY) -m benchmarks.run
