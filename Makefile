# Test lanes.
#
#   make tier1        — the full tier-1 verify command (what CI and the
#                       release gate run; includes the ~80s substrate
#                       train/serve loops)
#   make quick        — tier-1 minus tests marked `slow` (substrate
#                       end-to-end drivers); the faster inner-loop lane
#   make bench        — the paper-table benchmark suite (not a test gate)
#   make serve-smoke  — the serving entry points end-to-end: continuous-
#                       batching decode demo (mid-stream admission) plus
#                       the queue-driven analysis server (cold run, then a
#                       second process against the warm disk cache)
#   make sync-smoke   — the SyncModel lane: scoreboard semantics/property
#                       tests plus the per-backend divergence goldens
#                       (resource-pressure snapshots incl. the copy-storm
#                       cross-vendor blame divergence)

PY := python
PYTEST_FLAGS := -x -q

export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: tier1 quick bench serve-smoke sync-smoke

tier1:
	$(PY) -m pytest $(PYTEST_FLAGS)

quick:
	$(PY) -m pytest $(PYTEST_FLAGS) -m "not slow"

bench:
	$(PY) -m benchmarks.run

sync-smoke:
	$(PY) -m pytest $(PYTEST_FLAGS) tests/test_syncmodel.py \
		tests/test_backend_divergence.py

serve-smoke:
	$(PY) examples/serve_demo.py
	CACHE=$$(mktemp -d) && \
	$(PY) -m repro.launch.analysis_server --smoke --requests 8 --slots 3 \
		--backends all --cache-dir $$CACHE && \
	$(PY) -m repro.launch.analysis_server --smoke --requests 8 --slots 3 \
		--backends all --cache-dir $$CACHE; \
	status=$$?; rm -rf $$CACHE; exit $$status
