"""Tests for ``repro.rewrite`` — the advice-to-HLO rewrite engine that
closes the diagnose -> advise -> transform -> verify loop (PR-8 ISSUE):

* the printer round-trips: ``parse_hlo(emit_hlo(m)) == m`` on every
  golden fixture HLO and (hypothesis property) on generated storm
  modules of arbitrary width;
* the identity rewrite re-emits byte-identical text and its re-analysis
  reproduces baseline profile fingerprints on every existing golden lane;
* each program rewriter ships a structural-equivalence certificate whose
  declared kind survives an adversarial re-check (hypothesis property),
  and refuses hardware-only mutations with a *typed* ``NotApplicable``;
* ``Advisor.compose`` prices a stacked mutation with ONE joint replay;
* ``RewriteLoop`` realizes >= 80% of every predicted speedup through a
  full re-analysis of the rewritten text, falls back from hardware-only
  advice to the rule's program-rewritable candidate, and lands in
  Diagnosis schema v5 via ``LeoService.diagnose(rewrite=True)``.
"""
import json

import pytest

from conftest import ASYNC_HLO, COPYSTORM_HLO
from repro.advisor import (
    Advisor,
    CoalesceSyncTags,
    Compose,
    Identity,
    PipelineAsyncChain,
    RelaxSyncEdge,
    ResizePool,
    ScaleLatency,
    SetIssue,
    TreeReduceChain,
    WhatIfEngine,
    mutation_from_dict,
    profile_fingerprint,
)
from repro.core import LeoService, get_backend, parse_hlo
from repro.core.sampler import VirtualSampler
from repro.rewrite import (
    EquivalenceViolation,
    NotApplicable,
    REWRITABLE_KINDS,
    RewriteLoop,
    apply_rewrite,
    emit_hlo,
    is_rewritable,
    rewrites_section,
)
from repro.rewrite.rewriters import check_equivalence

GOLDEN_BACKENDS = ("amd_mi300a", "intel_pvc", "nvidia_gh200",
                   "tpu_v4", "tpu_v5e", "tpu_v5p")

GPU_VENDOR_BACKENDS = ("nvidia_gh200", "amd_mi300a", "intel_pvc")


def _storm_hlo(n: int) -> str:
    from repro.launch.analysis_server import copy_storm_hlo
    return copy_storm_hlo(n)


def _fixture_texts():
    from repro.launch.analysis_server import demo_hlo, wide_ops_hlo
    return {
        "async": ASYNC_HLO,
        "copystorm8": COPYSTORM_HLO,
        "copystorm48": _storm_hlo(48),
        "wide_ops": wide_ops_hlo(),
        "demo": demo_hlo(),
    }


FIXTURES = _fixture_texts()


# --------------------------------------------------------------------------
# Printer round-trip.
# --------------------------------------------------------------------------

class TestPrinterRoundTrip:
    @pytest.mark.parametrize("name", sorted(FIXTURES))
    def test_parse_emit_parse_fixed_point(self, name):
        module = parse_hlo(FIXTURES[name])
        text = emit_hlo(module)
        assert parse_hlo(text) == module
        # and the emitted text is itself a fixed point
        assert emit_hlo(parse_hlo(text)) == text

    @pytest.mark.parametrize("name", sorted(FIXTURES))
    def test_round_trip_with_hints(self, name):
        hints = {"trip_counts": {"body.1": 7}} if name == "async" else \
            {"force_serial": True}
        module = parse_hlo(FIXTURES[name], hints=hints)
        assert parse_hlo(emit_hlo(module), hints=hints) == module

    def test_round_trip_preserves_fingerprints_everywhere(self):
        for name, text in FIXTURES.items():
            module = parse_hlo(text)
            reparsed = parse_hlo(emit_hlo(module))
            for backend in GOLDEN_BACKENDS:
                b = get_backend(backend)
                assert profile_fingerprint(
                    VirtualSampler(reparsed, b.hw, sync=b.sync).run()) == \
                    profile_fingerprint(
                        VirtualSampler(module, b.hw, sync=b.sync).run()), \
                    f"{name}/{backend}: round-trip changed the profile"

    def test_jaxpr_source_refused(self):
        import jax.numpy as jnp
        from repro.core.jaxpr_frontend import from_function
        from repro.rewrite import PrinterError

        def f(x):
            return jnp.sin(x).sum()
        module = from_function(f, jnp.ones((4, 4)))
        with pytest.raises(PrinterError):
            emit_hlo(module)


# --------------------------------------------------------------------------
# Identity rewrite: byte + fingerprint stability on every golden lane.
# --------------------------------------------------------------------------

class TestIdentityRewrite:
    @pytest.mark.parametrize("name", sorted(FIXTURES))
    def test_identity_is_byte_and_fingerprint_stable(self, name):
        module = parse_hlo(FIXTURES[name])
        result = apply_rewrite(module, Identity())
        assert result.changed is False
        assert result.hlo_text == emit_hlo(module)
        assert result.certificate.declared == "identical"
        for backend in GOLDEN_BACKENDS:
            b = get_backend(backend)
            assert profile_fingerprint(
                VirtualSampler(result.module, b.hw, sync=b.sync).run()) == \
                profile_fingerprint(
                    VirtualSampler(module, b.hw, sync=b.sync).run())


# --------------------------------------------------------------------------
# Rewriters: certificates + typed refusals.
# --------------------------------------------------------------------------

class TestRewriters:
    def test_coalesce_sync_tags_certificate(self):
        module = parse_hlo(_storm_hlo(12))
        result = apply_rewrite(module, CoalesceSyncTags(group=4))
        assert result.changed is True
        cert = result.certificate
        assert cert.declared == "sync_retag"
        assert 'sync_tag="' in result.hlo_text
        # the rewritten text is the truth: re-parsing it reproduces the
        # module the result carries
        assert parse_hlo(result.hlo_text) == result.module

    def test_coalesce_group_one_is_noop_refusal(self):
        module = parse_hlo(_storm_hlo(8))
        with pytest.raises(NotApplicable) as ei:
            apply_rewrite(module, CoalesceSyncTags(group=1))
        assert ei.value.code == "noop"
        assert ei.value.mutation_kind == "CoalesceSyncTags"

    def test_tree_reduce_certificate_and_realization(self):
        module = parse_hlo(_storm_hlo(16))
        result = apply_rewrite(module, TreeReduceChain(min_length=4))
        assert result.certificate.declared == "rebalance"
        assert result.certificate.rewired
        b = get_backend("intel_pvc")
        base = VirtualSampler(module, b.hw, sync=b.sync).run()
        rewritten = VirtualSampler(result.module, b.hw,
                                   sync=b.sync).run()
        assert rewritten.makespan_cycles < base.makespan_cycles

    def test_pipeline_async_chain(self):
        module = parse_hlo(_storm_hlo(16))
        try:
            result = apply_rewrite(module, PipelineAsyncChain(window=2))
        except NotApplicable as e:
            assert e.code in ("noop", "unsupported")
        else:
            assert result.certificate.declared in ("reorder", "identical")
            assert parse_hlo(result.hlo_text) == result.module

    @pytest.mark.parametrize("mutation", [
        ResizePool(pool="barrier_slot", capacity=12),
        SetIssue(policy="single"),
        ScaleLatency(hw_field="hbm_bw", factor=2.0),
    ])
    def test_hardware_mutations_typed_refusal(self, mutation):
        module = parse_hlo(COPYSTORM_HLO)
        assert not is_rewritable(mutation)
        with pytest.raises(NotApplicable) as ei:
            apply_rewrite(module, mutation)
        assert ei.value.code == "hardware_mutation"
        d = ei.value.to_dict()
        assert d["code"] == "hardware_mutation"
        assert d["mutation_kind"] == mutation.kind

    def test_relax_sync_edge_unsupported(self):
        module = parse_hlo(COPYSTORM_HLO)
        with pytest.raises(NotApplicable) as ei:
            apply_rewrite(module, RelaxSyncEdge(match="copy-done"))
        assert ei.value.code == "unsupported"

    def test_mutation_dict_accepted(self):
        module = parse_hlo(_storm_hlo(12))
        via_obj = apply_rewrite(module, CoalesceSyncTags(group=4))
        via_dict = apply_rewrite(
            module, {"kind": "CoalesceSyncTags", "group": 4})
        assert via_obj.hlo_text == via_dict.hlo_text
        assert via_obj.to_dict()["hlo_sha256"] == \
            via_dict.to_dict()["hlo_sha256"]

    def test_equivalence_check_rejects_tampering(self):
        module = parse_hlo(_storm_hlo(8))
        other = parse_hlo(_storm_hlo(12))
        with pytest.raises(EquivalenceViolation):
            check_equivalence(module, other,
                              mutation_kind="CoalesceSyncTags",
                              declared="sync_retag")

    def test_compose_rewrite_stacks_certificates(self):
        module = parse_hlo(_storm_hlo(48))
        stacked = Compose(parts=(CoalesceSyncTags(group=8),
                                 TreeReduceChain(min_length=4)))
        assert is_rewritable(stacked)
        result = apply_rewrite(module, stacked)
        cert = result.certificate
        assert cert.declared == "stacked"
        assert [p.declared for p in cert.parts] == \
            ["sync_retag", "rebalance"]
        assert [p["declared"] for p in cert.to_dict()["parts"]] == \
            ["sync_retag", "rebalance"]
        assert parse_hlo(result.hlo_text) == result.module

    def test_compose_with_hardware_part_refused(self):
        module = parse_hlo(COPYSTORM_HLO)
        stacked = Compose(parts=(CoalesceSyncTags(group=4),
                                 ResizePool(pool="barrier_slot",
                                            capacity=12)))
        assert not is_rewritable(stacked)
        with pytest.raises(NotApplicable) as ei:
            apply_rewrite(module, stacked)
        assert ei.value.code == "hardware_mutation"


# --------------------------------------------------------------------------
# Compose mutation + Advisor.compose.
# --------------------------------------------------------------------------

class TestCompose:
    def test_compose_round_trips_through_dict(self):
        stacked = Compose(parts=(CoalesceSyncTags(group=8),
                                 TreeReduceChain(min_length=4)))
        d = stacked.to_dict()
        assert d["kind"] == "Compose"
        back = mutation_from_dict(d)
        assert back == stacked
        assert back.describe().startswith("stack: ")

    def test_compose_replay_equals_sequential_application(self):
        module = parse_hlo(_storm_hlo(48))
        b = get_backend("nvidia_gh200")
        stacked = Compose(parts=(CoalesceSyncTags(group=8),
                                 TreeReduceChain(min_length=4)))
        joint = WhatIfEngine(module, b).replay(stacked)
        seq_module = TreeReduceChain(min_length=4).apply_module(
            CoalesceSyncTags(group=8).apply_module(module))
        seq = VirtualSampler(seq_module, b.hw, sync=b.sync).run()
        assert joint.profile.makespan_cycles == seq.makespan_cycles

    def test_advisor_compose_one_joint_replay(self):
        module = parse_hlo(_storm_hlo(48))
        b = get_backend("nvidia_gh200")
        profile = VirtualSampler(module, b.hw, sync=b.sync).run()
        advisor = Advisor()
        report = advisor.report(module, b, profile=profile)
        before = report.candidates_replayed
        composed = advisor.compose(module, b, top_k=2, report=report,
                                   profile=profile)
        # exactly ONE extra replay priced the whole stack
        assert composed.candidates_replayed == before + 1
        stacked = [a for a in composed.advice
                   if a.mutation.get("kind") == "Compose"]
        assert len(stacked) == 1
        advice = stacked[0]
        assert advice.rule.startswith("compose(")
        assert advice.modeled_speedup > 1.0
        # input report untouched
        assert all(a.mutation.get("kind") != "Compose"
                   for a in report.advice)

    def test_advisor_compose_fewer_than_two_is_identity(self):
        module = parse_hlo(_storm_hlo(48))
        b = get_backend("nvidia_gh200")
        advisor = Advisor()
        report = advisor.report(module, b)
        assert advisor.compose(module, b, top_k=1,
                               report=report) is report

    def test_advisor_compose_explicit_mutations(self):
        module = parse_hlo(_storm_hlo(48))
        b = get_backend("nvidia_gh200")
        advisor = Advisor()
        report = advisor.report(module, b)
        composed = advisor.compose(
            module, b, report=report,
            mutations=[CoalesceSyncTags(group=8),
                       TreeReduceChain(min_length=4)])
        stacked = [a for a in composed.advice
                   if a.mutation.get("kind") == "Compose"]
        assert len(stacked) == 1
        parts = stacked[0].mutation["parts"]
        assert [p["kind"] for p in parts] == \
            ["CoalesceSyncTags", "TreeReduceChain"]


# --------------------------------------------------------------------------
# RewriteLoop: predicted vs realized, fallback, stacking.
# --------------------------------------------------------------------------

class TestRewriteLoop:
    def test_loop_realizes_predictions_per_vendor(self):
        hlo = _storm_hlo(48)
        for backend in GPU_VENDOR_BACKENDS:
            rep = RewriteLoop(top_k=2).run(hlo, backend)
            assert rep.outcomes, f"{backend}: loop applied nothing"
            for o in rep.outcomes:
                assert o.realized_fraction >= 0.8, \
                    (backend, o.rule, o.realized_fraction)
                assert o.certificate["declared"] in (
                    "identical", "sync_retag", "reorder", "rebalance",
                    "stacked")

    def test_amd_falls_back_from_hardware_advice(self):
        rep = RewriteLoop(top_k=2).run(_storm_hlo(48), "amd_mi300a")
        fallbacks = [o for o in rep.outcomes
                     if o.source == "rule_fallback"]
        assert fallbacks
        fb = fallbacks[0]
        assert fb.refusal is not None
        assert fb.refusal["code"] == "hardware_mutation"
        assert fb.mutation["kind"] in REWRITABLE_KINDS
        # hardware-only advice the loop could not lower is reported
        assert rep.skipped or fallbacks

    def test_vendor_divergence_distinct_rewrites(self):
        best = {}
        for backend in GPU_VENDOR_BACKENDS:
            rep = RewriteLoop(top_k=2).run(_storm_hlo(48), backend)
            b = rep.best
            mut = dict(b.mutation)
            best[backend] = (mut.pop("kind"), tuple(sorted(
                (k, v) for k, v in mut.items() if v is not None)))
        assert len(set(best.values())) == 3, best

    def test_loop_report_round_trips_to_dict(self):
        rep = RewriteLoop(top_k=2).run(_storm_hlo(12), "nvidia_gh200")
        d = rep.to_dict()
        assert d["backend"] == "nvidia_gh200"
        assert d["baseline_makespan_cycles"] == rep.baseline_makespan_cycles
        assert len(d["outcomes"]) == len(rep.outcomes)
        json.dumps(d)    # wire-safe

    def test_stacked_outcome_when_two_rewrites_apply(self):
        # hand the loop a report with two distinct program rewrites: the
        # loop must price + apply the Compose stack as a third outcome
        from repro.advisor.advisor import Advice, AdvisorReport
        hlo = _storm_hlo(48)
        module = parse_hlo(hlo)
        b = get_backend("nvidia_gh200")
        profile = VirtualSampler(module, b.hw, sync=b.sync).run()
        engine = WhatIfEngine(module, b)
        engine._baseline = profile
        advice = []
        for rule, mutation in (
                ("batch_sync_allocations", CoalesceSyncTags(group=8)),
                ("expose_ilp_tree_reduce", TreeReduceChain(min_length=4))):
            priced = engine.replay(mutation)
            advice.append(Advice(
                rule=rule, mutation=mutation.to_dict(),
                description=mutation.describe(),
                modeled_speedup=priced.modeled_speedup,
                modeled_delta_cycles=priced.delta_cycles,
                confidence=0.9))
        report = AdvisorReport(
            backend=b.name, advice=advice,
            baseline_makespan_cycles=profile.makespan_cycles,
            rules_matched=2, candidates_replayed=engine.replays,
            advisor_seconds=0.0)
        rep = RewriteLoop(top_k=2).run(
            hlo, b, profile=profile, advisor_report=report)
        stacked = [o for o in rep.outcomes if o.source == "stacked"]
        assert len(stacked) == 1
        o = stacked[0]
        assert o.mutation["kind"] == "Compose"
        assert o.certificate["declared"] == "stacked"
        assert o.realized_fraction >= 0.8
        # the stack beats its best single part
        singles = [x for x in rep.outcomes if x.source != "stacked"]
        assert o.realized_speedup >= max(
            x.realized_speedup for x in singles) - 1e-9

    def test_rewrites_section_shape(self):
        rep = RewriteLoop(top_k=2).run(_storm_hlo(12), "nvidia_gh200")
        sec = rewrites_section(rep)
        assert sec["recorded"] is True
        assert sec["count"] == len(rep.outcomes)
        for item in sec["items"]:
            assert {"rule", "source", "mutation", "predicted_speedup",
                    "realized_speedup", "realized_fraction",
                    "certificate"} <= set(item)


# --------------------------------------------------------------------------
# Service wiring: schema v5 surface.
# --------------------------------------------------------------------------

class TestServiceRewrite:
    def test_diagnose_rewrite_records_section(self):
        svc = LeoService()
        diag = svc.diagnose(_storm_hlo(12), backend="nvidia_gh200",
                            advise=True, rewrite=True)
        assert diag.schema_version == 6
        assert diag.rewrites["recorded"] is True
        assert diag.rewrites["count"] >= 1
        assert diag.advice["recorded"] is True
        from repro.core import Diagnosis
        assert Diagnosis.from_json(diag.to_json()) == diag

    def test_rewrite_without_advise_keeps_advice_unrecorded(self):
        svc = LeoService()
        diag = svc.diagnose(_storm_hlo(12), backend="nvidia_gh200",
                            rewrite=True)
        assert diag.rewrites["recorded"] is True
        assert diag.advice["recorded"] is False

    def test_plain_diagnosis_never_aliases_rewrites(self):
        svc = LeoService()
        with_rw = svc.diagnose(_storm_hlo(12), backend="nvidia_gh200",
                               rewrite=True)
        plain = svc.diagnose(_storm_hlo(12), backend="nvidia_gh200")
        assert with_rw.rewrites["recorded"] is True
        assert plain.rewrites["recorded"] is False

    def test_markdown_renders_rewrite_lines(self):
        svc = LeoService()
        diag = svc.diagnose(_storm_hlo(48), backend="amd_mi300a",
                            rewrite=True)
        md = diag.to_markdown()
        assert "Applied rewrites (predicted vs realized)" in md
        assert "realized" in md


# --------------------------------------------------------------------------
# Hypothesis properties (ISSUE satellites).
# --------------------------------------------------------------------------

class TestProperties:
    def test_round_trip_property_generated_storms(self):
        hypothesis = pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (requirements-dev.txt)")
        from hypothesis import given, settings, strategies as st

        modules = {}

        @settings(max_examples=20, deadline=None)
        @given(n=st.integers(2, 24), dim=st.sampled_from((64, 256, 512)))
        def prop(n, dim):
            module = modules.setdefault(
                (n, dim), parse_hlo(_storm_hlo_dim(n, dim)))
            text = emit_hlo(module)
            assert parse_hlo(text) == module
            assert emit_hlo(parse_hlo(text)) == text

        def _storm_hlo_dim(n, dim):
            from repro.launch.analysis_server import copy_storm_hlo
            return copy_storm_hlo(n, dim)

        prop()

    def test_rewriter_preserves_certificate_property(self):
        hypothesis = pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (requirements-dev.txt)")
        from hypothesis import given, settings, strategies as st

        modules = {}

        @settings(max_examples=15, deadline=None)
        @given(n=st.integers(4, 24), group=st.integers(2, 8),
               which=st.sampled_from(("coalesce", "tree")))
        def prop(n, group, which):
            module = modules.setdefault(n, parse_hlo(_storm_hlo(n)))
            mutation = CoalesceSyncTags(group=group) \
                if which == "coalesce" else TreeReduceChain(min_length=4)
            try:
                result = apply_rewrite(module, mutation)
            except NotApplicable:
                return
            # adversarial re-check: certify the re-parsed module against
            # the original under the declared kind, from scratch
            cert = check_equivalence(
                module, result.module,
                mutation_kind=mutation.kind,
                declared=result.certificate.declared)
            assert cert.declared == result.certificate.declared

        prop()
