"""Per-backend divergence regression (ROADMAP open item).

Golden snapshot of what each registered vendor descriptor says about the
fixed async-collective HLO fixture: top root causes, recommended action,
dominant stall in the unified §II-D taxonomy AND the vendor-native
vocabulary, plus the modeled step time.  Any drift in a backend's
class-estimate constants, taxonomy table, or the blame/pruning pipeline
shows up here as a precise per-backend diff instead of a silent
cross-vendor collapse.

When a constant is *intentionally* recalibrated (e.g. against
vendor-published microbenchmarks), regenerate the golden block:

  PYTHONPATH=src python tests/test_backend_divergence.py
"""
import pytest

from repro.core import LeoService

#: backend -> expected snapshot on the ASYNC_HLO fixture (8 devices).
GOLDEN = {
    "amd_mi300a": {
        "vendor": "amd",
        "top_root_causes": ["main.1::send.1", "main.1::ag-start",
                            "main.1::gather.1"],
        "top_action": "overlap_or_reshard_collective",
        "dominant_stall": "collective_wait",
        "dominant_native": "xgmi_wait",
        "est_step_seconds": 1.3694410101078169e-05,
    },
    "intel_pvc": {
        "vendor": "intel",
        "top_root_causes": ["main.1::send.1", "main.1::ag-start",
                            "main.1::gather.1"],
        "top_action": "overlap_or_reshard_collective",
        "dominant_stall": "collective_wait",
        "dominant_native": "xelink_wait",
        "est_step_seconds": 2.5089868292682942e-05,
    },
    "nvidia_gh200": {
        "vendor": "nvidia",
        "top_root_causes": ["main.1::send.1", "main.1::ag-start",
                            "main.1::gather.1"],
        "top_action": "overlap_or_reshard_collective",
        "dominant_stall": "collective_wait",
        "dominant_native": "membar",
        "est_step_seconds": 1.2805013803278685e-05,
    },
    "tpu_v4": {
        "vendor": "google",
        "top_root_causes": ["main.1::send.1", "main.1::ag-start",
                            "main.1::gather.1"],
        "top_action": "overlap_or_reshard_collective",
        "dominant_stall": "collective_wait",
        "dominant_native": "ici_wait",
        "est_step_seconds": 8.056923914999224e-06,
    },
    "tpu_v5e": {
        "vendor": "google",
        "top_root_causes": ["main.1::send.1", "main.1::ag-start",
                            "main.1::gather.1"],
        "top_action": "overlap_or_reshard_collective",
        "dominant_stall": "collective_wait",
        "dominant_native": "ici_wait",
        "est_step_seconds": 9.404746294650976e-06,
    },
    "tpu_v5p": {
        "vendor": "google",
        "top_root_causes": ["main.1::send.1", "main.1::ag-start",
                            "main.1::gather.1"],
        "top_action": "overlap_or_reshard_collective",
        "dominant_stall": "collective_wait",
        "dominant_native": "ici_wait",
        "est_step_seconds": 4.330242965641951e-06,
    },
}


def _snapshot(diag) -> dict:
    dominant = max(diag.top_stalls[0]["breakdown"],
                   key=diag.top_stalls[0]["breakdown"].get)
    return {
        "vendor": diag.vendor,
        "top_root_causes": [rc["instruction"]
                            for rc in diag.root_causes[:3]],
        "top_action": (diag.recommendations[0].action
                       if diag.recommendations else None),
        "dominant_stall": dominant,
        "dominant_native": diag.stall_taxonomy[dominant],
        "est_step_seconds": diag.estimated_step_seconds,
    }


@pytest.fixture(scope="module")
def diagnoses():
    from conftest import ASYNC_HLO
    service = LeoService()
    return service.diagnose_fanout(ASYNC_HLO, hints={"total_devices": 8})


class TestBackendDivergenceRegression:
    def test_every_golden_backend_is_registered(self, diagnoses):
        missing = set(GOLDEN) - set(diagnoses)
        assert not missing, f"backends vanished from the registry: {missing}"

    @pytest.mark.parametrize("backend", sorted(GOLDEN))
    def test_backend_snapshot(self, diagnoses, backend):
        got = _snapshot(diagnoses[backend])
        want = dict(GOLDEN[backend])
        est_want = want.pop("est_step_seconds")
        est_got = got.pop("est_step_seconds")
        assert got == want
        assert est_got == pytest.approx(est_want, rel=1e-9)

    def test_vendor_taxonomies_actually_diverge(self, diagnoses):
        """The same unified stall must speak differently per vendor —
        drift that collapses taxonomies to one vocabulary is a bug."""
        natives = {GOLDEN[b]["dominant_native"] for b in GOLDEN}
        assert len(natives) >= 4   # ici/membar/xgmi/xelink at minimum

    def test_modeled_times_diverge(self, diagnoses):
        times = {b: d.estimated_step_seconds for b, d in diagnoses.items()
                 if b in GOLDEN}
        assert len({round(t, 12) for t in times.values()}) == len(times)


if __name__ == "__main__":
    # regenerate the GOLDEN block after an intentional recalibration
    import sys
    sys.path.insert(0, "tests")
    from conftest import ASYNC_HLO
    diags = LeoService().diagnose_fanout(ASYNC_HLO,
                                         hints={"total_devices": 8})
    for name in sorted(diags):
        snap = _snapshot(diags[name])
        print(f'    "{name}": {{')
        for k, v in snap.items():
            print(f'        "{k}": {v!r},')
        print("    },")
