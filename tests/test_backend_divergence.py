"""Per-backend divergence regression (ROADMAP open item).

Golden snapshots of what each registered vendor descriptor says about two
fixed fixtures:

* ``ASYNC_HLO`` (async collective + gather + while loop): top root causes,
  recommended action, dominant stall in the unified §II-D taxonomy AND the
  vendor-native vocabulary, plus the modeled step time;
* ``COPYSTORM_HLO`` (8 concurrent async copies): the §III-E *resource
  pressure* snapshot — whether the storm oversubscribes the backend's
  finite sync resources, which pool contends, and the dominant stall
  class.  This is the paper's headline case-study shape: the SAME program
  serializes on waitcnt counters on the AMD-class part (sync_resource /
  s_waitcnt_alias), fits Intel's 16 SWSB tokens (sync_wait), and lands in
  between on NVIDIA's 6 named barriers — three vendors, three different
  top blame classes.

Any drift in a backend's class-estimate constants, taxonomy table, sync
model, or the blame/pruning pipeline shows up here as a precise
per-backend diff instead of a silent cross-vendor collapse.

When a constant is *intentionally* recalibrated (e.g. against
vendor-published microbenchmarks), regenerate the golden blocks:

  PYTHONPATH=src python tests/test_backend_divergence.py
"""
import pytest

from repro.core import LeoService

#: backend -> expected snapshot on the ASYNC_HLO fixture (8 devices).
GOLDEN = {
    "amd_mi300a": {
        "vendor": "amd",
        "top_root_causes": ["main.1::send.1", "main.1::ag-start",
                            "main.1::gather.1"],
        "top_action": "overlap_or_reshard_collective",
        "dominant_stall": "collective_wait",
        "dominant_native": "xgmi_wait",
        "est_step_seconds": 1.3694410101078169e-05,
    },
    "intel_pvc": {
        "vendor": "intel",
        "top_root_causes": ["main.1::send.1", "main.1::ag-start",
                            "main.1::gather.1"],
        "top_action": "overlap_or_reshard_collective",
        "dominant_stall": "collective_wait",
        "dominant_native": "xelink_wait",
        "est_step_seconds": 2.5089868292682942e-05,
    },
    "nvidia_gh200": {
        "vendor": "nvidia",
        "top_root_causes": ["main.1::send.1", "main.1::ag-start",
                            "main.1::gather.1"],
        "top_action": "overlap_or_reshard_collective",
        "dominant_stall": "collective_wait",
        "dominant_native": "membar",
        "est_step_seconds": 1.2805013803278685e-05,
    },
    "tpu_v4": {
        "vendor": "google",
        "top_root_causes": ["main.1::send.1", "main.1::ag-start",
                            "main.1::gather.1"],
        "top_action": "overlap_or_reshard_collective",
        "dominant_stall": "collective_wait",
        "dominant_native": "ici_wait",
        "est_step_seconds": 8.056923914999224e-06,
    },
    "tpu_v5e": {
        "vendor": "google",
        "top_root_causes": ["main.1::send.1", "main.1::ag-start",
                            "main.1::gather.1"],
        "top_action": "overlap_or_reshard_collective",
        "dominant_stall": "collective_wait",
        "dominant_native": "ici_wait",
        "est_step_seconds": 9.404746294650976e-06,
    },
    "tpu_v5p": {
        "vendor": "google",
        "top_root_causes": ["main.1::send.1", "main.1::ag-start",
                            "main.1::gather.1"],
        "top_action": "overlap_or_reshard_collective",
        "dominant_stall": "collective_wait",
        "dominant_native": "ici_wait",
        "est_step_seconds": 4.330242965641951e-06,
    },
}


#: backend -> §III-E resource-pressure snapshot on the COPYSTORM fixture
#: (8 concurrent async copies, no sharding).
COPYSTORM_GOLDEN = {
    "amd_mi300a": {
        "vendor": "amd",
        "dominant_stall": "sync_resource",
        "dominant_native": "s_waitcnt_alias",
        "contended": True,
        "contended_pool": "waitcnt_counter",
        "sync_blames": 6,
        "est_step_seconds": 4.238071446540881e-06,
    },
    "intel_pvc": {
        "vendor": "intel",
        "dominant_stall": "sync_wait",
        "dominant_native": "sync_func_wait",
        "contended": False,
        "contended_pool": None,
        "sync_blames": 0,
        "est_step_seconds": 4.875944512195124e-06,
    },
    "nvidia_gh200": {
        "vendor": "nvidia",
        "dominant_stall": "mem_dep",
        "dominant_native": "long_scoreboard",
        "contended": True,
        "contended_pool": "named_barrier",
        "sync_blames": 2,
        "est_step_seconds": 4.0725991584699435e-06,
    },
    "tpu_v4": {
        "vendor": "google",
        "dominant_stall": "sync_wait",
        "dominant_native": "dma_semaphore_wait",
        "contended": False,
        "contended_pool": None,
        "sync_blames": 0,
        "est_step_seconds": 1.2940726229253915e-05,
    },
    "tpu_v5e": {
        "vendor": "google",
        "dominant_stall": "sync_wait",
        "dominant_native": "dma_semaphore_wait",
        "contended": False,
        "contended_pool": None,
        "sync_blames": 0,
        "est_step_seconds": 1.9352570753123946e-05,
    },
    "tpu_v5p": {
        "vendor": "google",
        "dominant_stall": "sync_wait",
        "dominant_native": "dma_semaphore_wait",
        "contended": False,
        "contended_pool": None,
        "sync_blames": 0,
        "est_step_seconds": 5.767908860759494e-06,
    },
}


def _dominant(diag) -> str:
    return max(diag.top_stalls[0]["breakdown"],
               key=diag.top_stalls[0]["breakdown"].get)


def _snapshot(diag) -> dict:
    dominant = _dominant(diag)
    return {
        "vendor": diag.vendor,
        "top_root_causes": [rc["instruction"]
                            for rc in diag.root_causes[:3]],
        "top_action": (diag.recommendations[0].action
                       if diag.recommendations else None),
        "dominant_stall": dominant,
        "dominant_native": diag.stall_taxonomy[dominant],
        "est_step_seconds": diag.estimated_step_seconds,
    }


def _copystorm_snapshot(diag) -> dict:
    dominant = _dominant(diag)
    sr = diag.sync_resources
    contended_pools = [p["pool"] for p in sr["pools"] if p.get("evictions")]
    return {
        "vendor": diag.vendor,
        "dominant_stall": dominant,
        "dominant_native": diag.stall_taxonomy[dominant],
        "contended": sr["contended"],
        "contended_pool": contended_pools[0] if contended_pools else None,
        "sync_blames": len(sr.get("blame", [])),
        "est_step_seconds": diag.estimated_step_seconds,
    }


@pytest.fixture(scope="module")
def diagnoses():
    from conftest import ASYNC_HLO
    service = LeoService()
    return service.diagnose_fanout(ASYNC_HLO, hints={"total_devices": 8})


@pytest.fixture(scope="module")
def copystorm_diagnoses():
    from conftest import COPYSTORM_HLO
    service = LeoService()
    return service.diagnose_fanout(COPYSTORM_HLO)


class TestBackendDivergenceRegression:
    def test_every_golden_backend_is_registered(self, diagnoses):
        missing = set(GOLDEN) - set(diagnoses)
        assert not missing, f"backends vanished from the registry: {missing}"

    @pytest.mark.parametrize("backend", sorted(GOLDEN))
    def test_backend_snapshot(self, diagnoses, backend):
        got = _snapshot(diagnoses[backend])
        want = dict(GOLDEN[backend])
        est_want = want.pop("est_step_seconds")
        est_got = got.pop("est_step_seconds")
        assert got == want
        assert est_got == pytest.approx(est_want, rel=1e-9)

    def test_vendor_taxonomies_actually_diverge(self, diagnoses):
        """The same unified stall must speak differently per vendor —
        drift that collapses taxonomies to one vocabulary is a bug."""
        natives = {GOLDEN[b]["dominant_native"] for b in GOLDEN}
        assert len(natives) >= 4   # ici/membar/xgmi/xelink at minimum

    def test_modeled_times_diverge(self, diagnoses):
        times = {b: d.estimated_step_seconds for b, d in diagnoses.items()
                 if b in GOLDEN}
        assert len({round(t, 12) for t in times.values()}) == len(times)


class TestSyncResourceDivergence:
    """COPYSTORM regression: the same 8-copy storm must blame differently
    per vendor *because of finite sync resources* (ISSUE acceptance)."""

    @pytest.mark.parametrize("backend", sorted(COPYSTORM_GOLDEN))
    def test_copystorm_snapshot(self, copystorm_diagnoses, backend):
        got = _copystorm_snapshot(copystorm_diagnoses[backend])
        want = dict(COPYSTORM_GOLDEN[backend])
        est_want = want.pop("est_step_seconds")
        est_got = got.pop("est_step_seconds")
        assert got == want
        assert est_got == pytest.approx(est_want, rel=1e-9)

    def test_top_blame_class_differs_across_gpu_vendors(
            self, copystorm_diagnoses):
        """The headline §VI result: NVIDIA-, AMD- and Intel-class parts
        each report a DIFFERENT top blame class on the same program, and
        the difference is driven by resource pressure (the contended
        backends are exactly the ones whose pools are smaller than the
        storm)."""
        classes = {b: _dominant(copystorm_diagnoses[b])
                   for b in ("nvidia_gh200", "amd_mi300a", "intel_pvc")}
        assert len(set(classes.values())) == 3, classes
        # AMD's two waitcnt counters are the scarcest resource: its top
        # blame class IS the resource exhaustion itself
        assert classes["amd_mi300a"] == "sync_resource"
        # Intel's 16 SWSB tokens absorb the storm: no resource pressure
        assert not copystorm_diagnoses["intel_pvc"].sync_resources[
            "contended"]
        assert copystorm_diagnoses["nvidia_gh200"].sync_resources[
            "contended"]
        assert copystorm_diagnoses["amd_mi300a"].sync_resources["contended"]

    def test_contended_backends_name_concrete_instances(
            self, copystorm_diagnoses):
        for backend, want in COPYSTORM_GOLDEN.items():
            sr = copystorm_diagnoses[backend].sync_resources
            if not want["contended"]:
                assert not sr.get("blame")
                continue
            pool = next(p for p in sr["pools"]
                        if p["pool"] == want["contended_pool"])
            assert pool["peak_in_flight"] == pool["capacity"]
            for b in sr["blame"]:
                assert b["resource"] in pool["instances"]


if __name__ == "__main__":
    # regenerate the GOLDEN blocks after an intentional recalibration
    import sys
    sys.path.insert(0, "tests")
    from conftest import ASYNC_HLO, COPYSTORM_HLO
    diags = LeoService().diagnose_fanout(ASYNC_HLO,
                                         hints={"total_devices": 8})
    print("GOLDEN = {")
    for name in sorted(diags):
        snap = _snapshot(diags[name])
        print(f'    "{name}": {{')
        for k, v in snap.items():
            print(f'        "{k}": {v!r},')
        print("    },")
    print("}")
    storm = LeoService().diagnose_fanout(COPYSTORM_HLO)
    print("COPYSTORM_GOLDEN = {")
    for name in sorted(storm):
        snap = _copystorm_snapshot(storm[name])
        print(f'    "{name}": {{')
        for k, v in snap.items():
            print(f'        "{k}": {v!r},')
        print("    },")
    print("}")
