"""Shared fixtures. NOTE: device count must stay 1 here (smoke tests and
benches see the real CPU); only launch/dryrun.py forces 512 host devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest


@pytest.fixture(scope="session")
def small_compiled_step():
    """A small sharded train-step-like program compiled on 1 CPU device."""
    import jax
    import jax.numpy as jnp

    def step(w1, w2, x):
        def body(c, _):
            h = jnp.einsum("bd,df->bf", c, w1)
            h = jax.nn.gelu(h)
            c = jnp.einsum("bf,fd->bd", h, w2)
            return c, ()
        c, _ = jax.lax.scan(body, x, None, length=3)
        return c.sum()

    w1 = jax.ShapeDtypeStruct((64, 128), jnp.bfloat16)
    w2 = jax.ShapeDtypeStruct((128, 64), jnp.bfloat16)
    x = jax.ShapeDtypeStruct((8, 64), jnp.bfloat16)
    lowered = jax.jit(step).lower(w1, w2, x)
    return lowered.compile()


# Hand-written, format-valid HLO exercising async pairs (the NVIDIA-barrier
# analogue), tokens (SWSB analogue), and a while loop — features the CPU
# backend does not emit.
ASYNC_HLO = """\
HloModule fixture_async

%add.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add.2 = f32[] add(%a, %b)
}

%body.1 (p.1: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p.1 = (s32[], f32[128,128]) parameter(0)
  %iv = s32[] get-tuple-element(%p.1), index=0
  %one = s32[] constant(1)
  %iv2 = s32[] add(%iv, %one)
  %acc = f32[128,128] get-tuple-element(%p.1), index=1
  %gain = f32[128,128] multiply(%acc, %acc)
  ROOT %out = (s32[], f32[128,128]) tuple(%iv2, %gain)
}

%cond.1 (p.2: (s32[], f32[128,128])) -> pred[] {
  %p.2 = (s32[], f32[128,128]) parameter(0)
  %iv3 = s32[] get-tuple-element(%p.2), index=0
  %lim = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv3, %lim), direction=LT
}

ENTRY %main.1 (arg0: f32[128,128], arg1: f32[128,128]) -> f32[128,128] {
  %arg0 = f32[128,128] parameter(0)
  %arg1 = f32[128,128] parameter(1)
  %gather.1 = f32[128,128] gather(%arg0, %arg1), metadata={op_name="jit(step)/model/embed/gather"}
  %ag-start = f32[128,128] all-gather-start(%gather.1), channel_id=1, replica_groups=[2,4]<=[8], dimensions={0}, metadata={op_name="jit(step)/model/layer/allgather"}
  %indep = f32[128,128] multiply(%arg1, %arg1)
  %ag-done = f32[128,128] all-gather-done(%ag-start), metadata={op_name="jit(step)/model/layer/allgather"}
  %dot.1 = f32[128,128] dot(%ag-done, %indep), lhs_contracting_dims={1}, rhs_contracting_dims={0}, metadata={op_name="jit(step)/model/layer/mlp/dot_general" source_file="model.py" source_line=42}
  %tok0 = token[] after-all(%gather.1)
  %send.1 = (f32[128,128], u32[], token[]) send(%dot.1, %tok0), channel_id=2
  %send-done.1 = token[] send-done(%send.1), channel_id=2
  %zero = s32[] constant(0)
  %init = (s32[], f32[128,128]) tuple(%zero, %dot.1)
  %loop = (s32[], f32[128,128]) while(%init), condition=%cond.1, body=%body.1
  %result = f32[128,128] get-tuple-element(%loop), index=1
  ROOT %final = f32[128,128] add(%result, %indep)
}
"""


@pytest.fixture()
def async_hlo_text():
    return ASYNC_HLO


def _copystorm_hlo(n_copies: int = 8, dim: int = 512) -> str:
    """Oversubscription fixture (§III-E): `n_copies` async copies all in
    flight before any done — more than NVIDIA-class parts have barrier
    slots (6) and AMD-class parts have waitcnt counters (2), but fewer
    than Intel-class SWSB tokens (16) or TPU async contexts (32), so the
    same program serializes on some vendors and sails through on others.
    One shared builder (also the crossvendor example's demo trace) so the
    goldens and the demo can never drift apart."""
    from repro.launch.analysis_server import copy_storm_hlo
    return copy_storm_hlo(n_copies, dim)


#: 8 concurrent async copies: oversubscribes NVIDIA barriers and AMD
#: waitcnt counters, fits Intel SWSB tokens and TPU async contexts.
COPYSTORM_HLO = _copystorm_hlo()


@pytest.fixture()
def copystorm_hlo_text():
    return COPYSTORM_HLO
