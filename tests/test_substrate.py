"""Substrate tests: optimizer, checkpointing (incl. corruption/crash
consistency), data pipeline determinism, fault-tolerance logic, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data import DataPipeline, SyntheticConfig, SyntheticTokenDataset
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_gradients,
)
from repro.runtime import (
    ElasticController,
    FaultTolerantLoop,
    HeartbeatMonitor,
    StragglerPolicy,
)


class TestOptimizer:
    def _quad(self):
        params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}
        loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
        return params, loss

    def test_adamw_reduces_loss(self):
        params, loss = self._quad()
        state = adamw_init(params)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        l0 = loss(params)
        for _ in range(50):
            grads = jax.grad(loss)(params)
            params, state = adamw_update(cfg, grads, state, params)
        assert float(loss(params)) < 0.1 * float(l0)

    def test_bf16_params_keep_f32_master(self):
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = adamw_init(params)
        assert state["master"]["w"].dtype == jnp.float32
        grads = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
        new_p, new_s = adamw_update(AdamWConfig(lr=1e-4), grads, state,
                                    params)
        assert new_p["w"].dtype == jnp.bfloat16
        # master moved even though the bf16 delta may round away
        assert float(jnp.abs(new_s["master"]["w"] - 1.0).max()) > 0

    def test_clip_global_norm(self):
        grads = {"a": jnp.full((10,), 100.0)}
        clipped, gnorm = clip_by_global_norm(grads, 1.0)
        assert float(gnorm) > 100
        norm_after = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
        assert float(norm_after) == pytest.approx(1.0, rel=1e-4)

    def test_grad_compression_error_feedback(self):
        grads = {"w": jnp.array([1.0, 1e-4, -0.5])}
        q1, ef = compress_gradients(grads)
        # error feedback carries the quantization residual
        assert ef["w"].shape == (3,)
        q2, ef2 = compress_gradients(grads, ef)
        # two-step average closer to the truth than a single step
        err1 = np.abs(np.asarray(q1["w"]) - np.asarray(grads["w"])).max()
        avg = (np.asarray(q1["w"]) + np.asarray(q2["w"])) / 2
        err2 = np.abs(avg - np.asarray(grads["w"])).max()
        assert err2 <= err1 + 1e-9


class TestCheckpoint:
    def _state(self, v=0.0):
        return {"params": {"w": jnp.full((4, 4), v)},
                "step": jnp.array(int(v), jnp.int32)}

    def test_roundtrip(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 7, self._state(7.0))
        restored, step = restore_checkpoint(d, self._state())
        assert step == 7
        np.testing.assert_allclose(restored["params"]["w"], 7.0)

    def test_latest_wins_and_rotation(self, tmp_path):
        d = str(tmp_path)
        mgr = CheckpointManager(d, keep=2, async_saves=False)
        for s in (1, 2, 3, 4):
            mgr.save(s, self._state(float(s)))
        restored, step = mgr.restore_latest(self._state())
        assert step == 4
        from repro.checkpoint import list_checkpoints
        assert len(list_checkpoints(d)) == 2  # rotated to keep=2

    def test_async_save(self, tmp_path):
        d = str(tmp_path)
        mgr = CheckpointManager(d, keep=3, async_saves=True)
        mgr.save(5, self._state(5.0))
        mgr.wait()
        _, step = mgr.restore_latest(self._state())
        assert step == 5

    def test_corrupt_checkpoint_skipped(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 1, self._state(1.0))
        save_checkpoint(d, 2, self._state(2.0))
        # corrupt the newest
        with open(os.path.join(d, "step_00000002", "arrays.npz"), "wb") as f:
            f.write(b"garbage")
        restored, step = restore_checkpoint(d, self._state())
        assert step == 1  # fell back to the valid one

    def test_torn_write_invisible(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 1, self._state(1.0))
        os.makedirs(os.path.join(d, "step_00000009.tmp"))
        _, step = restore_checkpoint(d, self._state())
        assert step == 1


class TestDataPipeline:
    def test_deterministic_and_resumable(self):
        ds = SyntheticTokenDataset(SyntheticConfig(vocab_size=100,
                                                   seq_len=16, seed=3))
        p = DataPipeline(ds, global_batch=8)
        b1 = p.host_batch(5)
        b2 = p.host_batch(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = p.host_batch(6)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_host_sharding_disjoint(self):
        ds = SyntheticTokenDataset(SyntheticConfig(vocab_size=1000,
                                                   seq_len=8, seed=1))
        p0 = DataPipeline(ds, global_batch=8, host_index=0, host_count=2)
        p1 = DataPipeline(ds, global_batch=8, host_index=1, host_count=2)
        a, b = p0.host_batch(0)["tokens"], p1.host_batch(0)["tokens"]
        assert a.shape == (4, 8) and not np.array_equal(a, b)

    def test_labels_shift(self):
        ds = SyntheticTokenDataset(SyntheticConfig(vocab_size=50,
                                                   seq_len=12, seed=0))
        b = ds.batch(0, 0, 2)
        # autoregressive alignment: labels are tokens shifted by one
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_prefetch_iterator(self):
        ds = SyntheticTokenDataset(SyntheticConfig(vocab_size=50, seq_len=4))
        p = DataPipeline(ds, global_batch=4)
        it = p(start_step=3)
        first = next(it)
        expect = p.device_batch(3)
        np.testing.assert_array_equal(np.asarray(first["tokens"]),
                                      np.asarray(expect["tokens"]))


class TestFaultTolerance:
    def test_failure_detection(self):
        t = [0.0]
        mon = HeartbeatMonitor(4, timeout=10.0, clock=lambda: t[0])
        for h in range(4):
            mon.heartbeat(h, 1)
        t[0] = 5.0
        for h in range(3):
            mon.heartbeat(h, 2)
        assert mon.failed_hosts() == []
        t[0] = 14.0  # host 3 silent for 14s (> 10); hosts 0-2 for 9s
        assert mon.failed_hosts() == [3]

    def test_straggler_detection(self):
        t = [0.0]
        mon = HeartbeatMonitor(4, straggler_factor=2.0, clock=lambda: t[0])
        for step in (1, 2, 3):
            for h in range(4):
                t[0] = step * 1.0 + (3.0 * step if h == 3 else 0.0)
                mon.heartbeat(h, step)
        assert 3 in mon.stragglers()

    def test_elastic_plan_keeps_tp(self):
        ctl = ElasticController(devices_per_host=8, model_parallel=16)
        plan = ctl.plan(surviving_hosts=list(range(30)), failed=[30, 31])
        assert plan.model == 16
        assert plan.data == 8  # 240 devices -> dp 15 -> pow2 8
        assert plan.devices <= 240

    def test_loop_recovers_from_failure(self):
        t = [0.0]
        mon = HeartbeatMonitor(4, timeout=5.0, clock=lambda: t[0])
        ctl = ElasticController(devices_per_host=4, model_parallel=2)
        recovered = {}

        def recover(plan):
            recovered["plan"] = plan
            return {"restored": True}, 17

        loop = FaultTolerantLoop(mon, ctl, recover)
        for h in range(4):
            mon.heartbeat(h, 1)
        t[0] = 20.0
        for h in range(3):
            mon.heartbeat(h, 2)
        state, step, _ = loop.check_and_recover({"restored": False}, 2)
        assert state["restored"] and step == 17
        assert recovered["plan"].model == 2
        assert loop.events and "3" in loop.events[0].reason


@pytest.mark.slow
class TestTrainDriver:
    def test_smoke_train_loss_decreases(self, tmp_path):
        from repro.launch.train import main
        res = main(["--arch", "qwen2-0.5b", "--smoke", "--steps", "30",
                    "--batch", "8", "--seq", "32",
                    "--checkpoint-dir", str(tmp_path)])
        assert res["final_loss"] < res["first_loss"]

    def test_restore_resumes(self, tmp_path):
        from repro.launch.train import main
        main(["--arch", "qwen2-0.5b", "--smoke", "--steps", "10",
              "--batch", "4", "--seq", "16", "--checkpoint-dir",
              str(tmp_path), "--checkpoint-every", "5"])
        res = main(["--arch", "qwen2-0.5b", "--smoke", "--steps", "12",
                    "--batch", "4", "--seq", "16", "--checkpoint-dir",
                    str(tmp_path), "--restore"])
        assert res["steps"] == 2  # resumed from step 10


@pytest.mark.slow
class TestServeEngine:
    def test_batched_requests_complete(self):
        from repro.launch.serve import main
        out = main(["--arch", "qwen2-0.5b", "--requests", "5",
                    "--slots", "2", "--max-new", "4", "--max-len", "32"])
        assert len(out) == 5
        assert all(len(toks) == 4 for toks in out.values())
