"""Advice-divergence regression (the PR-7 ISSUE golden).

The paper's case study 1: the *same* 48-copy async storm wants a
*different* fix per vendor, and LEO's advisor must say so — with the
what-if replay backing each claim with a modeled speedup:

* **NVIDIA-class** — 6 device-shared named barriers oversubscribed:
  batch synchronization points (``batch_sync_allocations``, phrased as
  batched ``bar.sync``);
* **AMD-class** — 2 per-wave waitcnt counters oversubscribed: coalesce
  counter-style waits (``coalesce_outstanding_waits``, phrased as
  ``s_waitcnt`` on groups);
* **Intel-class** — 16 SBIDs absorb the storm without contention; the
  bottleneck is issue-side (``expose_ilp_tree_reduce``: restructure the
  serial reduction so the 8x2 fabric co-issues).

Pinned in ``tests/goldens/advice_divergence.json``: the top rule, its
priced mutation, the modeled speedup, and the vendor phrasing for every
golden backend.  Any drift in the rule matchers, mutation semantics, the
replay engine, or a vendor's sync/issue constants shows up as a precise
per-backend diff.

Regenerate after an intentional recalibration (the CI golden-drift gate
runs exactly this and fails on an uncommitted diff):

  PYTHONPATH=src python tests/test_advisor_divergence.py
"""
import json
import os

import pytest

from repro.advisor import Advisor, Identity, WhatIfEngine, profile_fingerprint
from repro.core import get_backend, parse_hlo

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "goldens",
                           "advice_divergence.json")

GOLDEN_BACKENDS = ("amd_mi300a", "intel_pvc", "nvidia_gh200",
                   "tpu_v4", "tpu_v5e", "tpu_v5p")

#: The vendors the paper's case study contrasts; each must get a
#: *different* top rule and a >= 1.2x modeled speedup on this workload.
DIVERGING_VENDORS = ("nvidia_gh200", "amd_mi300a", "intel_pvc")

#: The fixture: 48 concurrent async copies feeding one serial reduction —
#: oversubscribes NVIDIA's 6 barriers and AMD's 2 waitcnt counters while
#: Intel's 16 SBIDs stay uncontended (the workload of the ISSUE golden).
N_COPIES = 48


def _load_goldens() -> dict:
    if not os.path.exists(GOLDEN_PATH):
        return {}
    with open(GOLDEN_PATH) as f:
        return json.load(f)


GOLDENS = _load_goldens()


def _storm_module():
    from repro.launch.analysis_server import copy_storm_hlo
    return parse_hlo(copy_storm_hlo(N_COPIES))


def _snapshot(report) -> dict:
    top = report.top
    return {
        "rules_matched": report.rules_matched,
        "candidates_replayed": report.candidates_replayed,
        "advice_rules": [a.rule for a in report.advice],
        "top_rule": top.rule if top else None,
        "top_mutation": dict(top.mutation) if top else None,
        "top_speedup": top.modeled_speedup if top else 1.0,
        "top_confidence": top.confidence if top else None,
        "top_description": top.description if top else None,
    }


@pytest.fixture(scope="module")
def reports():
    module = _storm_module()
    return {name: Advisor().report(module, get_backend(name))
            for name in GOLDEN_BACKENDS}


class TestAdviceDivergenceRegression:
    def test_golden_file_covers_every_backend(self):
        assert sorted(k for k in GOLDENS if not k.startswith("_")) == \
            sorted(GOLDEN_BACKENDS)

    @pytest.mark.parametrize("backend", sorted(GOLDEN_BACKENDS))
    def test_backend_snapshot(self, reports, backend):
        got, want = _snapshot(reports[backend]), dict(GOLDENS[backend])
        assert got.pop("top_speedup") == \
            pytest.approx(want.pop("top_speedup"), rel=1e-9)
        assert got == want

    def test_three_vendors_get_three_different_top_rules(self, reports):
        """ISSUE acceptance: the advice-divergence golden pins *different*
        top rules on NVIDIA vs AMD vs Intel for the same program."""
        tops = {b: reports[b].top.rule for b in DIVERGING_VENDORS}
        assert len(set(tops.values())) == 3, tops
        assert tops["nvidia_gh200"] == "batch_sync_allocations"
        # PR-9: wave residency (occupancy) is AMD's decisive lever now
        assert tops["amd_mi300a"] == "raise_occupancy"
        assert tops["intel_pvc"] == "expose_ilp_tree_reduce"

    @pytest.mark.parametrize("backend", DIVERGING_VENDORS)
    def test_top_mutation_speeds_up_the_blamed_vendor(self, reports,
                                                      backend):
        """ISSUE acceptance: the top advice is priced at >= 1.2x modeled
        speedup on every blamed vendor."""
        assert reports[backend].top.modeled_speedup >= 1.2

    def test_phrasing_is_vendor_native(self, reports):
        assert "bar.sync" in reports["nvidia_gh200"].top.description
        # PR-9: AMD's top advice is the residency knob, phrased in
        # waves-per-EU / VGPR terms rather than s_waitcnt terms.
        assert "waves-per-eu" in \
            reports["amd_mi300a"].top.description.lower()
        assert "SBID" in reports["intel_pvc"].top.description

    @pytest.mark.parametrize("backend", sorted(GOLDEN_BACKENDS))
    def test_identity_replay_matches_baseline(self, backend):
        """The golden's precondition: replaying the null mutation on the
        golden workload is byte-identical to the baseline profile."""
        engine = WhatIfEngine(_storm_module(), get_backend(backend))
        assert profile_fingerprint(engine.replay(Identity()).profile) == \
            profile_fingerprint(engine.baseline())


def regenerate() -> dict:
    """Recompute the golden (recalibration/drift-gate entry point);
    writes ``tests/goldens/advice_divergence.json`` in place."""
    module = _storm_module()
    goldens = {
        "_comment": "Advice-divergence golden (48-copy storm, one serial "
                    "reduction); regenerate with `PYTHONPATH=src python "
                    "tests/test_advisor_divergence.py` after an "
                    "intentional recalibration (the CI golden-drift gate "
                    "runs exactly that and fails on an uncommitted diff).",
    }
    for name in sorted(GOLDEN_BACKENDS):
        goldens[name] = _snapshot(Advisor().report(module,
                                                   get_backend(name)))
    with open(GOLDEN_PATH, "w") as f:
        json.dump(goldens, f, indent=2, sort_keys=True)
        f.write("\n")
    return goldens


if __name__ == "__main__":
    regenerated = regenerate()
    for name in sorted(k for k in regenerated if not k.startswith("_")):
        snap = regenerated[name]
        print(f"{name}: top={snap['top_rule']} "
              f"({snap['top_speedup']:.3f}x)")
    print(f"wrote {GOLDEN_PATH}")
