"""HLO parser unit tests: shapes, instructions, costs, trip counts,
collectives, metadata — validated against both fixtures and a real compiled
XLA program."""
import pytest

from repro.core.hlo_parser import parse_hlo, parse_shape
from repro.core.isa import OpClass, ShapeInfo, SyncKind
from repro.core.collectives import (
    collective_operand_bytes,
    collective_summary,
    total_collective_bytes,
)


class TestShapeParsing:
    def test_array(self):
        s = parse_shape("bf16[4,128]{1,0}")
        assert s.dtype == "bf16" and s.dims == (4, 128)
        assert s.byte_size == 4 * 128 * 2

    def test_layout_with_tiling(self):
        s = parse_shape("f32[16,1024]{1,0:T(8,128)}")
        assert s.dims == (16, 1024) and s.byte_size == 16 * 1024 * 4

    def test_scalar(self):
        s = parse_shape("pred[]")
        assert s.dtype == "pred" and s.dims == () and s.num_elements == 1

    def test_tuple(self):
        s = parse_shape("(f32[2,4]{1,0}, s32[])")
        assert s.is_tuple and len(s.elements) == 2
        assert s.byte_size == 2 * 4 * 4 + 4

    def test_token(self):
        assert parse_shape("token[]").byte_size == 0


class TestFixtureParsing:
    def test_structure(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        assert mod.entry == "main.1"
        assert set(mod.computations) == {
            "add.1", "body.1", "cond.1", "main.1"}
        assert mod.computations["body.1"].kind == "loop_body"
        assert mod.computations["cond.1"].kind == "loop_cond"

    def test_trip_count_from_condition(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text)
        loop = mod.computations["main.1"].get("loop")
        assert loop.trip_count == 5

    def test_async_pair_sync_info(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text)
        main = mod.computations["main.1"]
        start = main.get("ag-start")
        done = main.get("ag-done")
        assert start.op_class is OpClass.SYNC_SET
        assert start.sync.kind is SyncKind.BARRIER
        assert start.sync.sets == ("ag-start",)
        assert done.op_class is OpClass.SYNC_WAIT
        assert done.sync.waits == ("ag-start",)

    def test_token_sync_info(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text)
        tok = mod.computations["main.1"].get("tok0")
        assert tok.sync.kind is SyncKind.TOKEN

    def test_metadata(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text)
        dot = mod.computations["main.1"].get("dot.1")
        assert dot.op_name == "jit(step)/model/layer/mlp/dot_general"
        assert dot.source_file == "model.py" and dot.source_line == 42

    def test_dot_flops(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text)
        dot = mod.computations["main.1"].get("dot.1")
        assert dot.flops == 2 * 128 * 128 * 128

    def test_collective_bytes(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        start = mod.computations["main.1"].get("ag-start")
        # all-gather over groups of 4: out_bytes * (n-1)/n
        assert start.comm_bytes == pytest.approx(
            128 * 128 * 4 * 3 / 4)

    def test_trip_aware_flops(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text)
        # multiply in loop body: 128*128 flops x 5 trips contributes
        # body: multiply (128*128) + iv add (1); cond: compare (1)
        diff = mod.total_flops(True) - mod.total_flops(False)
        assert diff == pytest.approx(4 * (128 * 128 + 2))  # 4 extra trips


class TestAgainstRealXLA:
    def test_flops_match_cost_analysis(self, small_compiled_step):
        ca = small_compiled_step.cost_analysis()
        # jax >= 0.4.30 returns one properties dict per executable program
        # (a list); older versions returned the dict bare.  Our single-jit
        # fixture has exactly one program either way.
        if isinstance(ca, list):
            ca = ca[0]
        mod = parse_hlo(small_compiled_step.as_text())
        # XLA counts loop bodies once; our trip-unaware total should agree
        # within 20% (fusion/layout noise; measured ~4.5% on jax 0.4.37).
        ours = mod.total_flops(trip_aware=False)
        assert ours == pytest.approx(ca["flops"], rel=0.2)

    def test_trip_aware_exceeds_xla(self, small_compiled_step):
        mod = parse_hlo(small_compiled_step.as_text())
        assert mod.total_flops(True) > 2.0 * mod.total_flops(False)

    def test_all_instructions_have_shapes(self, small_compiled_step):
        mod = parse_hlo(small_compiled_step.as_text())
        for instr in mod.all_instructions():
            assert isinstance(instr.shape, ShapeInfo)


class TestCollectiveExtraction:
    def test_operand_bytes_prescription(self, async_hlo_text):
        stats = collective_operand_bytes(async_hlo_text)
        assert "all-gather" in stats
        assert stats["all-gather"].op_count == 1
        assert stats["all-gather"].operand_bytes == 128 * 128 * 4

    def test_total_wire_bytes(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        assert total_collective_bytes(mod) > 0

    def test_collective_in_loop_scales_with_trips(self):
        text = """\
HloModule loop_coll
%add.9 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}
%body.9 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c1 = s32[] constant(1)
  %i2 = s32[] add(%i, %c1)
  %x = f32[64] get-tuple-element(%p), index=1
  %ar = f32[64] all-reduce(%x), replica_groups=[1,4]<=[4], to_apply=%add.9
  ROOT %t = (s32[], f32[64]) tuple(%i2, %ar)
}
%cond.9 (p2: (s32[], f32[64])) -> pred[] {
  %p2 = (s32[], f32[64]) parameter(0)
  %i3 = s32[] get-tuple-element(%p2), index=0
  %lim = s32[] constant(7)
  ROOT %lt = pred[] compare(%i3, %lim), direction=LT
}
ENTRY %e (a0: f32[64]) -> (s32[], f32[64]) {
  %a0 = f32[64] parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[64]) tuple(%z, %a0)
  ROOT %w = (s32[], f32[64]) while(%init), condition=%cond.9, body=%body.9
}
"""
        mod = parse_hlo(text, hints={"total_devices": 4})
        summary = collective_summary(mod, trip_aware=True)
        per_op = 2 * 64 * 4 * 3 / 4
        assert summary["all-reduce"].wire_bytes == pytest.approx(7 * per_op)
        unaware = collective_summary(mod, trip_aware=False)
        assert unaware["all-reduce"].wire_bytes == pytest.approx(per_op)
