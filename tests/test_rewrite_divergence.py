"""Rewrite-divergence regression (the PR-8 ISSUE golden).

The closed loop on the paper's case study 1: the *same* 48-copy async
storm gets a *different applied HLO rewrite* per GPU vendor, and the
realized speedup (a full re-analysis of the rewritten text) must deliver
>= 80% of what the advisor's what-if replay predicted:

* **NVIDIA-class** — the top advice (``batch_sync_allocations``) lowers
  directly: ``CoalesceSyncTags(group=8)`` retags barrier waits in the
  text (``sync_tag`` frontend attributes), certificate ``sync_retag``;
* **AMD-class** — the top advice is hardware-only (grow a waitcnt
  counter pool), so the loop *falls back* to the rule's
  program-rewritable candidate: ``CoalesceSyncTags(group=6)`` at the
  waitcnt group size, source ``rule_fallback``, original refusal
  recorded;
* **Intel-class** — ``TreeReduceChain(min_length=4)`` rebalances the
  serial reduction into a log-depth tree, certificate ``rebalance``
  (leaf-multiset checked); realized exceeds modeled because the
  re-parsed text sheds the in-memory mutant's stale costs.

Pinned in ``tests/goldens/rewrite_divergence.json``: the applied
mutation, its source (advice vs rule_fallback), the certificate kind,
predicted and realized speedups, and the baseline makespan per vendor.

Regenerate after an intentional recalibration (the CI golden-drift gate
runs exactly this and fails on an uncommitted diff):

  PYTHONPATH=src python tests/test_rewrite_divergence.py
"""
import json
import os

import pytest

from repro.core import get_backend, parse_hlo
from repro.rewrite import RewriteLoop

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "goldens",
                           "rewrite_divergence.json")

#: The vendors the paper's case study contrasts; each must get a
#: *different* applied rewrite and realize >= 80% of its prediction.
DIVERGING_VENDORS = ("nvidia_gh200", "amd_mi300a", "intel_pvc")

#: Same workload as the advice-divergence golden: 48 concurrent async
#: copies feeding one serial reduction.
N_COPIES = 48

#: ISSUE acceptance floor: realized speedup must deliver at least this
#: fraction of the modeled prediction, vendor by vendor.
REALIZED_FLOOR = 0.8


def _load_goldens() -> dict:
    if not os.path.exists(GOLDEN_PATH):
        return {}
    with open(GOLDEN_PATH) as f:
        return json.load(f)


GOLDENS = _load_goldens()


def _storm_hlo() -> str:
    from repro.launch.analysis_server import copy_storm_hlo
    return copy_storm_hlo(N_COPIES)


def _snapshot(report) -> dict:
    best = report.best
    return {
        "baseline_makespan_cycles": report.baseline_makespan_cycles,
        "n_outcomes": len(report.outcomes),
        "skipped_rules": sorted(s["rule"] for s in report.skipped),
        "best_rule": best.rule if best else None,
        "best_source": best.source if best else None,
        "best_mutation": dict(best.mutation) if best else None,
        "best_certificate": best.certificate["declared"] if best else None,
        "best_predicted_speedup": best.predicted_speedup if best else 1.0,
        "best_realized_speedup": best.realized_speedup if best else 1.0,
        "best_refusal_code": (best.refusal or {}).get("code")
        if best else None,
    }


@pytest.fixture(scope="module")
def reports():
    hlo = _storm_hlo()
    return {name: RewriteLoop(top_k=2).run(hlo, name)
            for name in DIVERGING_VENDORS}


class TestRewriteDivergenceRegression:
    def test_golden_file_covers_every_vendor(self):
        assert sorted(k for k in GOLDENS if not k.startswith("_")) == \
            sorted(DIVERGING_VENDORS)

    @pytest.mark.parametrize("backend", sorted(DIVERGING_VENDORS))
    def test_backend_snapshot(self, reports, backend):
        got, want = _snapshot(reports[backend]), dict(GOLDENS[backend])
        for field in ("baseline_makespan_cycles",
                      "best_predicted_speedup", "best_realized_speedup"):
            assert got.pop(field) == \
                pytest.approx(want.pop(field), rel=1e-9), (backend, field)
        assert got == want

    def test_three_vendors_get_three_different_rewrites(self, reports):
        """ISSUE acceptance: each blamed GPU vendor's top advice lowers
        to a *different* applied rewrite of the same program."""
        applied = {}
        for name, rep in reports.items():
            mut = dict(rep.best.mutation)
            applied[name] = (mut.pop("kind"), tuple(sorted(
                (k, v) for k, v in mut.items() if v is not None)))
        assert len(set(applied.values())) == 3, applied

    @pytest.mark.parametrize("backend", sorted(DIVERGING_VENDORS))
    def test_realized_fraction_meets_floor(self, reports, backend):
        """ISSUE acceptance: the rewritten HLO, re-analyzed through the
        full pipeline, realizes >= 80% of the modeled speedup."""
        for o in reports[backend].outcomes:
            assert o.realized_fraction >= REALIZED_FLOOR, \
                (backend, o.rule, o.realized_fraction)

    def test_amd_fallback_is_recorded(self, reports):
        best = reports["amd_mi300a"].best
        assert best.source == "rule_fallback"
        assert best.refusal is not None
        assert best.refusal["code"] == "hardware_mutation"

    @pytest.mark.parametrize("backend", sorted(DIVERGING_VENDORS))
    def test_certificates_are_checked_kinds(self, reports, backend):
        for o in reports[backend].outcomes:
            assert o.certificate["declared"] in (
                "identical", "sync_retag", "reorder", "rebalance",
                "stacked")


def regenerate() -> dict:
    """Recompute the golden (recalibration/drift-gate entry point);
    writes ``tests/goldens/rewrite_divergence.json`` in place."""
    hlo = _storm_hlo()
    goldens = {
        "_comment": "Rewrite-divergence golden (48-copy storm, one serial "
                    "reduction): per-GPU-vendor applied rewrite + realized "
                    "speedup from the closed diagnose->advise->transform->"
                    "verify loop. Regenerate with `PYTHONPATH=src python "
                    "tests/test_rewrite_divergence.py` after an intentional "
                    "recalibration (the CI golden-drift gate runs exactly "
                    "that and fails on an uncommitted diff).",
    }
    for name in sorted(DIVERGING_VENDORS):
        goldens[name] = _snapshot(RewriteLoop(top_k=2).run(hlo, name))
    with open(GOLDEN_PATH, "w") as f:
        json.dump(goldens, f, indent=2, sort_keys=True)
        f.write("\n")
    return goldens


if __name__ == "__main__":
    regenerated = regenerate()
    for name in sorted(k for k in regenerated if not k.startswith("_")):
        snap = regenerated[name]
        frac = (snap["best_realized_speedup"] - 1) / \
            max(snap["best_predicted_speedup"] - 1, 1e-12)
        print(f"{name}: {snap['best_mutation']['kind']} "
              f"[{snap['best_source']}] predicted "
              f"{snap['best_predicted_speedup']:.3f}x -> realized "
              f"{snap['best_realized_speedup']:.3f}x ({frac:.0%})")
    print(f"wrote {GOLDEN_PATH}")
