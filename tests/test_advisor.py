"""Tests for ``repro.advisor`` — the what-if replay engine, the rule
catalog, the ranked advisor, the Diagnosis schema-v4 wiring, and the
advisor-guided hillclimb (PR-7 ISSUE acceptance):

* the ``Identity`` mutation replays byte-identically to baseline on every
  pre-existing golden lane (3 fixtures x {native, single-stream} x all 6
  golden backends) — fingerprint equality, not approx;
* growing a sync pool's capacity never *increases* modeled sync_resource
  stall cycles (hypothesis property across backends/pools/sizes);
* ``Diagnosis.from_json(to_json(d)) == d`` holds at v4 with recorded
  advice (hypothesis property);
* the advisor-guided search reaches the blind search's best objective in
  <= half the evaluations on the copy-storm workload (fixed seed).
"""
import json

import pytest

from conftest import ASYNC_HLO, COPYSTORM_HLO
from repro.advisor import (
    Advice,
    Advisor,
    CoalesceSyncTags,
    Evidence,
    Identity,
    PipelineAsyncChain,
    RelaxSyncEdge,
    ResizePool,
    RULES,
    ScaleLatency,
    SetIssue,
    TreeReduceChain,
    WhatIfEngine,
    advice_section,
    match_rules,
    mutation_from_dict,
    profile_fingerprint,
    rule_by_name,
    sync_resource_stall_cycles,
)
from repro.core import (
    SINGLE_ISSUE,
    AnalyzeRequest,
    Diagnosis,
    LeoService,
    get_backend,
    parse_hlo,
)

GOLDEN_BACKENDS = ("amd_mi300a", "intel_pvc", "nvidia_gh200",
                   "tpu_v4", "tpu_v5e", "tpu_v5p")

GPU_VENDOR_BACKENDS = ("nvidia_gh200", "amd_mi300a", "intel_pvc")


def _wide_hlo() -> str:
    from repro.launch.analysis_server import wide_ops_hlo
    return wide_ops_hlo()


def _storm_hlo(n: int) -> str:
    from repro.launch.analysis_server import copy_storm_hlo
    return copy_storm_hlo(n)


_FIXTURES = {
    "async": ASYNC_HLO,
    "copystorm": COPYSTORM_HLO,
}


@pytest.fixture(scope="module")
def modules():
    """fixture-name -> parsed Module (parsed once per test module)."""
    fixtures = dict(_FIXTURES, wide=_wide_hlo())
    return {name: parse_hlo(text) for name, text in fixtures.items()}


def _variant(backend_name: str, variant: str):
    b = get_backend(backend_name)
    if variant == "single_stream":
        return b.with_issue(SINGLE_ISSUE, name=f"{backend_name}@single")
    return b


# --------------------------------------------------------------------------
# Identity replay: byte-identical on every pre-existing golden lane.
# --------------------------------------------------------------------------

class TestIdentityReplay:
    """ISSUE acceptance: the identity what-if replay is byte-identical to
    the baseline StallProfile on all pre-existing golden lanes (the same
    3 fixtures x 2 issue variants x 6 backends that
    tests/goldens/backend_divergence.json pins)."""

    @pytest.mark.parametrize("backend", GOLDEN_BACKENDS)
    @pytest.mark.parametrize("variant", ("native", "single_stream"))
    @pytest.mark.parametrize("fixture", ("async", "copystorm", "wide"))
    def test_identity_is_byte_identical(self, modules, fixture, variant,
                                        backend):
        engine = WhatIfEngine(modules[fixture], _variant(backend, variant))
        res = engine.replay(Identity())
        assert profile_fingerprint(res.profile) == \
            profile_fingerprint(engine.baseline())
        assert res.modeled_speedup == 1.0
        assert res.delta_cycles == 0.0

    def test_replay_never_mutates_the_inputs(self, modules):
        module = modules["copystorm"]
        backend = get_backend("nvidia_gh200")
        before = profile_fingerprint(
            WhatIfEngine(module, backend).baseline())
        engine = WhatIfEngine(module, backend)
        for mutation in (CoalesceSyncTags(group=4),
                         PipelineAsyncChain(window=2),
                         TreeReduceChain(),
                         RelaxSyncEdge(match="copy"),
                         ResizePool(pool="named_barrier", capacity=12),
                         SetIssue(queues=1, width=1),
                         ScaleLatency(hw_field="hbm_bw", factor=2.0)):
            engine.replay(mutation)
        after = profile_fingerprint(
            WhatIfEngine(module, backend).baseline())
        assert before == after


# --------------------------------------------------------------------------
# Mutation semantics.
# --------------------------------------------------------------------------

class TestMutations:
    def test_resize_pool_grow_and_shrink(self):
        b = get_backend("nvidia_gh200")
        grown = ResizePool(pool="named_barrier", capacity=9).apply_backend(b)
        pool = next(p for p in grown.sync.pools if p.name == "named_barrier")
        assert pool.capacity == 9
        assert len(set(pool.instances)) == 9
        shrunk = ResizePool(pool="named_barrier",
                            capacity=2).apply_backend(b)
        pool = next(p for p in shrunk.sync.pools
                    if p.name == "named_barrier")
        assert pool.capacity == 2
        # originals untouched; mutant renamed so caches cannot alias it
        assert next(p for p in b.sync.pools
                    if p.name == "named_barrier").capacity == 6
        assert grown.name != b.name and "~" in grown.name

    def test_resize_pool_unknown_pool_raises(self):
        with pytest.raises(KeyError, match="no sync pool"):
            ResizePool(pool="nope", capacity=2).apply_backend(
                get_backend("nvidia_gh200"))

    def test_scale_latency_validates_field(self):
        b = get_backend("amd_mi300a")
        with pytest.raises(KeyError, match="scalable"):
            ScaleLatency(hw_field="clock_hz", factor=2.0).apply_backend(b)
        doubled = ScaleLatency(hw_field="hbm_bw", factor=2.0).apply_backend(b)
        assert doubled.hw.hbm_bw == pytest.approx(2 * b.hw.hbm_bw)

    def test_set_issue_inherits_unset_knobs(self):
        b = get_backend("intel_pvc")
        m = SetIssue(width=4).apply_backend(b)
        assert m.issue.width == 4
        assert m.issue.queues == b.issue.queues
        assert m.issue.policy == b.issue.policy

    def test_coalesce_groups_tags_without_touching_data_deps(self, modules):
        module = modules["copystorm"]
        mutated = CoalesceSyncTags(group=4).apply_module(module)
        orig = module.entry_computation
        new = mutated.entry_computation
        assert [i.name for i in orig.instructions] == \
            [i.name for i in new.instructions]
        assert [i.operands for i in orig.instructions] == \
            [i.operands for i in new.instructions]
        # 8 starts sharing tags in groups of 4 -> 2 distinct live tags
        tags = {t for i in new.instructions for t in i.sync.sets
                if i.sync.sets}
        orig_tags = {t for i in orig.instructions for t in i.sync.sets
                     if i.sync.sets}
        assert len(tags) == 2 and len(orig_tags) == 8

    def test_tree_reduce_preserves_names_and_root(self):
        # a serial 7-add chain over 8 leaves
        lines = ["HloModule chain", "", "ENTRY %main (p0: f32[64]) -> f32[64] {"]
        for i in range(8):
            lines.append(f"  %l{i} = f32[64] parameter({i})")
        lines.append("  %c0 = f32[64] add(%l0, %l1)")
        for i in range(1, 7):
            lines.append(f"  %c{i} = f32[64] add(%c{i-1}, %l{i+1})")
        lines.append("  ROOT %out = f32[64] multiply(%c6, %c6)")
        lines.append("}")
        module = parse_hlo("\n".join(lines))
        mutated = TreeReduceChain(min_length=4).apply_module(module)
        comp = mutated.entry_computation
        assert [i.name for i in comp.instructions] == \
            [i.name for i in module.entry_computation.instructions]
        # the tail still computes the root and consumes two prior adds
        tail = comp.get("c6")
        assert set(tail.operands) <= {f"c{i}" for i in range(6)}
        # depth shrinks from 7 serial levels to ceil(log2(8)) = 3
        def depth(name):
            instr = comp.get(name)
            if instr is None or instr.opcode != "add":
                return 0
            return 1 + max(depth(op) for op in instr.operands)
        assert depth("c6") == 3

    def test_mutation_dict_round_trip(self):
        for mutation in (Identity(),
                         ResizePool(pool="named_barrier", capacity=9),
                         SetIssue(queues=2, width=4, policy="round_robin"),
                         ScaleLatency(hw_field="hbm_bw", factor=2.0),
                         CoalesceSyncTags(group=8),
                         PipelineAsyncChain(window=2),
                         TreeReduceChain(min_length=6),
                         RelaxSyncEdge(match="copy")):
            data = mutation.to_dict()
            json.loads(json.dumps(data))    # JSON-pure
            assert mutation_from_dict(data) == mutation
        with pytest.raises(KeyError, match="unknown mutation kind"):
            mutation_from_dict({"kind": "Warp9"})


# --------------------------------------------------------------------------
# Rules: evidence patterns match per vendor, phrased natively.
# --------------------------------------------------------------------------

class TestRules:
    @pytest.fixture(scope="class")
    def storm_evidence(self):
        module = parse_hlo(_storm_hlo(48))
        out = {}
        for name in GPU_VENDOR_BACKENDS:
            backend = get_backend(name)
            profile = WhatIfEngine(module, backend).baseline()
            out[name] = Evidence(backend=backend, profile=profile)
        return out

    def test_vendors_match_different_rules(self, storm_evidence):
        matched = {name: [r.name for r in match_rules(ev)]
                   for name, ev in storm_evidence.items()}
        assert "batch_sync_allocations" in matched["nvidia_gh200"]
        assert "coalesce_outstanding_waits" in matched["amd_mi300a"]
        assert "expose_ilp_tree_reduce" in matched["intel_pvc"]
        # Intel's SBIDs absorb the storm: no sync-contention rule fires
        assert not any(r.startswith(("batch_", "coalesce_", "recycle_"))
                       for r in matched["intel_pvc"])

    def test_vendor_phrasing_is_native(self, storm_evidence):
        rule = rule_by_name("batch_sync_allocations")
        phrases = {name: rule.phrase(ev.backend)
                   for name, ev in storm_evidence.items()}
        assert "bar.sync" in phrases["nvidia_gh200"]
        assert "s_barrier" in phrases["amd_mi300a"]
        assert len(set(phrases.values())) == 3
        waits = rule_by_name("coalesce_outstanding_waits")
        assert "s_waitcnt" in waits.phrase(
            storm_evidence["amd_mi300a"].backend)
        sbids = rule_by_name("recycle_scoreboard_tokens")
        assert "SBID" in sbids.phrase(storm_evidence["intel_pvc"].backend)

    def test_evidence_lines_name_concrete_pressure(self, storm_evidence):
        lines = storm_evidence["nvidia_gh200"].lines()
        assert any("named_barrier" in ln and "evictions" in ln
                   for ln in lines)

    def test_rule_catalog_sanity(self):
        names = [r.name for r in RULES]
        assert len(names) == len(set(names))
        assert all(0 < r.confidence <= 1 for r in RULES)
        with pytest.raises(KeyError):
            rule_by_name("nope")


# --------------------------------------------------------------------------
# Advisor ranking + the Diagnosis v4 advice section.
# --------------------------------------------------------------------------

class TestAdvisor:
    @pytest.fixture(scope="class")
    def storm_reports(self):
        module = parse_hlo(_storm_hlo(48))
        return {name: Advisor().report(module, get_backend(name))
                for name in GPU_VENDOR_BACKENDS}

    def test_advice_ranked_by_score(self, storm_reports):
        for rep in storm_reports.values():
            scores = [a.score for a in rep.advice]
            assert scores == sorted(scores, reverse=True)
            assert all(a.modeled_speedup > 1.0 for a in rep.advice)

    def test_report_counts_replays(self, storm_reports):
        rep = storm_reports["nvidia_gh200"]
        assert rep.rules_matched >= 1
        assert rep.candidates_replayed >= rep.rules_matched
        assert rep.advisor_seconds > 0
        assert rep.top is rep.advice[0]

    def test_advice_round_trips(self, storm_reports):
        top = storm_reports["amd_mi300a"].top
        again = Advice.from_dict(json.loads(json.dumps(top.to_dict())))
        assert again.rule == top.rule
        assert again.to_mutation() == top.to_mutation()
        assert again.score == pytest.approx(top.score)

    def test_advice_section_shape(self, storm_reports):
        rep = storm_reports["intel_pvc"]
        section = advice_section(rep.advice, rep)
        assert section["recorded"] is True
        assert section["count"] == len(rep.advice)
        assert section["rules_matched"] == rep.rules_matched
        json.loads(json.dumps(section))     # JSON-pure

    def test_profile_seeding_skips_baseline_rerun(self):
        module = parse_hlo(_storm_hlo(8))
        backend = get_backend("nvidia_gh200")
        profile = WhatIfEngine(module, backend).baseline()
        advisor = Advisor()
        rep = advisor.report(module, backend, profile=profile)
        # candidates_replayed counts ONLY candidate replays: the baseline
        # came in from the pipeline and must not be re-paid
        assert rep.candidates_replayed >= 1


# --------------------------------------------------------------------------
# Service wiring: diagnose(advise=True), caching, rendering, wire flag.
# --------------------------------------------------------------------------

class TestServiceAdvice:
    @pytest.fixture(scope="class")
    def svc(self):
        return LeoService()

    @pytest.fixture(scope="class")
    def advised(self, svc):
        return svc.diagnose(_storm_hlo(48), backend="nvidia_gh200",
                            advise=True)

    def test_advise_lands_in_schema_v4(self, advised):
        assert advised.schema_version == 6
        assert advised.advice["recorded"] is True
        assert advised.advice["count"] >= 1
        top = advised.advice["items"][0]
        assert top["rule"] == "batch_sync_allocations"
        assert top["modeled_speedup"] >= 1.2

    def test_advise_false_keeps_not_recorded_default(self, svc, advised):
        plain = svc.diagnose(_storm_hlo(48), backend="nvidia_gh200")
        assert plain.advice["recorded"] is False
        # ...and the two shapes are cached under DIFFERENT keys
        again = svc.diagnose(_storm_hlo(48), backend="nvidia_gh200",
                             advise=True)
        assert again.advice == advised.advice

    def test_request_flag_round_trips_and_submits(self, svc):
        req = AnalyzeRequest(hlo_text=_storm_hlo(48), backend="amd_mi300a",
                             advise=True)
        again = AnalyzeRequest.from_json(req.to_json())
        assert again.advise is True
        diag = svc.submit(again)
        assert diag.advice["recorded"] is True
        # PR-9: on a wave-capable AMD part the priced advisor ranks
        # engaging residency above coalescing — hiding the vmcnt waits
        # beats shrinking them.  Coalescing stays on the board.
        ranked = [it["rule"] for it in diag.advice["items"]]
        assert ranked[0] == "raise_occupancy"
        assert "coalesce_outstanding_waits" in ranked

    def test_markdown_and_llm_context_render_advice(self, advised):
        md = advised.to_markdown()
        assert "Optimization advice (what-if replayed)" in md
        assert "batch_sync_allocations" in md
        ctx = advised.to_llm_context("C+L(S,A)")
        assert "Ranked optimization advice" in ctx
        assert "modeled" in ctx
        # the advice-free context level still renders (advice omitted)
        assert "Ranked optimization advice" not in \
            advised.to_llm_context("C+L(S)")

    def test_v4_json_round_trip_with_recorded_advice(self, advised):
        assert Diagnosis.from_json(advised.to_json()) == advised

    def test_advisor_metrics_observed(self):
        from repro.serve.metrics import MetricsRegistry
        reg = MetricsRegistry()
        svc = LeoService(metrics=reg)
        svc.diagnose(_storm_hlo(8), backend="nvidia_gh200", advise=True)
        text = reg.render()
        assert "leo_advisor_seconds_count 1" in text
        svc.diagnose(_storm_hlo(8), backend="nvidia_gh200")
        assert "leo_advisor_seconds_count 1" in reg.render()


# --------------------------------------------------------------------------
# Advisor-guided hillclimb (ISSUE acceptance: <= half the evaluations).
# --------------------------------------------------------------------------

class TestGuidedHillclimb:
    # Seed re-pinned when PR-9 grew the mutation space with SetOccupancy
    # (any space change reshuffles the blind order; the seed keeps the
    # guided-vs-blind comparison deterministic, not favourable).
    SEED = 0
    BUDGET = 16

    @pytest.fixture(scope="class")
    def searches(self):
        from repro.launch.hillclimb import whatif_search
        module = parse_hlo(_storm_hlo(48))
        out = {}
        for name in GPU_VENDOR_BACKENDS:
            backend = get_backend(name)
            blind = whatif_search(module, backend, mode="blind",
                                  budget=self.BUDGET, seed=self.SEED)
            guided = whatif_search(module, backend, mode="guided",
                                   budget=self.BUDGET, seed=self.SEED,
                                   target_speedup=blind["best_speedup"])
            out[name] = (blind, guided)
        return out

    @pytest.mark.parametrize("backend", GPU_VENDOR_BACKENDS)
    def test_guided_reaches_blind_best_in_half_the_evals(self, searches,
                                                         backend):
        blind, guided = searches[backend]
        assert guided["best_speedup"] >= blind["best_speedup"]
        assert guided["evaluations"] <= blind["evaluations"] / 2, \
            (guided["evaluations"], blind["evaluations"])
        # stronger: half of what blind needed just to FIND its best
        assert guided["evaluations"] * 2 <= \
            blind["evaluations_to_best"] + 1, \
            (guided["evaluations"], blind["evaluations_to_best"])

    def test_seeded_blind_search_is_reproducible(self):
        from repro.launch.hillclimb import whatif_search
        module = parse_hlo(_storm_hlo(8))
        backend = get_backend("nvidia_gh200")
        a = whatif_search(module, backend, mode="blind", budget=6, seed=7)
        b = whatif_search(module, backend, mode="blind", budget=6, seed=7)
        assert a["history"] == b["history"]
        c = whatif_search(module, backend, mode="blind", budget=6, seed=8)
        assert [h["mutation"] for h in c["history"]] != \
            [h["mutation"] for h in a["history"]]

    def test_mutation_space_covers_every_kind_family(self):
        from repro.launch.hillclimb import mutation_space
        kinds = {m.kind for m in mutation_space(get_backend("intel_pvc"))}
        assert {"ResizePool", "CoalesceSyncTags", "PipelineAsyncChain",
                "TreeReduceChain", "SetIssue", "ScaleLatency"} <= kinds


# --------------------------------------------------------------------------
# Hypothesis properties (ISSUE satellites).
# --------------------------------------------------------------------------

class TestProperties:
    def test_identity_byte_identical_property(self):
        hypothesis = pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (requirements-dev.txt)")
        from hypothesis import given, settings, strategies as st

        modules = {}

        @settings(max_examples=12, deadline=None)
        @given(backend=st.sampled_from(GOLDEN_BACKENDS),
               n=st.integers(2, 12))
        def prop(backend, n):
            module = modules.setdefault(n, parse_hlo(_storm_hlo(n)))
            engine = WhatIfEngine(module, get_backend(backend))
            assert profile_fingerprint(engine.replay(Identity()).profile) \
                == profile_fingerprint(engine.baseline())

        prop()

    def test_capacity_grow_never_increases_sync_stalls(self):
        hypothesis = pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (requirements-dev.txt)")
        from hypothesis import given, settings, strategies as st

        modules = {}

        @settings(max_examples=15, deadline=None)
        @given(backend=st.sampled_from(GPU_VENDOR_BACKENDS),
               n=st.integers(4, 16), extra=st.integers(1, 32),
               pool_idx=st.integers(0, 3))
        def prop(backend, n, extra, pool_idx):
            b = get_backend(backend)
            pools = b.sync.pools
            pool = pools[pool_idx % len(pools)]
            module = modules.setdefault(n, parse_hlo(_storm_hlo(n)))
            engine = WhatIfEngine(module, b)
            base = sync_resource_stall_cycles(engine.baseline())
            grown = engine.replay(ResizePool(
                pool=pool.name, capacity=pool.capacity + extra))
            assert sync_resource_stall_cycles(grown.profile) <= base + 1e-9

        prop()

    def test_v4_diagnosis_round_trip_property(self):
        hypothesis = pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (requirements-dev.txt)")
        from hypothesis import given, settings, strategies as st

        svc = LeoService()

        @settings(max_examples=8, deadline=None)
        @given(backend=st.sampled_from(GPU_VENDOR_BACKENDS),
               n=st.sampled_from((4, 8, 12)),
               advise=st.booleans(), n_chains=st.integers(1, 5))
        def prop(backend, n, advise, n_chains):
            diag = svc.diagnose(_storm_hlo(n), backend=backend,
                                advise=advise, n_chains=n_chains)
            assert diag.schema_version == 6
            assert diag.advice["recorded"] is advise
            assert Diagnosis.from_json(diag.to_json()) == diag

        prop()
