"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
shape/dtype sweeps, and LEO's waitcnt tracing through kernel DMA jaxprs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.5).astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("s,h,kv,hd", [
        (128, 4, 4, 64),    # MHA
        (256, 4, 2, 32),    # GQA
        (128, 8, 1, 64),    # MQA
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_matches_ref(self, s, h, kv, hd, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = _rand(ks[0], (2, s, h, hd), dtype)
        k = _rand(ks[1], (2, s, kv, hd), dtype)
        v = _rand(ks[2], (2, s, kv, hd), dtype)
        out = ops.flash_attention_op(q, k, v, causal=True, block_q=64,
                                     block_k=64, interpret=True)
        expect = ref.flash_attention_ref(q, k, v, causal=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect, np.float32),
                                   atol=tol, rtol=tol)

    def test_sliding_window(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = _rand(ks[0], (1, 256, 2, 32), jnp.float32)
        k = _rand(ks[1], (1, 256, 2, 32), jnp.float32)
        v = _rand(ks[2], (1, 256, 2, 32), jnp.float32)
        out = ops.flash_attention_op(q, k, v, causal=True, window=64,
                                     block_q=32, block_k=32, interpret=True)
        expect = ref.flash_attention_ref(q, k, v, causal=True, window=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=2e-5, rtol=2e-5)

    def test_matches_model_attention(self):
        """The model's chunked XLA path and the kernel agree."""
        from repro.models.attention import chunked_attention
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = _rand(ks[0], (2, 128, 4, 32), jnp.float32)
        k = _rand(ks[1], (2, 128, 2, 32), jnp.float32)
        v = _rand(ks[2], (2, 128, 2, 32), jnp.float32)
        out_kernel = ops.flash_attention_op(q, k, v, block_q=64, block_k=64,
                                            interpret=True)
        out_xla = chunked_attention(q, k, v, chunk=64)
        np.testing.assert_allclose(np.asarray(out_kernel),
                                   np.asarray(out_xla), atol=2e-5, rtol=2e-5)


class TestRmsnorm:
    @pytest.mark.parametrize("r,d", [(32, 128), (64, 256), (8, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("variant", ["baseline", "pipelined"])
    def test_matches_ref(self, r, d, dtype, variant):
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        x = _rand(ks[0], (r, d), dtype)
        scale = 1.0 + 0.1 * _rand(ks[1], (d,), jnp.float32)
        fn = ops.rmsnorm_baseline_op if variant == "baseline" \
            else ops.rmsnorm_op
        out = fn(x, scale, block_rows=8, interpret=True)
        expect = ref.rmsnorm_ref(x, scale)
        tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect, np.float32),
                                   atol=tol, rtol=tol)

    def test_leo_traces_rmsnorm_dma(self):
        """HipKittens case-study analogue: LEO's jaxpr front-end must trace
        mem_waitcnt edges through the pipelined kernel's DMA semaphores."""
        from repro.core import (
            EdgeKind, TPU_V5E, analyze_module, from_function,
        )
        from repro.kernels.rmsnorm import rmsnorm_pipelined

        x = jnp.zeros((32, 128), jnp.float32)
        scale = jnp.ones((128,), jnp.float32)
        module = from_function(
            lambda a, b: rmsnorm_pipelined(a, b, interpret=True), x, scale)
        # the pallas_call body must contain counted-semaphore sync ops
        sync_ops = [i for i in module.all_instructions()
                    if i.sync.sets or i.sync.waits]
        assert sync_ops, "expected dma_start/dma_wait in kernel jaxpr"
        an = analyze_module(module, TPU_V5E)
        waitcnt_edges = [e for e in an.graph.edges
                         if e.kind is EdgeKind.MEM_WAITCNT]
        assert waitcnt_edges, "LEO must trace through DMA semaphores"


class TestMlstmKernel:
    @pytest.mark.parametrize("s,h,hd,chunk", [(64, 2, 32, 16),
                                              (128, 1, 64, 32)])
    def test_matches_sequential_ref(self, s, h, hd, chunk):
        ks = jax.random.split(jax.random.PRNGKey(3), 5)
        b = 2
        q = _rand(ks[0], (b, s, h, hd), jnp.float32)
        k = _rand(ks[1], (b, s, h, hd), jnp.float32) / (hd ** 0.5)
        v = _rand(ks[2], (b, s, h, hd), jnp.float32)
        log_i = _rand(ks[3], (b, s, h), jnp.float32)
        log_f = jax.nn.log_sigmoid(_rand(ks[4], (b, s, h), jnp.float32) + 2.0)
        out = ops.mlstm_chunkwise_op(q, k, v, log_i, log_f, chunk=chunk,
                                     interpret=True)
        expect = ref.mlstm_ref(q, k, v, log_i, log_f)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=1e-4, rtol=1e-4)


class TestSsmKernel:
    @pytest.mark.parametrize("s,din,n,chunk", [(32, 128, 8, 8),
                                               (64, 256, 16, 16)])
    def test_matches_sequential_ref(self, s, din, n, chunk):
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        b = 2
        a = jax.nn.sigmoid(_rand(ks[0], (b, s, din, n), jnp.float32) + 1.0)
        bx = _rand(ks[1], (b, s, din, n), jnp.float32)
        c = _rand(ks[2], (b, s, n), jnp.float32)
        out = ops.ssm_scan_op(a, bx, c, chunk=chunk, interpret=True)
        expect = ref.ssm_scan_ref(a, bx, c)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=1e-4, rtol=1e-4)


class TestSlstmKernel:
    @pytest.mark.parametrize("s,d,chunk", [(32, 64, 8), (64, 128, 16)])
    def test_matches_sequential_ref(self, s, d, chunk):
        ks = jax.random.split(jax.random.PRNGKey(5), 2)
        b = 2
        xg = _rand(ks[0], (b, s, 4 * d), jnp.float32)
        r = _rand(ks[1], (d, 4 * d), jnp.float32) * 0.1
        out = ops.slstm_scan_op(xg, r, chunk=chunk, interpret=True)
        expect = ref.slstm_scan_ref(xg, r)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=1e-4, rtol=1e-4)
