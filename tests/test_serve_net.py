"""End-to-end serving tests over real sockets: LeoClient against a live
``LeoHttpd`` on an ephemeral port.

Covers the PR's acceptance contract: wire results byte-identical to
in-process ``LeoService.submit``; a full queue sheds 429 + Retry-After
and client backoff retries through it; cross-version clients round-trip
via the schema migration; N concurrent clients cost one parse; deadlines
answer 504; /metrics reports the serving catalog; drain is graceful.
"""
import http.client
import json
import threading
import time

import pytest

from repro.core.report import (
    ADVICE_NOT_RECORDED,
    ISSUE_PRESSURE_NOT_RECORDED,
    REWRITES_NOT_RECORDED,
    SCHEMA_VERSION,
    Diagnosis,
)
from repro.core.service import AnalyzeRequest, LeoService
from repro.serve import (
    LeoClient,
    LeoHttpd,
    MetricsRegistry,
    ProtocolError,
    RetriesExceeded,
    encode_request,
)


class _BlockingService(LeoService):
    """A LeoService whose analyses park on an Event — the deterministic
    way to hold a slot occupied while tests probe admission control,
    instead of racing against real pipeline latency."""

    def __init__(self, **kwargs):
        super().__init__(**kwargs)
        self.gate = threading.Event()

    def submit(self, request):
        self.gate.wait(timeout=30.0)
        return super().submit(request)


def _post_raw(port, body, host="127.0.0.1", timeout=10.0):
    """One raw POST /v1/analyze, no retries: (status, headers, payload)."""
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request("POST", "/v1/analyze", body=body,
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        return resp.status, dict(resp.headers.items()), resp.read()
    finally:
        conn.close()


def _await(predicate, timeout=5.0, poll=0.005):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(poll)
    return False


class TestRoundTrip:
    def test_byte_identical_to_in_process(self, async_hlo_text):
        svc = LeoService()
        with LeoHttpd(service=svc, port=0, slots=2) as app:
            with LeoClient(port=app.port) as client:
                req = AnalyzeRequest(hlo_text=async_hlo_text,
                                     backend="tpu_v5e",
                                     hints={"total_devices": 8})
                wire_diag = client.submit(req)
            inproc = svc.submit(AnalyzeRequest(
                hlo_text=async_hlo_text, backend="tpu_v5e",
                hints={"total_devices": 8}))
        assert wire_diag.to_json() == inproc.to_json()

    def test_fanout_and_timing(self, async_hlo_text):
        with LeoHttpd(port=0, slots=2) as app:
            with LeoClient(port=app.port) as client:
                resp = client.submit_wire(AnalyzeRequest(
                    hlo_text=async_hlo_text,
                    backends=["tpu_v5e", "amd_mi300a"]))
        assert resp.kind == "fanout"
        fanout = resp.result()
        assert sorted(fanout) == ["amd_mi300a", "tpu_v5e"]
        assert all(isinstance(d, Diagnosis) for d in fanout.values())
        # satellite: queue/service split surfaces in the wire timing
        assert resp.timing["queue_seconds"] >= 0
        assert resp.timing["service_seconds"] > 0
        assert resp.timing["seconds"] == pytest.approx(
            resp.timing["queue_seconds"] + resp.timing["service_seconds"],
            abs=1e-6)

    def test_batch_pipelines(self, async_hlo_text, copystorm_hlo_text):
        with LeoHttpd(port=0, slots=4) as app:
            with LeoClient(port=app.port) as client:
                reqs = [AnalyzeRequest(hlo_text=t, backend="tpu_v5e")
                        for t in (async_hlo_text, copystorm_hlo_text,
                                  async_hlo_text)]
                out = client.diagnose_batch(reqs)
        assert len(out) == 3
        # order-preserving: duplicates land identical
        assert out[0].to_json() == out[2].to_json()
        assert out[1].to_json() != out[0].to_json()

    def test_invalid_request_is_400_not_retried(self, async_hlo_text):
        with LeoHttpd(port=0, slots=1) as app:
            with LeoClient(port=app.port, max_retries=3) as client:
                with pytest.raises(ProtocolError):
                    client.diagnose("")     # empty hlo_text
                assert client.stats["retries"] == 0


class TestBackpressure:
    def test_full_queue_sheds_429_and_backoff_succeeds(
            self, async_hlo_text, copystorm_hlo_text):
        svc = _BlockingService(max_workers=4)
        body = encode_request(AnalyzeRequest(hlo_text=async_hlo_text,
                                             backend="tpu_v5e"))
        with LeoHttpd(service=svc, port=0, slots=1, max_queue=1,
                      retry_after_seconds=0.05) as app:
            try:
                # occupy the slot, then the queue
                t1 = threading.Thread(target=_post_raw,
                                      args=(app.port, body), daemon=True)
                t1.start()
                assert _await(lambda: app.engine.in_flight == 1)
                body2 = encode_request(AnalyzeRequest(
                    hlo_text=copystorm_hlo_text, backend="tpu_v5e"))
                t2 = threading.Thread(target=_post_raw,
                                      args=(app.port, body2), daemon=True)
                t2.start()
                assert _await(lambda: app.engine.queue_depth == 1)

                # 3rd concurrent request: shed with the retry hint
                status, headers, payload = _post_raw(app.port, body)
                assert status == 429
                assert float(headers["Retry-After"]) == \
                    pytest.approx(0.05)
                envelope = json.loads(payload)
                assert envelope["error"]["code"] == "overloaded"

                # a retrying client parked on the full queue wins once
                # the gate opens
                result = {}

                def retrying():
                    with LeoClient(port=app.port, max_retries=20,
                                   backoff_base_seconds=0.02,
                                   backoff_cap_seconds=0.1) as c:
                        result["diag"] = c.diagnose(async_hlo_text,
                                                    backend="tpu_v5e")
                        result["stats"] = dict(c.stats)

                t3 = threading.Thread(target=retrying, daemon=True)
                t3.start()
                assert _await(
                    lambda: app.m_sheds.value() >= 2, timeout=5.0)
                svc.gate.set()
                t3.join(timeout=30.0)
                assert "diag" in result, "retrying client never succeeded"
                assert result["stats"]["sheds_seen"] >= 1
                assert result["diag"].backend == "tpu_v5e"
                for t in (t1, t2):
                    t.join(timeout=30.0)
            finally:
                svc.gate.set()

    def test_no_retries_surfaces_retries_exceeded(self, async_hlo_text):
        svc = _BlockingService(max_workers=4)
        with LeoHttpd(service=svc, port=0, slots=1, max_queue=1) as app:
            try:
                body = encode_request(AnalyzeRequest(
                    hlo_text=async_hlo_text, backend="tpu_v5e"))
                t1 = threading.Thread(target=_post_raw,
                                      args=(app.port, body), daemon=True)
                t1.start()
                assert _await(lambda: app.engine.in_flight == 1)
                t2 = threading.Thread(target=_post_raw,
                                      args=(app.port, body), daemon=True)
                t2.start()
                assert _await(lambda: app.engine.queue_depth == 1)
                with LeoClient(port=app.port, max_retries=1,
                               backoff_base_seconds=0.01) as client:
                    with pytest.raises(RetriesExceeded) as ei:
                        client.diagnose(async_hlo_text, backend="tpu_v5e")
                assert ei.value.status == 429
            finally:
                svc.gate.set()


class TestDeadlines:
    def test_inflight_overdue_is_504_abandoned(self, async_hlo_text):
        svc = _BlockingService(max_workers=4)
        with LeoHttpd(service=svc, port=0, slots=1) as app:
            try:
                body = encode_request(
                    AnalyzeRequest(hlo_text=async_hlo_text,
                                   backend="tpu_v5e"),
                    deadline_seconds=0.3)
                t0 = time.monotonic()
                status, _, payload = _post_raw(app.port, body)
                took = time.monotonic() - t0
                assert status == 504
                assert json.loads(payload)["error"]["code"] == \
                    "deadline_exceeded"
                assert took < 5.0       # gave up near the deadline
                assert app.m_deadline.value() == 1
            finally:
                svc.gate.set()

    def test_queued_overdue_cancelled_without_slot(self, async_hlo_text,
                                                   copystorm_hlo_text):
        svc = _BlockingService(max_workers=4)
        with LeoHttpd(service=svc, port=0, slots=1, max_queue=4) as app:
            try:
                blocker = encode_request(AnalyzeRequest(
                    hlo_text=async_hlo_text, backend="tpu_v5e"))
                t1 = threading.Thread(target=_post_raw,
                                      args=(app.port, blocker), daemon=True)
                t1.start()
                assert _await(lambda: app.engine.in_flight == 1)
                doomed = encode_request(
                    AnalyzeRequest(hlo_text=copystorm_hlo_text,
                                   backend="tpu_v5e"),
                    deadline_seconds=0.2)
                status, _, payload = _post_raw(app.port, doomed)
                assert status == 504
                err = json.loads(payload)["error"]["message"]
                assert "never admitted" in err
            finally:
                svc.gate.set()


class TestCrossVersion:
    def test_v2_client_against_v3_server(self, async_hlo_text):
        """An old-generation client round-trips via the migration path:
        the wire downgrade is the exact inverse of ``from_dict`` (same
        payload shape as the committed v2 migration fixtures in
        tests/test_syncmodel.py)."""
        svc = LeoService()
        with LeoHttpd(service=svc, port=0, slots=2) as app:
            with LeoClient(port=app.port, accept_schema=2) as client:
                resp = client.submit_wire(AnalyzeRequest(
                    hlo_text=async_hlo_text, backend="tpu_v5e"))
            inproc = svc.submit(AnalyzeRequest(hlo_text=async_hlo_text,
                                               backend="tpu_v5e"))
        assert resp.schema_version == 2
        # a genuine v2 payload on the wire: the v3-only section is gone
        assert "issue_pressure" not in resp.payload
        assert resp.payload["schema_version"] == 2
        migrated = resp.result()
        assert migrated.schema_version == SCHEMA_VERSION
        assert migrated.issue_pressure == ISSUE_PRESSURE_NOT_RECORDED
        # identical to migrating the same v2 payload built by hand from
        # the in-process diagnosis (the test_syncmodel fixture recipe)
        v2_by_hand = inproc.to_dict()
        del v2_by_hand["issue_pressure"]
        v2_by_hand["schema_version"] = 2
        assert migrated.to_json() == \
            Diagnosis.from_dict(v2_by_hand).to_json()

    def test_v3_client_against_v4_server(self, async_hlo_text):
        """PR-7 ISSUE acceptance: a v3-era client asking a v4 server for
        advice-bearing diagnoses gets a genuine v3 payload (the ``advice``
        section is dropped on the wire), and migrating it forward equals
        the hand-built v3 migration fixture recipe."""
        svc = LeoService()
        with LeoHttpd(service=svc, port=0, slots=2) as app:
            with LeoClient(port=app.port, accept_schema=3) as client:
                resp = client.submit_wire(AnalyzeRequest(
                    hlo_text=async_hlo_text, backend="tpu_v5e",
                    advise=True))
            inproc = svc.submit(AnalyzeRequest(hlo_text=async_hlo_text,
                                               backend="tpu_v5e",
                                               advise=True))
        assert inproc.advice["recorded"] is True
        assert resp.schema_version == 3
        # a genuine v3 payload on the wire: the v4-only section is gone
        assert "advice" not in resp.payload
        assert "issue_pressure" in resp.payload
        assert resp.payload["schema_version"] == 3
        migrated = resp.result()
        assert migrated.schema_version == SCHEMA_VERSION
        assert migrated.advice == ADVICE_NOT_RECORDED
        assert migrated.issue_pressure == inproc.issue_pressure
        # identical to migrating the same v3 payload built by hand from
        # the in-process diagnosis (the test_syncmodel fixture recipe)
        v3_by_hand = inproc.to_dict()
        del v3_by_hand["advice"]
        v3_by_hand["schema_version"] = 3
        assert migrated.to_json() == \
            Diagnosis.from_dict(v3_by_hand).to_json()

    def test_v4_client_against_v5_server(self, copystorm_hlo_text):
        """PR-8 ISSUE acceptance: a v4-era client asking a v5 server for
        rewrite-bearing diagnoses gets a genuine v4 payload (the
        ``rewrites`` section is dropped on the wire, ``advice`` kept),
        and migrating it forward equals the hand-built v4 migration
        fixture recipe."""
        svc = LeoService()
        with LeoHttpd(service=svc, port=0, slots=2) as app:
            with LeoClient(port=app.port, accept_schema=4) as client:
                resp = client.submit_wire(AnalyzeRequest(
                    hlo_text=copystorm_hlo_text, backend="nvidia_gh200",
                    advise=True, rewrite=True))
            inproc = svc.submit(AnalyzeRequest(
                hlo_text=copystorm_hlo_text, backend="nvidia_gh200",
                advise=True, rewrite=True))
        assert inproc.rewrites["recorded"] is True
        assert resp.schema_version == 4
        # a genuine v4 payload on the wire: the v5-only section is gone,
        # the v4 advice section survives
        assert "rewrites" not in resp.payload
        assert "advice" in resp.payload
        assert resp.payload["schema_version"] == 4
        migrated = resp.result()
        assert migrated.schema_version == SCHEMA_VERSION
        assert migrated.rewrites == REWRITES_NOT_RECORDED
        assert migrated.advice == inproc.advice
        # identical to migrating the same v4 payload built by hand from
        # the in-process diagnosis (the test_syncmodel fixture recipe)
        v4_by_hand = inproc.to_dict()
        del v4_by_hand["rewrites"]
        v4_by_hand["schema_version"] = 4
        assert migrated.to_json() == \
            Diagnosis.from_dict(v4_by_hand).to_json()

    def test_v5_client_against_v6_server(self, copystorm_hlo_text):
        """PR-9 ISSUE acceptance: a v5-era client asking a v6 server for
        occupancy-engaged diagnoses gets a genuine v5 payload (the
        ``occupancy`` section is dropped on the wire, ``rewrites`` and
        ``advice`` kept), and migrating it forward equals the hand-built
        v5 migration fixture recipe."""
        from repro.core import DiagnoseOptions
        from repro.core.report import OCCUPANCY_NOT_RECORDED
        svc = LeoService()
        opts = DiagnoseOptions(advise=True, occupancy=True)
        with LeoHttpd(service=svc, port=0, slots=2) as app:
            with LeoClient(port=app.port, accept_schema=5) as client:
                resp = client.submit_wire(AnalyzeRequest(
                    hlo_text=copystorm_hlo_text, backend="amd_mi300a",
                    options=opts))
            inproc = svc.submit(AnalyzeRequest(
                hlo_text=copystorm_hlo_text, backend="amd_mi300a",
                options=opts))
        assert inproc.occupancy["recorded"] is True
        assert resp.schema_version == 5
        # a genuine v5 payload on the wire: the v6-only section is gone,
        # every v5 section survives
        assert "occupancy" not in resp.payload
        assert "advice" in resp.payload and "rewrites" in resp.payload
        assert resp.payload["schema_version"] == 5
        migrated = resp.result()
        assert migrated.schema_version == SCHEMA_VERSION
        assert migrated.occupancy == OCCUPANCY_NOT_RECORDED
        assert migrated.advice == inproc.advice
        # identical to migrating the same v5 payload built by hand from
        # the in-process diagnosis (the test_syncmodel fixture recipe)
        v5_by_hand = inproc.to_dict()
        del v5_by_hand["occupancy"]
        v5_by_hand["schema_version"] = 5
        assert migrated.to_json() == \
            Diagnosis.from_dict(v5_by_hand).to_json()

    def test_future_client_negotiates_down(self, async_hlo_text):
        """A newer-generation client (accept_schema > server's) just gets
        the server's newest — negotiation is min(), both directions."""
        with LeoHttpd(port=0, slots=2) as app:
            with LeoClient(port=app.port,
                           accept_schema=SCHEMA_VERSION + 4) as client:
                resp = client.submit_wire(AnalyzeRequest(
                    hlo_text=async_hlo_text, backend="tpu_v5e"))
        assert resp.schema_version == SCHEMA_VERSION
        assert "issue_pressure" in resp.payload


class TestConcurrency:
    def test_n_clients_one_parse(self, copystorm_hlo_text):
        """The single-flight invariant holds across the network: N
        concurrent clients hammering one warm server cost exactly one
        parse and one pipeline run (extends the in-process assertions in
        tests/test_service.py to the wire)."""
        svc = LeoService(max_workers=8)
        n = 6
        results = [None] * n
        with LeoHttpd(service=svc, port=0, slots=4, max_queue=2 * n) as app:
            barrier = threading.Barrier(n)

            def hammer(i):
                with LeoClient(port=app.port, max_retries=10,
                               backoff_base_seconds=0.02) as c:
                    barrier.wait()
                    results[i] = c.diagnose(copystorm_hlo_text,
                                            backend="tpu_v5e")

            threads = [threading.Thread(target=hammer, args=(i,),
                                        daemon=True) for i in range(n)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=60.0)
        assert all(r is not None for r in results)
        assert len({r.to_json() for r in results}) == 1
        assert svc.stats.parse_misses == 1
        assert svc.stats.analyze_calls - svc.stats.analyze_hits == 1


class TestHealthMetricsDrain:
    def test_endpoints_and_graceful_drain(self, async_hlo_text):
        metrics = MetricsRegistry()
        svc = LeoService(metrics=metrics)
        app = LeoHttpd(service=svc, port=0, slots=2, metrics=metrics)
        app.start()
        client = LeoClient(port=app.port)
        try:
            assert client.healthz()
            assert client.readyz()
            client.diagnose(async_hlo_text, backend="tpu_v5e")
            client.diagnose(async_hlo_text, backend="tpu_v5e")  # warm hit

            text = client.metrics_text()
            # the serving catalog: queue depth, sheds, cache tiers,
            # latency histograms — all present, traffic counted
            for name in ("leo_queue_depth", "leo_inflight_requests",
                         "leo_sheds_total", "leo_admissions_total",
                         "leo_deadline_exceeded_total", "leo_ready",
                         "leo_queue_seconds_bucket",
                         "leo_service_seconds_bucket",
                         "leo_parse_seconds_bucket",
                         "leo_pipeline_seconds_bucket"):
                assert name in text, f"missing {name}"
            assert 'leo_requests_total{endpoint="analyze",code="200"} 2' \
                in text
            assert ('leo_cache_requests_total{tier="diagnosis_memory",'
                    'result="hit"} 1') in text
            assert ('leo_cache_requests_total{tier="diagnosis_memory",'
                    'result="miss"} 1') in text
            assert 'leo_diagnoses_total{backend="tpu_v5e"} 2' in text
            assert "leo_ready 1" in text

            stats = client.server_stats()
            assert stats["diagnosis_hits"] == 1

            # drain: readyz flips, new admissions 503, in-flight finishes
            app.engine.begin_drain()
            assert not client.readyz()
            with pytest.raises(RetriesExceeded) as ei:
                with LeoClient(port=app.port, max_retries=1,
                               backoff_base_seconds=0.01) as c2:
                    c2.diagnose(async_hlo_text, backend="tpu_v5e")
            assert ei.value.status == 503
        finally:
            client.close()
            assert app.drain(timeout=10.0)

    def test_not_found_and_method_errors(self):
        with LeoHttpd(port=0, slots=1) as app:
            conn = http.client.HTTPConnection("127.0.0.1", app.port,
                                              timeout=5.0)
            try:
                conn.request("GET", "/nope")
                resp = conn.getresponse()
                payload = resp.read()
                assert resp.status == 404
                assert json.loads(payload)["error"]["code"] == "not_found"
            finally:
                conn.close()
