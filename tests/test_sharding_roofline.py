"""Sharding-rule and roofline unit tests (no 512-device mesh needed: rules
are pure functions of mesh shape + config; we build small meshes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_shape, smoke_config
from repro.core import TPU_V5E, TPU_V5P, compute_roofline, parse_hlo
from repro.launch import specs as S
from repro.parallel.sharding import ShardingRules


def _mesh(data=2, model=4):
    n = len(jax.devices())
    if n < data * model:
        pytest.skip(f"needs {data * model} devices (conftest keeps 1 host "
                    "device; rules are still covered by shape-math tests)")
    return jax.make_mesh((data, model), ("data", "model"))


class TestShardingRuleMath:
    """Pure spec-level checks via a fake mesh-shape object."""

    class FakeMesh:
        def __init__(self, shape):
            self.shape = shape
            self.axis_names = tuple(shape)

    def _rules(self, cfg, data=16, model=16):
        rules = ShardingRules.__new__(ShardingRules)
        rules.mesh = self.FakeMesh({"data": data, "model": model})
        rules.cfg = cfg
        rules.fsdp = True
        rules.zero1 = True
        rules.dp_axes = ("data",)
        rules.dp_spec = "data"
        rules.tp = model
        return rules

    def test_head_filter_blocks_subhead_sharding(self):
        cfg = get_config("qwen2-0.5b")   # 14 heads, kv=2: neither divides 16
        rules = self._rules(cfg)
        spec = rules._head_filter("groups/0/attn/wk", P(None, "model"),
                                  (24, 896, 128))
        assert spec == P(None, None)
        spec = rules._head_filter("groups/0/attn/wq", P(None, "model"),
                                  (24, 896, 896))
        assert spec == P(None, None)

    def test_head_filter_allows_divisible_heads(self):
        cfg = get_config("glm4-9b")      # 32 heads % 16 == 0
        rules = self._rules(cfg)
        spec = rules._head_filter("groups/0/attn/wq", P(None, "model"),
                                  (40, 4096, 4096))
        assert spec == P(None, "model")
        # kv=2 still blocked
        spec = rules._head_filter("groups/0/attn/wk", P(None, "model"),
                                  (40, 4096, 256))
        assert spec == P(None, None)

    def test_divisibility_filter(self):
        from repro.parallel.sharding import _divisibility_filter
        mesh = self.FakeMesh({"data": 16, "model": 16})
        # hymba vocab 32001 is not divisible by 16
        spec = _divisibility_filter(P("model", None), (32001, 1600), mesh)
        assert spec == P(None, None)
        spec = _divisibility_filter(P("model", None), (32000, 1600), mesh)
        assert spec == P("model", None)

    def test_auto_fsdp_shards_large_weights(self):
        from repro.parallel.sharding import _auto_shard_dp
        mesh = self.FakeMesh({"data": 16, "model": 16})
        # 7168 x 19200 bf16 = 263 MB > 128 MB threshold
        spec = _auto_shard_dp(P(None, None, "model"), (62, 7168, 19200),
                              mesh, ("data",), 128 * 2**20)
        assert "data" in tuple(spec)
        # small tensor untouched
        spec = _auto_shard_dp(P(None, None), (64, 64), mesh, ("data",),
                              128 * 2**20)
        assert spec == P(None, None)


class TestInputSpecs:
    def test_train_specs_match_shape(self):
        cfg = get_config("qwen2-0.5b")
        shape = get_shape("train_4k")
        specs = S.input_specs(cfg, shape)
        assert specs["batch"]["tokens"].shape == (256, 4096)
        assert "state" in specs
        # params + optimizer mirror each other leaf-for-leaf
        n_params = len(jax.tree.leaves(specs["state"]["params"]))
        n_mu = len(jax.tree.leaves(specs["state"]["opt"]["mu"]))
        assert n_params == n_mu

    def test_frontend_archs_get_embeds(self):
        cfg = get_config("musicgen-medium")
        specs = S.batch_specs(cfg, get_shape("train_4k"))
        assert "embeds" in specs and specs["embeds"].shape == (256, 4096,
                                                               1536)
        assert "tokens" not in specs

    def test_decode_specs(self):
        cfg = get_config("glm4-9b")
        shape = get_shape("decode_32k")
        specs = S.input_specs(cfg, shape)
        assert specs["batch"]["token"].shape == (128,)
        kv = specs["decode_state"]["groups"][0]["kv"]["k"]
        assert kv.shape == (40, 128, 32768, 2, 128)

    def test_no_device_allocation(self):
        """input_specs must be pure ShapeDtypeStructs — no arrays."""
        cfg = smoke_config(get_config("qwen2-0.5b"))
        specs = S.input_specs(cfg, get_shape("train_4k"))
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


class TestRoofline:
    def _module(self):
        text = """\
HloModule rl
ENTRY %main (a: bf16[1024,1024], b: bf16[1024,1024]) -> bf16[1024,1024] {
  %a = bf16[1024,1024] parameter(0)
  %b = bf16[1024,1024] parameter(1)
  ROOT %d = bf16[1024,1024] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
        return parse_hlo(text)

    def test_terms_match_hand_calc(self):
        mod = self._module()
        rl = compute_roofline(mod, TPU_V5E, chips=1, label="t",
                              model_flops=2 * 1024**3)
        assert rl.hlo_flops == pytest.approx(2 * 1024**3)
        assert rl.compute_s == pytest.approx(2 * 1024**3 / 197e12)
        # bytes: two operand reads by the dot + its output write
        assert rl.hlo_bytes == pytest.approx(3 * 1024 * 1024 * 2)
        assert rl.useful_ratio == pytest.approx(1.0)
        # AI = 341 flops/byte > v5e ridge (197T/819G = 240): compute-bound
        assert rl.dominant == "compute"

    def test_backend_shifts_dominance(self):
        mod = self._module()
        e = compute_roofline(mod, TPU_V5E, chips=1, label="e")
        p = compute_roofline(parse_hlo(
            open_text := None) if False else mod, TPU_V5P, chips=1,
            label="p")
        # v5p's memory term shrinks 3.4x while compute shrinks 2.3x
        assert p.memory_s < e.memory_s
        assert (e.memory_s / e.compute_s) > (p.memory_s / p.compute_s)

    def test_collective_term_from_text(self):
        text = """\
HloModule coll
%add (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %s = f32[] add(%x, %y)
}
ENTRY %main (a: f32[4096]) -> f32[4096] {
  %a = f32[4096] parameter(0)
  ROOT %ar = f32[4096] all-reduce(%a), replica_groups=[16,16]<=[256], to_apply=%add
}
"""
        mod = parse_hlo(text, hints={"total_devices": 256})
        rl = compute_roofline(mod, TPU_V5E, chips=256, label="c")
        expect = 2 * 4096 * 4 * 15 / 16 / 50e9
        assert rl.collective_s == pytest.approx(expect)
        assert rl.dominant == "collective" or rl.collective_s > 0


class TestFusedRegionPricing:
    def test_marked_region_pays_no_bytes(self):
        import jax.numpy as jnp
        from repro.models.flags import FUSED_REGION_MARK

        def f(x):
            with jax.named_scope(FUSED_REGION_MARK):
                y = jnp.tanh(x) * 2.0
                y = y @ x
            return y.sum()

        x = jnp.zeros((256, 256), jnp.float32)
        hlo = jax.jit(f).lower(x).compile().as_text()
        mod = parse_hlo(hlo)
        marked = [i for i in mod.all_instructions()
                  if FUSED_REGION_MARK in i.op_name]
        assert marked, "scope must survive into HLO metadata"
        assert all(i.bytes_read == 0 and i.bytes_written == 0
                   for i in marked)
        # FLOPs must NOT be zeroed
        assert any(i.flops > 0 for i in marked)
