"""Dependency graph, sync tracing, pruning, blame, slicing, coverage tests."""
import pytest

from repro.core import (
    EdgeKind,
    OpClass,
    StallClass,
    TPU_V5E,
    TPU_V5P,
    analyze_hlo,
    analyze_module,
    build_dependency_graph,
    parse_hlo,
    sample,
    single_dependency_coverage,
)
from repro.core.blame import attribute_blame
from repro.core.isa import (
    Computation,
    Instruction,
    Module,
    ShapeInfo,
    SyncInfo,
    SyncKind,
    classify_opcode,
)
from repro.core.pruning import prune
from repro.core.sync_trace import add_sync_edges


def _mk(name, opcode, operands=(), comp="c", sync=None, shape=None, **kw):
    instr = Instruction(
        name=name, opcode=opcode, op_class=classify_opcode(opcode),
        shape=shape or ShapeInfo(dtype="f32", dims=(128, 128)),
        operands=tuple(operands), computation=comp, index=0, **kw)
    if sync is not None:
        instr.sync = sync
    return instr


def _module(instrs, name="synthetic"):
    comp = Computation(name="c", kind="entry")
    for i in instrs:
        comp.add(i)
    instrs[-1].is_root = True
    mod = Module(name=name, entry="c")
    mod.add_computation(comp)
    return mod


class TestDependencyGraph:
    def test_simple_raw_edges(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        graph = build_dependency_graph(mod, TPU_V5E)
        deps = {(e.producer, e.consumer) for e in graph.edges}
        assert ("main.1::ag-done", "main.1::dot.1") in deps
        assert ("main.1::indep", "main.1::dot.1") in deps

    def test_sees_through_tuple_glue(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        graph = build_dependency_graph(mod, TPU_V5E)
        # %final adds %result = gte(loop, 1); resolution must reach the
        # loop-body producer %gain (through while + tuple glue).
        producers = {e.producer for e in graph.deps_of("main.1::final",
                                                       alive_only=False)}
        assert "body.1::gain" in producers

    def test_loop_carried_edge(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        graph = build_dependency_graph(mod, TPU_V5E)
        kinds = {e.kind for e in graph.deps_of("body.1::gain",
                                               alive_only=False)}
        assert EdgeKind.LOOP_CARRIED in kinds

    def test_cross_computation_resolution(self, async_hlo_text):
        """A use inside the loop body must also reach the init value in the
        caller (paper: union of reaching defs at joins)."""
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        graph = build_dependency_graph(mod, TPU_V5E)
        producers = {e.producer for e in graph.deps_of("body.1::gain",
                                                       alive_only=False)}
        assert "main.1::dot.1" in producers  # init path

    def test_predicate_edge(self):
        instrs = [
            _mk("p", "parameter", shape=ShapeInfo("pred", (128,)),
                attributes={"literal": "0"}),
            _mk("a", "parameter", attributes={"literal": "1"}),
            _mk("b", "parameter", attributes={"literal": "2"}),
            _mk("sel", "select", ("p", "a", "b")),
        ]
        mod = _module(instrs)
        graph = build_dependency_graph(mod, TPU_V5E)
        kinds = {(e.producer, e.kind) for e in graph.deps_of(
            "c::sel", alive_only=False)}
        assert ("c::p", EdgeKind.PREDICATE) in kinds
        assert ("c::a", EdgeKind.REG_RAW) in kinds


class TestSyncTracing:
    def test_barrier_edges(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        graph = build_dependency_graph(mod, TPU_V5E)
        n = add_sync_edges(graph)
        assert n > 0
        edges = {(e.producer, e.consumer) for e in graph.edges
                 if e.kind is EdgeKind.MEM_BARRIER}
        assert ("main.1::ag-start", "main.1::ag-done") in edges
        # ...and *through* the start to the gather it transfers.
        assert ("main.1::gather.1", "main.1::ag-done") in edges

    def test_token_edges(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        graph = build_dependency_graph(mod, TPU_V5E)
        add_sync_edges(graph)
        kinds = {e.kind for e in graph.edges}
        assert EdgeKind.MEM_SWSB in kinds

    def test_waitcnt_oldest_m_minus_n(self):
        """s_waitcnt semantics: wait(counter=N) blames the (M-N) oldest."""
        sem = "sem0"
        instrs = [
            _mk("a", "parameter", attributes={"literal": "0"}),
            _mk("d1", "dma_start", ("a",),
                sync=SyncInfo(SyncKind.WAITCNT, sets=(sem,))),
            _mk("d2", "dma_start", ("a",),
                sync=SyncInfo(SyncKind.WAITCNT, sets=(sem,))),
            _mk("d3", "dma_start", ("a",),
                sync=SyncInfo(SyncKind.WAITCNT, sets=(sem,))),
            _mk("w1", "dma_wait", (),
                sync=SyncInfo(SyncKind.WAITCNT, waits=(sem,), counter=1)),
            _mk("use", "add", ("a", "a")),
        ]
        mod = _module(instrs)
        graph = build_dependency_graph(mod, TPU_V5E)
        add_sync_edges(graph)
        blamed = {e.producer for e in graph.edges
                  if e.kind is EdgeKind.MEM_WAITCNT and e.consumer == "c::w1"}
        # M=3 pending, N=1 allowed outstanding -> blame the 2 oldest.
        assert "c::d1" in blamed and "c::d2" in blamed
        assert "c::d3" not in blamed

    def test_waitcnt_epoch_boundary(self):
        sem = "s"
        instrs = [
            _mk("a", "parameter", attributes={"literal": "0"}),
            _mk("d1", "dma_start", ("a",),
                sync=SyncInfo(SyncKind.WAITCNT, sets=(sem,))),
            _mk("w0", "dma_wait", (),
                sync=SyncInfo(SyncKind.WAITCNT, waits=(sem,), counter=0)),
            _mk("d2", "dma_start", ("a",),
                sync=SyncInfo(SyncKind.WAITCNT, sets=(sem,))),
            _mk("w1", "dma_wait", (),
                sync=SyncInfo(SyncKind.WAITCNT, waits=(sem,), counter=0)),
            _mk("use", "add", ("a", "a")),
        ]
        mod = _module(instrs)
        graph = build_dependency_graph(mod, TPU_V5E)
        add_sync_edges(graph)
        blamed_w1 = {e.producer for e in graph.edges
                     if e.kind is EdgeKind.MEM_WAITCNT and
                     e.consumer == "c::w1"}
        # d1 drained at the w0 epoch; d2 (plus reach-through to its data
        # operand "a") is what w1 actually waits on.
        assert "c::d2" in blamed_w1 and "c::d1" not in blamed_w1


class TestPruning:
    def test_sync_edges_survive(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        graph = build_dependency_graph(mod, TPU_V5E)
        add_sync_edges(graph)
        profile = sample(mod, TPU_V5E)
        prune(graph, profile, TPU_V5E)
        sync_alive = [e for e in graph.alive_edges if e.kind.is_sync]
        assert sync_alive

    def test_barrier_stage_prunes_unwaited(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        graph = build_dependency_graph(mod, TPU_V5E)
        profile = sample(mod, TPU_V5E)
        prune(graph, profile, TPU_V5E)
        # reg edge ag-start -> anything that doesn't wait must be pruned
        for e in graph.edges:
            if e.producer == "main.1::ag-start" and not e.kind.is_sync:
                consumer = mod.find(e.consumer)
                if "ag-start" not in consumer.sync.waits:
                    assert e.pruned_by == "barrier"

    def test_coverage_improves(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        an = analyze_hlo(async_hlo_text, hints={"total_devices": 8})
        assert an.coverage_after.coverage >= an.coverage_before.coverage - 1e-9


class TestBlame:
    def test_conservation(self, async_hlo_text):
        """Eq. 1 is a partition of S_j: attributed + self-blame == total."""
        an = analyze_hlo(async_hlo_text, hints={"total_devices": 8})
        attributed = sum(e.cycles for e in an.blame.entries)
        self_blamed = sum(s.cycles for s in an.blame.self_blame)
        assert attributed + self_blamed == pytest.approx(
            an.profile.total_stall_cycles, rel=1e-6)

    def test_factors_recorded(self, async_hlo_text):
        an = analyze_hlo(async_hlo_text, hints={"total_devices": 8})
        for e in an.blame.entries[:5]:
            assert set(e.factors) == {"dist", "eff", "issue", "match"}
            assert 0 <= e.factors["dist"] <= 1.0 + 1e-9

    def test_self_blame_subcategories(self):
        instrs = [
            _mk("a", "parameter", attributes={"literal": "0"},
                shape=ShapeInfo("f32", (4096, 4096))),
            _mk("idx", "parameter", attributes={"literal": "1"},
                shape=ShapeInfo("s32", (64,))),
            _mk("g", "gather", ("a", "idx"),
                shape=ShapeInfo("f32", (64, 4096))),
            _mk("r", "add", ("g", "g")),
        ]
        mod = _module(instrs)
        an = analyze_module(mod, TPU_V5E)
        cats = {s.subcategory for s in an.blame.self_blame}
        # whatever stalls without surviving deps classifies meaningfully
        assert cats <= {"memory latency", "compute saturation",
                        "synchronization overhead", "collective wait",
                        "instruction fetch", "indirect addressing",
                        "unclassified"}


class TestEndToEnd:
    def test_real_program(self, small_compiled_step):
        an = analyze_hlo(small_compiled_step.as_text())
        assert an.profile.total_stall_cycles > 0
        assert an.chains
        assert an.blame.top_root_causes(3)
        assert an.estimated_step_seconds > 0

    def test_cross_backend_divergence_possible(self, small_compiled_step):
        txt = small_compiled_step.as_text()
        a_e = analyze_hlo(txt, hw=TPU_V5E)
        a_p = analyze_hlo(txt, hw=TPU_V5P)
        # v5p is strictly faster on every axis for the same program
        assert a_p.estimated_step_seconds < a_e.estimated_step_seconds

    def test_cct_hot_path(self, small_compiled_step):
        an = analyze_hlo(small_compiled_step.as_text())
        hot = an.cct.hot_path()
        assert len(hot) >= 1

    def test_structured_report_roundtrip(self, small_compiled_step):
        import json
        from repro.core import structured_report
        an = analyze_hlo(small_compiled_step.as_text())
        rep = structured_report(an)
        js = json.dumps(rep)
        assert json.loads(js)["module"]

    def test_diagnostic_context_levels(self, small_compiled_step):
        from repro.core import diagnostic_context
        an = analyze_hlo(small_compiled_step.as_text())
        c = diagnostic_context("C", "code here")
        cs = diagnostic_context("C+S", "code here", an)
        cls_ = diagnostic_context("C+L(S)", "code here", an)
        assert len(c) < len(cs) < len(cls_)
        assert "root-cause" in cls_ or "Recommendations" in cls_
