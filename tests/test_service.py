"""Tests for the serving-grade API: typed AnalyzeRequest/Diagnosis schema,
bounded LRU + on-disk cache tiers, concurrent fan-out with single-flight
dedup, and deprecation-shim parity (byte-identical to Diagnosis output)."""
import json

import pytest

from repro.core import (
    ADVICE_NOT_RECORDED,
    AnalyzeRequest,
    Diagnosis,
    LeoService,
    LeoSession,
    LRUCache,
    Recommendation,
    SCHEMA_VERSION,
    analyze_hlo,
    diagnostic_context,
    recommendations,
    structured_report,
)


@pytest.fixture()
def analysis(async_hlo_text):
    return analyze_hlo(async_hlo_text, hw="tpu_v5e",
                       hints={"total_devices": 8})


# --------------------------------------------------------------------------
# LRUCache unit behavior.
# --------------------------------------------------------------------------

class TestLRUCache:
    def test_eviction_order_is_lru_not_fifo(self):
        evicted = []
        c = LRUCache(2, on_evict=lambda k, v: evicted.append(k))
        c["a"], c["b"] = 1, 2
        _ = c["a"]              # touch: b is now least-recent
        c["c"] = 3
        assert evicted == ["b"]
        assert set(c) == {"a", "c"}
        assert c.evictions == 1

    def test_unbounded_when_capacity_none(self):
        c = LRUCache(None)
        for i in range(1000):
            c[i] = i
        assert len(c) == 1000 and c.evictions == 0

    def test_capacity_validation(self):
        with pytest.raises(ValueError):
            LRUCache(0)


# --------------------------------------------------------------------------
# AnalyzeRequest schema.
# --------------------------------------------------------------------------

class TestAnalyzeRequest:
    def test_json_round_trip(self):
        req = AnalyzeRequest(hlo_text="HloModule m", backend="tpu_v5e",
                             hints={"total_devices": 8}, n_chains=3,
                             request_id="r-1")
        back = AnalyzeRequest.from_json(req.to_json())
        assert back == req

    def test_validation(self):
        with pytest.raises(ValueError, match="non-empty"):
            AnalyzeRequest().validate()
        with pytest.raises(ValueError, match="not both"):
            AnalyzeRequest(hlo_text="x", backend="a",
                           backends=["b"]).validate()
        bad = AnalyzeRequest(hlo_text="x", schema_version=SCHEMA_VERSION + 1)
        with pytest.raises(ValueError, match="schema_version"):
            bad.validate()


# --------------------------------------------------------------------------
# Diagnosis schema: losslessness + views.
# --------------------------------------------------------------------------

class TestDiagnosis:
    def test_real_diagnosis_json_round_trip_is_lossless(self, analysis):
        d = Diagnosis.from_analysis(analysis)
        back = Diagnosis.from_json(d.to_json())
        assert back == d
        assert back.to_json() == d.to_json()

    def test_version_mismatch_rejected(self, analysis):
        payload = json.loads(Diagnosis.from_analysis(analysis).to_json())
        payload["schema_version"] = SCHEMA_VERSION + 1
        with pytest.raises(ValueError, match="schema_version"):
            Diagnosis.from_dict(payload)

    def test_markdown_view(self, analysis):
        md = Diagnosis.from_analysis(analysis).to_markdown()
        assert md.startswith("# LEO diagnosis")
        assert "## Top root causes" in md
        assert "## Recommendations" in md

    def test_llm_context_levels_nest(self, analysis):
        d = Diagnosis.from_analysis(analysis)
        c = d.to_llm_context("C", code="kernel src")
        cs = d.to_llm_context("C+S", code="kernel src")
        cls_ = d.to_llm_context("C+L(S)", code="kernel src")
        assert len(c) < len(cs) < len(cls_)
        assert "root-cause" in cls_
        with pytest.raises(ValueError, match="unknown context level"):
            d.to_llm_context("C+X")

    def test_property_round_trip_lossless(self):
        """from_json(to_json(d)) == d over generated instances."""
        hypothesis = pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        finite = st.floats(allow_nan=False, allow_infinity=False)
        text = st.text(max_size=12)
        jsonish = st.dictionaries(text, st.one_of(finite, text,
                                                  st.integers(),
                                                  st.none()),
                                  max_size=4)
        recs = st.builds(Recommendation, action=text, target=text,
                         scope=text, reason=text, est_cycles=finite)
        diags = st.builds(
            Diagnosis,
            backend=text, module_name=text,
            estimated_step_seconds=finite, total_stall_cycles=finite,
            coverage_before=finite, coverage_after=finite,
            pruning=jsonish,
            top_stalls=st.lists(jsonish, max_size=3),
            chains=st.lists(jsonish, max_size=3),
            root_causes=st.lists(jsonish, max_size=3),
            self_blame=st.lists(jsonish, max_size=3),
            recommendations=st.lists(recs, max_size=3),
            vendor=st.one_of(st.none(), text),
            stall_taxonomy=st.one_of(st.none(),
                                     st.dictionaries(text, text,
                                                     max_size=3)),
            # v4: both the migration default and recorded advice shapes
            advice=st.one_of(
                st.just(dict(ADVICE_NOT_RECORDED)),
                st.fixed_dictionaries({"recorded": st.just(True),
                                       "count": st.integers(0, 3),
                                       "items": st.lists(jsonish,
                                                         max_size=3)})),
            schema_version=st.just(SCHEMA_VERSION),
        )

        @settings(max_examples=50, deadline=None)
        @given(diags)
        def check(d):
            assert Diagnosis.from_json(d.to_json()) == d

        check()


# --------------------------------------------------------------------------
# Deprecation shims: byte-identical to the Diagnosis methods they wrap.
# --------------------------------------------------------------------------

class TestShimParity:
    def test_structured_report_matches_diagnosis_bytes(self, analysis):
        with pytest.warns(DeprecationWarning, match="structured_report"):
            legacy = structured_report(analysis)
        new = Diagnosis.from_analysis(analysis).to_dict()
        assert json.dumps(legacy, sort_keys=False) == \
            json.dumps(new, sort_keys=False)

    def test_diagnostic_context_matches_to_llm_context_bytes(self, analysis):
        d = Diagnosis.from_analysis(analysis)
        for level in ("C", "C+S", "C+L(S)"):
            with pytest.warns(DeprecationWarning):
                legacy = diagnostic_context(level, "src", analysis)
            assert legacy == d.to_llm_context(level, code="src")

    def test_recommendations_shim_matches_field(self, analysis):
        with pytest.warns(DeprecationWarning, match="recommendations"):
            legacy = recommendations(analysis)
        assert legacy == Diagnosis.from_analysis(analysis).recommendations


# --------------------------------------------------------------------------
# Bounded cache tiers.
# --------------------------------------------------------------------------

class TestBoundedCaches:
    def test_parse_lru_eviction_re_misses(self, async_hlo_text):
        """Capacity-1 parse cache: A, B, A again -> three real parses."""
        session = LeoSession(hints={"total_devices": 8},
                             parse_cache_size=1)
        other = async_hlo_text.replace("fixture_async", "fixture_other")
        session.parse(async_hlo_text)
        session.parse(other)                 # evicts A
        session.parse(async_hlo_text)        # must re-parse
        assert session.stats.parse_misses == 3
        assert session.cache_evictions["parse"] == 2
        # within-capacity access still hits
        assert session.stats.parse_calls == 3

    def test_analysis_lru_eviction_re_runs(self, async_hlo_text):
        session = LeoSession(hints={"total_devices": 8},
                             analysis_cache_size=1)
        session.analyze(async_hlo_text, backend="tpu_v5e")
        session.analyze(async_hlo_text, backend="tpu_v5p")   # evicts v5e
        session.analyze(async_hlo_text, backend="tpu_v5e")   # re-runs
        assert session.stats.analyze_misses == 3
        assert session.stats.parse_misses == 1   # parse tier unaffected
        assert session.cache_evictions["analysis"] == 2

    def test_identity_keys_stay_unique_across_evictions(self,
                                                        async_hlo_text):
        """Identity keys carry a monotonic suffix: even with the parse
        LRU pinned at capacity (constant len), two distinct Modules can
        never produce the same key, so a recycled id() after eviction
        cannot resurface another module's cached analyses."""
        from repro.core import parse_hlo
        session = LeoSession(parse_cache_size=1)
        m1 = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        m2 = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        _, k1 = session._resolve_module(m1, None)
        _, k2 = session._resolve_module(m2, None)   # evicts m1
        assert k1 != k2
        assert session.cache_evictions["parse"] == 1

    def test_unbounded_by_default(self, async_hlo_text):
        session = LeoSession(hints={"total_devices": 8})
        session.analyze(async_hlo_text, backend="tpu_v5e")
        session.analyze(async_hlo_text, backend="tpu_v5e")
        assert session.stats.analyze_misses == 1
        assert session.cache_evictions == {"parse": 0, "graph": 0,
                                           "analysis": 0}


# --------------------------------------------------------------------------
# On-disk tier: cross-process persistence.
# --------------------------------------------------------------------------

class TestDiskCache:
    def test_second_cold_session_parses_zero_times(self, async_hlo_text,
                                                   tmp_path):
        """Acceptance criterion: warm disk cache -> zero HLO parses."""
        svc1 = LeoService(cache_dir=str(tmp_path))
        an1 = svc1.analyze(async_hlo_text, backend="tpu_v5e",
                           hints={"total_devices": 8})
        assert svc1.stats.parse_misses == 1

        svc2 = LeoService(cache_dir=str(tmp_path))   # "second process"
        an2 = svc2.analyze(async_hlo_text, backend="tpu_v5e",
                           hints={"total_devices": 8})
        assert svc2.stats.parse_misses == 0
        assert svc2.stats.parse_disk_hits == 1
        assert an2.estimated_step_seconds == an1.estimated_step_seconds

    def test_second_cold_service_serves_diagnosis_without_pipeline(
            self, async_hlo_text, tmp_path):
        svc1 = LeoService(cache_dir=str(tmp_path))
        d1 = svc1.diagnose(async_hlo_text, backend="tpu_v5e",
                           hints={"total_devices": 8})
        svc2 = LeoService(cache_dir=str(tmp_path))
        d2 = svc2.diagnose(async_hlo_text, backend="tpu_v5e",
                           hints={"total_devices": 8})
        assert d2 == d1
        # neither parsed nor analyzed: the gzipped JSON answered
        assert svc2.stats.parse_calls == 0
        assert svc2.stats.analyze_calls == 0
        assert svc2.diagnosis_hits == 1
        assert svc2.disk_cache.stats.diagnosis_hits == 1

    def test_corrupt_artifact_reads_as_miss(self, async_hlo_text, tmp_path):
        svc1 = LeoService(cache_dir=str(tmp_path))
        svc1.diagnose(async_hlo_text, hints={"total_devices": 8})
        # truncate every artifact
        for p in tmp_path.rglob("*.gz"):
            p.write_bytes(b"not gzip")
        svc2 = LeoService(cache_dir=str(tmp_path))
        d = svc2.diagnose(async_hlo_text, hints={"total_devices": 8})
        assert svc2.stats.parse_misses == 1      # fell back to parsing
        assert d.module_name


# --------------------------------------------------------------------------
# On-disk tier eviction: size cap + TTL sweep (the .leo_cache dir must not
# grow without bound).
# --------------------------------------------------------------------------

class TestDiskEviction:
    def _fill(self, cache, n=6):
        from repro.core import DiskCache  # noqa: F401 (import for clarity)
        import hashlib
        keys = []
        for i in range(n):
            key = hashlib.sha256(f"artifact-{i}".encode()).hexdigest()
            cache.store_module(key, {"format": "test", "payload": "x" * 4096,
                                     "i": i})
            keys.append(key)
        return keys

    def test_size_cap_evicts_oldest_first(self, tmp_path):
        import os
        from repro.core import DiskCache
        cache = DiskCache(str(tmp_path), max_bytes=1)   # everything over cap
        keys = self._fill(cache, n=4)
        # stagger mtimes so eviction order is deterministic
        for i, key in enumerate(keys):
            os.utime(cache._path("modules", key, ".pkl.gz"),
                     (1_000_000 + i, 1_000_000 + i))
        stats = cache.sweep()
        assert stats["evicted"] == 4
        assert cache.total_bytes() == 0
        assert cache.stats.evictions == 4
        assert cache.stats.bytes_evicted == stats["bytes_freed"] > 0

    def test_size_cap_keeps_newest_within_budget(self, tmp_path):
        import os
        from repro.core import DiskCache
        cache = DiskCache(str(tmp_path))
        keys = self._fill(cache, n=5)
        for i, key in enumerate(keys):
            os.utime(cache._path("modules", key, ".pkl.gz"),
                     (1_000_000 + i, 1_000_000 + i))
        sizes = [os.path.getsize(cache._path("modules", k, ".pkl.gz"))
                 for k in keys]
        cache.max_bytes = sizes[-1] + sizes[-2]   # room for exactly two
        cache.sweep()
        survivors = [k for k in keys
                     if os.path.exists(cache._path("modules", k, ".pkl.gz"))]
        assert survivors == keys[-2:]             # oldest-accessed went first

    def test_ttl_expires_idle_artifacts(self, tmp_path):
        import time
        from repro.core import DiskCache
        cache = DiskCache(str(tmp_path), ttl_seconds=3600.0)
        keys = self._fill(cache, n=3)
        # nothing is idle yet
        assert cache.sweep()["evicted"] == 0
        # pretend an hour+ passed
        stats = cache.sweep(now=time.time() + 7200.0)
        assert stats["evicted"] == 3
        assert all(cache.load_module(k) is None for k in keys)

    def test_hits_refresh_mtime_so_hot_artifacts_survive(self, tmp_path):
        import os
        import time
        from repro.core import DiskCache
        cache = DiskCache(str(tmp_path), ttl_seconds=3600.0)
        keys = self._fill(cache, n=2)
        old = time.time() - 7200.0
        for key in keys:
            os.utime(cache._path("modules", key, ".pkl.gz"), (old, old))
        assert cache.load_module(keys[0]) is not None   # hit refreshes mtime
        stats = cache.sweep()
        assert stats["evicted"] == 1                    # only the cold one
        assert cache.load_module(keys[0]) is not None

    def test_sweep_triggers_opportunistically_on_writes(self, tmp_path):
        from repro.core import DiskCache
        cache = DiskCache(str(tmp_path), max_bytes=1, sweep_interval=4)
        self._fill(cache, n=4)                          # 4th write sweeps
        assert cache.stats.sweeps >= 1
        assert cache.stats.evictions >= 1

    def test_service_passes_disk_bounds_through(self, async_hlo_text,
                                                tmp_path):
        svc = LeoService(cache_dir=str(tmp_path),
                         disk_cache_max_bytes=123456,
                         disk_cache_ttl_seconds=60.0)
        assert svc.disk_cache.max_bytes == 123456
        assert svc.disk_cache.ttl_seconds == 60.0
        svc.diagnose(async_hlo_text, hints={"total_devices": 8})
        assert "evictions" in svc.stats_dict()["disk"]


# --------------------------------------------------------------------------
# Cross-process safety: the disk tier as a multi-worker warm cache.
# --------------------------------------------------------------------------

class TestCrossProcessDiskCache:
    def test_sweep_lockfile_admits_one_compactor(self, tmp_path):
        """Only one process sweeps at a time: with the ``.sweep.lock``
        flock held elsewhere, a non-blocking sweep skips."""
        fcntl = pytest.importorskip("fcntl")
        import os
        from repro.core import DiskCache
        cache = DiskCache(str(tmp_path), max_bytes=1)
        # stand in for another worker process: flock conflicts between
        # distinct open file descriptions even within one process
        fd = os.open(cache._sweep_lock_path, os.O_CREAT | os.O_RDWR, 0o644)
        fcntl.flock(fd, fcntl.LOCK_EX)
        try:
            assert cache.sweep(blocking=False).get("skipped") == 1
            assert cache.stats.sweeps == 0
        finally:
            os.close(fd)
        assert "skipped" not in cache.sweep(blocking=False)
        assert cache.stats.sweeps == 1

    def test_scan_skips_artifacts_unlinked_mid_sweep(self, tmp_path,
                                                     monkeypatch):
        """FileNotFoundError between listing and stat (a concurrently-
        exiting process's final sweep) is skip-and-continue."""
        import hashlib
        import os
        from repro.core import DiskCache
        cache = DiskCache(str(tmp_path), max_bytes=1)
        keys = [hashlib.sha256(f"x{i}".encode()).hexdigest()
                for i in range(3)]
        for key in keys:
            cache.store_module(key, {"payload": "y" * 1024})
        victim = cache._path("modules", keys[1], ".pkl.gz")
        real_stat = os.stat

        def racing_stat(path, *args, **kwargs):
            if path == victim:
                os.unlink(victim)       # the "other process" wins the race
                # the original file is gone; stat must raise exactly the
                # error a lost race produces
            return real_stat(path, *args, **kwargs)

        monkeypatch.setattr(os, "stat", racing_stat)
        stats = cache.sweep()           # must not raise
        monkeypatch.undo()
        assert stats["evicted"] == 2    # the victim was already gone
        assert cache.total_bytes() == 0

    def test_two_processes_hammer_one_cache_dir(self, tmp_path):
        """Satellite: a writer process stores/loads/sweeps in a loop and
        exits while this process sweeps and clears the same root — no
        crash on either side (atomic publish + lockfile + skip-and-
        continue scanning)."""
        import os
        import subprocess
        import sys
        import time
        import repro.core
        from repro.core import DiskCache
        src_dir = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(repro.core.__file__))))
        root = str(tmp_path / "shared")
        child_src = (
            "import hashlib, sys\n"
            f"sys.path.insert(0, {src_dir!r})\n"
            "from repro.core import DiskCache\n"
            f"cache = DiskCache({root!r}, max_bytes=16384, "
            "sweep_interval=4)\n"
            "for i in range(150):\n"
            "    key = hashlib.sha256(str(i).encode()).hexdigest()\n"
            "    cache.store_module(key, {'payload': 'z' * 2048, 'i': i})\n"
            "    cache.load_module(key)\n"
            "cache.flush()\n"
        )
        proc = subprocess.Popen([sys.executable, "-c", child_src],
                                stderr=subprocess.PIPE)
        sweeper = DiskCache(root, max_bytes=8192, sweep_interval=2)
        rounds = 0
        while proc.poll() is None:
            sweeper.sweep(blocking=False)
            sweeper.sweep(blocking=True)
            if rounds % 7 == 3:
                sweeper.clear()         # rip whole kind dirs out from under
            rounds += 1
            time.sleep(0.002)
        _, stderr = proc.communicate(timeout=30)
        assert proc.returncode == 0, stderr.decode()
        assert rounds >= 1
        sweeper.flush()                 # and the survivor still sweeps


# --------------------------------------------------------------------------
# Concurrency: fan-out with single-flight dedup.
# --------------------------------------------------------------------------

class TestConcurrentFanout:
    def test_concurrent_compare_backends_parses_once(self, async_hlo_text):
        """Acceptance criterion: >=6 backends on the thread pool, 1 parse."""
        svc = LeoService(hints={"total_devices": 8}, max_workers=6)
        results = svc.compare_backends(async_hlo_text)
        assert len(results) >= 6
        assert svc.stats.parse_misses == 1
        assert svc.stats.parse_calls == len(results)
        mods = {id(an.module) for an in results.values()}
        assert len(mods) == 1
        # each backend ran its own pipeline
        assert svc.stats.analyze_misses == len(results)
        svc.close()

    def test_concurrent_batch_of_duplicates_single_flights(
            self, async_hlo_text):
        svc = LeoService(hints={"total_devices": 8}, max_workers=8)
        out = svc.analyze_batch([async_hlo_text] * 8, backend="tpu_v5e")
        assert len(out) == 8
        assert all(an is out[0] for an in out)
        assert svc.stats.parse_misses == 1
        assert svc.stats.analyze_misses == 1     # 7 waited on the winner
        svc.close()

    def test_diagnose_batch_typed_requests(self, async_hlo_text):
        svc = LeoService(max_workers=4)
        reqs = [AnalyzeRequest(hlo_text=async_hlo_text,
                               backend=b, hints={"total_devices": 8})
                for b in ("tpu_v5e", "tpu_v5p", "nvidia_gh200")]
        reqs.append(AnalyzeRequest(hlo_text=async_hlo_text,
                                   backends=["amd_mi300a", "intel_pvc"],
                                   hints={"total_devices": 8}))
        out = svc.diagnose_batch(reqs)
        assert [isinstance(o, Diagnosis) for o in out] == \
            [True, True, True, False]
        assert set(out[3]) == {"amd_mi300a", "intel_pvc"}
        assert svc.stats.parse_misses == 1
        svc.close()

    def test_caller_mutation_cannot_poison_diagnosis_cache(
            self, async_hlo_text):
        svc = LeoService()
        d1 = svc.diagnose(async_hlo_text, backend="tpu_v5e",
                          hints={"total_devices": 8})
        d1.recommendations.insert(0, Recommendation(
            action="fuse_kernels", target="<pipeline>", scope="",
            reason="caller-side insertion", est_cycles=1.0))
        d2 = svc.diagnose(async_hlo_text, backend="tpu_v5e",
                          hints={"total_devices": 8})
        assert all(r.action != "fuse_kernels" for r in d2.recommendations)
        assert svc.diagnosis_hits == 1

    def test_service_submit_returns_serializable(self, async_hlo_text):
        svc = LeoService()
        diag = svc.submit(AnalyzeRequest(hlo_text=async_hlo_text,
                                         backend="amd_mi300a",
                                         hints={"total_devices": 8}))
        assert Diagnosis.from_json(diag.to_json()) == diag
        assert diag.vendor == "amd"


# --------------------------------------------------------------------------
# DiagnoseOptions: the typed request surface (PR-9 api_redesign satellite).
# --------------------------------------------------------------------------

class TestDiagnoseOptions:
    def test_defaults_match_legacy_kwarg_defaults(self):
        from repro.core import DiagnoseOptions
        o = DiagnoseOptions()
        assert (o.n_chains, o.prune_unexecuted, o.advise, o.rewrite,
                o.occupancy) == (5, True, False, False, False)

    def test_validation(self):
        from repro.core import DiagnoseOptions
        with pytest.raises(ValueError, match="n_chains"):
            DiagnoseOptions(n_chains=0).validate()

    def test_cache_keys_byte_identical_to_pre_v6_layout(self,
                                                        async_hlo_text):
        """ISSUE acceptance: for every pre-existing knob combination the
        options-built key equals the historical hash byte for byte — a
        warm disk cache survives the API redesign.  The formula below is
        the pre-v6 layout, frozen on purpose: if this test breaks, warm
        caches broke."""
        import hashlib
        from itertools import product
        from repro.core import DiagnoseOptions, get_backend
        from repro.core.service import DIAGNOSIS_KEY_VERSION
        svc = LeoService()
        backend = get_backend("tpu_v5e")
        hints = {"total_devices": 8}
        mkey = svc.session.module_key(async_hlo_text, hints)
        backend_fp = repr((backend.name, backend.vendor, backend.hw,
                           sorted((k.value, v) for k, v
                                  in backend.stall_taxonomy.items()),
                           backend.sync))
        for n_chains, prune, advise, rewrite in product(
                (1, 5), (True, False), (True, False), (True, False)):
            want = hashlib.sha256(json.dumps([
                mkey, backend_fp, n_chains, prune, advise, rewrite,
                DIAGNOSIS_KEY_VERSION, svc.session.pipeline.names,
            ]).encode()).hexdigest()
            opts = DiagnoseOptions(n_chains=n_chains,
                                   prune_unexecuted=prune,
                                   advise=advise, rewrite=rewrite)
            got = svc._diagnosis_key(async_hlo_text, backend, hints, opts)
            assert got == want, opts

    def test_occupancy_gets_its_own_key(self, async_hlo_text):
        from repro.core import DiagnoseOptions, get_backend
        svc = LeoService()
        backend = get_backend("nvidia_gh200")
        plain = svc._diagnosis_key(async_hlo_text, backend, None,
                                   DiagnoseOptions())
        occ = svc._diagnosis_key(async_hlo_text, backend, None,
                                 DiagnoseOptions(occupancy=True))
        assert plain != occ

    def test_legacy_kwargs_warn_once_and_build_same_options(
            self, async_hlo_text):
        import warnings as _warnings
        from repro.core import service as service_mod
        from repro.core import DiagnoseOptions
        service_mod._LEGACY_KWARG_WARNED.clear()
        svc = LeoService()
        with _warnings.catch_warnings(record=True) as caught:
            _warnings.simplefilter("always")
            d1 = svc.diagnose(async_hlo_text, backend="tpu_v5e",
                              n_chains=3)
            svc.diagnose(async_hlo_text, backend="tpu_v5e", n_chains=3)
        deprecations = [w for w in caught
                        if issubclass(w.category, DeprecationWarning)]
        assert len(deprecations) == 1          # warned once per site shape
        assert "DiagnoseOptions" in str(deprecations[0].message)
        d2 = svc.diagnose(async_hlo_text, backend="tpu_v5e",
                          options=DiagnoseOptions(n_chains=3))
        assert d1 == d2
        assert svc.diagnosis_hits >= 2         # same cache key all along

    def test_mixing_options_and_legacy_kwargs_raises(self,
                                                     async_hlo_text):
        from repro.core import DiagnoseOptions
        svc = LeoService()
        with pytest.raises(TypeError, match="options"):
            svc.diagnose(async_hlo_text, backend="tpu_v5e",
                         options=DiagnoseOptions(), advise=True)

    def test_request_wire_layout_stays_flat(self, async_hlo_text):
        """An occupancy-unaware peer reads the same flat request dict it
        always did; the new key is additive."""
        from repro.core import DiagnoseOptions
        req = AnalyzeRequest(hlo_text=async_hlo_text, backend="tpu_v5e",
                             options=DiagnoseOptions(advise=True,
                                                     occupancy=True))
        data = json.loads(req.to_json())
        assert data["advise"] is True and data["occupancy"] is True
        assert "options" not in data           # no nested object on wire
        again = AnalyzeRequest.from_json(req.to_json())
        assert again.options == req.options
        # a pre-v6 peer's dict (no occupancy key) parses with default off
        del data["occupancy"]
        legacy = AnalyzeRequest.from_dict(data)
        assert legacy.options.occupancy is False

    def test_v6_round_trip_with_occupancy(self, async_hlo_text):
        """ISSUE acceptance: v6 `from_json(to_json(d)) == d` with the
        occupancy section recorded."""
        from repro.core import DiagnoseOptions
        svc = LeoService()
        diag = svc.diagnose(async_hlo_text, backend="amd_mi300a",
                            hints={"total_devices": 8},
                            options=DiagnoseOptions(occupancy=True))
        assert diag.schema_version == SCHEMA_VERSION == 6
        assert diag.occupancy["recorded"] is True
        assert diag.occupancy["waves"] == 4
        assert Diagnosis.from_json(diag.to_json()) == diag
        # single-wave parts take the knob without engaging anything
        tpu = svc.diagnose(async_hlo_text, backend="tpu_v5e",
                           hints={"total_devices": 8},
                           options=DiagnoseOptions(occupancy=True))
        assert tpu.occupancy["recorded"] is False
        assert Diagnosis.from_json(tpu.to_json()) == tpu
