"""Tests for the redesigned public API: backend registry, pass pipeline,
and the cached `LeoSession` facade (plus legacy-shim parity)."""
import json

import pytest

from repro.core import (
    Backend,
    BackendRegistry,
    DEFAULT_PIPELINE,
    LeoSession,
    Pipeline,
    PipelineOrderError,
    StallClass,
    SyncSemantics,
    TPU_V5E,
    UnknownBackendError,
    analyze_hlo,
    analyze_module,
    cross_backend_analyze,
    default_pipeline,
    get_backend,
    list_backends,
    parse_hlo,
    register_backend,
    resolve_backend,
    structured_report,
)
from repro.core.backends import GENERIC_TAXONOMY, REGISTRY
from repro.core.passes import AnalysisPass, CCTPass


def _stable_report(analysis) -> str:
    """Canonical JSON of the deterministic report fields (timings excluded —
    structured_report carries none)."""
    return json.dumps(structured_report(analysis), sort_keys=True)


# --------------------------------------------------------------------------
# Backend registry.
# --------------------------------------------------------------------------

class TestBackendRegistry:
    def test_six_default_backends(self):
        names = {b.name for b in list_backends()}
        assert {"tpu_v5e", "tpu_v5p", "tpu_v4", "nvidia_gh200",
                "amd_mi300a", "intel_pvc"} <= names

    def test_lookup_and_vendor_taxonomy(self):
        nv = get_backend("nvidia_gh200")
        assert nv.vendor == "nvidia"
        assert nv.native_stall_name(StallClass.MEM_DEP) == "long_scoreboard"
        amd = get_backend("amd_mi300a")
        assert amd.native_stall_name(StallClass.MEM_DEP) == "s_waitcnt_vmcnt"

    def test_unknown_backend_error_names_known(self):
        with pytest.raises(UnknownBackendError) as ei:
            get_backend("tpu_v9000")
        assert "tpu_v9000" in str(ei.value)
        assert "tpu_v5e" in str(ei.value)
        # it is still a KeyError for legacy except-clauses
        assert isinstance(ei.value, KeyError)

    def test_register_and_duplicate_rejection(self):
        reg = BackendRegistry()
        b = Backend(name="acme_asic", vendor="acme", hw=TPU_V5E,
                    stall_taxonomy=GENERIC_TAXONOMY,
                    sync=SyncSemantics())
        reg.register(b)
        assert reg.get("acme_asic") is b
        with pytest.raises(ValueError, match="already registered"):
            reg.register(b)
        reg.register(b, overwrite=True)   # explicit replace is allowed

    def test_third_party_registration_in_global_registry(self):
        b = Backend(name="test_tmp_backend", vendor="test", hw=TPU_V5E,
                    stall_taxonomy=GENERIC_TAXONOMY)
        try:
            register_backend(b)
            assert get_backend("test_tmp_backend") is b
            assert resolve_backend("test_tmp_backend") is b
        finally:
            REGISTRY.unregister("test_tmp_backend")

    def test_resolve_bare_hardware_model_finds_registered(self):
        assert resolve_backend(TPU_V5E).name == "tpu_v5e"

    def test_resolve_rejects_garbage(self):
        with pytest.raises(TypeError):
            resolve_backend(42)


# --------------------------------------------------------------------------
# Pipeline.
# --------------------------------------------------------------------------

class TestPipeline:
    def test_default_pass_order(self):
        assert default_pipeline().names == [
            "sample", "depgraph", "coverage_before", "sync_edges", "prune",
            "coverage_after", "blame", "chains", "cct"]

    def test_reorder_preserves_results_when_dataflow_allows(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        base = DEFAULT_PIPELINE.analyze(mod, "tpu_v5e")
        # cct only needs the profile; hoisting it right after sampling is a
        # legal reorder and must not change any result
        hoisted = default_pipeline().reordered(
            ["sample", "cct", "depgraph", "coverage_before", "sync_edges",
             "prune", "coverage_after", "blame", "chains"])
        moved = hoisted.analyze(mod, "tpu_v5e")
        assert _stable_report(moved) == _stable_report(base)

    def test_invalid_order_raises(self):
        with pytest.raises(PipelineOrderError, match="chains"):
            default_pipeline().reordered(
                ["sample", "depgraph", "coverage_before", "sync_edges",
                 "prune", "coverage_after", "chains", "blame", "cct"])

    def test_without_pass_skips_artifact(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        pruned = default_pipeline().without("sync_edges")
        ctx = pruned.run(mod, "tpu_v5e")
        assert ctx.sync_edges_added is None
        full = DEFAULT_PIPELINE.run(mod, "tpu_v5e")
        assert full.sync_edges_added > 0

    def test_custom_pass_insertion_and_hooks(self, async_hlo_text):
        seen = []

        class EdgeCountPass(AnalysisPass):
            name = "edge_count"
            requires = ("graph",)

            def run(self, ctx):
                seen.append(len(ctx.graph.edges))

        timings = {}
        pipe = default_pipeline(
            on_pass_end=lambda p, ctx, secs: timings.setdefault(p.name, secs)
        ).with_pass(EdgeCountPass(), after="depgraph")
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        an = pipe.analyze(mod, "tpu_v5e")
        # the inserted pass ran between depgraph and sync_edges, so it saw
        # the pre-sync edge count
        assert seen and seen[0] == an.prune_stats.initial_edges - \
            an.sync_edges_added
        assert set(timings) == set(pipe.names)
        assert set(an.pass_seconds) == set(pipe.names)

    def test_duplicate_pass_names_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            Pipeline([CCTPass(), CCTPass()])

    def test_trimmed_pipeline_analyze_raises_named_error(self, async_hlo_text):
        from repro.core import IncompletePipelineError
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        trimmed = default_pipeline().without("cct")
        # run() works and simply leaves the artifact unset ...
        assert trimmed.run(mod, "tpu_v5e").cct is None
        # ... while analyze() needs the full LeoAnalysis artifact set and
        # must say which artifact is missing (exported exception type)
        with pytest.raises(IncompletePipelineError, match="cct"):
            trimmed.analyze(mod, "tpu_v5e")


# --------------------------------------------------------------------------
# LeoSession caching.
# --------------------------------------------------------------------------

class TestLeoSession:
    def test_compare_backends_parses_exactly_once(self, async_hlo_text):
        """Acceptance criterion: >= 6 backends, one parse."""
        session = LeoSession(hints={"total_devices": 8})
        results = session.compare_backends(async_hlo_text)
        assert len(results) >= 6
        assert session.stats.parse_misses == 1
        assert session.stats.parse_calls == len(results)
        assert session.stats.parse_hits == len(results) - 1
        # every backend produced a full analysis on the shared module
        mods = {id(an.module) for an in results.values()}
        assert len(mods) == 1
        assert all(an.chains is not None and an.blame is not None
                   for an in results.values())

    def test_analysis_cache_hit_on_repeat(self, async_hlo_text):
        session = LeoSession(hints={"total_devices": 8})
        a1 = session.analyze(async_hlo_text, backend="tpu_v5e")
        a2 = session.analyze(async_hlo_text, backend="tpu_v5e")
        assert a1 is a2
        assert session.stats.analyze_calls == 2
        assert session.stats.analyze_misses == 1

    def test_graph_cache_reused_across_options(self, async_hlo_text):
        session = LeoSession(hints={"total_devices": 8})
        a1 = session.analyze(async_hlo_text, backend="tpu_v5e", n_chains=3)
        a2 = session.analyze(async_hlo_text, backend="tpu_v5e", n_chains=7)
        assert a1 is not a2
        assert session.stats.graph_requests == 2
        assert session.stats.graph_builds == 1          # second run clones
        # the clone is independent: both analyses carry their own prune marks
        assert a1.graph is not a2.graph
        assert a1.prune_stats.surviving_edges == a2.prune_stats.surviving_edges

    def test_divergent_vendors_diverge(self, async_hlo_text):
        """Observation 1: the same program models differently across the
        vendor-class backends (times must not all collapse together)."""
        session = LeoSession(hints={"total_devices": 8})
        res = session.compare_backends(
            async_hlo_text,
            backends=["tpu_v5e", "nvidia_gh200", "amd_mi300a", "intel_pvc"])
        times = {n: an.estimated_step_seconds for n, an in res.items()}
        assert len({round(t, 12) for t in times.values()}) == len(times)
        # intel_pvc: thin Xe-Link + blocking collectives -> this collective-
        # heavy fixture must be slowest there among the GPU-class parts
        assert times["intel_pvc"] > times["nvidia_gh200"]
        assert times["intel_pvc"] > times["amd_mi300a"]

    def test_vendor_report_speaks_native_taxonomy(self, async_hlo_text):
        session = LeoSession(hints={"total_devices": 8})
        an = session.analyze(async_hlo_text, backend="nvidia_gh200")
        rep = structured_report(an)
        assert rep["vendor"] == "nvidia"
        assert rep["stall_taxonomy"]["mem_dep"] == "long_scoreboard"
        assert any("native_breakdown" in s for s in rep["top_stalls"])

    def test_session_sees_backends_registered_after_construction(
            self, async_hlo_text):
        session = LeoSession(hints={"total_devices": 8})
        n_before = len(session.backends)
        b = Backend(name="late_registered", vendor="test", hw=TPU_V5E,
                    stall_taxonomy=GENERIC_TAXONOMY)
        try:
            register_backend(b)
            assert len(session.backends) == n_before + 1
            res = session.compare_backends(async_hlo_text)
            assert "late_registered" in res
        finally:
            REGISTRY.unregister("late_registered")

    def test_direct_module_identity_keys_do_not_alias(self, async_hlo_text):
        """Two distinct Module objects must never share a cache entry even
        if CPython recycles ids (the session retains identity-keyed
        modules, making reuse impossible while cached)."""
        session = LeoSession()
        m1 = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        m2 = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        a1 = session.analyze(m1, backend="tpu_v5e")
        a2 = session.analyze(m2, backend="tpu_v5e")
        assert a1.module is m1 and a2.module is m2
        _, k1 = session._resolve_module(m1, None)
        _, k2 = session._resolve_module(m2, None)
        assert k1 != k2
        assert session._modules[k1] is m1    # retained -> id can't recycle

    def test_batch_reuses_cache(self, async_hlo_text):
        session = LeoSession(hints={"total_devices": 8})
        out = session.analyze_batch([async_hlo_text, async_hlo_text],
                                    backend="tpu_v5e")
        assert out[0] is out[1]
        assert session.stats.parse_misses == 1


# --------------------------------------------------------------------------
# Legacy shim parity (acceptance criterion).
# --------------------------------------------------------------------------

class TestShimParity:
    def test_analyze_hlo_matches_session(self, async_hlo_text):
        legacy = analyze_hlo(async_hlo_text, hw=TPU_V5E,
                             hints={"total_devices": 8})
        session = LeoSession(hints={"total_devices": 8})
        new = session.analyze(async_hlo_text, backend="tpu_v5e")
        assert _stable_report(legacy) == _stable_report(new)
        assert legacy.summary() == new.summary()

    def test_analyze_module_matches_pipeline(self, async_hlo_text):
        mod = parse_hlo(async_hlo_text, hints={"total_devices": 8})
        legacy = analyze_module(mod, TPU_V5E, n_chains=4)
        direct = DEFAULT_PIPELINE.analyze(mod, "tpu_v5e", n_chains=4)
        assert _stable_report(legacy) == _stable_report(direct)

    def test_cross_backend_analyze_matches_compare_backends(self, async_hlo_text):
        legacy = cross_backend_analyze(async_hlo_text,
                                       hints={"total_devices": 8})
        session = LeoSession(hints={"total_devices": 8})
        new = session.compare_backends(async_hlo_text)
        assert set(legacy) == set(new)
        assert len(legacy) >= 6
        for name in legacy:
            assert _stable_report(legacy[name]) == _stable_report(new[name])

    def test_shim_accepts_backend_names(self, async_hlo_text):
        by_name = analyze_hlo(async_hlo_text, hw="tpu_v5e",
                              hints={"total_devices": 8})
        by_model = analyze_hlo(async_hlo_text, hw=TPU_V5E,
                               hints={"total_devices": 8})
        assert _stable_report(by_name) == _stable_report(by_model)
