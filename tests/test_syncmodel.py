"""Tests for the first-class SyncModel API (§III-E finite sync resources).

Covers the scoreboard's allocation semantics (capacity, oldest-eviction
serialization, counter-style re-arm, per-queue replicas under a
multi-queue issue model), the SyncSemantics deprecation shim's parity,
behavioral resource exhaustion end-to-end on the single-stream lane (the
PR-3 acceptance criterion: the same copy storm stalls NVIDIA-class parts
and sails through Intel-class parts, with the consumed instance named in
the Diagnosis), pool-scope behavior at native queue counts (CTA-shared
barriers still contend, per-wave counters spread the storm), the
sync-edge resource annotation, and the v1/v2 -> v3 schema migrations.
"""
import json

import pytest

from repro.core import (
    DiagnoseOptions,
    Diagnosis,
    DiskCache,
    LeoService,
    MIN_SCHEMA_VERSION,
    SCHEMA_VERSION,
    SINGLE_ISSUE,
    StallClass,
    SyncKind,
    SyncModel,
    SyncResourcePool,
    SyncSemantics,
    TPU_V5E,
    analyze_hlo,
    get_backend,
    list_backends,
)
from repro.core.backends import Backend, GENERIC_TAXONOMY


def _single(name: str) -> Backend:
    """Single-stream (K=1) variant of a registered backend: the lane the
    PR-3 §III-E exhaustion semantics were pinned on."""
    return get_backend(name).with_issue(SINGLE_ISSUE, name=f"{name}@ss")


def _two_slot_model() -> SyncModel:
    return SyncModel(
        pools=(SyncResourcePool(name="bar", kind=SyncKind.BARRIER,
                                label="two barriers", instances=("b0", "b1")),),
        routing={SyncKind.BARRIER: "bar", SyncKind.WAITCNT: "bar",
                 SyncKind.TOKEN: "bar"})


def _sync_resource_cycles(analysis) -> float:
    return sum(rec.stall_breakdown.get(StallClass.SYNC_RESOURCE, 0.0)
               for rec in analysis.profile.records.values())


# --------------------------------------------------------------------------
# Scoreboard unit semantics.
# --------------------------------------------------------------------------

class TestScoreboard:
    def test_acquire_assigns_distinct_instances(self):
        sb = _two_slot_model().scoreboard()
        a = sb.acquire(SyncKind.BARRIER, "t0", consumer="i0", now=0.0)
        b = sb.acquire(SyncKind.BARRIER, "t1", consumer="i1", now=0.0)
        assert {a.instance, b.instance} == {"b0", "b1"}
        assert a.stall_cycles == b.stall_cycles == 0.0
        assert sb.in_flight(SyncKind.BARRIER) == 2

    def test_exhaustion_serializes_against_oldest(self):
        sb = _two_slot_model().scoreboard()
        sb.acquire(SyncKind.BARRIER, "t0", consumer="i0", now=0.0)
        sb.complete(SyncKind.BARRIER, "t0", 100.0)
        sb.acquire(SyncKind.BARRIER, "t1", consumer="i1", now=1.0)
        sb.complete(SyncKind.BARRIER, "t1", 50.0)
        # pool full: the third acquire evicts t0 (the OLDEST, not the
        # earliest-completing) and inherits its remaining latency
        c = sb.acquire(SyncKind.BARRIER, "t2", consumer="i2", now=10.0)
        assert c.evicted_tag.endswith("t0")
        assert c.evicted_holder == "i0"
        assert c.stall_cycles == pytest.approx(90.0)
        assert c.available_at == pytest.approx(100.0)
        assert sb.in_flight(SyncKind.BARRIER) == 2   # never exceeds capacity

    def test_every_eviction_pays_realloc_overhead(self):
        """Slot reuse always pays the drain/re-arm cost (hwmodel's
        sync_realloc_cycles), even when the evicted holder's transfer
        already landed — only a FREE instance is free."""
        sb = _two_slot_model().scoreboard(realloc_cycles=8.0)
        sb.acquire(SyncKind.BARRIER, "t0", consumer="i0", now=0.0)
        sb.complete(SyncKind.BARRIER, "t0", 100.0)
        sb.acquire(SyncKind.BARRIER, "t1", consumer="i1", now=0.0)
        stalled = sb.acquire(SyncKind.BARRIER, "t2", consumer="i2", now=10.0)
        assert stalled.stall_cycles == pytest.approx(98.0)   # 90 + realloc
        done = sb.acquire(SyncKind.BARRIER, "t3", consumer="i3", now=500.0)
        assert done.stall_cycles == pytest.approx(8.0)       # re-arm only
        sb.retire(SyncKind.BARRIER, "t2")
        freed = sb.acquire(SyncKind.BARRIER, "t4", consumer="i4", now=501.0)
        assert freed.stall_cycles == 0.0                     # truly free

    def test_same_tag_rearm_is_counter_increment(self):
        """Pallas streams re-arm the SAME semaphore repeatedly: that's one
        physical counter tracking N outstanding ops, not N instances."""
        sb = _two_slot_model().scoreboard()
        for _ in range(5):
            acq = sb.acquire(SyncKind.WAITCNT, "sem", consumer="dma", now=0.0)
            assert acq.stall_cycles == 0.0
        assert sb.in_flight(SyncKind.WAITCNT) == 1
        # one retire per outstanding op; the 5th drains it
        for _ in range(5):
            assert sb.retire(SyncKind.WAITCNT, "sem")
        assert sb.in_flight(SyncKind.WAITCNT) == 0
        assert not sb.retire(SyncKind.WAITCNT, "sem")

    def test_retire_drain_to_counter_semantics(self):
        sb = _two_slot_model().scoreboard()
        for _ in range(4):
            sb.acquire(SyncKind.WAITCNT, "sem", consumer="dma", now=0.0)
        sb.retire(SyncKind.WAITCNT, "sem", drain_to=1)   # s_waitcnt vmcnt(1)
        assert sb.in_flight(SyncKind.WAITCNT) == 1
        sb.retire(SyncKind.WAITCNT, "sem", drain_to=0)
        assert sb.in_flight(SyncKind.WAITCNT) == 0

    def test_fork_isolates_state(self):
        sb = _two_slot_model().scoreboard()
        sb.acquire(SyncKind.BARRIER, "t0", consumer="i0", now=0.0)
        fork = sb.fork()
        fork.acquire(SyncKind.BARRIER, "t1", consumer="i1", now=0.0)
        assert fork.in_flight(SyncKind.BARRIER) == 2
        assert sb.in_flight(SyncKind.BARRIER) == 1

    def test_report_shape_is_json_pure(self):
        sb = _two_slot_model().scoreboard()
        sb.acquire(SyncKind.BARRIER, "t0", consumer="i0", now=0.0)
        report = sb.report()
        json.dumps(report.to_dict())   # must not raise
        (pool,) = report.pools
        assert pool["capacity"] == 2 and pool["peak_in_flight"] == 1
        assert set(pool["serves"]) == {"barrier", "waitcnt", "token"}


class TestScoreboardProperty:
    def test_capacity_invariant_and_roundtrip_all_backends(self):
        """For every registered backend at its NATIVE queue count, any
        acquire sequence (random kinds, tags, issuing queues) keeps every
        per-queue board within its pool capacity, and retiring everything
        acquired drains the scoreboard to empty (ISSUE satellite)."""
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (requirements-dev.txt)")
        from hypothesis import given, settings, strategies as st

        backends = [b for b in list_backends() if b.sync.pools]
        assert len(backends) >= 6

        ops = st.lists(
            st.tuples(st.sampled_from(list(SyncKind)),
                      st.integers(0, 40),        # tag ids
                      st.integers(0, 15)),       # issuing queue (mod K)
            min_size=1, max_size=80)

        @settings(max_examples=60, deadline=None)
        @given(st.integers(0, len(backends) - 1), ops)
        def check(bidx, sequence):
            backend = backends[bidx]
            queues = backend.issue.queues
            sb = backend.sync.scoreboard(queues=queues)
            capacities = {p.name: p.capacity for p in backend.sync.pools}
            acquired = set()
            for t, (kind, tag, queue) in enumerate(sequence):
                sb.acquire(kind, f"t{tag}", consumer=f"i{t}", now=float(t),
                           queue=queue % queues)
                acquired.add((kind, f"t{tag}"))
                for pool_name, cap in capacities.items():
                    for board in sb._boards[pool_name]:
                        assert board.in_flight <= cap
            for kind, tag in acquired:
                while sb.retire(kind, tag):
                    pass
            assert sb.total_in_flight == 0

        check()

    def test_k1_multiqueue_degenerates_to_plain_scoreboard(self):
        """ISSUE satellite (parity anchor at the scoreboard level): for
        any acquire/retire sequence, a multi-queue scoreboard receiving
        everything on queue 0 behaves identically — same serialization
        stalls, same instance assignment modulo the ``q0:`` prefix — to a
        ``queues=1`` scoreboard of the same model."""
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (requirements-dev.txt)")
        from hypothesis import given, settings, strategies as st

        model = SyncModel(
            pools=(SyncResourcePool(
                name="ctr", kind=SyncKind.WAITCNT, label="two counters",
                instances=("c0", "c1"), scope="queue"),),
            routing={k: "ctr" for k in SyncKind})

        ops = st.lists(
            st.tuples(st.booleans(),           # acquire vs retire
                      st.integers(0, 6)),      # tag id
            min_size=1, max_size=60)

        @settings(max_examples=80, deadline=None)
        @given(ops)
        def check(sequence):
            plain = model.scoreboard(realloc_cycles=3.0, queues=1)
            multi = model.scoreboard(realloc_cycles=3.0, queues=4)
            for t, (is_acquire, tag) in enumerate(sequence):
                if is_acquire:
                    a = plain.acquire(SyncKind.WAITCNT, f"t{tag}",
                                      consumer=f"i{t}", now=float(t))
                    b = multi.acquire(SyncKind.WAITCNT, f"t{tag}",
                                      consumer=f"i{t}", now=float(t),
                                      queue=0)
                    assert (a.stall_cycles, a.available_at,
                            a.evicted_holder) == \
                        (b.stall_cycles, b.available_at, b.evicted_holder)
                    assert b.instance == f"q0:{a.instance}"
                else:
                    assert plain.retire(SyncKind.WAITCNT, f"t{tag}") == \
                        multi.retire(SyncKind.WAITCNT, f"t{tag}")
            assert plain.total_in_flight == multi.total_in_flight

        check()


# --------------------------------------------------------------------------
# SyncSemantics deprecation shim.
# --------------------------------------------------------------------------

class TestSyncSemanticsShim:
    def test_construction_warns(self):
        with pytest.warns(DeprecationWarning, match="SyncSemantics"):
            SyncSemantics()

    def test_backend_converts_shim_to_model(self):
        with pytest.warns(DeprecationWarning):
            sem = SyncSemantics(barrier_slots=4, waitcnt_counters=0,
                                swsb_tokens=0,
                                mechanisms=(SyncKind.BARRIER,))
        b = Backend(name="shim_test", vendor="test", hw=TPU_V5E,
                    stall_taxonomy=GENERIC_TAXONOMY, sync=sem)
        assert isinstance(b.sync, SyncModel)
        assert b.sync.barrier_slots == 4
        assert b.sync.pool_for(SyncKind.BARRIER).name == "named_barrier"
        # unexposed mechanisms are emulated on the primary pool
        assert b.sync.pool_for(SyncKind.TOKEN).name == "named_barrier"

    def test_legacy_knob_views_round_trip(self):
        with pytest.warns(DeprecationWarning):
            sem = SyncSemantics(barrier_slots=6, waitcnt_counters=2,
                                swsb_tokens=16, async_collectives=False)
        model = sem.to_model()
        assert model.barrier_slots == sem.barrier_slots
        assert model.waitcnt_counters == sem.waitcnt_counters
        assert model.swsb_tokens == sem.swsb_tokens
        assert model.async_collectives == sem.async_collectives
        assert set(model.mechanisms) == set(sem.mechanisms)

    def test_shim_backend_analysis_parity(self, copystorm_hlo_text):
        """A backend defined through the shim must analyze byte-identically
        to one defined through the equivalent hand-built SyncModel."""
        with pytest.warns(DeprecationWarning):
            sem = SyncSemantics(mechanisms=(SyncKind.BARRIER,),
                                barrier_slots=3, waitcnt_counters=0,
                                swsb_tokens=0)
        via_shim = Backend(name="parity_shim", vendor="test", hw=TPU_V5E,
                           stall_taxonomy=GENERIC_TAXONOMY, sync=sem)
        via_model = Backend(name="parity_model", vendor="test", hw=TPU_V5E,
                            stall_taxonomy=GENERIC_TAXONOMY,
                            sync=SyncModel.from_semantics(sem))
        a = analyze_hlo(copystorm_hlo_text, hw=via_shim)
        b = analyze_hlo(copystorm_hlo_text, hw=via_model)
        da, db = Diagnosis.from_analysis(a), Diagnosis.from_analysis(b)
        da.backend = db.backend = "x"   # only the names differ
        assert da.to_json() == db.to_json()
        assert _sync_resource_cycles(a) == _sync_resource_cycles(b) > 0

    def test_no_shipped_backend_uses_the_shim(self):
        for b in list_backends():
            assert isinstance(b.sync, SyncModel), b.name


# --------------------------------------------------------------------------
# Behavioral resource exhaustion (ISSUE acceptance criterion).
# --------------------------------------------------------------------------

class TestResourceExhaustion:
    @pytest.fixture(scope="class")
    def per_backend(self):
        """Single-stream (K=1) lane: the §III-E exhaustion semantics below
        were pinned on the serialized issue model and must keep holding
        there verbatim; native-issue behavior (per-queue pools spreading
        the storm) is covered by TestPerQueueScoreboards."""
        from conftest import COPYSTORM_HLO
        svc = LeoService()
        singles = [_single(b.name) for b in list_backends()]
        return {s.name.split("@", 1)[0]:
                (svc.analyze(COPYSTORM_HLO, backend=s),
                 svc.diagnose(COPYSTORM_HLO, backend=s))
                for s in singles}

    def test_nvidia_exhausts_barrier_slots_intel_does_not(self, per_backend):
        """8 in-flight async copies > 6 NVIDIA barrier slots but < 16 Intel
        SWSB tokens: stall cycles and a SYNC_RESOURCE blame entry appear on
        the NVIDIA-class backend only, naming the consumed instance."""
        nv_an, nv_diag = per_backend["nvidia_gh200"]
        it_an, it_diag = per_backend["intel_pvc"]

        assert _sync_resource_cycles(nv_an) > 0
        assert nv_an.blame.sync_resource, "missing SYNC_RESOURCE evidence"
        worst = nv_an.blame.sync_resource[0]
        assert worst.pool == "named_barrier"
        assert worst.resource in {f"B{i}" for i in range(1, 7)}
        assert worst.holder.startswith("main.1::cp")
        # the Diagnosis names the same concrete instance
        sr = nv_diag.sync_resources
        assert sr["recorded"] and sr["contended"]
        assert any(b["resource"] == worst.resource for b in sr["blame"])
        nv_pool = next(p for p in sr["pools"]
                       if p["pool"] == "named_barrier")
        assert nv_pool["peak_in_flight"] == nv_pool["capacity"] == 6
        assert nv_pool["evictions"] > 0

        assert _sync_resource_cycles(it_an) == 0
        assert not it_an.blame.sync_resource
        assert not it_diag.sync_resources["contended"]

    def test_amd_counter_aliasing_is_heaviest(self, per_backend):
        """Two waitcnt counters < 6 barrier slots: the same storm must
        serialize MORE on the AMD-class part than the NVIDIA-class part."""
        amd_an, amd_diag = per_backend["amd_mi300a"]
        nv_an, _ = per_backend["nvidia_gh200"]
        amd_pool = next(p for p in amd_diag.sync_resources["pools"]
                        if p["pool"] == "waitcnt_counter")
        assert amd_pool["capacity"] == 2
        assert amd_pool["evictions"] > 2
        assert len(amd_an.blame.sync_resource) > \
            len(nv_an.blame.sync_resource)

    def test_tpu_contexts_absorb_the_storm(self, per_backend):
        for name in ("tpu_v5e", "tpu_v5p", "tpu_v4"):
            an, diag = per_backend[name]
            assert _sync_resource_cycles(an) == 0
            assert not diag.sync_resources["contended"]

    def test_pressure_surfaces_in_markdown_and_llm_context(self,
                                                           per_backend):
        _, nv_diag = per_backend["nvidia_gh200"]
        md = nv_diag.to_markdown()
        assert "Sync-resource pressure" in md
        assert "6/6 in flight" in md
        ctx = nv_diag.to_llm_context("C+L(S)", code="src")
        assert "sync-resource pressure" in ctx
        assert "oversubscription" in ctx

    def test_same_named_tags_in_different_computations_do_not_alias(self):
        """Sync identifiers are instruction names, unique only per
        computation: a callee re-using the entry's op names must claim its
        own resources, not piggyback on the caller's live allocation."""
        from repro.core import parse_hlo
        from repro.core.sampler import VirtualSampler
        hlo = """\
HloModule alias_fixture

%callee.1 (cp: f32[64,64]) -> f32[64,64] {
  %cp = f32[64,64] parameter(0)
  %cp0-start = (f32[64,64], f32[64,64], u32[]) copy-start(%cp)
  ROOT %cp0-done = f32[64,64] copy-done(%cp0-start)
}

ENTRY %main.1 (arg0: f32[64,64]) -> f32[64,64] {
  %arg0 = f32[64,64] parameter(0)
  %cp0-start = (f32[64,64], f32[64,64], u32[]) copy-start(%arg0)
  %inner = f32[64,64] call(%arg0), to_apply=%callee.1
  %cp0-done = f32[64,64] copy-done(%cp0-start)
  ROOT %out = f32[64,64] add(%cp0-done, %inner)
}
"""
        module = parse_hlo(hlo)
        backend = get_backend("nvidia_gh200")
        sampler = VirtualSampler(module, backend.hw, sync=backend.sync)
        sampler.run()
        pool = sampler.scoreboard.report().pool("named_barrier")
        # the callee's cp0-start claimed its OWN slot while the entry's
        # was still in flight: 2 distinct acquisitions, peak 2
        assert pool["acquisitions"] == 2
        assert pool["peak_in_flight"] == 2

    def test_sync_edges_annotated_with_instances(self, per_backend):
        nv_an, _ = per_backend["nvidia_gh200"]
        annotated = [e for e in nv_an.graph.edges
                     if e.kind.is_sync and e.resource is not None]
        assert annotated
        assert all(e.resource.startswith("B") for e in annotated)
        # the sync_edges pass exported per-instance edge counts
        nv_pool = nv_an.sync_pressure.pool("named_barrier")
        assert nv_pool["edges_per_instance"]
        assert sum(nv_pool["edges_per_instance"].values()) == len(annotated)


# --------------------------------------------------------------------------
# Per-queue scoreboards under native issue models (PR-4 tentpole).
# --------------------------------------------------------------------------

class TestPerQueueScoreboards:
    """Native-issue behavior: pool *scope* decides whether multi-queue
    issue relieves §III-E pressure.  NVIDIA's device-scoped (CTA-shared)
    barriers contend regardless of queue count; AMD's per-wave counters
    replicate per queue, so the 8-copy storm spreads — but a 12-copy
    storm (3 per queue > 2 counters) contends on EVERY queue."""

    def test_device_scoped_barriers_still_contend_at_native_k(self):
        from conftest import COPYSTORM_HLO
        diag = LeoService().diagnose(COPYSTORM_HLO, backend="nvidia_gh200")
        sr = diag.sync_resources
        assert sr["contended"]
        pool = next(p for p in sr["pools"] if p["pool"] == "named_barrier")
        assert pool["scope"] == "device" and pool["queues"] == 1
        assert pool["peak_in_flight"] == pool["capacity"] == 6
        # device-scoped instances keep their plain names (no queue prefix)
        assert all(b["resource"].startswith("B") for b in sr["blame"])

    def test_per_wave_counters_spread_the_storm_at_native_k(self):
        from conftest import COPYSTORM_HLO
        diag = LeoService().diagnose(COPYSTORM_HLO, backend="amd_mi300a")
        pool = next(p for p in diag.sync_resources["pools"]
                    if p["pool"] == "waitcnt_counter")
        assert pool["scope"] == "queue" and pool["queues"] == 4
        # 8 copies round-robin over 4 queues = 2 per queue = exactly the
        # per-wave counter capacity: no oversubscription anywhere
        assert not diag.sync_resources["contended"]
        assert all(q["evictions"] == 0 for q in pool["per_queue"])

    def test_overdriven_per_queue_pool_contends_on_every_queue(self):
        from repro.launch.analysis_server import copy_storm_hlo
        diag = LeoService().diagnose(copy_storm_hlo(12),
                                     backend="amd_mi300a")
        sr = diag.sync_resources
        pool = next(p for p in sr["pools"]
                    if p["pool"] == "waitcnt_counter")
        assert sr["contended"]
        assert len(pool["per_queue"]) == 4
        for q in pool["per_queue"]:
            assert q["evictions"] >= 1, q
            assert q["peak_in_flight"] <= pool["capacity"]
        # blame names queue-qualified instances ("q2:vmcnt")
        assert sr["blame"]
        for b in sr["blame"]:
            assert b["resource"] in pool["instances"]
            assert b["resource"].split(":")[0].startswith("q")

    def test_fusion_body_edges_share_the_report_namespace(self):
        """Computations only the static replay reaches (fusion bodies —
        the sampler never schedules them) must still get instance
        annotations that exist in the multi-queue pressure report's
        namespace (`q0:vmcnt`), not the bare single-queue names."""
        hlo = """\
HloModule fusion_sync

%fused_computation (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64] parameter(0)
  %cps = (f32[64,64], f32[64,64], u32[]) copy-start(%p0)
  ROOT %cpd = f32[64,64] copy-done(%cps)
}

ENTRY %main.1 (arg0: f32[64,64]) -> f32[64,64] {
  %arg0 = f32[64,64] parameter(0)
  ROOT %fus = f32[64,64] fusion(%arg0), kind=kLoop, calls=%fused_computation
}
"""
        an = LeoService().analyze(hlo, backend="amd_mi300a")
        edges = [e for e in an.graph.edges
                 if e.kind.is_sync and e.resource is not None]
        assert edges, "fusion-body sync edges lost their annotation"
        pool = an.sync_pressure.pool("waitcnt_counter")
        for e in edges:
            assert e.resource in pool["instances"], e.resource
            assert e.resource.startswith("q0:")
        assert sum(pool["edges_per_instance"].values()) == len(edges)

    def test_measured_profile_fallback_shares_the_report_namespace(self):
        """With a measured StallProfile (no sampler pressure/assignment),
        the static-only pressure report must still be minted at the
        backend's queue count so edges_per_instance matches the
        q-prefixed edge annotations."""
        from conftest import COPYSTORM_HLO
        from repro.core import parse_hlo
        from repro.core.passes import default_pipeline
        from repro.core.sampler import VirtualSampler
        backend = get_backend("amd_mi300a")
        module = parse_hlo(COPYSTORM_HLO)
        prof = VirtualSampler(module, backend.hw, sync=backend.sync).run()
        prof.sync_pressure = None          # what a measured profile lacks
        prof.sync_assignment = None
        prof.issue_pressure = None
        ctx = default_pipeline().run(module, backend, profile=prof)
        pool = ctx.sync_pressure.pool("waitcnt_counter")
        assert pool["queues"] == 4
        assert pool["edges_per_instance"]
        assert all(i.startswith("q") for i in pool["edges_per_instance"])

    def test_counter_rearm_lands_on_the_holding_queue(self):
        """A live tag re-armed from another queue is a counter increment
        on the replica that holds it, not a fresh allocation elsewhere."""
        model = SyncModel(
            pools=(SyncResourcePool(
                name="ctr", kind=SyncKind.WAITCNT, label="one counter",
                instances=("c0",), scope="queue"),),
            routing={k: "ctr" for k in SyncKind})
        sb = model.scoreboard(queues=2)
        a = sb.acquire(SyncKind.WAITCNT, "sem", consumer="i0", now=0.0,
                       queue=0)
        b = sb.acquire(SyncKind.WAITCNT, "sem", consumer="i1", now=1.0,
                       queue=1)
        assert a.instance == b.instance == "q0:c0"
        assert sb.in_flight(SyncKind.WAITCNT, queue=0) == 1
        assert sb.in_flight(SyncKind.WAITCNT, queue=1) == 0
        assert sb.retire(SyncKind.WAITCNT, "sem")
        assert sb.retire(SyncKind.WAITCNT, "sem")
        assert sb.total_in_flight == 0


# --------------------------------------------------------------------------
# Schema v1-v5 -> v6 migration (PR-3/PR-4/PR-7/PR-8/PR-9 satellites).
# --------------------------------------------------------------------------

class TestSchemaMigration:
    def _payload(self, async_hlo_text, version: int) -> dict:
        an = analyze_hlo(async_hlo_text, hw="tpu_v5e",
                         hints={"total_devices": 8})
        data = Diagnosis.from_analysis(an).to_dict()
        del data["occupancy"]               # pre-v6
        if version < 5:
            del data["rewrites"]            # pre-v5
        if version < 4:
            del data["advice"]              # pre-v4
        if version < 3:
            del data["issue_pressure"]      # pre-v3
        if version < 2:
            del data["sync_resources"]      # pre-v2
        data["schema_version"] = version
        return data

    def test_v1_payload_migrates_with_not_recorded_defaults(self,
                                                            async_hlo_text):
        assert SCHEMA_VERSION == 6 and MIN_SCHEMA_VERSION == 1
        diag = Diagnosis.from_dict(self._payload(async_hlo_text, 1))
        assert diag.schema_version == SCHEMA_VERSION
        assert diag.sync_resources["recorded"] is False
        assert "not recorded" in diag.sync_resources["note"]
        assert diag.issue_pressure["recorded"] is False
        assert "not recorded" in diag.issue_pressure["note"]
        assert diag.advice["recorded"] is False
        assert "not recorded" in diag.advice["note"]
        assert diag.rewrites["recorded"] is False
        assert "not recorded" in diag.rewrites["note"]
        assert diag.occupancy["recorded"] is False
        assert "not recorded" in diag.occupancy["note"]
        # migrated payloads re-serialize as v6 and round-trip exactly
        assert Diagnosis.from_json(diag.to_json()) == diag

    def test_v2_payload_keeps_sync_resources_and_defaults_issue(
            self, async_hlo_text):
        """ISSUE acceptance: Diagnosis v2 payloads load through the v3
        migration — their recorded sync_resources survive, only the new
        issue_pressure section gets the explicit default."""
        diag = Diagnosis.from_dict(self._payload(async_hlo_text, 2))
        assert diag.schema_version == SCHEMA_VERSION
        assert diag.sync_resources["recorded"] is True
        assert diag.sync_resources["pools"]
        assert diag.issue_pressure["recorded"] is False
        assert diag.advice["recorded"] is False
        assert diag.rewrites["recorded"] is False
        assert Diagnosis.from_json(diag.to_json()) == diag

    def test_v3_payload_keeps_issue_pressure_and_defaults_advice(
            self, async_hlo_text):
        """PR-7 ISSUE acceptance: v3 payloads migrate into v4 with an
        explicit "not recorded" advice default; every recorded section
        survives untouched."""
        diag = Diagnosis.from_dict(self._payload(async_hlo_text, 3))
        assert diag.schema_version == SCHEMA_VERSION
        assert diag.sync_resources["recorded"] is True
        assert diag.issue_pressure["recorded"] is True
        assert diag.advice["recorded"] is False
        assert "not recorded" in diag.advice["note"]
        assert diag.rewrites["recorded"] is False
        assert Diagnosis.from_json(diag.to_json()) == diag

    def test_v4_payload_keeps_advice_and_defaults_rewrites(
            self, async_hlo_text):
        """PR-8 ISSUE acceptance: v4 payloads migrate into v5 with an
        explicit "not recorded" rewrites default; every recorded section
        survives untouched."""
        diag = Diagnosis.from_dict(self._payload(async_hlo_text, 4))
        assert diag.schema_version == SCHEMA_VERSION
        assert diag.sync_resources["recorded"] is True
        assert diag.issue_pressure["recorded"] is True
        assert diag.rewrites["recorded"] is False
        assert "not recorded" in diag.rewrites["note"]
        assert diag.occupancy["recorded"] is False
        assert Diagnosis.from_json(diag.to_json()) == diag

    def test_v5_payload_keeps_rewrites_and_defaults_occupancy(
            self, async_hlo_text):
        """PR-9 ISSUE acceptance: v5 payloads (pre-occupancy) migrate
        into v6 with an explicit "not recorded" occupancy default; every
        recorded section survives untouched."""
        diag = Diagnosis.from_dict(self._payload(async_hlo_text, 5))
        assert diag.schema_version == SCHEMA_VERSION
        assert diag.sync_resources["recorded"] is True
        assert diag.issue_pressure["recorded"] is True
        assert diag.occupancy["recorded"] is False
        assert "not recorded" in diag.occupancy["note"]
        assert Diagnosis.from_json(diag.to_json()) == diag

    def test_newer_schema_still_rejected(self, async_hlo_text):
        data = self._payload(async_hlo_text, 1)
        data["schema_version"] = SCHEMA_VERSION + 1
        with pytest.raises(ValueError, match="schema_version"):
            Diagnosis.from_dict(data)
        data["schema_version"] = 0
        with pytest.raises(ValueError, match="schema_version"):
            Diagnosis.from_dict(data)

    @pytest.mark.parametrize("version", [1, 2, 3, 4, 5])
    def test_service_serves_migrated_artifact_without_pipeline(
            self, async_hlo_text, tmp_path, version):
        """The diagnosis disk key deliberately excludes SCHEMA_VERSION, so
        a schema-only bump keeps hitting pre-bump artifacts and migrates
        them instead of re-running the pipeline."""
        import gzip
        svc = LeoService(cache_dir=str(tmp_path))
        backend = svc.session.default_backend
        dkey = svc._diagnosis_key(async_hlo_text, backend,
                                  {"total_devices": 8}, DiagnoseOptions())
        path = svc.disk_cache._path("diagnoses", dkey, ".json.gz")
        import os
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with gzip.open(path, "wt", encoding="utf-8") as f:
            json.dump(self._payload(async_hlo_text, version), f)
        diag = svc.diagnose(async_hlo_text, hints={"total_devices": 8})
        assert svc.stats.analyze_calls == 0       # served from disk
        assert diag.schema_version == SCHEMA_VERSION
        assert diag.advice["recorded"] is False
        if version < 3:
            assert diag.issue_pressure["recorded"] is False

    def test_warm_disk_cache_with_v1_artifact_still_answers(
            self, async_hlo_text, tmp_path):
        """A disk tier written before the schema bump must read as a hit
        (migrated), not crash or silently refuse the whole cache."""
        import gzip
        cache = DiskCache(str(tmp_path))
        cache.store_diagnosis(
            "k1", Diagnosis.from_dict(self._payload(async_hlo_text, 1)))
        # rewrite the artifact as a genuine v1 payload
        path = cache._path("diagnoses", "k1", ".json.gz")
        data = self._payload(async_hlo_text, 1)
        with gzip.open(path, "wt", encoding="utf-8") as f:
            json.dump(data, f)
        diag = cache.load_diagnosis("k1")
        assert diag is not None
        assert diag.sync_resources["recorded"] is False
        assert diag.issue_pressure["recorded"] is False
        assert cache.stats.diagnosis_hits == 1
