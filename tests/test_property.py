"""Property-based tests (hypothesis) for LEO's invariants.

Strategy: generate random-but-valid instruction streams (DAG programs in
the unified model) and assert pipeline invariants that must hold for *any*
program:

  * blame conservation: attributed + self-blame cycles == total stall cycles
  * pruning soundness: sync edges never pruned by opcode/latency stages
  * coverage bounds and monotone edge counts
  * sampler sanity: makespan >= critical-resource occupancy of any op
  * parser round-trip on synthesized HLO text
"""
import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (
    TPU_V5E,
    analyze_module,
    build_dependency_graph,
    parse_hlo,
    sample,
)
from repro.core.isa import (
    Computation,
    Instruction,
    Module,
    OpClass,
    ShapeInfo,
    classify_opcode,
)

_OPCODES = ["add", "multiply", "exponential", "dot", "gather",
            "dynamic-slice", "transpose", "reduce", "copy", "tanh"]


@st.composite
def instruction_streams(draw):
    """A random straight-line SSA program with 3..24 instructions."""
    n = draw(st.integers(3, 24))
    n_params = draw(st.integers(1, 3))
    dims = draw(st.sampled_from([(64,), (32, 64), (8, 128)]))
    instrs = []
    for i in range(n_params):
        instrs.append(Instruction(
            name=f"p{i}", opcode="parameter", op_class=OpClass.PARAMETER,
            shape=ShapeInfo("f32", dims), operands=(),
            computation="c", index=0, attributes={"literal": str(i)}))
    for i in range(n):
        opcode = draw(st.sampled_from(_OPCODES))
        n_ops = 2 if opcode in ("add", "multiply", "dot", "gather") else 1
        avail = [ins.name for ins in instrs]
        operands = tuple(draw(st.sampled_from(avail)) for _ in range(n_ops))
        instr = Instruction(
            name=f"v{i}", opcode=opcode, op_class=classify_opcode(opcode),
            shape=ShapeInfo("f32", dims), operands=operands,
            computation="c", index=0)
        elems = instr.shape.num_elements
        if opcode == "dot":
            instr.flops = 2.0 * elems * dims[-1]
        elif instr.op_class in (OpClass.COMPUTE, OpClass.REDUCE):
            instr.flops = float(elems)
        instr.bytes_read = float(sum(
            ShapeInfo("f32", dims).byte_size for _ in operands))
        instr.bytes_written = float(instr.shape.byte_size)
        instrs.append(instr)
    comp = Computation(name="c", kind="entry")
    for ins in instrs:
        comp.add(ins)
    instrs[-1].is_root = True
    mod = Module(name="prop", entry="c")
    mod.add_computation(comp)
    return mod


@settings(max_examples=40, deadline=None)
@given(instruction_streams())
def test_blame_conservation(module):
    an = analyze_module(module, TPU_V5E)
    attributed = sum(e.cycles for e in an.blame.entries)
    self_blamed = sum(s.cycles for s in an.blame.self_blame)
    total = an.profile.total_stall_cycles
    assert attributed + self_blamed == pytest.approx(total, rel=1e-6, abs=1)


@settings(max_examples=40, deadline=None)
@given(instruction_streams())
def test_pruning_never_removes_sync_edges(module):
    an = analyze_module(module, TPU_V5E)
    for e in an.graph.edges:
        if e.kind.is_sync:
            assert e.pruned_by in (None, "execution")


@settings(max_examples=40, deadline=None)
@given(instruction_streams())
def test_coverage_in_unit_interval(module):
    an = analyze_module(module, TPU_V5E)
    for cov in (an.coverage_before, an.coverage_after):
        assert 0.0 <= cov.coverage <= 1.0
    assert an.prune_stats.surviving_edges <= an.prune_stats.initial_edges


@settings(max_examples=40, deadline=None)
@given(instruction_streams())
def test_makespan_dominates_occupancy(module):
    profile = sample(module, TPU_V5E)
    for rec in profile.records.values():
        assert rec.total_samples <= profile.makespan_cycles + 1e-6


@settings(max_examples=40, deadline=None)
@given(instruction_streams())
def test_blame_lands_on_real_instructions(module):
    an = analyze_module(module, TPU_V5E)
    for q, cycles in an.blame.top_root_causes(100):
        assert cycles >= 0
        assert module.find(q) is not None


# -- parser round-trip property ---------------------------------------------------

@st.composite
def hlo_programs(draw):
    """Synthesize valid HLO text with a random elementwise chain."""
    n = draw(st.integers(1, 10))
    dim = draw(st.sampled_from([16, 64, 256]))
    lines = [f"  %p0 = f32[{dim}] parameter(0)"]
    names = ["p0"]
    for i in range(n):
        op = draw(st.sampled_from(["add", "multiply", "subtract"]))
        a = draw(st.sampled_from(names))
        b = draw(st.sampled_from(names))
        lines.append(f"  %v{i} = f32[{dim}] {op}(%{a}, %{b})")
        names.append(f"v{i}")
    lines.append(f"  ROOT %r = f32[{dim}] negate(%{names[-1]})")
    body = "\n".join(lines)
    return (f"HloModule prop_mod\n\nENTRY %main (p0: f32[{dim}]) -> "
            f"f32[{dim}] {{\n{body}\n}}\n"), n, dim


@settings(max_examples=40, deadline=None)
@given(hlo_programs())
def test_parser_roundtrip(case):
    text, n, dim = case
    mod = parse_hlo(text)
    entry = mod.entry_computation
    # parameter + n ops + root
    assert len(entry.instructions) == n + 2
    assert entry.root is not None and entry.root.opcode == "negate"
    for instr in entry.instructions:
        if instr.op_class is OpClass.COMPUTE:
            assert instr.shape.dims == (dim,)
    # flops: 1 per element per elementwise op (negate included)
    assert mod.total_flops() == pytest.approx((n + 1) * dim)


@settings(max_examples=20, deadline=None)
@given(hlo_programs())
def test_graph_edges_reference_program(case):
    text, n, dim = case
    mod = parse_hlo(text)
    graph = build_dependency_graph(mod, TPU_V5E)
    for e in graph.edges:
        assert mod.find(e.producer) is not None
        assert mod.find(e.consumer) is not None
