"""Multi-process serving tests: LeoWorkerPool, metrics aggregation, and
client-side load balancing.

Pins the PR's acceptance contract:

  * a pre-forked pool serves real traffic on one shared port, and the
    aggregated ``/metrics`` counter totals equal the sum of the
    per-worker registry dumps;
  * a SIGKILLed worker is respawned by the supervisor while the client's
    retry path completes every request with zero errors;
  * SIGTERM drains rolling — workers exit 0 one at a time, in order;
  * a request parsed by one worker is a zero-parse disk hit for another
    worker sharing the ``cache_dir`` (the PR 2 stats assertion extended
    to the network path);
  * ``LeoClient(endpoints=[...])`` balances by power-of-two-choices over
    the observed ``queue_seconds`` EWMA, ejects dead endpoints with
    half-open probing, keeps ``diagnose_batch`` order-preserving across
    replicas, and leaks no sockets after a threaded batch.
"""
import http.client
import json
import os
import random
import signal
import socket
import time

import pytest

from repro.core.service import AnalyzeRequest, LeoService
from repro.serve import (
    LeoClient,
    LeoHttpd,
    MetricsRegistry,
    aggregate_dumps,
    encode_request,
)
from repro.serve.pool import LeoWorkerPool, respawn_delay

fork_only = pytest.mark.skipif(not hasattr(os, "fork"),
                               reason="LeoWorkerPool needs os.fork")


def _await(predicate, timeout=15.0, poll=0.02):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(poll)
    return False


# ---------------------------------------------------------------------------
# metrics aggregation (pure, no fork)
# ---------------------------------------------------------------------------

class TestAggregateDumps:
    def _registries(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        for reg, n in ((a, 3), (b, 5)):
            c = reg.counter("leo_requests_total", "requests",
                            labelnames=("endpoint", "code"))
            c.inc(n, endpoint="analyze", code="200")
            c.inc(1, endpoint="healthz", code="200")
            h = reg.histogram("leo_queue_seconds", "queue wait",
                              buckets=(0.1, 1.0))
            for v in [0.05] * n + [0.5]:
                h.observe(v)
            reg.gauge("leo_ready", "ready flag").set(1.0)
        return a, b

    def test_counters_sum_across_workers(self):
        a, b = self._registries()
        text = aggregate_dumps({"0": a.dump(), "1": b.dump()})
        assert 'leo_requests_total{endpoint="analyze",code="200"} 8' in text
        assert 'leo_requests_total{endpoint="healthz",code="200"} 2' in text

    def test_histograms_sum_buckets_sums_counts(self):
        a, b = self._registries()
        text = aggregate_dumps({"0": a.dump(), "1": b.dump()})
        # 3+5 observations at 0.05 (le=0.1) plus one 0.5 each (le=1.0)
        assert 'leo_queue_seconds_bucket{le="0.1"} 8' in text
        assert 'leo_queue_seconds_bucket{le="1"} 10' in text
        assert 'leo_queue_seconds_bucket{le="+Inf"} 10' in text
        assert "leo_queue_seconds_count 10" in text
        total = 3 * 0.05 + 0.5 + 5 * 0.05 + 0.5
        assert f"leo_queue_seconds_sum {total}" in text

    def test_gauges_labeled_per_worker_not_summed(self):
        a, b = self._registries()
        text = aggregate_dumps({"0": a.dump(), "1": b.dump()})
        assert 'leo_ready{worker="0"} 1' in text
        assert 'leo_ready{worker="1"} 1' in text
        assert "\nleo_ready 2" not in text

    def test_worker_missing_a_metric_contributes_nothing(self):
        a, b = self._registries()
        b.counter("leo_only_b_total", "only b").inc(4)
        text = aggregate_dumps({"0": a.dump(), "1": b.dump()})
        assert "leo_only_b_total 4" in text


class TestRespawnDelay:
    def test_free_restarts_are_immediate(self):
        assert respawn_delay([], 100.0) == 0.0
        assert respawn_delay([99.0, 99.5], 100.0) == 0.0

    def test_storm_backs_off_exponentially_to_cap(self):
        history = [100.0, 100.1, 100.2]
        assert respawn_delay(history, 100.3) == 0.5
        history.append(100.3)
        assert respawn_delay(history, 100.4) == 1.0
        many = [100.0 + 0.01 * i for i in range(12)]
        assert respawn_delay(many, 100.2) == 5.0          # capped

    def test_old_crashes_age_out_of_the_window(self):
        history = [10.0, 11.0, 12.0, 13.0]
        assert respawn_delay(history, 50.0) == 0.0        # all outside 30s


# ---------------------------------------------------------------------------
# client-side load balancing (in-process servers, no fork)
# ---------------------------------------------------------------------------

class TestClientLoadBalancing:
    def test_power_of_two_choices_prefers_lower_ewma(self):
        client = LeoClient(endpoints=["127.0.0.1:1", "127.0.0.1:2"],
                           rng=random.Random(7))
        client.endpoints[0].ewma_queue_seconds = 0.5
        client.endpoints[1].ewma_queue_seconds = 0.01
        picks = {client._pick_endpoint() for _ in range(32)}
        assert picks == {1}     # both sampled every time; lower EWMA wins

    def test_untried_endpoint_looks_attractive(self):
        client = LeoClient(endpoints=["127.0.0.1:1", "127.0.0.1:2"],
                           rng=random.Random(7))
        client.endpoints[0].ewma_queue_seconds = 0.2    # observed, loaded
        picks = {client._pick_endpoint() for _ in range(32)}
        assert picks == {1}     # None EWMA sorts below any observation

    def test_ejection_and_half_open_probe(self):
        client = LeoClient(endpoints=["127.0.0.1:1", "127.0.0.1:2"],
                           rng=random.Random(7), eject_seconds=0.5)
        client._note_conn_failure(0, now=100.0)
        snap = client.lb_snapshot()
        assert snap[0]["failures"] == 1
        # while ejected only the healthy endpoint is picked
        assert {client._pick_endpoint(now=100.2) for _ in range(8)} == {1}
        # cool-off expired: exactly one half-open probe is admitted
        assert client._pick_endpoint(now=100.6) == 0
        assert client.endpoints[0].probing
        assert {client._pick_endpoint(now=100.6) for _ in range(8)} == {1}
        # a failed probe re-ejects with a doubled cool-off
        client._note_conn_failure(0, now=100.6)
        assert client.endpoints[0].ejected_until == pytest.approx(101.6)
        # a successful probe fully reinstates
        client._note_success(0)
        assert not client.endpoints[0].probing
        assert client.endpoints[0].failures == 0

    def test_all_endpoints_dead_still_picks_one(self):
        client = LeoClient(endpoints=["127.0.0.1:1", "127.0.0.1:2"])
        client._note_conn_failure(0, now=100.0)
        client._note_conn_failure(1, now=101.0)
        assert client._pick_endpoint(now=100.1) == 0    # least-recently ejected

    def test_balances_across_two_live_servers(self, async_hlo_text,
                                               copystorm_hlo_text):
        with LeoHttpd(port=0, slots=2) as app1, \
                LeoHttpd(port=0, slots=2) as app2:
            eps = [f"127.0.0.1:{app1.port}", f"127.0.0.1:{app2.port}"]
            with LeoClient(endpoints=eps, max_retries=3) as client:
                reqs = [AnalyzeRequest(
                    hlo_text=(async_hlo_text if i % 2 == 0
                              else copystorm_hlo_text),
                    backend="tpu_v5e") for i in range(8)]
                out = client.diagnose_batch(reqs, max_connections=4)
                assert len(out) == 8
                # order-preserving across replicas: every even slot got
                # the async diagnosis, every odd slot the copy-storm one
                assert len({d.to_json() for d in out[0::2]}) == 1
                assert len({d.to_json() for d in out[1::2]}) == 1
                assert out[0].to_json() != out[1].to_json()
                served = [app.m_requests.value(endpoint="analyze",
                                               code="200")
                          for app in (app1, app2)]
                assert sum(served) == 8
                assert all(s > 0 for s in served), \
                    f"traffic never spread: {served}"

    def test_dead_endpoint_routes_to_survivor(self, async_hlo_text):
        # grab a port that refuses connections (bound, never listening
        # beyond close)
        dead = socket.socket()
        dead.bind(("127.0.0.1", 0))
        dead_port = dead.getsockname()[1]
        dead.close()
        with LeoHttpd(port=0, slots=2) as app:
            eps = [f"127.0.0.1:{dead_port}", f"127.0.0.1:{app.port}"]
            with LeoClient(endpoints=eps, max_retries=5,
                           backoff_base_seconds=0.01) as client:
                for _ in range(4):
                    d = client.diagnose(async_hlo_text, backend="tpu_v5e")
                    assert d.backend == "tpu_v5e"
                snap = client.lb_snapshot()
                by_port = {s["port"]: s for s in snap}
                assert by_port[dead_port]["failures"] >= 1
                assert by_port[app.port]["failures"] == 0

    def test_close_reaches_other_threads_connections(self, async_hlo_text):
        """Satellite: no socket leaks after a threaded diagnose_batch —
        pool-thread keep-alive conns are pruned when the batch ends, and
        close() closes whatever remains (any thread's)."""
        with LeoHttpd(port=0, slots=4) as app:
            client = LeoClient(port=app.port)
            reqs = [AnalyzeRequest(hlo_text=async_hlo_text,
                                   backend="tpu_v5e")] * 6
            for _ in range(3):
                out = client.diagnose_batch(reqs, max_connections=6)
                assert len(out) == 6
            assert _await(lambda: client.open_connection_count() == 0), \
                (f"{client.open_connection_count()} sockets leaked by "
                 f"batch pool threads")
            # the calling thread's own conn is registered and closed too
            client.diagnose(async_hlo_text, backend="tpu_v5e")
            assert client.open_connection_count() == 1
            client.close()
            assert client.open_connection_count() == 0


# ---------------------------------------------------------------------------
# the pre-forked pool (fork required)
# ---------------------------------------------------------------------------

def _encode(hlo_text, backend="tpu_v5e"):
    return encode_request(AnalyzeRequest(hlo_text=hlo_text,
                                         backend=backend))


@fork_only
class TestPoolServing:
    def test_round_trip_and_batch_through_shared_port(self, async_hlo_text,
                                                      copystorm_hlo_text):
        with LeoWorkerPool(workers=2, port=0, slots=2,
                           control_port=None) as pool:
            assert pool.wait_ready(30.0)
            with LeoClient(port=pool.port, max_retries=3) as client:
                d = client.diagnose(async_hlo_text, backend="tpu_v5e")
                assert d.backend == "tpu_v5e"
                reqs = [AnalyzeRequest(
                    hlo_text=(async_hlo_text if i % 2 == 0
                              else copystorm_hlo_text),
                    backend="tpu_v5e") for i in range(6)]
                out = client.diagnose_batch(reqs, max_connections=3)
                assert len(out) == 6
                assert len({d.to_json() for d in out[0::2]}) == 1
                assert out[0].to_json() != out[1].to_json()

    @pytest.mark.skipif(not hasattr(socket, "SO_REUSEPORT"),
                        reason="needs SO_REUSEPORT")
    def test_reuseport_fallback_serves(self, async_hlo_text):
        with LeoWorkerPool(workers=2, port=0, slots=2, mode="reuseport",
                           control_port=None) as pool:
            assert pool.wait_ready(30.0)
            with LeoClient(port=pool.port, max_retries=3) as client:
                d = client.diagnose(async_hlo_text, backend="amd_mi300a")
                assert d.backend == "amd_mi300a"

    def test_aggregated_counters_equal_sum_of_worker_dumps(
            self, async_hlo_text):
        """Acceptance: aggregated /metrics counter totals == the sum of
        the per-worker registries."""
        n_requests = 6
        with LeoWorkerPool(workers=2, port=0, slots=2) as pool:
            assert pool.wait_ready(30.0)
            with LeoClient(port=pool.port, max_retries=3) as client:
                reqs = [AnalyzeRequest(hlo_text=async_hlo_text,
                                       backend="tpu_v5e")] * n_requests
                out = client.diagnose_batch(reqs, max_connections=3)
                assert len(out) == n_requests

            def served_total():
                total = 0
                for snap in pool.worker_snapshots().values():
                    dump = snap["metrics"].get("leo_requests_total", {})
                    for key, value in dump.get("values", []):
                        if key == ["analyze", "200"]:
                            total += value
                return total

            # wait for every worker's post-traffic heartbeat to land
            assert _await(lambda: served_total() == n_requests), \
                f"worker dumps total {served_total()} != {n_requests}"

            text = pool.aggregate_metrics_text()
            assert (f'leo_requests_total{{endpoint="analyze",code="200"}} '
                    f"{n_requests}") in text
            # gauges arrive per worker, never summed
            assert 'leo_ready{worker="0"} 1' in text
            assert 'leo_ready{worker="1"} 1' in text

            # the pool's control HTTP endpoints serve the same page
            conn = http.client.HTTPConnection("127.0.0.1",
                                              pool.control_port,
                                              timeout=10.0)
            try:
                conn.request("GET", "/metrics")
                body = conn.getresponse().read().decode()
                assert (f'leo_requests_total{{endpoint="analyze",'
                        f'code="200"}} {n_requests}') in body
                conn.request("GET", "/stats")
                stats = json.loads(conn.getresponse().read())
                assert len(stats["workers"]) == 2
                conn.request("GET", "/healthz")
                resp = conn.getresponse()
                resp.read()             # drain: keep-alive stays usable
                assert resp.status == 200
                conn.request("GET", "/readyz")
                resp = conn.getresponse()
                resp.read()
                assert resp.status == 200
            finally:
                conn.close()

    def test_sigkilled_worker_respawns_and_requests_complete(
            self, async_hlo_text):
        """Acceptance: worker-crash respawn, with the client's retry path
        completing every request with zero errors."""
        with LeoWorkerPool(workers=2, port=0, slots=2,
                           control_port=None) as pool:
            assert pool.wait_ready(30.0)
            pids0 = dict(pool.worker_pids)
            victim_idx, victim_pid = sorted(pids0.items())[0]
            os.kill(victim_pid, signal.SIGKILL)
            with LeoClient(port=pool.port, max_retries=8,
                           backoff_base_seconds=0.02) as client:
                for i in range(6):
                    d = client.diagnose(async_hlo_text, backend="tpu_v5e")
                    assert d.backend == "tpu_v5e"
            assert _await(
                lambda: pool.worker_pids.get(victim_idx)
                not in (None, victim_pid), timeout=30.0), \
                "supervisor never respawned the SIGKILLed worker"
            assert pool.respawns_total >= 1
            assert pool.wait_ready(30.0)    # replacement reports ready

    def test_rolling_sigterm_drain_exits_zero_in_order(self,
                                                       async_hlo_text):
        """Acceptance: rolling SIGTERM drain — one worker at a time, all
        exit 0."""
        pool = LeoWorkerPool(workers=2, port=0, slots=2,
                             control_port=None).start()
        try:
            assert pool.wait_ready(30.0)
            with LeoClient(port=pool.port, max_retries=3) as client:
                client.diagnose(async_hlo_text, backend="tpu_v5e")
        finally:
            assert pool.drain() is True
        events = pool.drain_events
        sigterms = [e for e in events if e[0] == "sigterm"]
        exits = [e for e in events if e[0] == "exit"]
        assert [idx for _, idx, _ in sigterms] == [0, 1]
        assert [idx for _, idx, _ in exits] == [0, 1]
        # rolling: worker 1 is not told to stop until worker 0 exited
        assert sigterms[1][2] >= exits[0][2]
        for rec in pool._records.values():
            assert rec.exit_code == 0

    def test_cross_process_warm_tier_zero_parses(self, tmp_path,
                                                 async_hlo_text):
        """Satellite: a request parsed (cold) by one worker process is a
        zero-parse disk hit for a different worker process sharing the
        cache_dir — the PR 2 stats assertion extended to the network
        path, across real forked server processes."""
        body = _encode(async_hlo_text)
        with LeoWorkerPool(workers=2, port=0, slots=2,
                           cache_dir=str(tmp_path / "cache"),
                           control_port=None) as pool:
            assert pool.wait_ready(30.0)

            def stats_on(conn):
                conn.request("GET", "/stats")
                return json.loads(conn.getresponse().read())

            def analyze_on(conn):
                conn.request("POST", "/v1/analyze", body=body,
                             headers={"Content-Type": "application/json"})
                resp = conn.getresponse()
                payload = resp.read()
                assert resp.status == 200, payload[:200]

            # cold request: whichever worker this keep-alive connection
            # landed on parses and publishes to the shared disk tier
            first = http.client.HTTPConnection("127.0.0.1", pool.port,
                                               timeout=30.0)
            analyze_on(first)
            first_stats = stats_on(first)
            first_pid = first_stats["pid"]
            assert first_stats["parse_calls"] >= 1
            first.close()

            # find a keep-alive connection accepted by the *other*
            # worker (connection affinity: one conn stays with the
            # worker that accepted it)
            other = None
            for _ in range(200):
                conn = http.client.HTTPConnection("127.0.0.1", pool.port,
                                                  timeout=30.0)
                if stats_on(conn)["pid"] != first_pid:
                    other = conn
                    break
                conn.close()
                time.sleep(0.01)
            assert other is not None, \
                "kernel never balanced a connection to the second worker"
            try:
                analyze_on(other)
                stats = stats_on(other)
                assert stats["pid"] != first_pid
                # zero HLO parses: the diagnosis came off the shared
                # disk tier, never touching the parser
                assert stats["parse_calls"] == 0
                assert stats["disk"]["diagnosis_hits"] >= 1
            finally:
                other.close()


@fork_only
class TestPoolValidation:
    def test_rejects_zero_workers(self):
        with pytest.raises(ValueError):
            LeoWorkerPool(workers=0)

    def test_rejects_unknown_mode(self):
        with pytest.raises(ValueError):
            LeoWorkerPool(workers=2, mode="threads")
