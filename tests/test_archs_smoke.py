"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
train-grad step + decode step on CPU, asserting shapes and finiteness.

Full configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, smoke_config
from repro.models import (
    decode_step,
    forward,
    init_decode_state,
    init_params,
    loss_fn,
)

B, S = 2, 32


def _batch(cfg, rng):
    kt, kl, ke = jax.random.split(rng, 3)
    batch = {"labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend != "none":
        batch["embeds"] = jax.random.normal(ke, (B, S, cfg.d_model),
                                            jnp.float32) * 0.02
    else:
        batch["tokens"] = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", [c.name for c in ALL_ARCHS])
def test_smoke_forward_and_grad(arch):
    cfg = smoke_config(next(c for c in ALL_ARCHS if c.name == arch))
    rng = jax.random.PRNGKey(0)
    params = init_params(rng, cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, aux = jax.jit(
        lambda p, b: forward(p, cfg, tokens=b.get("tokens"),
                             embeds=b.get("embeds"), chunk=16))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch, chunk=16)))(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    flat = jax.tree.leaves(grads)
    assert flat and all(bool(jnp.isfinite(g).all()) for g in flat), \
        f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", [c.name for c in ALL_ARCHS])
def test_smoke_decode(arch):
    cfg = smoke_config(next(c for c in ALL_ARCHS if c.name == arch))
    rng = jax.random.PRNGKey(0)
    params = init_params(rng, cfg)
    state = init_decode_state(cfg, batch=B, max_len=64)
    token = jnp.zeros((B,), jnp.int32)

    step = jax.jit(lambda p, s, t, pos: decode_step(p, s, cfg, t, pos))
    logits, state = step(params, state, token, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # a second step must thread state correctly
    logits2, state = step(params, state, token + 1, jnp.int32(1))
    assert bool(jnp.isfinite(logits2).all())
    assert not np.allclose(np.asarray(logits), np.asarray(logits2))


def test_param_counts_match_assignment():
    """Analytical N should be in the right ballpark for the named sizes."""
    import re
    expectations = {
        "qwen2-0.5b": (0.3e9, 0.8e9),
        "glm4-9b": (8e9, 12e9),
        "deepseek-coder-33b": (28e9, 38e9),
        "deepseek-v2-236b": (180e9, 280e9),
        "phi3.5-moe-42b-a6.6b": (35e9, 50e9),
        "hymba-1.5b": (1.0e9, 2.2e9),
        "musicgen-medium": (1.2e9, 2.4e9),
        "internvl2-2b": (1.5e9, 2.8e9),
        "h2o-danube-3-4b": (3e9, 5e9),
        "xlstm-125m": (0.08e9, 0.2e9),
    }
    for cfg in ALL_ARCHS:
        lo, hi = expectations[cfg.name]
        n = cfg.param_count()
        assert lo <= n <= hi, f"{cfg.name}: N={n/1e9:.2f}B not in " \
            f"[{lo/1e9:.1f}, {hi/1e9:.1f}]"


def test_moe_active_params_less_than_total():
    from repro.configs import get_config
    ds = get_config("deepseek-v2-236b")
    assert ds.active_param_count() < 0.2 * ds.param_count()
    phi = get_config("phi3.5-moe-42b-a6.6b")
    assert phi.active_param_count() < 0.5 * phi.param_count()


def test_long_context_applicability():
    from repro.configs import get_config, shapes_for
    long_ok = {c.name for c in ALL_ARCHS
               if any(s.name == "long_500k" for s in shapes_for(c))}
    assert long_ok == {"xlstm-125m", "hymba-1.5b", "h2o-danube-3-4b"}
