"""Unit tests for the multi-stream issue model (PR-4 tentpole).

Covers `IssueModel` validation, `Backend.with_issue` derivation, the
sampler's port arbitration (concurrent issue shortens makespans; port
waits classify as `pipe_busy` vs `not_selected` by the occupant's
execution pipe; K=1 records neither), the per-queue `issue_pressure`
report, the `BlameResult.scheduler_contention` evidence channel, and
service-cache non-aliasing between issue variants of one backend.
"""
import json

import pytest

from repro.core import (
    LeoService,
    SINGLE_ISSUE,
    IssueModel,
    StallClass,
    get_backend,
    parse_hlo,
)
from repro.core.sampler import VirtualSampler, pipe_of


def _variant(queues, width=1, policy="round_robin", base="tpu_v5e"):
    return get_backend(base).with_issue(
        IssueModel(queues=queues, width=width, policy=policy),
        name=f"{base}@test-q{queues}w{width}{policy[0]}")


def _hlo(ops):
    """Tiny single-computation module from a list of op lines."""
    body = "\n".join(f"  {line}" for line in ops)
    return (f"HloModule issue_unit\n\nENTRY %main.1 (a: f32[64,64]) -> "
            f"f32[64,64] {{\n  %a = f32[64,64] parameter(0)\n{body}\n}}\n")


#: Four independent same-pipe (VPU) multiplies, then a reduction tail.
WIDE4 = _hlo([
    "%m0 = f32[64,64] multiply(%a, %a)",
    "%m1 = f32[64,64] multiply(%a, %a)",
    "%m2 = f32[64,64] multiply(%a, %a)",
    "%m3 = f32[64,64] multiply(%a, %a)",
    "%s1 = f32[64,64] add(%m0, %m1)",
    "%s2 = f32[64,64] add(%s1, %m2)",
    "ROOT %s3 = f32[64,64] add(%s2, %m3)",
])

#: A slow MXU op first, then two independent VPU ops: on 2 round-robin
#: queues the third op is assigned behind the dot — a different pipe, so
#: its wait is an arbitration loss (`not_selected`).
MIXED3 = _hlo([
    "%d0 = f32[64,64] dot(%a, %a), lhs_contracting_dims={1}, "
    "rhs_contracting_dims={0}",
    "%m1 = f32[64,64] multiply(%a, %a)",
    "%m2 = f32[64,64] multiply(%a, %a)",
    "ROOT %s1 = f32[64,64] add(%d0, %m2)",
])


def _stall_cycles(profile, cls):
    return sum(r.stall_breakdown.get(cls, 0.0)
               for r in profile.records.values())


def _run(hlo, backend):
    module = parse_hlo(hlo)
    return VirtualSampler(module, backend.hw, sync=backend.sync).run()


class TestIssueModel:
    def test_validation(self):
        with pytest.raises(ValueError, match="queues"):
            IssueModel(queues=0)
        with pytest.raises(ValueError, match="width"):
            IssueModel(width=0)
        with pytest.raises(ValueError, match="policy"):
            IssueModel(policy="lifo")

    def test_ports_and_multi_stream(self):
        assert SINGLE_ISSUE.ports == 1 and not SINGLE_ISSUE.multi_stream
        assert IssueModel(queues=8, width=2).ports == 16

    def test_with_issue_derives_renamed_backend(self):
        base = get_backend("nvidia_gh200")
        k1 = base.with_issue(SINGLE_ISSUE)
        assert k1.name == "nvidia_gh200@q1x1-round_robin"
        assert k1.hw.issue == SINGLE_ISSUE
        assert k1.hw.clock_hz == base.hw.clock_hz
        assert base.issue.queues == 4        # original untouched
        # policy is part of the derived name: two variants differing only
        # in scheduler policy must never alias in name-keyed caches
        rr = base.with_issue(IssueModel(4, 1, "round_robin"))
        go = base.with_issue(IssueModel(4, 1, "greedy_oldest"))
        assert rr.name != go.name

    def test_every_shipped_backend_declares_an_issue_model(self):
        from repro.core import list_backends
        policies = set()
        for b in list_backends():
            assert b.issue.queues >= 1
            policies.add(b.issue.policy)
        assert policies >= {"round_robin", "greedy_oldest"}


class TestPortArbitration:
    def test_concurrent_issue_shortens_makespan(self):
        serial = _run(WIDE4, _variant(1))
        wide = _run(WIDE4, _variant(4))
        assert wide.makespan_cycles < serial.makespan_cycles

    def test_single_stream_records_no_scheduler_stalls(self):
        prof = _run(WIDE4, _variant(1))
        assert _stall_cycles(prof, StallClass.NOT_SELECTED) == 0
        assert _stall_cycles(prof, StallClass.PIPE_BUSY) == 0
        assert prof.issue_pressure is not None
        assert not prof.issue_pressure.contended

    def test_same_pipe_contention_is_pipe_busy(self):
        """4 VPU multiplies on 2 queues: the overflow pair waits behind
        same-pipe occupants — `pipe_busy`, never `not_selected`."""
        prof = _run(WIDE4, _variant(2))
        assert _stall_cycles(prof, StallClass.PIPE_BUSY) > 0
        assert _stall_cycles(prof, StallClass.NOT_SELECTED) == 0
        ev = prof.issue_pressure.events
        assert ev and all(e["stall_class"] == "pipe_busy" for e in ev)
        assert all(e["holder"].startswith("main.1::m") for e in ev)

    def test_cross_pipe_contention_is_not_selected(self):
        """With round-robin assignment the second multiply lands behind
        the slow dot: ready, but its queue is held by another pipe —
        `not_selected` (arbitration loss)."""
        prof = _run(MIXED3, _variant(2, policy="round_robin"))
        assert _stall_cycles(prof, StallClass.NOT_SELECTED) > 0
        blocked = [e for e in prof.issue_pressure.events
                   if e["stall_class"] == "not_selected"]
        assert blocked and blocked[0]["holder"] == "main.1::d0"
        assert blocked[0]["pipe"] == "vpu"

    def test_greedy_beats_round_robin_on_asymmetric_occupants(self):
        """greedy_oldest is work-conserving: it issues behind the
        earliest-freeing slot (the early-retiring copy, a different pipe
        -> cheap `not_selected`), while static round-robin pins the
        multiply behind its own queue's slow same-pipe occupant
        (expensive `pipe_busy`)."""
        asym = _hlo([
            "%m0 = f32[64,64] multiply(%a, %a)",
            "%cp1 = f32[64,64] copy(%a)",
            "%m2 = f32[64,64] multiply(%a, %a)",
            "ROOT %s1 = f32[64,64] add(%m2, %m0)",
        ])
        greedy = _run(asym, _variant(2, policy="greedy_oldest"))
        rr = _run(asym, _variant(2, policy="round_robin"))
        g_ns = _stall_cycles(greedy, StallClass.NOT_SELECTED)
        g_pb = _stall_cycles(greedy, StallClass.PIPE_BUSY)
        r_pb = _stall_cycles(rr, StallClass.PIPE_BUSY)
        assert g_ns > 0 and g_pb == 0        # waited on the copy's slot
        assert r_pb > 0                      # waited on the multiply
        assert g_ns < r_pb                   # work conservation pays
        g_ev = greedy.issue_pressure.events
        assert g_ev[0]["holder"] == "main.1::cp1"
        r_ev = rr.issue_pressure.events
        assert r_ev[0]["holder"] == "main.1::m0"

    def test_width_multiplies_ports(self):
        """queues=1 x width=4 gives the same port count as queues=4 x
        width=1 — the four independent multiplies all issue at t0."""
        by_width = _run(WIDE4, _variant(1, width=4))
        by_queues = _run(WIDE4, _variant(4))
        assert by_width.makespan_cycles == by_queues.makespan_cycles

    def test_dependent_chain_charges_data_stalls_not_contention(self):
        chain = _hlo([
            "%c0 = f32[64,64] multiply(%a, %a)",
            "%c1 = f32[64,64] multiply(%c0, %c0)",
            "ROOT %c2 = f32[64,64] multiply(%c1, %c1)",
        ])
        prof = _run(chain, _variant(4, policy="greedy_oldest"))
        assert _stall_cycles(prof, StallClass.NOT_SELECTED) == 0
        assert _stall_cycles(prof, StallClass.PIPE_BUSY) == 0
        assert _stall_cycles(prof, StallClass.EXEC_DEP) > 0

    def test_pipe_of_families(self):
        module = parse_hlo(MIXED3)
        by_name = {i.name: i for i in module.all_instructions()}
        assert pipe_of(by_name["d0"]) == "mxu"
        assert pipe_of(by_name["m1"]) == "vpu"


class TestIssuePressureSurface:
    @pytest.fixture(scope="class")
    def analysis(self):
        svc = LeoService()
        backend = _variant(2, base="tpu_v5e")
        an = svc.analyze(WIDE4, backend=backend)
        diag = svc.diagnose(WIDE4, backend=backend)
        return an, diag

    def test_report_is_json_pure_and_sums_per_queue(self, analysis):
        an, _ = analysis
        report = an.issue_pressure
        data = report.to_dict()
        json.dumps(data)   # must not raise
        assert data["queues"] == 2 and data["contended"]
        assert data["contention_cycles"] == pytest.approx(
            sum(q["not_selected_cycles"] + q["pipe_busy_cycles"]
                for q in data["per_queue"]))
        assert sum(q["issued"] for q in data["per_queue"]) > 0

    def test_blame_channel_sorted_and_populated(self, analysis):
        an, _ = analysis
        sched = an.blame.scheduler_contention
        assert sched
        assert all(s.stall_class in ("pipe_busy", "not_selected")
                   for s in sched)
        assert [s.cycles for s in sched] == \
            sorted((s.cycles for s in sched), reverse=True)
        assert all(0 <= s.queue < 2 for s in sched)

    def test_diagnosis_section_round_trips(self, analysis):
        from repro.core import Diagnosis
        _, diag = analysis
        ip = diag.issue_pressure
        assert ip["recorded"] and ip["contended"]
        assert ip["blame"]
        assert Diagnosis.from_json(diag.to_json()) == diag

    def test_issue_variants_do_not_alias_in_service_caches(self):
        """The K=1 and native variants of one backend must produce
        distinct cached diagnoses (the derived name keys the cache)."""
        svc = LeoService()
        native = svc.diagnose(WIDE4, backend=_variant(2))
        single = svc.diagnose(WIDE4, backend=_variant(1))
        assert native.estimated_step_seconds < \
            single.estimated_step_seconds
        assert single.issue_pressure["queues"] == 1

    def test_while_loop_warmup_does_not_pollute_pressure(self):
        """The while warm-up pass runs on a scratch collector: contention
        is charged once per steady-state iteration set, not once extra."""
        loop_hlo = """\
HloModule loop_issue

%body.1 (p.1: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p.1 = (s32[], f32[64,64]) parameter(0)
  %iv = s32[] get-tuple-element(%p.1), index=0
  %one = s32[] constant(1)
  %iv2 = s32[] add(%iv, %one)
  %acc = f32[64,64] get-tuple-element(%p.1), index=1
  %w0 = f32[64,64] multiply(%acc, %acc)
  %w1 = f32[64,64] multiply(%acc, %acc)
  %w2 = f32[64,64] multiply(%acc, %acc)
  %gain = f32[64,64] add(%w0, %w1)
  %gain2 = f32[64,64] add(%gain, %w2)
  ROOT %out = (s32[], f32[64,64]) tuple(%iv2, %gain2)
}

%cond.1 (p.2: (s32[], f32[64,64])) -> pred[] {
  %p.2 = (s32[], f32[64,64]) parameter(0)
  %iv3 = s32[] get-tuple-element(%p.2), index=0
  %lim = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv3, %lim), direction=LT
}

ENTRY %main.1 (arg0: f32[64,64]) -> f32[64,64] {
  %arg0 = f32[64,64] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[64,64]) tuple(%zero, %arg0)
  %loop = (s32[], f32[64,64]) while(%init), condition=%cond.1, body=%body.1
  ROOT %result = f32[64,64] get-tuple-element(%loop), index=1
}
"""
        prof = _run(loop_hlo, _variant(2))
        report = prof.issue_pressure
        # 3 independent multiplies on 2 queues contend in the body; the
        # recorded cycles carry the steady-state weight (trip count), and
        # the body's weighted contention equals the report's total — no
        # extra unweighted warm-up contribution.
        trips = 5
        per_event = {}
        for e in report.events:
            per_event.setdefault(e["consumer"], 0.0)
            per_event[e["consumer"]] += e["stall_cycles"] * e["weight"]
        assert per_event, "expected loop-body contention"
        for consumer, cycles in per_event.items():
            rec_cycles = sum(
                prof.records[consumer].stall_breakdown.get(c, 0.0)
                for c in (StallClass.NOT_SELECTED, StallClass.PIPE_BUSY))
            assert rec_cycles == pytest.approx(cycles), consumer
        assert all(e["weight"] == trips for e in report.events)
        # control wrappers (the while op) record an issue event but no
        # busy cycles — their bodies' instructions already charge their
        # queues, so per-queue occupancy can never exceed the makespan
        for q in report.per_queue:
            assert q["busy_cycles"] <= prof.makespan_cycles, q


# --------------------------------------------------------------------------
# Wave occupancy on the issue fabric (PR-9 tentpole)
# --------------------------------------------------------------------------

GOLDEN_BACKENDS = ("amd_mi300a", "intel_pvc", "nvidia_gh200",
                   "tpu_v4", "tpu_v5e", "tpu_v5p")

#: Backends whose sync pools are queue-scoped: engaging residency cannot
#: perturb the issue timeline, so their exposed cycles are bounded by the
#: single-wave baseline's hideable demand.  NVIDIA is deliberately absent
#: — its device-scope barriers are shared across waves, so more residency
#: can *create* sync serialization (the cross-vendor divergence).
QUEUE_SCOPED_BACKENDS = ("amd_mi300a", "intel_pvc")


def _occ_variant(base, waves, window=None):
    from repro.core import OccupancyModel
    native = base.native_occupancy
    return base.with_occupancy(OccupancyModel(
        waves=waves,
        limiter=native.limiter if waves > 1 else "none",
        window_cycles=window if window is not None
        else native.window_cycles))


def _profile_fingerprint(profile):
    """Everything the sampler records, in comparable form."""
    return (profile.makespan_cycles, {
        q: (r.total_samples, r.latency_samples, r.exec_count,
            dict(r.stall_breakdown), dict(r.blockers))
        for q, r in profile.records.items()})


def _hideable_demand(profile):
    """Stall cycles the wave credit is allowed to absorb: dependence/sync
    waits plus resource serialization (mirrors the sampler exactly)."""
    from repro.core.sampler import _HIDEABLE_STALLS
    classes = set(_HIDEABLE_STALLS) | {StallClass.SYNC_RESOURCE}
    return sum(r.stall_breakdown.get(c, 0.0)
               for r in profile.records.values() for c in classes)


class TestOccupancyModel:
    def test_validation(self):
        from repro.core import OccupancyModel
        with pytest.raises(ValueError, match="waves"):
            OccupancyModel(waves=0)
        with pytest.raises(ValueError, match="limiter"):
            OccupancyModel(waves=2, limiter="vibes")
        with pytest.raises(ValueError, match="window_cycles"):
            OccupancyModel(waves=2, limiter="register_file",
                           window_cycles=0.0)

    def test_with_occupancy_derives_renamed_backend(self):
        base = get_backend("nvidia_gh200")
        native = base.with_occupancy()
        assert native.name != base.name
        assert native.occupancy == base.native_occupancy
        assert base.occupancy.waves == 1          # original untouched
        # every OccupancyModel field lands in the name: variants that
        # differ only in the hiding window must never alias in caches
        a = _occ_variant(base, 8, window=32.0)
        b = _occ_variant(base, 8, window=64.0)
        assert a.name != b.name

    def test_shipped_parts_declare_native_residency(self):
        from repro.core import list_backends
        declared = {b.name: b.native_occupancy for b in list_backends()}
        assert declared["nvidia_gh200"].waves == 8
        assert declared["nvidia_gh200"].limiter == "register_file"
        assert declared["amd_mi300a"].waves == 4
        assert declared["amd_mi300a"].limiter == "wavefront_slots"
        assert declared["intel_pvc"].waves == 2
        assert declared["intel_pvc"].limiter == "thread_slots"
        for tpu in ("tpu_v4", "tpu_v5e", "tpu_v5p"):
            assert not declared[tpu].multi_wave
        # ...but every registered part SAMPLES single-wave by default:
        # plain profiles are the pre-occupancy parity anchor
        for b in list_backends():
            assert not b.occupancy.multi_wave, b.name


class TestOccupancySampler:
    @pytest.mark.parametrize("backend", GOLDEN_BACKENDS)
    def test_w1_parity_deterministic(self, backend):
        """A W=1 occupancy variant reproduces the plain profile exactly
        on every shipped backend (no hypothesis needed for the anchor)."""
        from conftest import COPYSTORM_HLO
        module = parse_hlo(COPYSTORM_HLO)
        base = get_backend(backend)
        plain = VirtualSampler(module, base.hw, sync=base.sync).run()
        w1 = _occ_variant(base, 1, window=7.5)
        gated = VirtualSampler(module, w1.hw, sync=w1.sync).run()
        assert _profile_fingerprint(gated) == _profile_fingerprint(plain)
        assert gated.occupancy_pressure is None

    def test_w1_is_byte_identical_on_all_backends(self):
        """ISSUE acceptance (hypothesis): a W=1 occupancy sampler — any
        window, any limiter metadata — degenerates byte-identically to
        the pre-occupancy sampler on every shipped backend."""
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (requirements-dev.txt)")
        from hypothesis import given, settings, strategies as st
        from conftest import ASYNC_HLO, COPYSTORM_HLO

        modules = {h: parse_hlo(h) for h in (ASYNC_HLO, COPYSTORM_HLO,
                                             WIDE4, MIXED3)}

        @settings(max_examples=24, deadline=None)
        @given(backend=st.sampled_from(GOLDEN_BACKENDS),
               hlo=st.sampled_from(sorted(modules)),
               window=st.floats(0.5, 512.0, allow_nan=False))
        def prop(backend, hlo, window):
            base = get_backend(backend)
            plain = VirtualSampler(modules[hlo], base.hw,
                                   sync=base.sync).run()
            w1 = _occ_variant(base, 1, window=window)
            gated = VirtualSampler(modules[hlo], w1.hw, sync=w1.sync).run()
            assert _profile_fingerprint(gated) == \
                _profile_fingerprint(plain)
            assert gated.occupancy_pressure is None

        prop()

    def test_exposed_conservation_for_any_waves(self):
        """ISSUE acceptance (hypothesis): for any W >= 1 the report's
        exposed_cycles equal the run's surviving hideable-class stalls
        (nothing hidden is double-charged, nothing exposed vanishes),
        and banked credit respects the per-queue (W-1) x window cap."""
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (requirements-dev.txt)")
        from hypothesis import given, settings, strategies as st
        from conftest import COPYSTORM_HLO

        module = parse_hlo(COPYSTORM_HLO)

        @settings(max_examples=24, deadline=None)
        @given(backend=st.sampled_from(GOLDEN_BACKENDS),
               waves=st.integers(2, 8),
               window=st.floats(8.0, 256.0, allow_nan=False))
        def prop(backend, waves, window):
            base = get_backend(backend)
            occ = _occ_variant(base, waves, window=window)
            prof = VirtualSampler(module, occ.hw, sync=occ.sync).run()
            rep = prof.occupancy_pressure
            survived = _hideable_demand(prof) + _stall_cycles(
                prof, StallClass.OCCUPANCY_LIMITED)
            assert rep.exposed_cycles == pytest.approx(survived)
            assert rep.hidden_cycles >= 0.0
            # the makespan never compresses past the W-fold overlap bound
            plain = VirtualSampler(module, base.hw, sync=base.sync).run()
            assert prof.makespan_cycles >= \
                plain.makespan_cycles / waves - 1e-9

        prop()

    @pytest.mark.parametrize("backend", QUEUE_SCOPED_BACKENDS)
    def test_exposed_bounded_by_baseline_on_queue_scoped_parts(
            self, backend):
        """With queue-scoped sync pools the timeline is residency-
        invariant, so exposed cycles can only shrink from the single-wave
        baseline's hideable demand (hiding removes, never adds)."""
        from conftest import COPYSTORM_HLO
        module = parse_hlo(COPYSTORM_HLO)
        base = get_backend(backend)
        plain = VirtualSampler(module, base.hw, sync=base.sync).run()
        budget = _hideable_demand(plain)
        for waves in (2, 4, 8):
            occ = _occ_variant(base, waves)
            prof = VirtualSampler(module, occ.hw, sync=occ.sync).run()
            rep = prof.occupancy_pressure
            assert rep.exposed_cycles <= budget + 1e-6, waves
            assert rep.hidden_cycles + rep.exposed_cycles == \
                pytest.approx(budget), waves

    def test_device_scope_sharing_can_hurt_nvidia(self):
        """The cross-vendor punchline: NVIDIA's device-scope named
        barriers are shared across resident waves, so raising residency
        can RAISE sync serialization past what hiding reclaims."""
        from conftest import COPYSTORM_HLO
        module = parse_hlo(COPYSTORM_HLO)
        base = get_backend("nvidia_gh200")
        plain = VirtualSampler(module, base.hw, sync=base.sync).run()
        occ = _occ_variant(base, 8)
        prof = VirtualSampler(module, occ.hw, sync=occ.sync).run()
        rep = prof.occupancy_pressure
        assert rep.exposed_cycles > _hideable_demand(plain)
        # conservation still holds within the W=8 run itself
        survived = _hideable_demand(prof) + _stall_cycles(
            prof, StallClass.OCCUPANCY_LIMITED)
        assert rep.exposed_cycles == pytest.approx(survived)

    def test_multi_wave_hides_latency_on_amd(self):
        """AMD's queue-scoped waitcnt counters let residency pay off:
        shorter makespan, positive hidden credit, and the leftover waits
        reclassified as occupancy_limited (hiding ran out of waves)."""
        from conftest import COPYSTORM_HLO
        module = parse_hlo(COPYSTORM_HLO)
        base = get_backend("amd_mi300a")
        plain = VirtualSampler(module, base.hw, sync=base.sync).run()
        occ = base.with_occupancy()
        prof = VirtualSampler(module, occ.hw, sync=occ.sync).run()
        assert prof.makespan_cycles < plain.makespan_cycles
        rep = prof.occupancy_pressure
        assert rep.hidden_cycles > 0
        assert len(rep.per_queue) == occ.issue.queues
        assert _stall_cycles(prof, StallClass.OCCUPANCY_LIMITED) > 0

    def test_occupancy_variants_do_not_alias_in_service_caches(self):
        """Engaged and plain analyses of one backend must produce
        distinct cached diagnoses (the derived name keys the cache)."""
        from conftest import COPYSTORM_HLO
        svc = LeoService()
        base = get_backend("amd_mi300a")
        plain = svc.diagnose(COPYSTORM_HLO, backend=base)
        engaged = svc.diagnose(COPYSTORM_HLO,
                               backend=base.with_occupancy())
        assert engaged.estimated_step_seconds < \
            plain.estimated_step_seconds
        assert plain.occupancy["recorded"] is False
        assert engaged.occupancy["recorded"] is True
        assert engaged.occupancy["waves"] == 4
