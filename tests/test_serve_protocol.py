"""Unit tests for the serving wire protocol and the metrics registry —
the two halves of ``repro.serve`` that need no sockets."""
import json

import pytest

from repro.core.report import (
    ADVICE_NOT_RECORDED,
    ISSUE_PRESSURE_NOT_RECORDED,
    MIN_SCHEMA_VERSION,
    SCHEMA_VERSION,
    SYNC_RESOURCES_NOT_RECORDED,
    Diagnosis,
)
from repro.core.service import AnalyzeRequest, LeoService
from repro.serve.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.serve.protocol import (
    PROTOCOL_VERSION,
    ProtocolError,
    decode_request,
    decode_response,
    downgrade_diagnosis_dict,
    encode_error,
    encode_request,
    encode_result,
    negotiate_schema,
)


@pytest.fixture
def diagnosis(async_hlo_text):
    # one process-wide service: the session caches make every test after
    # the first answer from memory
    return _SVC.diagnose(async_hlo_text, backend="tpu_v5e")


_SVC = LeoService()


# --------------------------------------------------------------------------
# Requests.
# --------------------------------------------------------------------------

class TestRequestEnvelope:
    def test_round_trip(self, async_hlo_text):
        req = AnalyzeRequest(hlo_text=async_hlo_text, backend="tpu_v5e",
                             hints={"total_devices": 8}, n_chains=3)
        wire = decode_request(encode_request(req, deadline_seconds=2.5))
        assert wire.request.hlo_text == req.hlo_text
        assert wire.request.backend == "tpu_v5e"
        assert wire.request.hints == {"total_devices": 8}
        assert wire.request.n_chains == 3
        assert wire.deadline_seconds == 2.5
        assert wire.negotiated_schema == SCHEMA_VERSION
        assert wire.protocol_version == PROTOCOL_VERSION

    def test_schema_version_not_pinned_on_the_wire(self, async_hlo_text):
        """The request body must NOT carry the sender's schema_version —
        that is what lets a v2-era client talk to a v3 server (the
        receiver re-pins to its own generation before validate())."""
        req = AnalyzeRequest(hlo_text=async_hlo_text)
        body = json.loads(encode_request(req))
        assert "schema_version" not in body["request"]
        # a sender from another generation decodes fine
        wire = decode_request(encode_request(req, accept_schema=2))
        assert wire.request.schema_version == SCHEMA_VERSION
        assert wire.negotiated_schema == 2

    def test_bad_json(self):
        with pytest.raises(ProtocolError) as ei:
            decode_request(b"{nope")
        assert ei.value.code == "bad_json"
        assert ei.value.http_status == 400

    def test_unsupported_protocol_version(self, async_hlo_text):
        body = json.loads(encode_request(AnalyzeRequest(
            hlo_text=async_hlo_text)))
        body["protocol_version"] = PROTOCOL_VERSION + 10
        with pytest.raises(ProtocolError) as ei:
            decode_request(json.dumps(body))
        assert ei.value.code == "protocol_version"

    def test_invalid_request_body(self):
        payload = json.dumps({"protocol_version": PROTOCOL_VERSION,
                              "request": {"hlo_text": ""}})
        with pytest.raises(ProtocolError) as ei:
            decode_request(payload)
        assert ei.value.code == "invalid_request"

    def test_bad_deadline(self, async_hlo_text):
        body = json.loads(encode_request(AnalyzeRequest(
            hlo_text=async_hlo_text)))
        body["deadline_seconds"] = -1
        with pytest.raises(ProtocolError) as ei:
            decode_request(json.dumps(body))
        assert ei.value.code == "invalid_request"


# --------------------------------------------------------------------------
# Schema negotiation + downgrade.
# --------------------------------------------------------------------------

class TestSchemaNegotiation:
    def test_negotiate(self):
        assert negotiate_schema(SCHEMA_VERSION) == SCHEMA_VERSION
        assert negotiate_schema(SCHEMA_VERSION + 5) == SCHEMA_VERSION
        assert negotiate_schema(2) == 2
        with pytest.raises(ProtocolError):
            negotiate_schema(MIN_SCHEMA_VERSION - 1)

    def test_downgrade_drops_newer_sections(self, diagnosis):
        full = diagnosis.to_dict()
        v3 = downgrade_diagnosis_dict(full, 3)
        assert v3["schema_version"] == 3
        assert "advice" not in v3
        assert "issue_pressure" in v3
        v2 = downgrade_diagnosis_dict(full, 2)
        assert v2["schema_version"] == 2
        assert "advice" not in v2
        assert "issue_pressure" not in v2
        assert "sync_resources" in v2
        v1 = downgrade_diagnosis_dict(full, 1)
        assert "issue_pressure" not in v1
        assert "sync_resources" not in v1
        # the input is never mutated
        assert "advice" in full
        assert "issue_pressure" in full
        assert full["schema_version"] == SCHEMA_VERSION

    def test_downgrade_then_migrate_forward(self, diagnosis):
        """The wire downgrade and the reader's from_dict migration are
        exact inverses up to the explicit 'not recorded' defaults —
        the same contract the disk cache already honors."""
        v3 = downgrade_diagnosis_dict(diagnosis.to_dict(), 3)
        migrated = Diagnosis.from_dict(v3)
        assert migrated.schema_version == SCHEMA_VERSION
        assert migrated.advice == ADVICE_NOT_RECORDED
        assert migrated.issue_pressure == diagnosis.issue_pressure
        v2 = downgrade_diagnosis_dict(diagnosis.to_dict(), 2)
        migrated = Diagnosis.from_dict(v2)
        assert migrated.schema_version == SCHEMA_VERSION
        assert migrated.advice == ADVICE_NOT_RECORDED
        assert migrated.issue_pressure == ISSUE_PRESSURE_NOT_RECORDED
        assert migrated.sync_resources == diagnosis.sync_resources
        v1 = downgrade_diagnosis_dict(diagnosis.to_dict(), 1)
        migrated = Diagnosis.from_dict(v1)
        assert migrated.sync_resources == SYNC_RESOURCES_NOT_RECORDED

    def test_upgrade_on_the_wire_rejected(self, diagnosis):
        v2 = downgrade_diagnosis_dict(diagnosis.to_dict(), 2)
        with pytest.raises(ProtocolError):
            downgrade_diagnosis_dict(v2, SCHEMA_VERSION)


# --------------------------------------------------------------------------
# Responses.
# --------------------------------------------------------------------------

class TestResponseEnvelope:
    def test_diagnosis_round_trip(self, diagnosis):
        payload = encode_result(diagnosis, request_id="req-7",
                                timing={"queue_seconds": 0.01,
                                        "service_seconds": 0.5,
                                        "seconds": 0.51})
        resp = decode_response(payload)
        assert resp.ok and resp.kind == "diagnosis"
        assert resp.request_id == "req-7"
        assert resp.timing["service_seconds"] == 0.5
        out = resp.result()
        assert out.to_json() == diagnosis.to_json()

    def test_fanout_round_trip(self, diagnosis):
        payload = encode_result({"tpu_v5e": diagnosis,
                                 "amd_mi300a": diagnosis})
        resp = decode_response(payload)
        assert resp.kind == "fanout"
        out = resp.result()
        assert sorted(out) == ["amd_mi300a", "tpu_v5e"]
        assert out["tpu_v5e"].to_json() == diagnosis.to_json()

    def test_downgraded_response(self, diagnosis):
        resp = decode_response(encode_result(diagnosis, schema_version=2))
        assert resp.schema_version == 2
        assert "issue_pressure" not in resp.payload
        migrated = resp.result()
        assert migrated.issue_pressure == ISSUE_PRESSURE_NOT_RECORDED

    def test_error_envelope(self):
        payload, status = encode_error("overloaded", "queue full",
                                       retry_after=0.25, request_id="r1")
        assert status == 429
        resp = decode_response(payload)
        assert not resp.ok
        with pytest.raises(ProtocolError) as ei:
            resp.result()
        assert ei.value.code == "overloaded"
        assert ei.value.retry_after == 0.25

    def test_undecodable_response(self):
        with pytest.raises(ProtocolError):
            decode_response(b"not json")
        with pytest.raises(ProtocolError):
            decode_response(json.dumps({"ok": True, "kind": "mystery"}))


# --------------------------------------------------------------------------
# Metrics registry.
# --------------------------------------------------------------------------

class TestMetrics:
    def test_counter(self):
        reg = MetricsRegistry()
        c = reg.counter("t_total", "help", labelnames=("kind",))
        c.inc(kind="a")
        c.inc(2, kind="a")
        c.inc(kind="b")
        assert c.value(kind="a") == 3
        assert c.value(kind="b") == 1
        with pytest.raises(ValueError):
            c.inc(-1, kind="a")
        with pytest.raises(ValueError):
            c.inc(wrong="a")

    def test_gauge_callback(self):
        reg = MetricsRegistry()
        g = reg.gauge("t_depth", "help")
        state = {"v": 5}
        g.set_function(lambda: state["v"])
        assert g.value() == 5
        state["v"] = 9
        assert "t_depth 9" in reg.render()

    def test_histogram_buckets_cumulative(self):
        reg = MetricsRegistry()
        h = reg.histogram("t_seconds", "help", buckets=(0.1, 1.0, 10.0))
        for v in (0.05, 0.5, 5.0, 50.0):
            h.observe(v)
        text = reg.render()
        assert 't_seconds_bucket{le="0.1"} 1' in text
        assert 't_seconds_bucket{le="1"} 2' in text
        assert 't_seconds_bucket{le="10"} 3' in text
        assert 't_seconds_bucket{le="+Inf"} 4' in text
        assert "t_seconds_count 4" in text
        assert h.sum() == pytest.approx(55.55)

    def test_get_or_create_shares_and_rejects_mismatch(self):
        reg = MetricsRegistry()
        a = reg.counter("t_total", "help")
        assert reg.counter("t_total", "other help") is a
        with pytest.raises(ValueError):
            reg.gauge("t_total", "help")
        with pytest.raises(ValueError):
            reg.counter("t_total", "help", labelnames=("x",))

    def test_render_format(self):
        reg = MetricsRegistry()
        reg.counter("t_b_total", "second").inc()
        reg.gauge("t_a_depth", "first").set(2)
        text = reg.render()
        # name-sorted, HELP/TYPE headers, trailing newline
        assert text.index("t_a_depth") < text.index("t_b_total")
        assert "# HELP t_a_depth first" in text
        assert "# TYPE t_b_total counter" in text
        assert text.endswith("\n")

    def test_instrument_classes_exported(self):
        assert all(t is not None for t in (Counter, Gauge, Histogram))
