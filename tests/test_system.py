"""End-to-end behaviour tests for the paper's system: the full LEO loop —
compile -> virtual-sample -> slice -> blame -> recommend -> apply the
implicated fix -> re-compile -> measure the improvement — on a real (reduced)
model, plus cross-backend divergence on the same artifact."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_config
from repro.core import (
    HARDWARE_MODELS,
    TPU_V5E,
    analyze_hlo,
    compute_roofline,
    parse_hlo,
)
from repro.models import init_params, loss_fn
from repro.models.flags import flags


@pytest.fixture(scope="module")
def qwen_smoke():
    cfg = smoke_config(get_config("qwen2-0.5b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jnp.zeros((4, 128), jnp.int32),
        "labels": jnp.zeros((4, 128), jnp.int32),
    }
    return cfg, params, batch


def _compile_loss(cfg, params, batch):
    return jax.jit(
        lambda p, b: loss_fn(p, cfg, b, chunk=64)).lower(
            params, batch).compile()


class TestLeoGuidedLoop:
    def test_full_loop_improves_modeled_memory(self, qwen_smoke):
        cfg, params, batch = qwen_smoke
        # 1. baseline compile + LEO analysis
        base_hlo = _compile_loss(cfg, params, batch).as_text()
        an = analyze_hlo(base_hlo, hw=TPU_V5E)
        assert an.profile.total_stall_cycles >= 0
        assert an.chains or an.blame.occupancy_blame, \
            "LEO must produce a diagnosis"
        # 2. chains carry framework-scope attribution (CCT, Kripke-style)
        scoped = [l for c in an.chains for l in c.links if l.op_name]
        assert scoped, "chains must attribute through op_name scopes"
        # 3. apply the flash-attention fix the memory diagnosis implicates
        base_rl = compute_roofline(parse_hlo(base_hlo), TPU_V5E, chips=1,
                                   label="base")
        with flags(attention_impl="pallas_fused"):
            opt_hlo = _compile_loss(cfg, params, batch).as_text()
        opt_rl = compute_roofline(parse_hlo(opt_hlo), TPU_V5E, chips=1,
                                  label="opt")
        # 4. the modeled memory term must drop; FLOPs must not change
        assert opt_rl.memory_s < base_rl.memory_s
        assert opt_rl.hlo_flops == pytest.approx(base_rl.hlo_flops,
                                                 rel=0.01)

    def test_cross_backend_divergence(self, qwen_smoke):
        cfg, params, batch = qwen_smoke
        hlo = _compile_loss(cfg, params, batch).as_text()
        times = {}
        for name, hw in HARDWARE_MODELS.items():
            times[name] = analyze_hlo(hlo, hw=hw).estimated_step_seconds
        # same program, strictly ordered by hardware capability
        assert times["tpu_v5p"] < times["tpu_v4"] < times["tpu_v5e"]

    def test_coverage_never_degrades(self, qwen_smoke):
        cfg, params, batch = qwen_smoke
        hlo = _compile_loss(cfg, params, batch).as_text()
        an = analyze_hlo(hlo, hw=TPU_V5E)
        assert an.coverage_after.coverage >= an.coverage_before.coverage

    def test_reports_are_actionable(self, qwen_smoke):
        from repro.core import diagnostic_context
        cfg, params, batch = qwen_smoke
        hlo = _compile_loss(cfg, params, batch).as_text()
        an = analyze_hlo(hlo, hw=TPU_V5E)
        ctx = diagnostic_context("C+L(S)", "kernel source here", an)
        assert "Recommendations" in ctx
        assert len(ctx) > len(diagnostic_context("C", "kernel source here"))
