"""Occupancy-divergence regression (the PR-9 ISSUE golden).

The paper's latency-hiding story, cross-vendor: engaging the *same*
"raise occupancy" counterfactual on the same latency-bound copy storm
yields a **different verdict per GPU vendor**, because residency interacts
with each vendor's sync-resource scoping:

* **AMD-class** — *decisive*: 4 wavefront slots per SIMD hide the vmcnt
  waits behind co-resident wavefronts; queue-scoped waitcnt counters mean
  extra waves add no serialization;
* **NVIDIA-class** — *harmful*: 8 resident warps share the device-scope
  named barriers, so the storm's sync traffic serializes 8 ways deeper
  than the hiding reclaims (more residency, slower program);
* **Intel-class** — *marginal*: only 2 hardware threads per Xe vector
  engine; hiding credit runs dry almost immediately
  (``OCCUPANCY_LIMITED`` dominates the reclassified waits);
* **TPU generations** — *single-wave*: no residency knob exists; the
  engaged profile is byte-identical to the plain one.

Pinned in ``tests/goldens/occupancy_divergence.json``: the native
residency descriptor, the modeled speedup of engaging it, the
hidden/exposed cycle split, and the per-vendor verdict for every golden
backend.  Any drift in the credit model, wave-scoreboard sharing, or a
vendor's occupancy constants shows up as a precise per-backend diff.

Regenerate after an intentional recalibration (the CI golden-drift gate
runs exactly this and fails on an uncommitted diff):

  PYTHONPATH=src python tests/test_occupancy_divergence.py
"""
import json
import os

import pytest

from repro.core import StallClass, get_backend, parse_hlo
from repro.core.sampler import VirtualSampler

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "goldens",
                           "occupancy_divergence.json")

GOLDEN_BACKENDS = ("amd_mi300a", "intel_pvc", "nvidia_gh200",
                   "tpu_v4", "tpu_v5e", "tpu_v5p")

#: The per-vendor verdicts the paper's cross-vendor story requires.
EXPECTED_VERDICTS = {
    "amd_mi300a": "decisive",
    "nvidia_gh200": "harmful",
    "intel_pvc": "marginal",
    "tpu_v4": "single_wave",
    "tpu_v5e": "single_wave",
    "tpu_v5p": "single_wave",
}

#: The fixture: 12 concurrent async copies feeding one serial reduction —
#: latency-bound enough that hiding matters, sync-heavy enough that
#: NVIDIA's device-scope barriers punish extra residency.
N_COPIES = 12


def _load_goldens() -> dict:
    if not os.path.exists(GOLDEN_PATH):
        return {}
    with open(GOLDEN_PATH) as f:
        return json.load(f)


GOLDENS = _load_goldens()


def _storm_module():
    from repro.launch.analysis_server import copy_storm_hlo
    return parse_hlo(copy_storm_hlo(N_COPIES))


def _run(module, backend):
    return VirtualSampler(module, backend.hw, sync=backend.sync).run()


def _verdict(speedup: float, multi_wave: bool) -> str:
    if not multi_wave:
        return "single_wave"
    if speedup < 1.0:
        return "harmful"
    if speedup >= 1.2:
        return "decisive"
    return "marginal"


def _snapshot(module, backend) -> dict:
    """The golden's per-backend record: what engaging native residency
    does to this workload on this part."""
    plain = _run(module, backend)
    native = backend.native_occupancy
    if not native.multi_wave:
        return {
            "waves": native.waves,
            "limiter": native.limiter,
            "residency_speedup": 1.0,
            "verdict": "single_wave",
        }
    engaged = _run(module, backend.with_occupancy())
    rep = engaged.occupancy_pressure
    limited = sum(
        r.stall_breakdown.get(StallClass.OCCUPANCY_LIMITED, 0.0)
        for r in engaged.records.values())
    speedup = plain.makespan_cycles / engaged.makespan_cycles
    return {
        "waves": native.waves,
        "limiter": native.limiter,
        "window_cycles": native.window_cycles,
        "residency_speedup": speedup,
        "hidden_cycles": rep.hidden_cycles,
        "exposed_cycles": rep.exposed_cycles,
        "hidden_fraction": rep.hidden_fraction,
        "occupancy_limited_cycles": limited,
        "verdict": _verdict(speedup, True),
    }


@pytest.fixture(scope="module")
def snapshots():
    module = _storm_module()
    return {name: _snapshot(module, get_backend(name))
            for name in GOLDEN_BACKENDS}


class TestOccupancyDivergenceRegression:
    def test_golden_file_covers_every_backend(self):
        assert sorted(k for k in GOLDENS if not k.startswith("_")) == \
            sorted(GOLDEN_BACKENDS)

    @pytest.mark.parametrize("backend", sorted(GOLDEN_BACKENDS))
    def test_backend_snapshot(self, snapshots, backend):
        got, want = dict(snapshots[backend]), dict(GOLDENS[backend])
        for field in ("residency_speedup", "hidden_cycles",
                      "exposed_cycles", "hidden_fraction",
                      "occupancy_limited_cycles", "window_cycles"):
            if field in want:
                assert got.pop(field) == \
                    pytest.approx(want.pop(field), rel=1e-9), field
        assert got == want

    def test_three_vendors_get_three_different_verdicts(self, snapshots):
        """ISSUE acceptance: a different occupancy verdict per GPU vendor
        at native W on the same latency-bound fixture."""
        verdicts = {b: snapshots[b]["verdict"] for b in GOLDEN_BACKENDS}
        assert verdicts == EXPECTED_VERDICTS
        gpu = {verdicts[b] for b in
               ("nvidia_gh200", "amd_mi300a", "intel_pvc")}
        assert len(gpu) == 3

    def test_amd_hiding_is_decisive(self, snapshots):
        snap = snapshots["amd_mi300a"]
        assert snap["residency_speedup"] >= 1.5
        assert snap["hidden_cycles"] > 0

    def test_nvidia_residency_backfires(self, snapshots):
        """Device-scope barrier sharing costs more than hiding reclaims:
        the engaged makespan is LONGER than the single-wave one."""
        assert snapshots["nvidia_gh200"]["residency_speedup"] < 1.0

    def test_intel_hiding_credit_runs_dry(self, snapshots):
        """Two resident threads barely dent the waits: the engaged run
        reclassifies stalls as occupancy_limited rather than hiding
        them."""
        snap = snapshots["intel_pvc"]
        assert 1.0 <= snap["residency_speedup"] < 1.2
        assert snap["occupancy_limited_cycles"] > 0

    @pytest.mark.parametrize("backend", sorted(GOLDEN_BACKENDS))
    def test_w1_parity_anchor(self, backend):
        """The golden's precondition: a W=1 occupancy variant reproduces
        the plain profile byte-identically on the golden workload."""
        from repro.core import OccupancyModel
        module = _storm_module()
        base = get_backend(backend)
        plain = _run(module, base)
        w1 = base.with_occupancy(OccupancyModel(waves=1, limiter="none"))
        gated = _run(module, w1)
        assert gated.makespan_cycles == plain.makespan_cycles
        for q, rec in plain.records.items():
            r2 = gated.records[q]
            assert (rec.total_samples, rec.latency_samples,
                    rec.stall_breakdown) == \
                (r2.total_samples, r2.latency_samples, r2.stall_breakdown)


def regenerate() -> dict:
    """Recompute the golden (recalibration/drift-gate entry point);
    writes ``tests/goldens/occupancy_divergence.json`` in place."""
    module = _storm_module()
    goldens = {
        "_comment": "Occupancy-divergence golden (12-copy storm, one "
                    "serial reduction): the verdict on engaging native "
                    "wave residency, per backend; regenerate with "
                    "`PYTHONPATH=src python "
                    "tests/test_occupancy_divergence.py` after an "
                    "intentional recalibration (the CI golden-drift gate "
                    "runs exactly that and fails on an uncommitted "
                    "diff).",
    }
    for name in sorted(GOLDEN_BACKENDS):
        goldens[name] = _snapshot(module, get_backend(name))
    with open(GOLDEN_PATH, "w") as f:
        json.dump(goldens, f, indent=2, sort_keys=True)
        f.write("\n")
    return goldens


if __name__ == "__main__":
    regenerated = regenerate()
    for name in sorted(k for k in regenerated if not k.startswith("_")):
        snap = regenerated[name]
        print(f"{name}: {snap['verdict']} "
              f"({snap['residency_speedup']:.3f}x at W={snap['waves']})")
    print(f"wrote {GOLDEN_PATH}")
