"""Shared model layers (pure functional JAX, params as nested dicts)."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]


def dense_init(key, shape, scale: float = 0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(dtype)


def linear(x: jnp.ndarray, w: jnp.ndarray,
           b: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


# -- RoPE --------------------------------------------------------------------

def rope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions (...,) -> cos/sin (..., head_dim//2), f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x (..., n_heads, head_dim); cos/sin broadcastable (..., head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :].astype(jnp.float32)
    sin = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(x.dtype)


# -- MLPs ---------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, kind: str = "swiglu",
             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"w_gate": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
                "w_up": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
                "w_down": dense_init(ks[2], (d_ff, d_model), dtype=dtype)}
    return {"w_up": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
            "w_down": dense_init(ks[1], (d_ff, d_model), dtype=dtype)}


def mlp(x: jnp.ndarray, p: Params) -> jnp.ndarray:
    if "w_gate" in p:
        h = jax.nn.silu(linear(x, p["w_gate"])) * linear(x, p["w_up"])
    else:
        h = jax.nn.gelu(linear(x, p["w_up"]))
    return linear(h, p["w_down"])


# -- Embedding -----------------------------------------------------------------

def init_embed(key, vocab: int, d_model: int, dtype=jnp.float32) -> Params:
    return {"table": dense_init(key, (vocab, d_model), scale=1.0,
                                dtype=dtype)}


def embed(tokens: jnp.ndarray, p: Params, dtype) -> jnp.ndarray:
    return p["table"].astype(dtype)[tokens]


def unembed(x: jnp.ndarray, table_or_w: jnp.ndarray,
            transpose: bool) -> jnp.ndarray:
    w = table_or_w.astype(x.dtype)
    if transpose:  # tied embeddings: table (V, D)
        return jnp.einsum("...d,vd->...v", x, w)
    return jnp.einsum("...d,dv->...v", x, w)
