"""Mixture-of-Experts with capacity-based sort dispatch (GShard-style).

Routing: softmax top-k with renormalized gates.  Dispatch: tokens sorted by
expert id, ranked within expert (rank >= capacity drops, standard token
dropping), scattered into per-expert capacity buffers, processed with
batched per-expert matmuls, and combined back gate-weighted.  The (E, C, D)
buffers shard over the "model" axis (expert parallelism): XLA inserts the
all-to-all at the data->expert resharding boundary, which is exactly the
collective LEO should see in MoE cells.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import dense_init, init_mlp, linear, mlp

Params = Dict[str, jnp.ndarray]


def init_moe(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, ff), dtype=dtype),
        "w_up": dense_init(ks[2], (e, d, ff), dtype=dtype),
        "w_down": dense_init(ks[3], (e, ff, d), dtype=dtype),
    }
    if cfg.mlp_kind != "swiglu":
        del p["w_gate"]
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, ff * cfg.n_shared_experts,
                               cfg.mlp_kind, dtype)
    return p


def _capacity(cfg: ArchConfig, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_forward(p: Params, x: jnp.ndarray, cfg: ArchConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> (y (B, S, D), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.n_experts
    cap = _capacity(cfg, t)
    xf = x.reshape(t, d)

    logits = linear(xf.astype(jnp.float32), p["router"])     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)           # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch-style).
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (t * k))
    aux = e * jnp.sum(me * ce)

    # Sort-dispatch: flatten (T*k) assignments, sort by expert, rank.
    flat_e = expert_ids.reshape(-1)                           # (T*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e)
    se, sg, st = flat_e[order], flat_gate[order], flat_tok[order]
    # rank within expert = position - first occurrence of that expert id
    first = jnp.searchsorted(se, se, side="left")
    rank = jnp.arange(t * k) - first
    keep = rank < cap
    dest = se * cap + jnp.minimum(rank, cap - 1)              # (T*k,)

    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[dest].add(jnp.where(keep[:, None], xf[st], 0))
    buf = buf.reshape(e, cap, d)

    # Per-expert FFN (batched over E -> expert-parallel shardable).
    if "w_gate" in p:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                                   p["w_gate"].astype(x.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf,
                                   p["w_up"].astype(x.dtype)))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    out_buf = out_buf.reshape(e * cap, d)

    # Combine: gather back and weight by gates.
    gathered = out_buf[dest] * jnp.where(keep, sg, 0.0)[:, None].astype(
        x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[st].add(gathered)

    if "shared" in p:
        y = y + mlp(xf, p["shared"])
    return y.reshape(b, s, d), aux


# -- shard_map expert parallelism (the LEO-guided collective fix) ---------------

def _local_dispatch_compute(p: Params, xf: jnp.ndarray, cfg: ArchConfig,
                            tp_axis: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-shard body: local routing, all-to-all to expert shards, batched
    expert FFN with *stationary* weights, all-to-all back, local combine.

    xf: local tokens (T_l, D); expert weights in `p` are the local shard
    (E_local, D, ff).  Wire traffic per chip = 2 x the dispatch buffer
    (~capacity_factor * k * T_l * D bytes) instead of the global-sort /
    weight-gather collectives XLA derives from global-view routing.
    """
    t_l, d = xf.shape
    k = cfg.top_k
    e = cfg.n_experts
    tp = jax.lax.axis_size(tp_axis)
    e_local = e // tp
    cap = _capacity(cfg, t_l)

    logits = linear(xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (t_l * k))
    aux = e * jnp.sum(me * ce)

    flat_e = expert_ids.reshape(-1)
    flat_gate = gate_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t_l), k)
    order = jnp.argsort(flat_e)                    # local sort only
    se, sg, st = flat_e[order], flat_gate[order], flat_tok[order]
    first = jnp.searchsorted(se, se, side="left")
    rank = jnp.arange(t_l * k) - first
    keep = rank < cap
    dest = se * cap + jnp.minimum(rank, cap - 1)

    buf = jnp.zeros((e * cap, d), xf.dtype)
    buf = buf.at[dest].add(jnp.where(keep[:, None], xf[st], 0))
    buf = buf.reshape(e, cap, d)

    # dispatch: (E, C_l, D) -> (E_local, tp*C_l, D) on the owning shard
    buf = jax.lax.all_to_all(buf, tp_axis, split_axis=0, concat_axis=1,
                             tiled=True)

    if "w_gate" in p:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                                   p["w_gate"].astype(xf.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(xf.dtype))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf,
                                   p["w_up"].astype(xf.dtype)))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(xf.dtype))

    # return: (E_local, tp*C_l, D) -> (E, C_l, D) back on the token shards
    out_buf = jax.lax.all_to_all(out_buf, tp_axis, split_axis=1,
                                 concat_axis=0, tiled=True)
    out_buf = out_buf.reshape(e * cap, d)

    gathered = out_buf[dest] * jnp.where(keep, sg, 0.0)[:, None].astype(
        xf.dtype)
    y = jnp.zeros((t_l, d), xf.dtype).at[st].add(gathered)
    return y, aux


def moe_forward_ep(p: Params, x: jnp.ndarray, cfg: ArchConfig
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """shard_map expert-parallel MoE: routing stays shard-local, expert
    weights stay stationary, the only collectives are two all-to-alls along
    the "model" axis.  Falls back to the global path off-mesh."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from ..parallel.context import get_current_mesh

    mesh = get_current_mesh()
    if mesh is None or "model" not in mesh.axis_names or \
            cfg.n_experts % mesh.shape["model"] != 0:
        return moe_forward(p, x, cfg)
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    b, s, d = x.shape

    def body(p_local, x_local):
        bl = x_local.shape[0]
        y, aux = _local_dispatch_compute(
            p_local, x_local.reshape(bl * s, d), cfg, "model")
        if "shared" in p_local:
            y = y + mlp(x_local.reshape(bl * s, d), p_local["shared"])
        aux = jax.lax.pmean(aux, dp_axes)
        return y.reshape(bl, s, d), aux

    expert_spec = {
        "router": P(), "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }
    if "w_gate" in p:
        expert_spec["w_gate"] = P("model", None, None)
    if "shared" in p:
        expert_spec["shared"] = {k2: P() for k2 in p["shared"]}

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(expert_spec, P(dp, None, None)),
        out_specs=(P(dp, None, None), P()),
        check_rep=False)
    return fn(p, x)
