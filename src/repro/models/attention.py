"""Attention: GQA (full/causal), sliding-window, and MLA (DeepSeek-V2).

Training/prefill attention uses a *chunked online-softmax* formulation (the
pure-jnp flash-attention shape): Python-level query-chunk loop with a
`lax.scan` over only the key chunks each query chunk can see, so causal and
sliding-window masking skip work structurally instead of masking a full
S x S score tensor.  This is both the XLA production path and the oracle the
Pallas kernel in `repro.kernels.flash_attention` is validated against.

Decode uses a KV cache: full cache for "full" attention, a ring buffer of
`window` entries for SWA, and the compressed (kv_lora + k_rope) cache with
*absorbed* projections for MLA — the O(kv_lora) decode path from the
DeepSeek-V2 paper rather than naive per-step decompression.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .flags import FUSED_REGION_MARK, get_flags
from .layers import apply_rope, dense_init, linear, rmsnorm, rope_cos_sin

Params = Dict[str, jnp.ndarray]

_NEG_INF = -1e30


# -- chunked online-softmax attention core -------------------------------------

def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      chunk: int = 512,
                      window: Optional[int] = None) -> jnp.ndarray:
    """Causal (optionally sliding-window) attention.

    q: (B, S, H, hd); k: (B, S, Kv, hd); v: (B, S, Kv, vd) with H % Kv == 0
    (vd may differ from hd — MLA uses qk_dim 192, v_dim 128).
    Returns (B, S, H, vd).  Work is triangular: query chunk i only touches
    key chunks in [max(0, i - window_chunks), i].
    """
    b, s, h, hd = q.shape
    vd = v.shape[-1]
    kv_heads = k.shape[2]
    groups = h // kv_heads
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, s)
    assert s % chunk == 0, f"seq {s} % chunk {chunk}"
    n_chunks = s // chunk

    # GQA: broadcast KV heads per key-chunk inside the loop (never reshape
    # q's head axis — it may be TP-sharded and a Kv x G split would force a
    # reshard).  The repeated chunk is small and fuses into the dot.
    qc = q.reshape(b, n_chunks, chunk, h, hd)
    kc = jnp.moveaxis(k.reshape(b, n_chunks, chunk, kv_heads, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, chunk, kv_heads, vd), 1, 0)

    win_chunks = None
    if window is not None:
        win_chunks = max(1, -(-window // chunk))  # ceil

    row_ids = jnp.arange(chunk)

    outputs = []
    for i in range(n_chunks):
        lo = 0 if win_chunks is None else max(0, i - win_chunks)
        qi = qc[:, i] * scale  # (B, C, H, hd), input dtype

        def kv_step(carry, inputs):
            m, l, acc = carry
            kj, vj, j = inputs
            if groups > 1:
                kj = jnp.repeat(kj, groups, axis=2)
                vj = jnp.repeat(vj, groups, axis=2)
            scores = jnp.einsum("bchd,bxhd->bhcx", qi, kj,
                                preferred_element_type=jnp.float32)
            q_pos = i * chunk + row_ids[:, None]
            k_pos = j * chunk + row_ids[None, :]
            mask = k_pos <= q_pos
            if window is not None:
                mask &= k_pos > q_pos - window
            scores = jnp.where(mask, scores, _NEG_INF)
            m_new = jnp.maximum(m, scores.max(axis=-1))
            p = jnp.exp(scores - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhcx,bxhd->bhcd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, chunk), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, chunk), jnp.float32)
        a0 = jnp.zeros((b, h, chunk, vd), jnp.float32)
        js = jnp.arange(lo, i + 1)
        if get_flags().attention_impl == "pallas_fused":
            # Cost-model the validated Pallas flash kernel (see
            # repro/kernels/flash_attention.py): the whole key sweep runs
            # as one kernel with (m, l, acc) resident in VMEM scratch.
            with jax.named_scope(FUSED_REGION_MARK):
                (m, l, acc), _ = jax.lax.scan(
                    kv_step, (m0, l0, a0), (kc[lo:i + 1], vc[lo:i + 1], js))
                out = acc / jnp.maximum(l, 1e-30)[..., None]
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0), (kc[lo:i + 1], vc[lo:i + 1], js))
            out = acc / jnp.maximum(l, 1e-30)[..., None]
        outputs.append(jnp.moveaxis(out, 1, 2))  # (B, C, H, vd)
    return jnp.concatenate(outputs, axis=1).astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, length_mask: jnp.ndarray
                     ) -> jnp.ndarray:
    """One-token attention against a cache.

    q: (B, H, hd); caches (B, S, Kv, hd); length_mask (B, S) bool.
    """
    b, h, hd = q.shape
    kv_heads = k_cache.shape[2]
    groups = h // kv_heads
    scale = 1.0 / math.sqrt(hd)
    qf = q.reshape(b, kv_heads, groups, hd) * scale
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache,
                        preferred_element_type=jnp.float32)
    scores = jnp.where(length_mask[:, None, None, :], scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, hd).astype(q.dtype)


# -- GQA module -----------------------------------------------------------------

def init_attn(key, cfg: ArchConfig, dtype) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], (d, h * hd), dtype=dtype),
         "wk": dense_init(ks[1], (d, kv * hd), dtype=dtype),
         "wv": dense_init(ks[2], (d, kv * hd), dtype=dtype),
         "wo": dense_init(ks[3], (h * hd, d), dtype=dtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def attn_forward(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                 positions: jnp.ndarray, chunk: int = 512) -> jnp.ndarray:
    """Full-sequence causal attention (training / prefill)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = linear(x, p["wq"], p.get("bq")).reshape(b, s, h, hd)
    k = linear(x, p["wk"], p.get("bk")).reshape(b, s, kv, hd)
    v = linear(x, p["wv"], p.get("bv")).reshape(b, s, kv, hd)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    window = cfg.window if cfg.attention == "swa" else None
    out = chunked_attention(q, k, v, chunk=chunk, window=window)
    return linear(out.reshape(b, s, h * hd), p["wo"])


def init_attn_cache(cfg: ArchConfig, batch: int, max_len: int, dtype
                    ) -> Params:
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    s = min(max_len, cfg.window) if cfg.attention == "swa" else max_len
    return {"k": jnp.zeros((batch, s, kv, hd), dtype),
            "v": jnp.zeros((batch, s, kv, hd), dtype)}


def attn_decode(p: Params, x: jnp.ndarray, cache: Params, pos: jnp.ndarray,
                cfg: ArchConfig,
                layer_idx: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Params]:
    """x (B, d); pos scalar int32. Returns (y (B, d), new cache).

    When `layer_idx` is given, `cache` holds *layer-stacked* buffers
    (L, B, S, Kv, hd) and the new token is written with a single-token
    dynamic-update-slice directly into the stack — the paged-cache pattern:
    per step the cache costs one token of writes and one layer of reads,
    never a per-layer copy through scan stacking.
    """
    b, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = linear(x, p["wq"], p.get("bq")).reshape(b, h, hd)
    k = linear(x, p["wk"], p.get("bk")).reshape(b, kv, hd)
    v = linear(x, p["wv"], p.get("bv")).reshape(b, kv, hd)
    cos, sin = rope_cos_sin(pos[None], hd, cfg.rope_theta)
    q = apply_rope(q[:, None], cos, sin)[:, 0]
    k = apply_rope(k[:, None], cos, sin)[:, 0]

    stacked = layer_idx is not None
    cache_len = cache["k"].shape[2 if stacked else 1]
    slot = pos % cache_len if cfg.attention == "swa" else pos
    if stacked:
        upd_k = k[None, :, None].astype(cache["k"].dtype)  # (1,B,1,kv,hd)
        upd_v = v[None, :, None].astype(cache["v"].dtype)
        k_stack = jax.lax.dynamic_update_slice(
            cache["k"], upd_k, (layer_idx, 0, slot, 0, 0))
        v_stack = jax.lax.dynamic_update_slice(
            cache["v"], upd_v, (layer_idx, 0, slot, 0, 0))
        k_cache = jax.lax.dynamic_index_in_dim(k_stack, layer_idx, 0,
                                               keepdims=False)
        v_cache = jax.lax.dynamic_index_in_dim(v_stack, layer_idx, 0,
                                               keepdims=False)
        new_cache = {"k": k_stack, "v": v_stack}
    else:
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k[:, None].astype(cache["k"].dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v[:, None].astype(cache["v"].dtype), (0, slot, 0, 0))
        new_cache = {"k": k_cache, "v": v_cache}

    idx = jnp.arange(cache_len)
    if cfg.attention == "swa":
        valid = (idx[None, :] <= slot) | \
            (jnp.full((1, cache_len), pos >= cache_len))
    else:
        valid = idx[None, :] <= pos
    out = decode_attention(q, k_cache, v_cache, valid)
    y = linear(out.reshape(b, h * hd), p["wo"])
    return y, new_cache


# -- MLA (DeepSeek-V2 multi-head latent attention) -------------------------------

def init_mla(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, \
        cfg.v_head_dim
    ks = jax.random.split(key, 6)
    p: Params = {}
    if cfg.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], (d, cfg.q_lora_rank), dtype=dtype)
        p["q_norm"] = jnp.ones((cfg.q_lora_rank,), dtype)
        p["wq_b"] = dense_init(ks[1], (cfg.q_lora_rank, h * (nope + rope_d)),
                               dtype=dtype)
    else:
        p["wq"] = dense_init(ks[1], (d, h * (nope + rope_d)), dtype=dtype)
    p["wkv_a"] = dense_init(ks[2], (d, cfg.kv_lora_rank + rope_d),
                            dtype=dtype)
    p["kv_norm"] = jnp.ones((cfg.kv_lora_rank,), dtype)
    p["wkv_b"] = dense_init(ks[3], (cfg.kv_lora_rank, h * (nope + vd)),
                            dtype=dtype)
    p["wo"] = dense_init(ks[4], (h * vd, d), dtype=dtype)
    return p


def _mla_q(p: Params, x, cfg: ArchConfig, positions):
    b = x.shape[0]
    s = x.shape[1] if x.ndim == 3 else 1
    h = cfg.n_heads
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    xq = x if x.ndim == 3 else x[:, None]
    if cfg.q_lora_rank:
        q = linear(rmsnorm(linear(xq, p["wq_a"]), p["q_norm"], cfg.norm_eps),
                   p["wq_b"])
    else:
        q = linear(xq, p["wq"])
    q = q.reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_cos_sin(positions, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def mla_forward(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                positions: jnp.ndarray, chunk: int = 512) -> jnp.ndarray:
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, \
        cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    kv = linear(x, p["wkv_a"])
    kv_c = rmsnorm(kv[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, rope_d, cfg.rope_theta)
    k_rope = apply_rope(kv[..., None, cfg.kv_lora_rank:], cos, sin)
    kv_up = linear(kv_c, p["wkv_b"]).reshape(b, s, h, nope + vd)
    k_nope, v = kv_up[..., :nope], kv_up[..., nope:]
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (b, s, h, rope_d))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    out = chunked_attention(q, k, v, chunk=chunk)
    return linear(out.reshape(b, s, h * vd), p["wo"])


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype
                   ) -> Params:
    return {"kv_c": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim),
                                dtype)}


def mla_decode(p: Params, x: jnp.ndarray, cache: Params, pos: jnp.ndarray,
               cfg: ArchConfig,
               layer_idx: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, Params]:
    """Absorbed-projection MLA decode: scores in kv_lora space, O(r) per
    cached token instead of per-head decompression.  With `layer_idx` the
    compressed cache is layer-stacked (L, B, S, r) and updated with a
    single-token write (see `attn_decode`)."""
    b, _ = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, \
        cfg.v_head_dim
    r = cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(p, x, cfg, pos[None])
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]        # (B, H, *)

    kv = linear(x, p["wkv_a"])
    kv_c = rmsnorm(kv[..., :r], p["kv_norm"], cfg.norm_eps)
    cos, sin = rope_cos_sin(pos[None], rope_d, cfg.rope_theta)
    k_rope = apply_rope(kv[..., None, r:][:, None], cos, sin)[:, 0, 0]

    if layer_idx is not None:
        kv_stack = jax.lax.dynamic_update_slice(
            cache["kv_c"], kv_c[None, :, None].astype(cache["kv_c"].dtype),
            (layer_idx, 0, pos, 0))
        kr_stack = jax.lax.dynamic_update_slice(
            cache["k_rope"],
            k_rope[None, :, None].astype(cache["k_rope"].dtype),
            (layer_idx, 0, pos, 0))
        kv_cache = jax.lax.dynamic_index_in_dim(kv_stack, layer_idx, 0,
                                                keepdims=False)
        kr_cache = jax.lax.dynamic_index_in_dim(kr_stack, layer_idx, 0,
                                                keepdims=False)
        new_cache = {"kv_c": kv_stack, "k_rope": kr_stack}
        return _mla_decode_core(p, x, cfg, q_nope, q_rope, kv_cache,
                                kr_cache, pos, new_cache)
    kv_cache = jax.lax.dynamic_update_slice(
        cache["kv_c"], kv_c[:, None].astype(cache["kv_c"].dtype),
        (0, pos, 0))
    kr_cache = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope[:, None].astype(cache["k_rope"].dtype),
        (0, pos, 0))

    # Absorb W_uk into q: q_c (B, H, r)
    return _mla_decode_core(p, x, cfg, q_nope, q_rope, kv_cache, kr_cache,
                            pos, {"kv_c": kv_cache, "k_rope": kr_cache})


def _mla_decode_core(p: Params, x, cfg: ArchConfig, q_nope, q_rope,
                     kv_cache, kr_cache, pos, new_cache
                     ) -> Tuple[jnp.ndarray, Params]:
    b = x.shape[0]
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, \
        cfg.v_head_dim
    r = cfg.kv_lora_rank
    w_uk = p["wkv_b"][:, : h * nope].reshape(r, h, nope)
    q_c = jnp.einsum("bhn,rhn->bhr", q_nope, w_uk,
                     preferred_element_type=jnp.float32)
    scale = 1.0 / math.sqrt(nope + rope_d)
    q_c = q_c.astype(kv_cache.dtype)
    scores = (jnp.einsum("bhr,bsr->bhs", q_c, kv_cache,
                         preferred_element_type=jnp.float32) +
              jnp.einsum("bhd,bsd->bhs", q_rope.astype(kr_cache.dtype),
                         kr_cache,
                         preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(kv_cache.shape[1])[None, :] <= pos
    scores = jnp.where(valid[:, None, :], scores, _NEG_INF)
    pattn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", pattn.astype(kv_cache.dtype), kv_cache,
                     preferred_element_type=jnp.float32)
    # Absorb W_uv on the way out: (B, H, r) x (r, H, vd) -> (B, H, vd)
    w_uv = p["wkv_b"][:, h * nope:].reshape(r, h, vd)
    out = jnp.einsum("bhr,rhv->bhv", ctx.astype(x.dtype), w_uv,
                     preferred_element_type=jnp.float32)
    y = linear(out.reshape(b, h * vd).astype(x.dtype), p["wo"])
    return y, new_cache
