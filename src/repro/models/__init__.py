"""Pure-JAX functional model zoo."""
from .transformer import (
    decode_step,
    forward,
    init_decode_state,
    init_params,
    layer_descriptors,
    layer_groups,
    loss_fn,
)

__all__ = [
    "decode_step", "forward", "init_decode_state", "init_params",
    "layer_descriptors", "layer_groups", "loss_fn",
]
