"""Model-level optimization flags (the §Perf hillclimb levers).

Explicit global switches so the dry-run can lower baseline and optimized
variants of the same architecture without threading options through every
layer:

  attention_impl : "xla"          — chunked online-softmax in pure XLA ops
                                    (paper-faithful baseline; the online-
                                    softmax state round-trips HBM per key
                                    block);
                   "pallas_fused" — cost-model the validated Pallas flash
                                    kernel: the attention inner loop is
                                    tagged with a fused-region scope and
                                    LEO's parser prices it as VMEM-resident
                                    (inputs/outputs only), FLOPs unchanged.
  ssm_fused      : False          — discretize (a, bx) for the whole
                                    sequence up front (materializes
                                    B x S x d_inner x N in HBM);
                   True           — discretize per chunk inside the scan
                                    (transient, fuses into the chunk body).
  moe_impl       : "global"       — routing over the global token axis
                                    (XLA inserts distributed sort/gather
                                    collectives);
                   "ep_shardmap"  — shard_map local routing + all-to-all
                                    expert parallelism over the "model"
                                    axis.
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ModelFlags:
    attention_impl: str = "xla"
    ssm_fused: bool = False
    ssm_pallas: bool = False      # cost-model the Pallas ssm_scan kernel
    mlstm_pallas: bool = False    # cost-model the Pallas mlstm_chunkwise kernel
    sequence_parallel: bool = False  # shard residual-stream activations over
                                     # "model" between blocks: XLA turns the
                                     # Megatron activation all-reduces into
                                     # reduce-scatter + all-gather pairs
    moe_impl: str = "global"
    fsdp_threshold_mb: int = 128  # per-shard size above which weights are
                                  # dp-sharded; raise when bf16 params fit
                                  # per chip (FSDP re-gathers per microstep)


_FLAGS = ModelFlags()

# Scope marker the HLO parser recognizes as "this region runs as one Pallas
# kernel": instructions inside pay no intra-region HBM traffic.
FUSED_REGION_MARK = "pallas_fused_region"


def get_flags() -> ModelFlags:
    return _FLAGS


def set_flags(**kwargs) -> ModelFlags:
    global _FLAGS
    _FLAGS = replace(_FLAGS, **kwargs)
    return _FLAGS


@contextmanager
def flags(**kwargs):
    global _FLAGS
    prev = _FLAGS
    _FLAGS = replace(_FLAGS, **kwargs)
    try:
        yield _FLAGS
    finally:
        _FLAGS = prev
