"""xLSTM blocks: chunkwise-parallel mLSTM and sequential sLSTM.

mLSTM (matrix memory): per head, C_t = f_t C_{t-1} + i_t v_t k_t^T with
exponential gating stabilized by a running max m_t.  Training uses the
chunkwise decomposition — inter-chunk recurrence over the (hd x hd) matrix
state via `lax.scan`, intra-chunk contributions via masked gated attention —
so the S x S score matrix never materializes beyond a chunk.

sLSTM (scalar memory): strictly sequential exponential-gated recurrence per
head, `lax.scan` over time; the paper pairs it with a gated (4/3) FFN.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .flags import FUSED_REGION_MARK, get_flags
from .layers import dense_init, linear, rmsnorm

Params = Dict[str, jnp.ndarray]


# -- mLSTM ----------------------------------------------------------------------

def init_mlstm(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    h, hd = cfg.n_heads, cfg.head_dim_
    din = h * hd
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], (d, 2 * din), dtype=dtype),   # x and gate
        "wq": dense_init(ks[1], (din, h * hd), dtype=dtype),
        "wk": dense_init(ks[2], (din, h * hd), dtype=dtype),
        "wv": dense_init(ks[3], (din, h * hd), dtype=dtype),
        "w_if": dense_init(ks[4], (din, 2 * h), dtype=jnp.float32),
        "norm": jnp.ones((din,), dtype),
        "w_down": dense_init(ks[6], (din, d), dtype=dtype),
    }


def mlstm_forward(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                  chunk: int = 128) -> jnp.ndarray:
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim_
    din = h * hd
    up = linear(x, p["w_up"])
    xin, zgate = up[..., :din], up[..., din:]
    q = linear(xin, p["wq"]).reshape(b, s, h, hd)
    k = linear(xin, p["wk"]).reshape(b, s, h, hd) / (hd ** 0.5)
    v = linear(xin, p["wv"]).reshape(b, s, h, hd)
    gates = linear(xin, p["w_if"]).astype(jnp.float32)          # (B,S,2H)
    log_i = gates[..., :h]                                       # pre-act i
    log_f = jax.nn.log_sigmoid(gates[..., h:])                   # log f_t

    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    qc = q.reshape(b, nc, chunk, h, hd)
    kc = k.reshape(b, nc, chunk, h, hd)
    vc = v.reshape(b, nc, chunk, h, hd)
    lic = log_i.reshape(b, nc, chunk, h)
    lfc = log_f.reshape(b, nc, chunk, h)

    def chunk_step(carry, inputs):
        # Stabilized chunkwise recurrence.  Unstabilized math per target u:
        #   C_u = exp(F_u) * C_in + sum_{t<=u} exp(F_u - F_t + i_t) v_t k_t^T
        # with F_t = cumsum(log f).  Stabilizer M_u = max(m_in + F_u,
        # F_u + max_{t<=u}(i_t - F_t)) keeps every exp() <= 1.
        c_state, n_state, m_state = carry       # (B,H,hd,hd), (B,H,hd), (B,H)
        qk, kk, vk, li, lf = inputs
        qk = qk.astype(jnp.float32)
        kk = kk.astype(jnp.float32)
        vk = vk.astype(jnp.float32)
        f_cum = jnp.cumsum(lf, axis=1)                       # F_t  (B,C,H)
        f_tot = f_cum[:, -1]                                 # F_C  (B,H)
        s_t = li - f_cum                                     # i_t - F_t
        s_runmax = jax.lax.associative_scan(jnp.maximum, s_t, axis=1)
        m_u = jnp.maximum(m_state[:, None], s_runmax) + f_cum  # (B,U,H)

        idx = jnp.arange(qk.shape[1])
        causal = (idx[:, None] >= idx[None, :])[None, :, :, None]
        log_w = (f_cum[:, :, None, :] - f_cum[:, None, :, :] +
                 li[:, None, :, :] - m_u[:, :, None, :])     # (B,U,T,H)
        w = jnp.where(causal, jnp.exp(log_w), 0.0)
        qkt = jnp.einsum("buhd,bthd->buth", qk, kk)
        scores = qkt * w
        intra = jnp.einsum("buth,bthd->buhd", scores, vk)
        norm_intra = scores.sum(axis=2)                      # (B,U,H)

        d_u = jnp.exp(f_cum + m_state[:, None] - m_u)        # (B,U,H)
        inter = jnp.einsum("buhd,bhde->buhe", qk, c_state) * d_u[..., None]
        norm_inter = jnp.einsum("buhd,bhd->buh", qk, n_state) * d_u
        denom = jnp.maximum(jnp.abs(norm_inter + norm_intra),
                            jnp.exp(-m_u))
        y = (inter + intra) / denom[..., None]

        m_new = m_u[:, -1]
        carry_decay = jnp.exp(f_tot + m_state - m_new)       # (B,H)
        src_w = jnp.exp(li + (f_tot[:, None] - f_cum) - m_new[:, None])
        c_new = c_state * carry_decay[..., None, None] + jnp.einsum(
            "bthd,bthe,bth->bhde", kk, vk, src_w)
        n_new = n_state * carry_decay[..., None] + jnp.einsum(
            "bthd,bth->bhd", kk, src_w)
        return (c_new, n_new, m_new), y

    c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, h, hd), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    xs = (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0),
          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(lic, 1, 0),
          jnp.moveaxis(lfc, 1, 0))
    if get_flags().mlstm_pallas:
        # Cost-model the validated Pallas chunkwise kernel
        # (repro/kernels/mlstm_scan.py): the (hd x hd) matrix state and all
        # intra-chunk gate/score intermediates live in VMEM scratch.
        with jax.named_scope(FUSED_REGION_MARK):
            (_, _, _), ys = jax.lax.scan(chunk_step, (c0, n0, m0), xs)
    else:
        (_, _, _), ys = jax.lax.scan(chunk_step, (c0, n0, m0), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, din).astype(x.dtype)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(zgate)
    return linear(y, p["w_down"])


def init_mlstm_state(cfg: ArchConfig, batch: int) -> Params:
    h, hd = cfg.n_heads, cfg.head_dim_
    return {"c": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32),
            "m": jnp.full((batch, h), -1e30, jnp.float32)}


def mlstm_decode(p: Params, x: jnp.ndarray, state: Params, cfg: ArchConfig
                 ) -> Tuple[jnp.ndarray, Params]:
    b, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim_
    din = h * hd
    up = linear(x, p["w_up"])
    xin, zgate = up[..., :din], up[..., din:]
    q = linear(xin, p["wq"]).reshape(b, h, hd).astype(jnp.float32)
    k = (linear(xin, p["wk"]).reshape(b, h, hd) / (hd ** 0.5)).astype(
        jnp.float32)
    v = linear(xin, p["wv"]).reshape(b, h, hd).astype(jnp.float32)
    gates = linear(xin, p["w_if"]).astype(jnp.float32)
    log_i = gates[..., :h]
    log_f = jax.nn.log_sigmoid(gates[..., h:])
    m_new = jnp.maximum(log_f + state["m"], log_i)
    i_w = jnp.exp(log_i - m_new)
    f_w = jnp.exp(log_f + state["m"] - m_new)
    c = state["c"] * f_w[..., None, None] + \
        jnp.einsum("bhd,bhe,bh->bhde", k, v, i_w)
    n = state["n"] * f_w[..., None] + k * i_w[..., None]
    num = jnp.einsum("bhd,bhde->bhe", q, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), 1.0)
    y = (num / den[..., None]).reshape(b, din).astype(x.dtype)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(zgate)
    return linear(y, p["w_down"]), {"c": c, "n": n, "m": m_new}


# -- sLSTM ----------------------------------------------------------------------

def init_slstm(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    ffd = int(d * 4 / 3)
    return {
        "w_gates": dense_init(ks[0], (d, 4 * d), dtype=dtype),  # i,f,z,o
        "r_gates": dense_init(ks[1], (d, 4 * d), scale=0.01, dtype=dtype),
        "ffn_gate": dense_init(ks[2], (d, ffd), dtype=dtype),
        "ffn_up": dense_init(ks[2], (d, ffd), dtype=dtype),
        "ffn_down": dense_init(ks[3], (ffd, d), dtype=dtype),
    }


def _slstm_cell(p: Params, xg: jnp.ndarray, state):
    """xg (B, 4D) precomputed input gates; state (c, n, h, m) each (B, D)."""
    c, n, hprev, m = state
    d = c.shape[-1]
    rec = linear(hprev, p["r_gates"]).astype(jnp.float32)
    g = xg.astype(jnp.float32) + rec
    gi, gf, gz, go = g[..., :d], g[..., d:2 * d], g[..., 2 * d:3 * d], \
        g[..., 3 * d:]
    log_f = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(log_f + m, gi)
    i_w = jnp.exp(gi - m_new)
    f_w = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(gz)
    c_new = f_w * c + i_w * z
    n_new = f_w * n + i_w
    h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_forward(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    b, s, d = x.shape
    xg = linear(x, p["w_gates"])                       # (B, S, 4D)

    def step(state, xg_t):
        return _slstm_cell(p, xg_t, state)

    init = (jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32),
            jnp.zeros((b, d), jnp.float32), jnp.full((b, d), -1e30,
                                                     jnp.float32))
    if get_flags().mlstm_pallas:
        # Cost-model the validated Pallas sLSTM kernel
        # (repro/kernels/slstm_scan.py): states + recurrent weights live in
        # VMEM across the whole sequence; the unfused backward otherwise
        # accumulates full-sequence gradient stacks every timestep.
        with jax.named_scope(FUSED_REGION_MARK):
            _, hs = jax.lax.scan(step, init, jnp.moveaxis(xg, 1, 0))
    else:
        _, hs = jax.lax.scan(step, init, jnp.moveaxis(xg, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    # gated ffn (4/3)
    f = jax.nn.silu(linear(y, p["ffn_gate"])) * linear(y, p["ffn_up"])
    return linear(f, p["ffn_down"])


def init_slstm_state(cfg: ArchConfig, batch: int) -> Params:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), -1e30,
                                                  jnp.float32)}


def slstm_decode(p: Params, x: jnp.ndarray, state: Params, cfg: ArchConfig
                 ) -> Tuple[jnp.ndarray, Params]:
    xg = linear(x, p["w_gates"])
    (c, n, h, m), y = _slstm_cell(
        p, xg, (state["c"], state["n"], state["h"], state["m"]))
    y = y.astype(x.dtype)
    f = jax.nn.silu(linear(y, p["ffn_gate"])) * linear(y, p["ffn_up"])
    return linear(f, p["ffn_down"]), {"c": c, "n": n, "h": h, "m": m}
