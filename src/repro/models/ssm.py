"""Selective state-space mixer (Mamba-style), chunked for TPU.

Recurrence per channel c with state size N:
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t          (N-vector)
    y_t = C_t . h_t + D * x_t

Training runs a `lax.scan` over sequence *chunks* with an associative scan
inside each chunk (log-depth within chunk, O(S/chunk) sequential steps
between chunks) — the standard TPU-friendly decomposition.  Decode carries
`h` as O(1) state, which is what makes `long_500k` feasible for SSM archs.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .flags import get_flags
from .layers import dense_init, linear

Params = Dict[str, jnp.ndarray]


def init_ssm(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, 2 * din), dtype=dtype),   # x and z
        "w_b": dense_init(ks[1], (din, n), dtype=dtype),
        "w_c": dense_init(ks[2], (din, n), dtype=dtype),
        "w_dt": dense_init(ks[3], (din,), scale=1.0, dtype=jnp.float32),
        "a_log": jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)
                         )[None, :].repeat(din, 0),             # (din, N)
        "d_skip": jnp.ones((din,), jnp.float32),
        "w_out": dense_init(ks[5], (din, d), dtype=dtype),
    }


def _discretize(p: Params, xin: jnp.ndarray):
    """xin (..., din) -> (a (...,din,N), bx (...,din,N), c (...,N))."""
    dt = jax.nn.softplus(xin.astype(jnp.float32) * p["w_dt"])  # (..., din)
    a = jnp.exp(-jnp.exp(p["a_log"]) * dt[..., None])          # (..., din, N)
    bsel = linear(xin, p["w_b"]).astype(jnp.float32)           # (..., N)
    csel = linear(xin, p["w_c"]).astype(jnp.float32)           # (..., N)
    bx = (dt * xin.astype(jnp.float32))[..., None] * bsel[..., None, :]
    return a, bx, csel


def ssm_forward(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                chunk: int = 128) -> jnp.ndarray:
    """x (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    din = cfg.ssm_expand * d
    xz = linear(x, p["w_in"])
    xin, z = xz[..., :din], xz[..., din:]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    def combine(p1, p2):
        a1, b1 = p1
        a2, b2 = p2
        return a1 * a2, b1 * a2 + b2

    if get_flags().ssm_fused:
        # Discretize per chunk inside the scan: (a, bx) exist only as
        # (B, chunk, din, N) transients fused into the chunk body — the
        # B x S x din x N materialization LEO flags in the baseline is gone.
        xin_c = jnp.moveaxis(xin.reshape(b, nc, chunk, din), 1, 0)

        def chunk_step(h0, xin_chunk):
            ac, bxc, cc = _discretize(p, xin_chunk)
            a_cum, bx_cum = jax.lax.associative_scan(
                combine, (ac, bxc), axis=1)
            h = a_cum * h0[:, None] + bx_cum
            y = jnp.einsum("bcdn,bcn->bcd", h, cc)
            return h[:, -1], y

        h0 = jnp.zeros((b, din, cfg.ssm_state), jnp.float32)
        if get_flags().ssm_pallas:
            # Cost-model the validated Pallas selective-scan kernel
            # (repro/kernels/ssm_scan.py): discretized terms and the
            # associative-scan stages live in VMEM; HBM traffic is the
            # xin chunks in and y chunks out.
            from .flags import FUSED_REGION_MARK
            with jax.named_scope(FUSED_REGION_MARK):
                _, ys = jax.lax.scan(chunk_step, h0, xin_c)
        else:
            _, ys = jax.lax.scan(chunk_step, h0, xin_c)
    else:
        a, bx, csel = _discretize(p, xin)
        a = a.reshape(b, nc, chunk, din, cfg.ssm_state)
        bx = bx.reshape(b, nc, chunk, din, cfg.ssm_state)
        csel = csel.reshape(b, nc, chunk, cfg.ssm_state)

        def chunk_step(h0, inputs):
            ac, bxc, cc = inputs  # (B, chunk, din, N), ...

            a_cum, bx_cum = jax.lax.associative_scan(
                combine, (ac, bxc), axis=1)
            h = a_cum * h0[:, None] + bx_cum          # (B, chunk, din, N)
            y = jnp.einsum("bcdn,bcn->bcd", h, cc)    # (B, chunk, din)
            return h[:, -1], y

        h0 = jnp.zeros((b, din, cfg.ssm_state), jnp.float32)
        _, ys = jax.lax.scan(chunk_step, h0,
                             (jnp.moveaxis(a, 1, 0), jnp.moveaxis(bx, 1, 0),
                              jnp.moveaxis(csel, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, din)
    y = y + xin.astype(jnp.float32) * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return linear(y, p["w_out"])


def init_ssm_state(cfg: ArchConfig, batch: int) -> Params:
    din = cfg.ssm_expand * cfg.d_model
    return {"h": jnp.zeros((batch, din, cfg.ssm_state), jnp.float32)}


def ssm_decode(p: Params, x: jnp.ndarray, state: Params, cfg: ArchConfig
               ) -> Tuple[jnp.ndarray, Params]:
    """x (B, D) one token; O(1) state update."""
    din = cfg.ssm_expand * cfg.d_model
    xz = linear(x, p["w_in"])
    xin, z = xz[..., :din], xz[..., din:]
    a, bx, csel = _discretize(p, xin)          # (B, din, N) x2, (B, N)
    h = a * state["h"] + bx
    y = jnp.einsum("bdn,bn->bd", h, csel)
    y = y + xin.astype(jnp.float32) * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return linear(y, p["w_out"]), {"h": h}
