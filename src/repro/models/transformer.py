"""Model assembly: per-layer blocks, grouped `lax.scan` stacks, train/decode.

Layers are described by (mixer, ffn) descriptors derived statically from the
config, run-length encoded into homogeneous *groups*; each group's params are
stacked with a leading `reps` axis and executed with `jax.lax.scan` — one
compiled body per group regardless of depth (critical for compile time at
62 layers) and the natural unit for activation rematerialization.

Supported mixers: attn (GQA full/SWA), mla, ssm (Mamba-style), hybrid
(parallel attn+SSM heads, Hymba-style), mlstm, slstm.  FFNs: mlp (SwiGLU or
GELU), moe (capacity dispatch), none.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .layers import (
    dense_init,
    embed,
    init_embed,
    init_mlp,
    linear,
    mlp,
    rmsnorm,
    unembed,
)

Params = Dict


# -- static layer plan -----------------------------------------------------------

def layer_descriptors(cfg: ArchConfig) -> List[Tuple[str, str]]:
    """Per-layer (mixer, ffn) descriptors."""
    out: List[Tuple[str, str]] = []
    for i, kind in enumerate(cfg.block_kinds):
        if kind in ("mlstm", "slstm"):
            out.append((kind, "none"))
            continue
        mixer = "hybrid" if kind == "hybrid" else (
            "mla" if cfg.attention == "mla" else
            ("ssm" if kind == "ssm" else "attn"))
        if cfg.n_experts > 0 and i >= cfg.first_dense_layers:
            ffn = "moe"
        elif cfg.d_ff > 0:
            ffn = "mlp"
        else:
            ffn = "none"
        out.append((mixer, ffn))
    return out


def layer_groups(cfg: ArchConfig) -> List[Tuple[Tuple[str, str], int]]:
    """Run-length encoded descriptors -> [(descriptor, reps)]."""
    descs = layer_descriptors(cfg)
    groups: List[Tuple[Tuple[str, str], int]] = []
    for d in descs:
        if groups and groups[-1][0] == d:
            groups[-1] = (d, groups[-1][1] + 1)
        else:
            groups.append((d, 1))
    return groups


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# -- init -------------------------------------------------------------------------

def _init_block(key, desc: Tuple[str, str], cfg: ArchConfig) -> Params:
    mixer, ffn = desc
    dtype = _dtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": jnp.ones((d,), dtype)}
    if mixer == "attn":
        p["attn"] = attn_mod.init_attn(ks[0], cfg, dtype)
    elif mixer == "mla":
        p["attn"] = attn_mod.init_mla(ks[0], cfg, dtype)
    elif mixer == "ssm":
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg, dtype)
    elif mixer == "hybrid":
        p["attn"] = attn_mod.init_attn(ks[0], cfg, dtype)
        p["ssm"] = ssm_mod.init_ssm(ks[3], cfg, dtype)
    elif mixer == "mlstm":
        p["mlstm"] = xlstm_mod.init_mlstm(ks[0], cfg, dtype)
    elif mixer == "slstm":
        p["slstm"] = xlstm_mod.init_slstm(ks[0], cfg, dtype)
    if ffn != "none":
        p["ln2"] = jnp.ones((d,), dtype)
        if ffn == "moe":
            p["ffn"] = moe_mod.init_moe(ks[1], cfg, dtype)
        else:
            p["ffn"] = init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_kind, dtype)
    return p


def init_params(rng, cfg: ArchConfig) -> Params:
    dtype = _dtype(cfg)
    keys = jax.random.split(rng, 3 + len(layer_groups(cfg)))
    params: Params = {"embed": init_embed(keys[0], cfg.vocab_size,
                                          cfg.d_model, dtype)}
    groups = []
    for gi, (desc, reps) in enumerate(layer_groups(cfg)):
        gkeys = jax.random.split(keys[2 + gi], reps)
        stacked = jax.vmap(lambda k: _init_block(k, desc, cfg))(gkeys)
        groups.append(stacked)
    params["groups"] = groups
    params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab_size),
                                    dtype=dtype)
    return params


# -- block forward -----------------------------------------------------------------

def _sp_constraint(x: jnp.ndarray) -> jnp.ndarray:
    """Megatron-style sequence parallelism: between blocks the residual
    stream lives sequence-sharded over "model", so the row-parallel
    projections' all-reduces decompose into reduce-scatter (+ all-gather at
    the next consumer) — half the wire bytes, and norms compute on 1/tp of
    the tokens."""
    from .flags import get_flags
    if not get_flags().sequence_parallel:
        return x
    from jax.sharding import PartitionSpec as P

    from ..parallel.context import get_current_mesh
    mesh = get_current_mesh()
    if mesh is None or "model" not in mesh.axis_names or \
            x.ndim != 3 or x.shape[1] % mesh.shape["model"] != 0:
        return x
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp = dp if len(dp) > 1 else dp[0]
    return jax.lax.with_sharding_constraint(x, P(dp, "model", None))


def _block_forward(p: Params, x: jnp.ndarray, desc: Tuple[str, str],
                   cfg: ArchConfig, positions: jnp.ndarray,
                   chunk: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence block. Returns (x, aux_loss)."""
    mixer, ffn = desc
    aux = jnp.zeros((), jnp.float32)
    x = _sp_constraint(x)
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if mixer == "attn":
        y = attn_mod.attn_forward(p["attn"], h, cfg, positions, chunk)
    elif mixer == "mla":
        y = attn_mod.mla_forward(p["attn"], h, cfg, positions, chunk)
    elif mixer == "ssm":
        y = ssm_mod.ssm_forward(p["ssm"], h, cfg)
    elif mixer == "hybrid":
        y = 0.5 * (attn_mod.attn_forward(p["attn"], h, cfg, positions, chunk)
                   + ssm_mod.ssm_forward(p["ssm"], h, cfg))
    elif mixer == "mlstm":
        y = xlstm_mod.mlstm_forward(p["mlstm"], h, cfg)
    elif mixer == "slstm":
        y = xlstm_mod.slstm_forward(p["slstm"], h, cfg)
    else:
        raise ValueError(mixer)
    x = x + y
    if ffn != "none":
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if ffn == "moe":
            from .flags import get_flags
            if get_flags().moe_impl == "ep_shardmap":
                y, aux = moe_mod.moe_forward_ep(p["ffn"], h, cfg)
            else:
                y, aux = moe_mod.moe_forward(p["ffn"], h, cfg)
            # named for selective remat: saving the MoE output keeps the
            # backward from re-running dispatch all-to-alls + expert FFNs
            from jax.ad_checkpoint import checkpoint_name
            y = checkpoint_name(y, "moe_out")
        else:
            y = mlp(h, p["ffn"])
        x = x + y
    return x, aux


def forward(params: Params, cfg: ArchConfig,
            tokens: Optional[jnp.ndarray] = None,
            embeds: Optional[jnp.ndarray] = None,
            chunk: int = 512,
            remat: str = "group") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Train/prefill forward. Returns (logits (B,S,V) f32, aux_loss)."""
    dtype = _dtype(cfg)
    if embeds is not None:
        x = embeds.astype(dtype)
    else:
        x = embed(tokens, params["embed"], dtype)
    s = x.shape[1]
    positions = jnp.arange(s)
    aux_total = jnp.zeros((), jnp.float32)

    for (desc, reps), stacked in zip(layer_groups(cfg), params["groups"]):
        def body(carry, layer_p, _desc=desc):
            xc, auxc = carry
            xn, aux = _block_forward(layer_p, xc, _desc, cfg, positions,
                                     chunk)
            return (xn, auxc + aux), None

        if remat == "group_save_moe":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.save_only_these_names(
                    "moe_out"))
        elif remat in ("group", "full"):
            body = jax.checkpoint(body)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stacked)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed(x, params["embed"]["table"], transpose=True)
    else:
        logits = unembed(x, params["head"], transpose=False)
    return logits.astype(jnp.float32), aux_total


# -- decode -------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    """Per-group stacked decode state (KV caches / recurrent states)."""
    dtype = _dtype(cfg)

    def one(desc) -> Params:
        mixer, _ = desc
        st: Params = {}
        if mixer == "attn":
            st["kv"] = attn_mod.init_attn_cache(cfg, batch, max_len, dtype)
        elif mixer == "mla":
            st["kv"] = attn_mod.init_mla_cache(cfg, batch, max_len, dtype)
        elif mixer == "ssm":
            st["ssm"] = ssm_mod.init_ssm_state(cfg, batch)
        elif mixer == "hybrid":
            st["kv"] = attn_mod.init_attn_cache(cfg, batch, max_len, dtype)
            st["ssm"] = ssm_mod.init_ssm_state(cfg, batch)
        elif mixer == "mlstm":
            st["mlstm"] = xlstm_mod.init_mlstm_state(cfg, batch)
        elif mixer == "slstm":
            st["slstm"] = xlstm_mod.init_slstm_state(cfg, batch)
        return st

    groups = []
    for desc, reps in layer_groups(cfg):
        st = one(desc)
        groups.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape), st))
    return {"groups": groups}


def _slice_state(stack: Params, li: jnp.ndarray) -> Params:
    return jax.tree.map(
        lambda s: jax.lax.dynamic_index_in_dim(s, li, 0, keepdims=False),
        stack)


def _unslice_state(stack: Params, new_s: Params, li: jnp.ndarray) -> Params:
    return jax.tree.map(
        lambda st, ns: jax.lax.dynamic_update_index_in_dim(
            st, ns.astype(st.dtype), li, 0), stack, new_s)


def _block_decode(p: Params, stack: Params, x: jnp.ndarray,
                  desc: Tuple[str, str], cfg: ArchConfig, pos: jnp.ndarray,
                  li: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    """One layer of decode against *group-stacked* state.

    KV caches stay stacked and receive single-token in-place writes
    (`layer_idx` path in attention); small recurrent states (SSM/xLSTM) are
    sliced out and written back whole — they are KBs, not GBs."""
    mixer, ffn = desc
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    new_stack: Params = dict(stack)
    if mixer == "attn":
        y, new_stack["kv"] = attn_mod.attn_decode(
            p["attn"], h, stack["kv"], pos, cfg, layer_idx=li)
    elif mixer == "mla":
        y, new_stack["kv"] = attn_mod.mla_decode(
            p["attn"], h, stack["kv"], pos, cfg, layer_idx=li)
    elif mixer == "ssm":
        y, ns = ssm_mod.ssm_decode(p["ssm"], h,
                                   _slice_state(stack["ssm"], li), cfg)
        new_stack["ssm"] = _unslice_state(stack["ssm"], ns, li)
    elif mixer == "hybrid":
        ya, new_stack["kv"] = attn_mod.attn_decode(
            p["attn"], h, stack["kv"], pos, cfg, layer_idx=li)
        ys, ns = ssm_mod.ssm_decode(p["ssm"], h,
                                    _slice_state(stack["ssm"], li), cfg)
        new_stack["ssm"] = _unslice_state(stack["ssm"], ns, li)
        y = 0.5 * (ya + ys)
    elif mixer == "mlstm":
        y, ns = xlstm_mod.mlstm_decode(p["mlstm"], h,
                                       _slice_state(stack["mlstm"], li), cfg)
        new_stack["mlstm"] = _unslice_state(stack["mlstm"], ns, li)
    elif mixer == "slstm":
        y, ns = xlstm_mod.slstm_decode(p["slstm"], h,
                                       _slice_state(stack["slstm"], li), cfg)
        new_stack["slstm"] = _unslice_state(stack["slstm"], ns, li)
    else:
        raise ValueError(mixer)
    x = x + y
    if ffn != "none":
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if ffn == "moe":
            y, _ = moe_mod.moe_forward(p["ffn"], h[:, None], cfg)
            y = y[:, 0]
        else:
            y = mlp(h, p["ffn"])
        x = x + y
    return x, new_stack


def decode_step(params: Params, state: Params, cfg: ArchConfig,
                token: jnp.ndarray, pos: jnp.ndarray
                ) -> Tuple[jnp.ndarray, Params]:
    """One decode step. token (B,) int32; pos scalar int32.

    Returns (logits (B, V) f32, new state).  Group state stays stacked as
    the scan *carry* (not ys) so caches are updated in place."""
    dtype = _dtype(cfg)
    x = embed(token, params["embed"], dtype)
    new_groups = []
    for (desc, reps), stacked_p, stacked_s in zip(
            layer_groups(cfg), params["groups"], state["groups"]):
        def body(carry, inputs, _desc=desc):
            x_c, stack = carry
            layer_p, li = inputs
            xn, stack = _block_decode(layer_p, stack, x_c, _desc, cfg, pos,
                                      li)
            return (xn, stack), None

        (x, new_s), _ = jax.lax.scan(
            body, (x, stacked_s), (stacked_p, jnp.arange(reps)))
        new_groups.append(new_s)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed(x, params["embed"]["table"], transpose=True)
    else:
        logits = unembed(x, params["head"], transpose=False)
    return logits.astype(jnp.float32), {"groups": new_groups}


# -- loss ---------------------------------------------------------------------------

def loss_fn(params: Params, cfg: ArchConfig, batch: Dict,
            chunk: int = 512, remat: str = "group",
            aux_weight: float = 0.01) -> jnp.ndarray:
    logits, aux = forward(params, cfg,
                          tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"),
                          chunk=chunk, remat=remat)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean() + aux_weight * aux
