"""Sharded, prefetching host data pipeline.

Each host materializes only its data-parallel shard of the global batch
(deterministically, from the step index), `device_put`s it with the batch
NamedSharding, and prefetches `depth` steps ahead on a worker thread.
Restart-from-step-N is exact: the pipeline has no state beyond N.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from .synthetic import SyntheticConfig, SyntheticTokenDataset


class DataPipeline:
    def __init__(self, dataset: SyntheticTokenDataset, global_batch: int,
                 shardings: Optional[Dict[str, Any]] = None,
                 host_index: int = 0, host_count: int = 1,
                 prefetch_depth: int = 2):
        assert global_batch % host_count == 0
        self.dataset = dataset
        self.global_batch = global_batch
        self.local_batch = global_batch // host_count
        self.host_index = host_index
        self.shardings = shardings
        self.prefetch_depth = prefetch_depth

    def host_batch(self, step: int) -> Dict[str, np.ndarray]:
        return self.dataset.batch(
            step, self.host_index * self.local_batch, self.local_batch)

    def device_batch(self, step: int) -> Dict[str, Any]:
        batch = self.host_batch(step)
        if self.shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return {k: jax.device_put(v, self.shardings.get(k))
                for k, v in batch.items()}

    def __call__(self, start_step: int = 0) -> Iterator[Dict[str, Any]]:
        """Prefetching iterator from `start_step` (exact resume point)."""
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch_depth)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                try:
                    q.put(self.device_batch(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
