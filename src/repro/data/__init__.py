from .synthetic import SyntheticConfig, SyntheticTokenDataset
from .pipeline import DataPipeline
