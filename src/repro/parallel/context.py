"""Ambient mesh context for shard_map-based layers.

The launcher (`dryrun.py`/`train.py`) sets the active mesh here so model
code deep inside a scanned layer stack can build `shard_map` regions without
threading the mesh through every call signature.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

from jax.sharding import Mesh

_CURRENT: Optional[Mesh] = None


def set_current_mesh(mesh: Optional[Mesh]) -> None:
    global _CURRENT
    _CURRENT = mesh


def get_current_mesh() -> Optional[Mesh]:
    return _CURRENT


@contextmanager
def mesh_context(mesh: Mesh):
    global _CURRENT
    prev = _CURRENT
    _CURRENT = mesh
    try:
        yield mesh
    finally:
        _CURRENT = prev
