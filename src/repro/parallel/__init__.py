from .sharding import ShardingRules
