"""Sharding rules: param/activation PartitionSpecs for the production mesh.

Axes: `"model"` carries tensor parallelism (Megatron col/row sharding,
vocab-parallel embeddings, expert parallelism for MoE); `("pod","data")` (or
`("data",)` single-pod) carries data parallelism, sequence parallelism for
batch-1 long-context decode, and ZeRO/FSDP weight sharding.

Rules match on (path suffix, rank).  Two automated passes follow the rules:

* **auto-FSDP**: any weight whose per-shard size still exceeds a threshold
  gets its largest remaining unsharded, divisible axis sharded over the DP
  axes (2-D weight sharding) — this is what makes deepseek-v2-236b's expert
  bank fit 16 GB/chip v5e HBM.
* **ZeRO-1**: optimizer moments/master weights reuse the param spec and then
  the same auto-pass with threshold 0 (always shard over DP when divisible),
  sharding optimizer state across the data axes.
"""
from __future__ import annotations

import math
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig

# (path regex, spec builder taking rank) — first match wins.  Specs are
# written for the *unstacked* trailing dims; a leading scan/stack axis is
# padded with None automatically by `_pad`.
_RULES: List[Tuple[str, Tuple[Optional[str], ...]]] = [
    (r"embed/table$", ("model", None)),
    (r"head$", (None, "model")),
    # attention
    (r"attn/w[qkv]$", (None, "model")),
    (r"attn/b[qkv]$", ("model",)),
    (r"attn/wo$", ("model", None)),
    # MLA
    (r"attn/wq_a$", (None, "model")),
    (r"attn/wq_b$", (None, "model")),
    (r"attn/wkv_a$", (None, None)),
    (r"attn/wkv_b$", (None, "model")),
    (r"attn/(q|kv)_norm$", (None,)),
    # dense MLP
    (r"ffn/w_(gate|up)$", (None, "model")),
    (r"ffn/w_down$", ("model", None)),
    (r"ffn/shared/w_(gate|up)$", (None, "model")),
    (r"ffn/shared/w_down$", ("model", None)),
    # MoE experts: expert-parallel over "model"
    (r"ffn/router$", (None, None)),
    (r"ffn/w_(gate|up)$", ("model", None, None)),      # (E, D, ff)
    (r"ffn/w_down$", ("model", None, None)),           # (E, ff, D)
    # SSM
    (r"ssm/w_in$", (None, "model")),
    (r"ssm/w_out$", ("model", None)),
    (r"ssm/w_[bc]$", ("model", None)),
    (r"ssm/(w_dt|d_skip)$", ("model",)),
    (r"ssm/a_log$", ("model", None)),
    # mLSTM / sLSTM
    (r"mlstm/w_up$", (None, "model")),
    (r"mlstm/w_down$", ("model", None)),
    (r"mlstm/w[qkv]$", ("model", None)),
    (r"mlstm/w_if$", (None, None)),
    (r"mlstm/norm$", (None,)),
    (r"slstm/(w|r)_gates$", (None, "model")),
    (r"slstm/ffn_(gate|up)$", (None, "model")),
    (r"slstm/ffn_down$", ("model", None)),
    # norms and everything else: replicated
    (r".*", ()),
]

_FSDP_THRESHOLD_BYTES = 128 * 2**20


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _pad(spec: Tuple, rank: int) -> P:
    """Left-pad a trailing-dims spec with None up to the leaf's rank.

    MoE expert banks are rank-3 specs; under a scan stack they become rank-4.
    Dense rules are rank-2.  Rank-1 rules cover biases/norm scales.
    """
    if len(spec) > rank:
        return P(*spec[len(spec) - rank:])
    return P(*((None,) * (rank - len(spec)) + tuple(spec)))


def _spec_for(path: str, shape: Tuple[int, ...], is_moe_leaf: bool) -> P:
    rank = len(shape)
    for pattern, spec in _RULES:
        # Disambiguate moe vs dense ffn rules by rank: expert banks have an
        # extra E axis (rank 3 before stacking, 4 after).
        if pattern.startswith(r"ffn/w_") and "shared" not in pattern:
            if is_moe_leaf != (len(spec) == 3):
                continue
        if re.search(pattern, path):
            return _pad(spec, rank)
    return P()


def _divisibility_filter(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharded entries whose axis size does not divide the dim (pjit
    rejects uneven explicit shardings — e.g. hymba's vocab of 32001)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, e in zip(shape, entries):
        if e is not None and dim % _axis_size(mesh, e) != 0:
            e = None
        out.append(e)
    return P(*out)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _auto_shard_dp(spec: P, shape: Tuple[int, ...], mesh: Mesh,
                   dp_axes: Tuple[str, ...],
                   threshold_bytes: int, itemsize: int = 2) -> P:
    """Shard the largest remaining divisible axis over DP if the per-shard
    size exceeds `threshold_bytes` (auto-FSDP / ZeRO pass)."""
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    dp_size = _axis_size(mesh, dp)
    if dp_size <= 1:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    shard_sizes = [
        shape[i] // _axis_size(mesh, entries[i]) for i in range(len(shape))]
    per_shard = int(np.prod(shard_sizes)) * itemsize if shape else itemsize
    if per_shard <= threshold_bytes:
        return spec
    # biggest unsharded axis divisible by dp_size
    cands = [(shard_sizes[i], i) for i in range(len(shape))
             if entries[i] is None and shape[i] % dp_size == 0 and
             shard_sizes[i] % dp_size == 0]
    if not cands:
        return spec
    _, idx = max(cands)
    entries[idx] = dp
    return P(*entries)


class ShardingRules:
    def __init__(self, mesh: Mesh, cfg: ArchConfig,
                 fsdp: bool = True, zero1: bool = True):
        self.mesh = mesh
        self.cfg = cfg
        self.fsdp = fsdp
        self.zero1 = zero1
        names = mesh.axis_names
        self.dp_axes: Tuple[str, ...] = tuple(
            a for a in names if a in ("pod", "data"))
        self.dp_spec = self.dp_axes if len(self.dp_axes) > 1 else \
            self.dp_axes[0]
        self.tp = mesh.shape.get("model", 1)

    def _head_filter(self, path: str, spec: P, shape: Tuple[int, ...]) -> P:
        """Drop "model" sharding that would cut *inside* attention heads.

        Sharding wk (D, Kv*hd) 16-way when Kv=2 slices within a head; XLA
        then shards the score contraction and inserts a giant per-chunk
        all-reduce (this exact bug was LEO's first real catch — see
        EXPERIMENTS.md §Perf).  Megatron practice: head projections shard
        over "model" only when the head count divides TP; otherwise the
        (small) projection is replicated and the arch runs attention
        data-parallel.  mLSTM/sLSTM mixers have few heads (and matrix-memory
        states) — replicated likewise; their model parallelism comes from
        the vocab-sharded embedding/head.
        """
        cfg = self.cfg
        q_ok = cfg.n_heads % self.tp == 0
        kv_ok = cfg.n_kv_heads % self.tp == 0
        drop = False
        if re.search(r"attn/(wq|bq|wq_a|wq_b)$", path) or \
                re.search(r"attn/wo$", path):
            drop = not q_ok
        elif re.search(r"attn/(wk|wv|bk|bv)$", path):
            drop = not kv_ok
        elif re.search(r"attn/wkv_b$", path):
            drop = not q_ok  # MLA up-projection is per-head
        elif re.search(r"(mlstm|slstm)/", path):
            drop = cfg.n_heads % self.tp != 0 or "mlstm" in path or \
                "slstm" in path
        if not drop:
            return spec
        return P(*[None if e == "model" else e for e in spec])

    # -- params ---------------------------------------------------------------

    def param_specs(self, params_shape) -> Any:
        def leaf(path, leaf_sds):
            ps = _path_str(path)
            is_moe = self.cfg.n_experts > 0 and "/ffn/" in ps and \
                "shared" not in ps and len(leaf_sds.shape) >= 4
            spec = _spec_for(ps, leaf_sds.shape, is_moe)
            spec = self._head_filter(ps, spec, leaf_sds.shape)
            spec = _divisibility_filter(spec, leaf_sds.shape, self.mesh)
            from ..models.flags import get_flags
            if is_moe and get_flags().moe_impl == "ep_shardmap":
                return spec  # stationary expert weights: no FSDP
            if self.fsdp:
                threshold = get_flags().fsdp_threshold_mb * 2**20
                spec = _auto_shard_dp(spec, leaf_sds.shape, self.mesh,
                                      self.dp_axes, threshold)
            return spec
        return jax.tree_util.tree_map_with_path(leaf, params_shape)

    def param_shardings(self, params_shape) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.param_specs(params_shape))

    # -- optimizer state (ZeRO-1) ------------------------------------------------

    def opt_specs(self, opt_shape, params_shape) -> Any:
        pspecs = self.param_specs(params_shape)

        def like_params(tree):
            def leaf(path, leaf_sds):
                ps = _path_str(path)
                is_moe = self.cfg.n_experts > 0 and "/ffn/" in ps and \
                    "shared" not in ps and len(leaf_sds.shape) >= 4
                spec = _spec_for(ps, leaf_sds.shape, is_moe)
                spec = self._head_filter(ps, spec, leaf_sds.shape)
                spec = _divisibility_filter(spec, leaf_sds.shape, self.mesh)
                if self.zero1:
                    spec = _auto_shard_dp(spec, leaf_sds.shape, self.mesh,
                                          self.dp_axes, 0, itemsize=4)
                elif self.fsdp:
                    spec = _auto_shard_dp(spec, leaf_sds.shape, self.mesh,
                                          self.dp_axes,
                                          _FSDP_THRESHOLD_BYTES, itemsize=4)
                return spec
            return jax.tree_util.tree_map_with_path(leaf, tree)

        return {
            "mu": like_params(opt_shape["mu"]),
            "nu": like_params(opt_shape["nu"]),
            "master": like_params(opt_shape["master"]),
            "count": P(),
        }

    # -- activations / step inputs -------------------------------------------------

    def batch_specs(self, cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, P]:
        dp = self.dp_spec
        if shape.kind in ("train", "prefill"):
            specs = {"labels": P(dp, None)}
            if cfg.frontend != "none":
                specs["embeds"] = P(dp, None, None)
            else:
                specs["tokens"] = P(dp, None)
            return specs
        # decode: batch-1 long-context cannot shard batch
        if shape.global_batch < _axis_size(self.mesh, dp):
            return {"token": P(None), "pos": P()}
        return {"token": P(dp), "pos": P()}

    def decode_state_specs(self, state_shape, shape: ShapeConfig) -> Any:
        dp = self.dp_spec
        batch_shardable = shape.global_batch >= _axis_size(self.mesh, dp)

        cache_budget = 8 * 2**30  # per-chip bytes before seq-sharding

        def leaf(path, leaf_sds):
            ps = _path_str(path)
            rank = len(leaf_sds.shape)
            # leading axis is the layer stack; axis 1 is batch
            if batch_shardable:
                entries = [None, dp] + [None] * (rank - 2)
                # KV caches too large for batch sharding alone (MHA archs
                # like musicgen at 32k x 128) additionally shard the
                # sequence axis over "model"; decode attention reduces
                # partial softmax stats across it.
                per_shard = int(np.prod(leaf_sds.shape)) * 2 // \
                    max(_axis_size(self.mesh, dp), 1)
                if per_shard > cache_budget and rank >= 3:
                    dims = leaf_sds.shape
                    seq_axis = int(np.argmax(dims[2:])) + 2
                    if dims[seq_axis] % self.tp == 0:
                        entries[seq_axis] = "model"
                return P(*entries)
            # batch-1: shard the longest axis (sequence for KV caches) over
            # data — sequence parallelism for long-context decode.
            if rank >= 3:
                dims = leaf_sds.shape
                seq_axis = int(np.argmax(dims[2:])) + 2
                if dims[seq_axis] % _axis_size(self.mesh, dp) == 0:
                    entries = [None] * rank
                    entries[seq_axis] = dp
                    return P(*entries)
            return P()
        return jax.tree_util.tree_map_with_path(leaf, state_shape)

    def logits_spec(self, shape: ShapeConfig) -> P:
        dp = self.dp_spec
        if shape.kind == "decode" and \
                shape.global_batch < _axis_size(self.mesh, dp):
            return P(None, "model")
        return P(dp, "model") if shape.kind == "decode" else P(dp, None, None)
