"""The closed loop: diagnose -> advise -> transform -> verify.

:class:`RewriteLoop` is the subsystem's top layer.  Given a program and
a backend it (1) runs the advisor, (2) lowers each top-k advice
mutation to an equivalence-checked HLO rewrite via
:func:`repro.rewrite.rewriters.apply_rewrite`, (3) **re-analyzes the
rewritten text through the real pipeline** — the same parse -> sample
path any consumer of the text would take, not the advisor's in-memory
replay — and (4) reports predicted-vs-realized speedup per rewrite.

Advice whose mutation is hardware-side (e.g. AMD's "grow the waitcnt
counter pool") cannot be lowered directly; the loop falls back to the
*same rule's* program-rewritable candidates (a pool that cannot grow in
silicon is exactly what tag coalescing fixes in software), prices the
fallback with its own what-if replay, and records the original typed
refusal alongside (``source="rule_fallback"``).

When two or more distinct program rewrites applied, the loop also
prices and applies them *stacked* through ``Advisor.compose`` — one
joint replay, one composed rewrite, one realized number
(``source="stacked"``).

``realized_fraction`` is the headline honesty metric: the share of the
*predicted* gain the re-analyzed rewrite actually delivers
(``(realized-1)/(predicted-1)``).  The rewrite-divergence golden pins
it >= 0.8 per GPU vendor on the 48-copy storm; fractions above 1.0
happen when the re-parse re-derives cheaper costs than the advisor's
in-memory mutant carried (the text is the truth, the replay the
estimate).
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Union

from ..advisor.advisor import Advice, Advisor, AdvisorReport
from ..advisor.rules import Evidence, rule_by_name
from ..advisor.whatif import Compose, Mutation, WhatIfEngine
from ..core.backends import Backend, BackendLike, resolve_backend
from ..core.hlo_parser import parse_hlo
from ..core.isa import Module
from ..core.sampler import StallProfile, VirtualSampler
from .rewriters import NotApplicable, RewriteResult, apply_rewrite, \
    is_rewritable

__all__ = ["RewriteOutcome", "RewriteReport", "RewriteLoop",
           "rewrites_section"]


@dataclass
class RewriteOutcome:
    """One advice item carried through transform + verify."""

    rule: str
    source: str                     # "advice" | "rule_fallback" | "stacked"
    mutation: Dict[str, Any]        # the mutation actually applied
    description: str
    predicted_speedup: float
    predicted_makespan_cycles: float
    realized_speedup: float
    realized_makespan_cycles: float
    certificate: Dict[str, Any]
    hlo_sha256: str
    hlo_bytes: int
    #: the original advice's typed refusal when source == "rule_fallback"
    refusal: Optional[Dict[str, Any]] = None

    @property
    def realized_fraction(self) -> float:
        """Share of the predicted gain the re-analysis delivered."""
        if self.predicted_speedup <= 1.0:
            return 1.0
        return (self.realized_speedup - 1.0) / (self.predicted_speedup - 1.0)

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "rule": self.rule,
            "source": self.source,
            "mutation": dict(self.mutation),
            "description": self.description,
            "predicted_speedup": self.predicted_speedup,
            "predicted_makespan_cycles": self.predicted_makespan_cycles,
            "realized_speedup": self.realized_speedup,
            "realized_makespan_cycles": self.realized_makespan_cycles,
            "realized_fraction": self.realized_fraction,
            "certificate": dict(self.certificate),
            "hlo_sha256": self.hlo_sha256,
            "hlo_bytes": self.hlo_bytes,
        }
        if self.refusal is not None:
            out["refusal"] = dict(self.refusal)
        return out


@dataclass
class RewriteReport:
    """Full rewrite-loop outcome for one ``(program, backend)`` pair."""

    backend: str
    baseline_makespan_cycles: float
    top_k: int
    outcomes: List[RewriteOutcome] = field(default_factory=list)
    #: advice that could not be lowered at all (typed refusals)
    skipped: List[Dict[str, Any]] = field(default_factory=list)
    rewrite_seconds: float = 0.0

    @property
    def best(self) -> Optional[RewriteOutcome]:
        if not self.outcomes:
            return None
        return max(self.outcomes, key=lambda o: o.realized_speedup)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "backend": self.backend,
            "baseline_makespan_cycles": self.baseline_makespan_cycles,
            "top_k": self.top_k,
            "outcomes": [o.to_dict() for o in self.outcomes],
            "skipped": [dict(s) for s in self.skipped],
            "rewrite_seconds": self.rewrite_seconds,
        }


class RewriteLoop:
    """Apply the advisor's top-k advice as verified HLO rewrites.

    ``advisor`` defaults to a stock :class:`Advisor`; ``top_k`` bounds
    how many advice items get lowered (and how many program rewrites the
    stacked candidate may compose)."""

    def __init__(self, advisor: Optional[Advisor] = None, *,
                 top_k: int = 2):
        if top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {top_k}")
        self.advisor = advisor if advisor is not None else Advisor()
        self.top_k = top_k

    # -- verify ---------------------------------------------------------------

    @staticmethod
    def _realize(result: RewriteResult, backend: Backend,
                 hints: Optional[dict],
                 session: Optional[Any]) -> float:
        """Makespan of the rewritten *text* through the real pipeline —
        via the session (cached, full pass stack) when one is supplied,
        else a direct parse-free sampler run on the re-parsed module
        (identical by the round-trip guarantee)."""
        if session is not None:
            analysis = session.analyze(result.hlo_text, backend=backend,
                                       hints=hints)
            return analysis.profile.makespan_cycles
        profile = VirtualSampler(result.module, backend.hw,
                                 sync=backend.sync).run()
        return profile.makespan_cycles

    # -- fallback -------------------------------------------------------------

    def _fallback(self, module: Module, advice: Advice,
                  evidence: Evidence, engine: WhatIfEngine,
                  hints: Optional[dict]):
        """Best program-rewritable candidate of the advice's own rule,
        priced by replay.  Returns ``(whatif_result, rewrite_result)`` or
        ``None`` when the rule offers nothing rewritable here."""
        try:
            rule = rule_by_name(advice.rule)
        except KeyError:
            return None
        # price every rewritable candidate first (a replay is one cheap
        # sampler run), then pay the expensive emit + re-parse + certify
        # of apply_rewrite only for the best one that actually applies
        priced = [engine.replay(cand) for cand in rule.candidates(evidence)
                  if is_rewritable(cand)]
        priced.sort(key=lambda r: -r.modeled_speedup)
        for result in priced:
            try:
                rewritten = apply_rewrite(module, result.mutation,
                                          hints=hints)
            except NotApplicable:
                continue
            return result, rewritten
        return None

    # -- the loop -------------------------------------------------------------

    def run(self, program: Union[str, Module], backend: BackendLike, *,
            hints: Optional[dict] = None,
            profile: Optional[StallProfile] = None,
            blame: Optional[object] = None,
            advisor_report: Optional[AdvisorReport] = None,
            session: Optional[Any] = None) -> RewriteReport:
        """Close the loop once.  ``session`` (a ``LeoSession`` /
        ``LeoService``-owned session) routes verification through the
        cached full pipeline; ``profile``/``blame``/``advisor_report``
        let a caller that already diagnosed skip re-paying those runs."""
        t0 = time.perf_counter()
        b = resolve_backend(backend)
        module = parse_hlo(program, hints) if isinstance(program, str) \
            else program
        if profile is None:
            profile = VirtualSampler(module, b.hw, sync=b.sync).run()
        if advisor_report is None:
            advisor_report = self.advisor.report(module, b, profile=profile,
                                                 blame=blame)
        evidence = Evidence(backend=b, profile=profile, blame=blame)
        engine = WhatIfEngine(module, b)
        engine._baseline = profile
        baseline = advisor_report.baseline_makespan_cycles

        report = RewriteReport(backend=b.name,
                               baseline_makespan_cycles=baseline,
                               top_k=self.top_k)
        applied_parts: List[Mutation] = []
        applied_keys: set = set()
        for advice in advisor_report.advice[:self.top_k]:
            mutation = advice.to_mutation()
            refusal: Optional[Dict[str, Any]] = None
            try:
                rewritten = apply_rewrite(module, mutation, hints=hints)
                source = "advice"
                predicted = advice.modeled_speedup
            except NotApplicable as refused:
                fallback = self._fallback(module, advice, evidence,
                                          engine, hints)
                if fallback is None:
                    report.skipped.append({
                        "rule": advice.rule,
                        "mutation": dict(advice.mutation),
                        "refusal": refused.to_dict(),
                    })
                    continue
                priced, rewritten = fallback
                mutation = priced.mutation
                source = "rule_fallback"
                predicted = priced.modeled_speedup
                refusal = refused.to_dict()
            realized_makespan = self._realize(rewritten, b, hints, session)
            realized = baseline / realized_makespan \
                if realized_makespan > 0 else 1.0
            report.outcomes.append(RewriteOutcome(
                rule=advice.rule,
                source=source,
                mutation=rewritten.mutation,
                description=advice.description,
                predicted_speedup=predicted,
                predicted_makespan_cycles=baseline / predicted
                if predicted > 0 else baseline,
                realized_speedup=realized,
                realized_makespan_cycles=realized_makespan,
                certificate=rewritten.certificate.to_dict(),
                hlo_sha256=hashlib.sha256(
                    rewritten.hlo_text.encode("utf-8")).hexdigest(),
                hlo_bytes=len(rewritten.hlo_text),
                refusal=refusal,
            ))
            key = repr(sorted(rewritten.mutation.items(), key=str))
            if rewritten.changed and key not in applied_keys:
                applied_keys.add(key)
                applied_parts.append(mutation)

        if len(applied_parts) >= 2:
            self._run_stacked(module, b, hints, profile, advisor_report,
                              applied_parts, session, report)
        report.rewrite_seconds = time.perf_counter() - t0
        return report

    def _run_stacked(self, module: Module, backend: Backend,
                     hints: Optional[dict], profile: StallProfile,
                     advisor_report: AdvisorReport,
                     parts: List[Mutation], session: Optional[Any],
                     report: RewriteReport) -> None:
        """Price the applied rewrites jointly (one ``Advisor.compose``
        replay), apply them stacked, and verify the composition."""
        composed_report = self.advisor.compose(
            module, backend, report=advisor_report, mutations=parts,
            profile=profile)
        composed = next((a for a in composed_report.advice
                         if a.mutation.get("kind") == "Compose"), None)
        if composed is None:
            return      # joint replay priced the stack at <= 1.0x
        try:
            rewritten = apply_rewrite(module, Compose(parts=tuple(parts)),
                                      hints=hints)
        except NotApplicable as refused:
            report.skipped.append({
                "rule": composed.rule,
                "mutation": dict(composed.mutation),
                "refusal": refused.to_dict(),
            })
            return
        realized_makespan = self._realize(rewritten, backend, hints, session)
        baseline = report.baseline_makespan_cycles
        report.outcomes.append(RewriteOutcome(
            rule=composed.rule,
            source="stacked",
            mutation=rewritten.mutation,
            description=composed.description,
            predicted_speedup=composed.modeled_speedup,
            predicted_makespan_cycles=baseline / composed.modeled_speedup
            if composed.modeled_speedup > 0 else baseline,
            realized_speedup=baseline / realized_makespan
            if realized_makespan > 0 else 1.0,
            realized_makespan_cycles=realized_makespan,
            certificate=rewritten.certificate.to_dict(),
            hlo_sha256=hashlib.sha256(
                rewritten.hlo_text.encode("utf-8")).hexdigest(),
            hlo_bytes=len(rewritten.hlo_text),
        ))


def rewrites_section(report: RewriteReport) -> Dict[str, Any]:
    """The JSON-pure Diagnosis-v5 ``rewrites`` section for a ran loop
    (contrast :data:`repro.core.report.REWRITES_NOT_RECORDED`)."""
    return {
        "recorded": True,
        "count": len(report.outcomes),
        "items": [o.to_dict() for o in report.outcomes],
        "skipped": [dict(s) for s in report.skipped],
        "baseline_makespan_cycles": report.baseline_makespan_cycles,
        "top_k": report.top_k,
    }
