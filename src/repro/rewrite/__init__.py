"""repro.rewrite — advice-to-HLO rewrites closing the optimize loop.

Three layers (see docs/rewrite.md):

  * :mod:`repro.rewrite.printer` — faithful HLO text emitter;
    ``parse_hlo(emit_hlo(m), hints) == m`` for any parser-produced ``m``;
  * :mod:`repro.rewrite.rewriters` — per-mutation program rewriters with
    structural-equivalence certificates and typed refusals;
  * :mod:`repro.rewrite.loop` — the :class:`RewriteLoop` that applies
    top-k advice (singly and stacked) and reports predicted-vs-realized
    speedup, surfaced as the Diagnosis v5 ``rewrites`` section.
"""
from .loop import RewriteLoop, RewriteOutcome, RewriteReport, \
    rewrites_section
from .printer import PrinterError, emit_hlo, emit_instruction, emit_shape
from .rewriters import (
    REWRITABLE_KINDS,
    EquivalenceCertificate,
    EquivalenceViolation,
    NotApplicable,
    RewriteError,
    RewriteResult,
    apply_rewrite,
    is_rewritable,
)

__all__ = [
    "emit_hlo", "emit_shape", "emit_instruction", "PrinterError",
    "RewriteError", "NotApplicable", "EquivalenceViolation",
    "EquivalenceCertificate", "RewriteResult", "REWRITABLE_KINDS",
    "apply_rewrite", "is_rewritable",
    "RewriteLoop", "RewriteOutcome", "RewriteReport", "rewrites_section",
]
