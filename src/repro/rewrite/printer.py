"""Faithful HLO text emitter — the inverse of ``repro.core.hlo_parser``.

The whole rewrite subsystem stands on one guarantee:

    parse_hlo(emit_hlo(m), hints) == m        for any parser-produced m

so a rewritten module can be lowered to text, shipped to the launch
layer, re-parsed, and re-analyzed with *zero* model drift.  The
guarantee holds because the parser recomputes every derived annotation
(costs, sync semantics, trip counts, fusion folding, virtual fusion)
deterministically from structure in ``HloParser._finalize`` — the
printer therefore only has to preserve structure:

  * computation order, instruction order, names, opcodes, ROOT/ENTRY;
  * shapes (dtype + dims; layouts are dropped by ``parse_shape``, so the
    canonical form here is already a fixed point);
  * operand references (emitted as bare ``%name``);
  * attributes **verbatim** in parse order, including ``metadata={...}``
    and the synthetic ``literal`` attribute the parser stores for
    constant/parameter operand text (printed back as the parenthesized
    operand);
  * ``frontend_attributes={sync_tag="..."}`` — the textual carrier for
    :class:`~repro.advisor.whatif.CoalesceSyncTags` remaps (see
    ``HloParser._annotate_sync``).

Scope: modules produced by :func:`repro.core.hlo_parser.parse_hlo` (and
mutations thereof).  Jaxpr-frontend modules carry annotations plain HLO
text cannot express (``predicate_operands``, ``source="jaxpr"``) and are
rejected rather than silently lossy.

Round-trip is property-tested in ``tests/test_rewrite.py`` over every
golden fixture HLO plus hypothesis-generated storm programs.
"""
from __future__ import annotations

from typing import List

from ..core.hlo_parser import _LITERAL_OPERAND_OPCODES
from ..core.isa import Computation, Instruction, Module, ShapeInfo

__all__ = ["emit_hlo", "emit_shape", "emit_instruction", "PrinterError"]


class PrinterError(ValueError):
    """The module carries state plain HLO text cannot represent."""


def emit_shape(shape: ShapeInfo) -> str:
    """Canonical shape text: ``dtype[d0,d1]`` / nested tuples.  Matches
    what ``parse_shape`` reconstructs (layouts are never re-emitted —
    the parser drops them, so they cannot round-trip anyway)."""
    if shape.is_tuple:
        return "(" + ", ".join(emit_shape(e) for e in shape.elements) + ")"
    return f"{shape.dtype}[{','.join(str(d) for d in shape.dims)}]"


def emit_instruction(instr: Instruction) -> str:
    """One instruction line, two-space indented, attributes verbatim."""
    if instr.opcode in _LITERAL_OPERAND_OPCODES:
        operand_txt = instr.attributes.get("literal", "")
    else:
        operand_txt = ", ".join(f"%{op}" for op in instr.operands)
    line = (f"  {'ROOT ' if instr.is_root else ''}%{instr.name} = "
            f"{emit_shape(instr.shape)} {instr.opcode}({operand_txt})")
    for key, value in instr.attributes.items():
        if key == "literal":
            continue
        line += f", {key}" if value == "" else f", {key}={value}"
    return line


def _emit_computation(comp: Computation, entry: bool) -> List[str]:
    params = ", ".join(f"{p.name}: {emit_shape(p.shape)}"
                       for p in comp.parameters)
    root = comp.root
    ret = emit_shape(root.shape) if root is not None else "()"
    lines = [f"{'ENTRY ' if entry else ''}%{comp.name} ({params}) "
             f"-> {ret} {{"]
    lines += [emit_instruction(i) for i in comp.instructions]
    lines.append("}")
    return lines


def emit_hlo(module: Module) -> str:
    """Module -> HLO text; ``parse_hlo(emit_hlo(m), hints) == m`` for any
    parser-produced ``m`` under the same hints."""
    if module.source != "hlo":
        raise PrinterError(
            f"cannot emit module {module.name!r} from source "
            f"{module.source!r}: only HLO-parsed modules round-trip "
            f"(jaxpr annotations have no HLO text form)")
    for instr in module.all_instructions():
        if instr.predicate_operands:
            raise PrinterError(
                f"instruction {instr.qualified_name!r} carries predicate "
                f"operands, which plain HLO text cannot express")
    blocks: List[str] = [f"HloModule {module.name}"]
    for name, comp in module.computations.items():
        blocks.append(
            "\n".join(_emit_computation(comp, entry=(name == module.entry))))
    return "\n\n".join(blocks) + "\n"
