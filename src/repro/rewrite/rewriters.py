"""Per-mutation program rewriters: advice -> equivalence-checked HLO.

The advisor's program-side :class:`~repro.advisor.whatif.Mutation`s edit
the in-memory :class:`~repro.core.isa.Module`; this layer lowers each
edit to actual HLO *text* and proves the result equivalent:

  * ``CoalesceSyncTags``  — the remapped sync sets are expressed as
    ``frontend_attributes={sync_tag="<leader>"}`` on the non-leader
    starts (the parser derives waiters' tags transitively), so the
    rewritten text re-parses to exactly the mutated sync accounting;
  * ``PipelineAsyncChain`` — instruction reordering is directly
    representable: sunk starts simply move down the program text;
  * ``TreeReduceChain``   — operand rewiring is directly representable:
    the chain's own nodes re-pair level by level, names unchanged;
  * ``Identity``          — re-emits the module verbatim (the byte-
    identity anchor the golden lanes assert);
  * ``Compose``           — applies its program-rewritable parts in
    sequence, carrying one certificate per step.

Hardware-side mutations (``ResizePool``, ``SetIssue``, ``ScaleLatency``)
have no program text to rewrite — they model a *different part*, not a
different program — and refuse with a typed :class:`NotApplicable`
(``code="hardware_mutation"``), as does ``RelaxSyncEdge`` (dropping a
wait without dropping the data operand has no HLO form;
``code="unsupported"``) and any rewrite that would leave the text
unchanged (``code="noop"``).

Every successful rewrite returns a :class:`RewriteResult` whose
``module`` is the **re-parse of the emitted text** (what any downstream
consumer of the text would see) and whose
:class:`EquivalenceCertificate` proves structural equivalence: same
computations, same instruction names/opcodes/shapes, same roots, and
dataflow-isomorphic modulo the rewrite's declared change.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..advisor.whatif import (
    _ASSOCIATIVE_OPCODES,
    Compose,
    Identity,
    Mutation,
    mutation_from_dict,
)
from ..core.hlo_parser import _SYNC_TAG_RE, parse_hlo
from ..core.isa import Computation, Module, OpClass
from .printer import emit_hlo

__all__ = [
    "RewriteError",
    "NotApplicable",
    "EquivalenceViolation",
    "EquivalenceCertificate",
    "RewriteResult",
    "REWRITABLE_KINDS",
    "apply_rewrite",
    "is_rewritable",
]

#: Mutation kinds with a registered program rewriter.  Everything else is
#: hardware-side (or has no HLO text form) and refuses with NotApplicable.
REWRITABLE_KINDS = ("Identity", "CoalesceSyncTags", "PipelineAsyncChain",
                    "TreeReduceChain", "Compose")

_HARDWARE_KINDS = ("ResizePool", "SetIssue", "ScaleLatency")


class RewriteError(RuntimeError):
    """Base for everything the rewrite layer raises."""


class NotApplicable(RewriteError):
    """Typed refusal: this mutation cannot be lowered to an HLO rewrite
    of this program.  ``code`` is machine-readable:

      * ``hardware_mutation`` — the mutation edits the backend model,
        not the program; there is no text to rewrite;
      * ``noop``              — the rewriter ran but the program is
        already in the target shape (emitted text unchanged);
      * ``unsupported``       — no rewriter is registered for this kind.
    """

    def __init__(self, mutation_kind: str, code: str, reason: str):
        super().__init__(f"{mutation_kind}: {reason}")
        self.mutation_kind = mutation_kind
        self.code = code
        self.reason = reason

    def to_dict(self) -> Dict[str, Any]:
        return {"mutation_kind": self.mutation_kind, "code": self.code,
                "reason": self.reason}


class EquivalenceViolation(RewriteError):
    """A rewriter produced a structurally non-equivalent module — always
    a bug in the rewriter, never a caller error."""


@dataclass
class EquivalenceCertificate:
    """Structural-equivalence proof for one rewrite.

    ``declared`` names the one way the rewrite is allowed to differ from
    the original; every *other* structural property was checked equal:

      * ``identical``  — nothing may differ (Identity);
      * ``sync_retag`` — only sync-tag attributes differ; dataflow and
        program order are bit-equal;
      * ``reorder``    — program order is permuted (def-before-use
        verified); dataflow is bit-equal;
      * ``rebalance``  — associative chains are rewired; every boundary
        node (one an unchanged consumer observes) reduces the same leaf
        multiset;
      * ``stacked``    — a Compose; ``parts`` carries one certificate
        per applied step.
    """

    mutation_kind: str
    declared: str
    checks: List[str] = field(default_factory=list)
    reordered: Tuple[str, ...] = ()     # qualified names whose index moved
    rewired: Tuple[str, ...] = ()       # qualified names whose operands changed
    parts: List["EquivalenceCertificate"] = field(default_factory=list)

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "mutation_kind": self.mutation_kind,
            "declared": self.declared,
            "checks": list(self.checks),
            "reordered": list(self.reordered),
            "rewired": list(self.rewired),
        }
        if self.parts:
            out["parts"] = [p.to_dict() for p in self.parts]
        return out


@dataclass
class RewriteResult:
    """One applied rewrite: the emitted text, its re-parse, and proof."""

    mutation: Dict[str, Any]            # Mutation.to_dict()
    hlo_text: str
    module: Module                      # parse_hlo(hlo_text, hints)
    certificate: EquivalenceCertificate
    changed: bool = True

    def to_dict(self) -> Dict[str, Any]:
        """JSON-light summary (the full text stays off the wire)."""
        import hashlib
        return {
            "mutation": dict(self.mutation),
            "certificate": self.certificate.to_dict(),
            "changed": self.changed,
            "hlo_sha256": hashlib.sha256(
                self.hlo_text.encode("utf-8")).hexdigest(),
            "hlo_bytes": len(self.hlo_text),
        }


# --------------------------------------------------------------------------
# Equivalence checking.
# --------------------------------------------------------------------------

def _strip_sync_tag(attrs: Dict[str, str]) -> Dict[str, str]:
    """Attributes with any sync_tag carrier removed (for sync_retag
    comparisons, where ONLY that attribute may differ)."""
    out = dict(attrs)
    fa = out.get("frontend_attributes")
    if fa is not None and _SYNC_TAG_RE.search(fa):
        inner = _SYNC_TAG_RE.sub("", fa.strip()[1:-1]).strip().strip(",")
        inner = inner.strip()
        if inner:
            out["frontend_attributes"] = "{" + inner + "}"
        else:
            out.pop("frontend_attributes")
    return out


def _check_skeleton(original: Module, rewritten: Module,
                    checks: List[str]) -> None:
    """Shared invariants: same computations, same instruction name sets,
    same opcode/shape per name, same root per computation."""
    if list(original.computations) != list(rewritten.computations):
        raise EquivalenceViolation(
            f"computation set changed: {list(original.computations)} -> "
            f"{list(rewritten.computations)}")
    if original.entry != rewritten.entry:
        raise EquivalenceViolation(
            f"entry changed: {original.entry!r} -> {rewritten.entry!r}")
    for cname, comp in original.computations.items():
        rcomp = rewritten.computations[cname]
        names = sorted(i.name for i in comp.instructions)
        rnames = sorted(i.name for i in rcomp.instructions)
        if names != rnames:
            raise EquivalenceViolation(
                f"{cname}: instruction set changed "
                f"(only {set(names) ^ set(rnames)} differ)")
        for instr in comp.instructions:
            other = rcomp.get(instr.name)
            if instr.opcode != other.opcode:
                raise EquivalenceViolation(
                    f"{cname}::{instr.name}: opcode {instr.opcode} -> "
                    f"{other.opcode}")
            if instr.shape != other.shape:
                raise EquivalenceViolation(
                    f"{cname}::{instr.name}: shape changed")
            if instr.is_root != other.is_root:
                raise EquivalenceViolation(
                    f"{cname}::{instr.name}: ROOT marker changed")
    checks.append("computations, instruction names, opcodes, shapes and "
                  "roots preserved")


def _changed_operands(comp: Computation,
                      rcomp: Computation) -> List[str]:
    return [i.name for i in comp.instructions
            if rcomp.get(i.name).operands != i.operands]


def _moved(comp: Computation, rcomp: Computation) -> List[str]:
    return [i.name for i in comp.instructions
            if rcomp.get(i.name).index != i.index]


def _check_def_before_use(comp: Computation) -> None:
    for instr in comp.instructions:
        for op in instr.operands:
            src = comp.get(op)
            if src is not None and src.index >= instr.index:
                raise EquivalenceViolation(
                    f"{comp.name}::{instr.name}: operand %{op} is defined "
                    f"at index {src.index} >= use at {instr.index}")


def _flatten_leaves(comp: Computation, name: str, changed: set,
                    opcode: str) -> Counter:
    """Multiset of leaf operand names reachable from ``name`` through
    changed same-opcode nodes — the value a rebalanced (sub)tree reduces."""
    out: Counter = Counter()
    stack = [name]
    while stack:
        cur = stack.pop()
        for op in comp.get(cur).operands:
            src = comp.get(op)
            if (op in changed and src is not None
                    and src.opcode == opcode):
                stack.append(op)
            else:
                out[op] += 1
    return out


def _check_rebalance(original: Module, rewritten: Module,
                     checks: List[str]) -> Tuple[str, ...]:
    """Every rewired node must be associative, and every *boundary* node
    (one consumed by unchanged code, or a root) must reduce the same
    leaf multiset as before."""
    rewired: List[str] = []
    for cname, comp in original.computations.items():
        rcomp = rewritten.computations[cname]
        if _moved(comp, rcomp):
            raise EquivalenceViolation(
                f"{cname}: rebalance must not reorder instructions")
        changed = set(_changed_operands(comp, rcomp))
        if not changed:
            continue
        for name in sorted(changed):
            if comp.get(name).opcode not in _ASSOCIATIVE_OPCODES:
                raise EquivalenceViolation(
                    f"{cname}::{name}: non-associative opcode "
                    f"{comp.get(name).opcode!r} was rewired")
        # boundary = a changed node some unchanged instruction consumes
        # (or a root): the points where the rest of the program observes
        # the reduction's value
        users: Dict[str, set] = {}
        for instr in comp.instructions:
            for op in set(instr.operands):
                users.setdefault(op, set()).add(instr.name)
        boundary = sorted(
            n for n in changed
            if comp.get(n).is_root
            or (users.get(n, set()) - changed)
            or not users.get(n))
        for n in boundary:
            opc = comp.get(n).opcode
            before = _flatten_leaves(comp, n, changed, opc)
            after = _flatten_leaves(rcomp, n, changed, opc)
            if before != after:
                raise EquivalenceViolation(
                    f"{cname}::{n}: rebalanced reduction changed its leaf "
                    f"multiset: {sorted(before.items())} -> "
                    f"{sorted(after.items())}")
        rewired.extend(f"{cname}::{n}" for n in sorted(changed))
        checks.append(
            f"{cname}: {len(boundary)} boundary node(s) reduce the same "
            f"leaf multiset over {len(changed)} rewired node(s)")
    return tuple(rewired)


def check_equivalence(original: Module, rewritten: Module, *,
                      mutation_kind: str,
                      declared: str) -> EquivalenceCertificate:
    """Verify ``rewritten`` against ``original`` modulo the ``declared``
    change; returns the certificate or raises
    :class:`EquivalenceViolation`."""
    checks: List[str] = []
    _check_skeleton(original, rewritten, checks)
    reordered: Tuple[str, ...] = ()
    rewired: Tuple[str, ...] = ()

    if declared in ("identical", "sync_retag"):
        for cname, comp in original.computations.items():
            rcomp = rewritten.computations[cname]
            bad = _changed_operands(comp, rcomp)
            if bad:
                raise EquivalenceViolation(
                    f"{cname}: operands changed on {bad[:3]} under a "
                    f"{declared} rewrite")
            if _moved(comp, rcomp):
                raise EquivalenceViolation(
                    f"{cname}: program order changed under a {declared} "
                    f"rewrite")
        checks.append("dataflow and program order bit-equal")
        if declared == "identical":
            for cname, comp in original.computations.items():
                rcomp = rewritten.computations[cname]
                for instr in comp.instructions:
                    if instr.attributes != rcomp.get(instr.name).attributes:
                        raise EquivalenceViolation(
                            f"{cname}::{instr.name}: attributes changed "
                            f"under an identity rewrite")
            checks.append("attributes bit-equal")
        else:
            retagged = []
            for cname, comp in original.computations.items():
                rcomp = rewritten.computations[cname]
                for instr in comp.instructions:
                    other = rcomp.get(instr.name)
                    if _strip_sync_tag(instr.attributes) != \
                            _strip_sync_tag(other.attributes):
                        raise EquivalenceViolation(
                            f"{cname}::{instr.name}: a non-sync_tag "
                            f"attribute changed under a sync_retag rewrite")
                    if instr.attributes != other.attributes:
                        retagged.append(f"{cname}::{instr.name}")
            checks.append(f"only sync_tag attributes differ "
                          f"({len(retagged)} op(s) retagged)")
            rewired = tuple(retagged)
    elif declared == "reorder":
        moved: List[str] = []
        for cname, comp in original.computations.items():
            rcomp = rewritten.computations[cname]
            bad = _changed_operands(comp, rcomp)
            if bad:
                raise EquivalenceViolation(
                    f"{cname}: operands changed on {bad[:3]} under a "
                    f"reorder rewrite")
            _check_def_before_use(rcomp)
            moved.extend(f"{cname}::{n}" for n in _moved(comp, rcomp))
        checks.append("dataflow bit-equal; new order is def-before-use "
                      f"valid ({len(moved)} op(s) moved)")
        reordered = tuple(moved)
    elif declared == "rebalance":
        rewired = _check_rebalance(original, rewritten, checks)
    else:
        raise ValueError(f"unknown declared change {declared!r}")

    return EquivalenceCertificate(mutation_kind=mutation_kind,
                                  declared=declared, checks=checks,
                                  reordered=reordered, rewired=rewired)


# --------------------------------------------------------------------------
# Rewriters.
# --------------------------------------------------------------------------

def _retag_sync_sets(module: Module) -> None:
    """Express each start op's (possibly remapped) sync set as a
    ``sync_tag`` frontend attribute, in place, so the emitted text
    re-parses to the same sync accounting.  Leaders (tag == own name)
    carry no attribute — the default — keeping the identity case
    byte-stable."""
    for comp in module.computations.values():
        for instr in comp.instructions:
            if instr.op_class is not OpClass.SYNC_SET or not instr.sync.sets:
                continue
            tag = instr.sync.sets[0]
            fa = instr.attributes.get("frontend_attributes", "")
            inner = _SYNC_TAG_RE.sub("", fa.strip()[1:-1]).strip().strip(",") \
                if fa else ""
            entries = [e for e in (inner.strip(),) if e]
            if tag != instr.name:
                entries.append(f'sync_tag="{tag}"')
            if entries:
                instr.attributes["frontend_attributes"] = \
                    "{" + ",".join(entries) + "}"
            else:
                instr.attributes.pop("frontend_attributes", None)


def _finish(original: Module, mutated: Module, mutation: Mutation,
            declared: str, hints: Optional[dict]) -> RewriteResult:
    """Emit, refuse no-ops, re-parse, certify."""
    text = emit_hlo(mutated)
    if text == emit_hlo(original) and not isinstance(mutation, Identity):
        raise NotApplicable(
            mutation.kind, "noop",
            f"the program is already in the target shape "
            f"({mutation.describe()} changes nothing)")
    module = parse_hlo(text, hints)
    cert = check_equivalence(original, module, mutation_kind=mutation.kind,
                             declared=declared)
    return RewriteResult(mutation=mutation.to_dict(), hlo_text=text,
                         module=module, certificate=cert,
                         changed=not isinstance(mutation, Identity))


def _rewrite_identity(module: Module, mutation: Mutation,
                      hints: Optional[dict]) -> RewriteResult:
    return _finish(module, module, mutation, "identical", hints)


def _rewrite_coalesce(module: Module, mutation: Mutation,
                      hints: Optional[dict]) -> RewriteResult:
    mutated = mutation.apply_module(module)
    if mutated is module:        # group == 1 returns the original
        raise NotApplicable(mutation.kind, "noop",
                            "group=1 coalescing is the identity")
    _retag_sync_sets(mutated)
    return _finish(module, mutated, mutation, "sync_retag", hints)


def _rewrite_pipeline(module: Module, mutation: Mutation,
                      hints: Optional[dict]) -> RewriteResult:
    return _finish(module, mutation.apply_module(module), mutation,
                   "reorder", hints)


def _rewrite_tree(module: Module, mutation: Mutation,
                  hints: Optional[dict]) -> RewriteResult:
    return _finish(module, mutation.apply_module(module), mutation,
                   "rebalance", hints)


def _rewrite_compose(module: Module, mutation: Compose,
                     hints: Optional[dict]) -> RewriteResult:
    if not mutation.parts:
        raise NotApplicable("Compose", "noop", "empty composition")
    for part in mutation.parts:
        if not is_rewritable(part):
            raise NotApplicable(
                "Compose", "hardware_mutation",
                f"part {part.kind} has no program rewrite; compose only "
                f"rewritable mutations for the stacked path")
    cur = module
    parts: List[EquivalenceCertificate] = []
    texts: List[str] = []
    any_change = False
    for part in mutation.parts:
        try:
            step = apply_rewrite(cur, part, hints=hints)
        except NotApplicable as e:
            if e.code == "noop":
                continue         # a stacked step may be subsumed by a prior one
            raise
        parts.append(step.certificate)
        texts.append(step.hlo_text)
        cur = step.module
        any_change = any_change or step.changed
    if not any_change or not texts:
        raise NotApplicable("Compose", "noop",
                            "no stacked step changed the program")
    cert = EquivalenceCertificate(
        mutation_kind="Compose", declared="stacked",
        checks=[f"{len(parts)} step(s) individually certified "
                f"(pairwise, in application order)"],
        parts=parts)
    return RewriteResult(mutation=mutation.to_dict(), hlo_text=texts[-1],
                         module=cur, certificate=cert, changed=True)


_REWRITERS: Dict[str, Callable[[Module, Any, Optional[dict]],
                               RewriteResult]] = {
    "Identity": _rewrite_identity,
    "CoalesceSyncTags": _rewrite_coalesce,
    "PipelineAsyncChain": _rewrite_pipeline,
    "TreeReduceChain": _rewrite_tree,
    "Compose": _rewrite_compose,
}


def is_rewritable(mutation: Mutation) -> bool:
    """Whether this mutation has a registered program rewriter (Compose
    counts only when every part does)."""
    if isinstance(mutation, Compose):
        return bool(mutation.parts) and all(is_rewritable(p)
                                            for p in mutation.parts)
    return mutation.kind in _REWRITERS


def apply_rewrite(module: Module, mutation: Any, *,
                  hints: Optional[dict] = None) -> RewriteResult:
    """Lower one mutation to an equivalence-checked HLO rewrite.

    ``mutation`` may be a :class:`Mutation` or its ``to_dict()`` form
    (the shape advice carries).  ``hints`` must match the hints the
    original module was parsed under, so the re-parse annotates costs
    identically.  Raises :class:`NotApplicable` (typed refusal) or
    :class:`EquivalenceViolation` (rewriter bug)."""
    if isinstance(mutation, dict):
        mutation = mutation_from_dict(mutation)
    kind = mutation.kind
    rewriter = _REWRITERS.get(kind)
    if rewriter is None:
        if kind in _HARDWARE_KINDS:
            raise NotApplicable(
                kind, "hardware_mutation",
                f"{mutation.describe()} edits the backend model, not the "
                f"program; there is no HLO rewrite to apply")
        raise NotApplicable(
            kind, "unsupported",
            f"no program rewriter is registered for {kind}")
    return rewriter(module, mutation, hints)
