"""Config shim: `--arch` maps here. See lm_archs.py."""
from .lm_archs import DEEPSEEK_CODER_33B as CONFIG

CONFIG = CONFIG
