"""Config registry: `get_config(name)` resolves an `--arch` id."""
from .base import (
    ArchConfig,
    DECODE_32K,
    LM_SHAPES,
    LONG_500K,
    PREFILL_32K,
    ShapeConfig,
    TRAIN_4K,
    model_flops,
    shapes_for,
    smoke_config,
)
from .lm_archs import ALL_ARCHS

CONFIGS = {c.name: c for c in ALL_ARCHS}
SHAPES = {s.name: s for s in LM_SHAPES}


def get_config(name: str) -> ArchConfig:
    try:
        return CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(CONFIGS)}")


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")


__all__ = [
    "ArchConfig", "ShapeConfig", "CONFIGS", "SHAPES", "ALL_ARCHS",
    "get_config", "get_shape", "model_flops", "shapes_for", "smoke_config",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K", "LM_SHAPES",
]
