"""Config shim: `--arch` maps here. See lm_archs.py."""
from .lm_archs import XLSTM_125M as CONFIG

CONFIG = CONFIG
