"""Config shim: `--arch` maps here. See lm_archs.py."""
from .lm_archs import H2O_DANUBE3_4B as CONFIG

CONFIG = CONFIG
