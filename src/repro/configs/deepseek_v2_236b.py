"""Config shim: `--arch` maps here. See lm_archs.py."""
from .lm_archs import DEEPSEEK_V2_236B as CONFIG

CONFIG = CONFIG
