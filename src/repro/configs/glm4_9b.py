"""Config shim: `--arch` maps here. See lm_archs.py."""
from .lm_archs import GLM4_9B as CONFIG

CONFIG = CONFIG
