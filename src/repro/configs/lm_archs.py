"""The 10 assigned architectures (exact configs from the assignment block).

Each is a frozen `ArchConfig`; provenance in `source`.  One module instead of
ten trivial files keeps the registry greppable; `repro/configs/<id>.py` shims
re-export each config so `--arch <id>` maps 1:1 onto a file as required.
"""
from .base import ArchConfig

XLSTM_125M = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, head_dim=192,
    attention="none",
    # xLSTM[7:1]-style mix: mostly mLSTM with periodic sLSTM blocks.
    block_unit=("mlstm", "mlstm", "mlstm", "slstm"),
    source="sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]",
)

QWEN2_0_5B = ArchConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151936, head_dim=64,
    qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
    source="GQA, QKV bias [arXiv:2407.10671; hf]",
)

H2O_DANUBE3_4B = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_ff=10240,
    vocab_size=32000, head_dim=120,
    attention="swa", window=4096, rope_theta=1e4,
    source="llama+mistral mix, SWA [arXiv:2401.16818; unverified]",
)

GLM4_9B = ArchConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab_size=151552, head_dim=128, rope_theta=1e4,
    source="RoPE, GQA [hf:THUDM/glm-4-9b; hf]",
)

DEEPSEEK_CODER_33B = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19200,
    vocab_size=32256, head_dim=128, rope_theta=1e5,
    source="llama-arch [arXiv:2401.14196; hf]",
)

HYMBA_1_5B = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32001, head_dim=64,
    ssm_state=16, ssm_expand=2,
    attention="swa", window=1024,  # hymba uses SWA on most hybrid layers
    source="parallel attn+mamba heads [arXiv:2411.13676; hf]",
)

DEEPSEEK_V2_236B = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, d_ff=12288,
    vocab_size=102400, head_dim=128,
    attention="mla",
    q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    n_experts=160, n_shared_experts=2, top_k=6, moe_d_ff=1536,
    first_dense_layers=1,
    source="MLA kv_lora=512, 2 shared+160 routed top-6 [arXiv:2405.04434; hf]",
)

PHI35_MOE = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab_size=32064, head_dim=128,
    n_experts=16, n_shared_experts=0, top_k=2, moe_d_ff=6400,
    source="16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct; hf]",
)

MUSICGEN_MEDIUM = ArchConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
    vocab_size=2048, head_dim=64,
    mlp_kind="gelu", frontend="audio",
    source="decoder-only over EnCodec tokens [arXiv:2306.05284; hf]",
)

INTERNVL2_2B = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
    vocab_size=92553, head_dim=128,
    frontend="vision",
    source="InternViT + InternLM2 [arXiv:2404.16821; hf]",
)

ALL_ARCHS = (
    XLSTM_125M, QWEN2_0_5B, H2O_DANUBE3_4B, GLM4_9B, DEEPSEEK_CODER_33B,
    HYMBA_1_5B, DEEPSEEK_V2_236B, PHI35_MOE, MUSICGEN_MEDIUM, INTERNVL2_2B,
)
