"""Architecture and shape configuration schema.

Every assigned architecture is a frozen `ArchConfig`; every workload shape a
`ShapeConfig`.  A (config, shape) pair fully determines the program the
launcher lowers — `train_step` for training shapes, `serve_step` (one-token
decode against a KV cache / recurrent state) for decode shapes, `prefill`
for prefill shapes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # attention flavor
    attention: str = "full"     # full | swa | mla | none
    window: int = 4096          # sliding-window size (attention == "swa")
    qkv_bias: bool = False
    rope_theta: float = 1e4
    mlp_kind: str = "swiglu"    # swiglu | gelu

    # MLA (deepseek-v2)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0  # leading layers with a dense MLP
    capacity_factor: float = 1.25

    # SSM / xLSTM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    block_unit: Tuple[str, ...] = ()   # repeating block-kind pattern, e.g.
                                       # ("mlstm","mlstm","mlstm","slstm")

    # modality frontend (stub: input_specs provides embeddings directly)
    frontend: str = "none"      # none | audio | vision

    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    source: str = ""            # provenance tag [arXiv/hf; tier]

    # -- derived -------------------------------------------------------------

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_recurrent(self) -> bool:
        """True when decode state is O(1) in sequence length."""
        return self.family in ("ssm",) or bool(self.block_unit)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence handling: SSM/recurrent or SWA."""
        return self.is_recurrent or self.attention == "swa" or \
            self.family == "hybrid"

    @property
    def block_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kinds, expanded from the repeating unit."""
        if self.block_unit:
            unit = self.block_unit
            reps = math.ceil(self.n_layers / len(unit))
            return tuple((unit * reps)[: self.n_layers])
        if self.family == "hybrid":
            return ("hybrid",) * self.n_layers
        return ("attn",) * self.n_layers

    def param_count(self) -> float:
        """Analytical parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.head_dim_
        total = self.vocab_size * d   # embedding
        if not self.tie_embeddings:
            total += d * self.vocab_size  # head
        for kind in self.block_kinds:
            total += 2 * d  # norms
            if kind == "attn" or kind == "hybrid":
                if self.attention == "mla":
                    qk = self.qk_nope_head_dim + self.qk_rope_head_dim
                    q_in = self.q_lora_rank or d
                    total += (d * self.q_lora_rank if self.q_lora_rank else 0)
                    total += q_in * self.n_heads * qk
                    total += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                    total += self.kv_lora_rank * self.n_heads * (
                        self.qk_nope_head_dim + self.v_head_dim)
                    total += self.n_heads * self.v_head_dim * d
                else:
                    total += d * self.n_heads * hd          # q
                    total += 2 * d * self.n_kv_heads * hd   # k, v
                    total += self.n_heads * hd * d          # o
            if kind == "hybrid" or kind == "ssm":
                d_in = self.ssm_expand * d
                total += d * 2 * d_in + d_in * d            # in/out proj
                total += d_in * 2 * self.ssm_state + d_in   # B,C,dt
            if kind == "mlstm":
                d_in = 2 * d
                total += d * 2 * d_in + d_in * d
                total += 3 * d_in                            # i,f,o gates
            if kind == "slstm":
                total += 4 * d * d + 4 * d                   # 4 gates
                total += int(d * (4 / 3) * d) * 2            # ffn
            # FFN
            if kind in ("attn", "hybrid", "ssm"):
                is_moe = self.n_experts > 0
                if is_moe:
                    ff = self.moe_d_ff or self.d_ff
                    n_mats = 3 if self.mlp_kind == "swiglu" else 2
                    total += d * self.n_experts  # router
                    total += self.n_experts * n_mats * d * ff
                    total += self.n_shared_experts * n_mats * d * ff
                elif self.d_ff > 0:
                    n_mats = 3 if self.mlp_kind == "swiglu" else 2
                    total += n_mats * d * self.d_ff
        return float(total)

    def active_param_count(self) -> float:
        """Activated parameters per token (MoE top-k instead of all-E)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        ff = self.moe_d_ff or self.d_ff
        n_mats = 3 if self.mlp_kind == "swiglu" else 2
        n_moe_layers = sum(1 for k in self.block_kinds
                           if k in ("attn", "hybrid", "ssm")) \
            - self.first_dense_layers
        inactive = n_moe_layers * (self.n_experts - self.top_k) * \
            n_mats * self.d_model * ff
        return float(full - inactive)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # train | prefill | decode

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

LM_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ArchConfig) -> Tuple[ShapeConfig, ...]:
    """The shape set for an arch; long_500k only for sub-quadratic archs."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        out.append(LONG_500K)
    return tuple(out)


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE), D = tokens/step.

    For non-train shapes the forward-only factor is 2*N instead of 6*N.
    """
    n = cfg.active_param_count()
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * n * shape.tokens_per_step


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    unit = cfg.block_unit
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=max(2, len(unit) or 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 4),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        q_lora_rank=32 if cfg.q_lora_rank else 0,
        kv_lora_rank=32 if cfg.kv_lora_rank else 0,
        qk_nope_head_dim=16 if cfg.attention == "mla" else cfg.qk_nope_head_dim,
        qk_rope_head_dim=8 if cfg.attention == "mla" else cfg.qk_rope_head_dim,
        v_head_dim=16 if cfg.attention == "mla" else cfg.v_head_dim,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        window=64 if cfg.attention == "swa" else cfg.window,
        first_dense_layers=min(cfg.first_dense_layers, 1),
    )
