"""Config shim: `--arch` maps here. See lm_archs.py."""
from .lm_archs import INTERNVL2_2B as CONFIG

CONFIG = CONFIG
