"""Config shim: `--arch` maps here. See lm_archs.py."""
from .lm_archs import HYMBA_1_5B as CONFIG

CONFIG = CONFIG
