"""Config shim: `--arch` maps here. See lm_archs.py."""
from .lm_archs import QWEN2_0_5B as CONFIG

CONFIG = CONFIG
