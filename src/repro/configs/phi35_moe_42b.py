"""Config shim: `--arch` maps here. See lm_archs.py."""
from .lm_archs import PHI35_MOE as CONFIG

CONFIG = CONFIG
