"""Config shim: `--arch` maps here. See lm_archs.py."""
from .lm_archs import MUSICGEN_MEDIUM as CONFIG

CONFIG = CONFIG
