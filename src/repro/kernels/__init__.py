"""Pallas TPU kernels: <name>.py + ops.py (jit wrappers) + ref.py (oracles)."""
