"""Pallas RMSNorm — two variants reproducing the HipKittens case study.

The paper's §VI-D(b): an expert-tuned RMSNorm still left 20-58% of stall
cycles on memory because loads were compiler-lowered to scalar accesses;
LEO's diagnosis led to *multi-row software pipelining with split s_waitcnt
counters*, worth 1.07-1.24x.

TPU analogue:

* `rmsnorm_baseline` — one row-block per grid step through the implicit
  BlockSpec pipeline.  Correct, but each grid step's compute waits on its
  own block arrival (the synchronous-load pattern LEO flags as exposed
  `mem_waitcnt` stalls).
* `rmsnorm_pipelined` — rows live in ANY (HBM) memory space; the kernel
  issues explicit `make_async_copy` DMAs into a double-buffered VMEM
  scratch with one DMA semaphore per buffer — literally "split waitcnt
  counters": while block i computes, block i+1 is in flight.  LEO's jaxpr
  front-end sees the dma_start/dma_wait pairs and traces `mem_waitcnt`
  edges through them (tests/test_kernels.py::test_leo_traces_rmsnorm_dma).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# -- baseline: implicit blockspec pipeline ------------------------------------

def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) *
                  scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_baseline(x: jnp.ndarray, scale: jnp.ndarray, *,
                     eps: float = 1e-5, block_rows: int = 8,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """x (R, D); scale (D,)."""
    r, d = x.shape
    block_rows = min(block_rows, r)
    assert r % block_rows == 0
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(r // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, scale)


# -- pipelined: explicit double-buffered DMA (split waitcnt counters) ----------

def _rmsnorm_pipelined_kernel(x_hbm, scale_ref, o_ref, buf, sems, *,
                              eps: float, block_rows: int, n_blocks: int):
    i = pl.program_id(0)
    slot = jax.lax.rem(i, 2)
    next_slot = jax.lax.rem(i + 1, 2)

    @pl.when(i == 0)
    def _prime():
        pltpu.make_async_copy(
            x_hbm.at[pl.ds(0, block_rows)], buf.at[0], sems.at[0]).start()

    @pl.when(i + 1 < n_blocks)
    def _prefetch():
        pltpu.make_async_copy(
            x_hbm.at[pl.ds((i + 1) * block_rows, block_rows)],
            buf.at[next_slot], sems.at[next_slot]).start()

    pltpu.make_async_copy(
        x_hbm.at[pl.ds(i * block_rows, block_rows)], buf.at[slot],
        sems.at[slot]).wait()

    x = buf[slot].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) *
                  scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pipelined(x: jnp.ndarray, scale: jnp.ndarray, *,
                      eps: float = 1e-5, block_rows: int = 8,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """x (R, D); scale (D,) — double-buffered manual DMA variant."""
    r, d = x.shape
    block_rows = min(block_rows, r)
    assert r % block_rows == 0
    n_blocks = r // block_rows
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return pl.pallas_call(
        functools.partial(_rmsnorm_pipelined_kernel, eps=eps,
                          block_rows=block_rows, n_blocks=n_blocks),
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, block_rows, d), x.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(x, scale)
