"""Jitted public wrappers for the Pallas kernels.

`interpret=None` auto-selects: compiled Mosaic on TPU, interpret mode on CPU
(the validation path this container uses).  These are the entry points model
code calls when `attention_impl="pallas"` etc.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from .flash_attention import flash_attention
from .mlstm_scan import mlstm_chunkwise
from .rmsnorm import rmsnorm_baseline, rmsnorm_pipelined
from .slstm_scan import slstm_scan
from .ssm_scan import ssm_scan

flash_attention_op = jax.jit(
    flash_attention,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))

rmsnorm_op = jax.jit(
    rmsnorm_pipelined,
    static_argnames=("eps", "block_rows", "interpret"))

rmsnorm_baseline_op = jax.jit(
    rmsnorm_baseline,
    static_argnames=("eps", "block_rows", "interpret"))

mlstm_chunkwise_op = jax.jit(
    mlstm_chunkwise, static_argnames=("chunk", "interpret"))

ssm_scan_op = jax.jit(ssm_scan, static_argnames=("chunk", "interpret"))

slstm_scan_op = jax.jit(slstm_scan, static_argnames=("chunk", "interpret"))

__all__ = [
    "flash_attention", "flash_attention_op", "mlstm_chunkwise",
    "mlstm_chunkwise_op", "rmsnorm_baseline", "rmsnorm_baseline_op",
    "rmsnorm_pipelined", "rmsnorm_op", "slstm_scan", "slstm_scan_op",
    "ssm_scan", "ssm_scan_op",
]
