"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None) -> jnp.ndarray:
    """Naive full-matrix attention. q (B,S,H,hd), k/v (B,S,Kv,hd)."""
    b, s, h, hd = q.shape
    kv_heads = k.shape[2]
    groups = h // kv_heads
    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask = kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x, scale, *, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) *
            scale.astype(jnp.float32)).astype(x.dtype)


def mlstm_ref(q, k, v, log_i, log_f) -> jnp.ndarray:
    """Step-by-step stabilized mLSTM recurrence (exact, O(S) sequential).

    q/k/v (B,S,H,hd); gates (B,S,H) log-space pre-activations."""
    b, s, h, hd = q.shape

    def step(carry, xs):
        c, n, m = carry
        qt, kt, vt, li, lf = xs
        qt = qt.astype(jnp.float32)
        kt = kt.astype(jnp.float32)
        vt = vt.astype(jnp.float32)
        m_new = jnp.maximum(lf + m, li)
        i_w = jnp.exp(li - m_new)
        f_w = jnp.exp(lf + m - m_new)
        c = c * f_w[..., None, None] + jnp.einsum(
            "bhd,bhe,bh->bhde", kt, vt, i_w)
        n = n * f_w[..., None] + kt * i_w[..., None]
        num = jnp.einsum("bhd,bhde->bhe", qt, c)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)),
                          jnp.exp(-m_new))
        y = num / den[..., None]
        return (c, n, m_new), y

    init = (jnp.zeros((b, h, hd, hd), jnp.float32),
            jnp.zeros((b, h, hd), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32))
    xs = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0),
          jnp.moveaxis(v, 1, 0), jnp.moveaxis(log_i.astype(jnp.float32),
                                              1, 0),
          jnp.moveaxis(log_f.astype(jnp.float32), 1, 0))
    _, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(q.dtype)


def ssm_scan_ref(a, bx, c) -> jnp.ndarray:
    """Exact sequential h = a*h + bx; y = h . c.  a/bx (B,S,din,N), c (B,S,N)."""
    def step(h, xs):
        a_t, bx_t, c_t = xs
        h = a_t.astype(jnp.float32) * h + bx_t.astype(jnp.float32)
        y = jnp.einsum("bdn,bn->bd", h, c_t.astype(jnp.float32))
        return h, y

    b, s, din, n = a.shape
    h0 = jnp.zeros((b, din, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0),
                                    jnp.moveaxis(bx, 1, 0),
                                    jnp.moveaxis(c, 1, 0)))
    return jnp.moveaxis(ys, 0, 1)


def slstm_scan_ref(xg, r) -> "jnp.ndarray":
    """Exact sequential sLSTM recurrence. xg (B,S,4D); r (D,4D)."""
    b, s_len, d4 = xg.shape
    d = d4 // 4

    def step(carry, xg_t):
        c, n, h, m = carry
        g = xg_t.astype(jnp.float32) + h @ r.astype(jnp.float32)
        gi, gf = g[:, :d], g[:, d:2 * d]
        gz, go = g[:, 2 * d:3 * d], g[:, 3 * d:]
        log_f = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(log_f + m, gi)
        i_w = jnp.exp(gi - m_new)
        f_w = jnp.exp(log_f + m - m_new)
        c = f_w * c + i_w * jnp.tanh(gz)
        n = f_w * n + i_w
        h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    z = jnp.zeros((b, d), jnp.float32)
    init = (z, z, z, jnp.full((b, d), -1e30, jnp.float32))
    _, hs = jax.lax.scan(step, init, jnp.moveaxis(xg, 1, 0))
    return jnp.moveaxis(hs, 0, 1).astype(xg.dtype)
