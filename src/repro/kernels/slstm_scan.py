"""Pallas sLSTM kernel (xLSTM scalar-memory recurrence).

The sLSTM is strictly sequential in time — per step, exponential-gated
scalar state updates plus a recurrent (D x 4D) matmul on the previous
hidden state.  Unfused, every step round-trips four (B, D) states and the
backward accumulates full-sequence gradient stacks per step (the xLSTM
authors ship fused CUDA kernels for exactly this reason).  This kernel
keeps (c, n, h, m) in VMEM scratch across the chunk grid axis and the
recurrent weight resident in VMEM, so HBM traffic is the per-chunk gate
pre-activations in and hidden states out.

Grid: (batch_blocks, n_chunks); the time recurrence runs as a fori_loop
inside the kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _slstm_kernel(xg_ref, r_ref, o_ref, c_ref, n_ref, h_ref, m_ref, *,
                  chunk: int, d: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        h_ref[...] = jnp.zeros_like(h_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)

    r = r_ref[...]                                   # (D, 4D) resident

    def step(t, _):
        xg = xg_ref[0, t].astype(jnp.float32)        # (B, 4D)
        rec = jax.lax.dot(h_ref[...], r,
                          preferred_element_type=jnp.float32)
        g = xg + rec
        gi, gf = g[:, :d], g[:, d:2 * d]
        gz, go = g[:, 2 * d:3 * d], g[:, 3 * d:]
        log_f = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(log_f + m_ref[...], gi)
        i_w = jnp.exp(gi - m_new)
        f_w = jnp.exp(log_f + m_ref[...] - m_new)
        c_new = f_w * c_ref[...] + i_w * jnp.tanh(gz)
        n_new = f_w * n_ref[...] + i_w
        h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1.0)
        c_ref[...] = c_new
        n_ref[...] = n_new
        h_ref[...] = h_new
        m_ref[...] = m_new
        o_ref[0, t] = h_new.astype(o_ref.dtype)
        return ()

    jax.lax.fori_loop(0, chunk, step, ())


def slstm_scan(xg: jnp.ndarray, r: jnp.ndarray, *, chunk: int = 64,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """xg (B, S, 4D) input gate pre-activations; r (D, 4D) recurrent weights.

    Returns hidden states (B, S, D)."""
    b, s, d4 = xg.shape
    d = d4 // 4
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    return pl.pallas_call(
        functools.partial(_slstm_kernel, chunk=chunk, d=d),
        grid=(1, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, b, d4),
                         lambda bi, ci: (0, ci, 0, 0)),
            pl.BlockSpec((d, d4), lambda bi, ci: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, b, d),
                               lambda bi, ci: (0, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, s, b, d), xg.dtype),
        scratch_shapes=[
            pltpu.VMEM((b, d), jnp.float32),
            pltpu.VMEM((b, d), jnp.float32),
            pltpu.VMEM((b, d), jnp.float32),
            pltpu.VMEM((b, d), jnp.float32),
        ],
        interpret=interpret,
    )(xg.swapaxes(0, 1)[None], r)[0].swapaxes(0, 1)
