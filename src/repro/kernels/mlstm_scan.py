"""Pallas chunkwise mLSTM kernel (xLSTM matrix-memory recurrence).

The (hd x hd) matrix state C, normalizer n and stabilizer m persist in VMEM
scratch across the chunk grid axis (sequential on TPU), so the recurrent
state never round-trips HBM between chunks — the same state-residency win
flash attention gets for (m, l, acc).  Grid: (batch, head, n_chunks); one
(chunk x hd) tile of q/k/v and a (chunk,) tile of each gate per step.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, o_ref,
                  c_ref, n_ref, m_ref, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)

    q = q_ref[0, :, 0, :].astype(jnp.float32)        # (C, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    li = li_ref[0, :, 0].astype(jnp.float32)         # (C,)
    lf = lf_ref[0, :, 0].astype(jnp.float32)

    f_cum = jnp.cumsum(lf)                           # F_t
    f_tot = f_cum[-1]
    s_t = li - f_cum
    s_runmax = jax.lax.cummax(s_t, axis=0)
    m_prev = m_ref[0]
    m_u = jnp.maximum(m_prev, s_runmax) + f_cum      # (C,)

    idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    log_w = (f_cum[:, None] - f_cum[None, :] + li[None, :] - m_u[:, None])
    w = jnp.where(idx >= jdx, jnp.exp(log_w), 0.0)   # (U, T)

    qkt = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    scores = qkt * w
    intra = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    norm_intra = scores.sum(axis=1)

    d_u = jnp.exp(f_cum + m_prev - m_u)              # (C,)
    inter = jax.lax.dot_general(q, c_ref[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32) \
        * d_u[:, None]
    norm_inter = (q @ n_ref[...]) * d_u
    denom = jnp.maximum(jnp.abs(norm_inter + norm_intra), jnp.exp(-m_u))
    o_ref[0, :, 0, :] = ((inter + intra) / denom[:, None]).astype(o_ref.dtype)

    m_new = m_u[-1]
    carry_decay = jnp.exp(f_tot + m_prev - m_new)
    src_w = jnp.exp(li + (f_tot - f_cum) - m_new)    # (C,)
    c_ref[...] = c_ref[...] * carry_decay + jax.lax.dot_general(
        k * src_w[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_ref[...] = n_ref[...] * carry_decay + (k * src_w[:, None]).sum(axis=0)
    m_ref[0] = m_new


def mlstm_chunkwise(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    log_i: jnp.ndarray, log_f: jnp.ndarray, *,
                    chunk: int = 64,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q/k/v (B,S,H,hd); log_i/log_f (B,S,H) pre-activations (log-space).

    Returns the normalized hidden states (B,S,H,hd)."""
    b, s, h, hd = q.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(_mlstm_kernel, chunk=chunk)
    qkv_spec = pl.BlockSpec((1, chunk, 1, hd),
                            lambda bi, hi, ci: (bi, ci, hi, 0))
    gate_spec = pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi))
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[qkv_spec, qkv_spec, qkv_spec, gate_spec, gate_spec],
        out_specs=qkv_spec,
        out_shape=jax.ShapeDtypeStruct((b, s, h, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),
            pltpu.VMEM((hd,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, log_i, log_f)
