"""Pallas selective-scan (Mamba-style SSM) kernel.

Grid: (batch, n_chunks) with the (d_inner x N) state persistent in VMEM
scratch across chunks.  Inside a chunk the recurrence h = a*h + bx runs as
a `fori_loop` over time steps on (d_inner, N) vector tiles — d_inner is the
lane dimension (multiples of 128 for the VPU), N=16 the sublane dimension.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(a_ref, bx_ref, c_ref, o_ref, h_ref, *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, h):
        a_t = a_ref[0, t].astype(jnp.float32)       # (din, N)
        bx_t = bx_ref[0, t].astype(jnp.float32)     # (din, N)
        c_t = c_ref[0, t].astype(jnp.float32)       # (N,)
        h = a_t * h + bx_t
        y = h @ c_t                                  # (din,)
        o_ref[0, t, :] = y.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


def ssm_scan(a: jnp.ndarray, bx: jnp.ndarray, c: jnp.ndarray, *,
             chunk: int = 16,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    """a/bx (B,S,din,N) discretized recurrence terms; c (B,S,N) readout.

    Returns y (B,S,din) with y_t = C_t . h_t, h_t = a_t * h_{t-1} + bx_t."""
    b, s, din, n = a.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    term_spec = pl.BlockSpec((1, chunk, din, n), lambda bi, ci: (bi, ci, 0, 0))
    return pl.pallas_call(
        functools.partial(_ssm_kernel, chunk=chunk),
        grid=(b, nc),
        in_specs=[term_spec, term_spec,
                  pl.BlockSpec((1, chunk, n), lambda bi, ci: (bi, ci, 0))],
        out_specs=pl.BlockSpec((1, chunk, din), lambda bi, ci: (bi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, din), jnp.float32),
        scratch_shapes=[pltpu.VMEM((din, n), jnp.float32)],
        interpret=interpret,
    )(a, bx, c)
