"""Pallas TPU flash attention (causal / sliding-window, GQA-aware).

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * the grid's last axis iterates *sequentially* on a TPU core, so the
    online-softmax running state (m, l, acc) lives in VMEM scratch that
    persists across key-block grid steps — no atomics or shared-memory
    staging as on GPUs;
  * BlockSpec index maps pin one (batch, q-head) pair per outer step and
    stream (block_q x head_dim) / (block_k x head_dim) tiles through VMEM;
    GQA maps the q-head grid index onto its KV head in the index map, so
    KV tiles are fetched once per group without materializing repeats;
  * block shapes default to 128 x head_dim — MXU-aligned (128 lanes) and
    well under VMEM (128*256*4B = 128 KiB per tile);
  * causal + window skipping is structural: off-band key blocks are
    `pl.when`-skipped entirely (no masked FLOPs, unlike an S x S mask).

This kernel eliminates the HBM round-trips of the XLA chunked-softmax path
(the `acc` loop-carry traffic LEO's §Perf baseline attributes) by keeping
the running state resident in VMEM for the whole key sweep.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, n_kv: int,
                  causal: bool, window: Optional[int]):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    first_ik = 0
    if causal and window is not None:
        # lowest key block the window can reach (static bound is grid-wide;
        # dynamic skip below handles per-iq bands)
        pass

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    in_band = True
    if causal:
        in_band = ik <= iq
    if window is not None:
        wb = -(-window // block_k)  # ceil
        in_band = jnp.logical_and(in_band, ik >= iq - wb)

    @pl.when(in_band)
    def _compute():
        q = q_ref[0, :, 0, :]                       # (Bq, hd)
        k = k_ref[0, :, 0, :]                       # (Bk, hd)
        v = v_ref[0, :, 0, :]
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (Bq, Bk)
        q_pos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones_like(scores, dtype=jnp.bool_)
        if causal:
            mask = k_pos <= q_pos
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        scores = jnp.where(mask, scores, _NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, scores.max(axis=1))
        p = jnp.exp(scores - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    last_ik = iq if causal else (n_kv - 1)

    @pl.when(ik == last_ik)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / denom[:, None]).astype(
            o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q (B,S,H,hd); k/v (B,S,Kv,hd) with H % Kv == 0. Returns (B,S,H,hd)."""
    b, s, h, hd = q.shape
    kv_heads = k.shape[2]
    groups = h // kv_heads
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    n_q = s // block_q
    n_kv = s // block_k
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kv=n_kv, causal=causal, window=window)

    grid = (b, h, n_q, n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bi, hi, qi, ki, g=groups:
                         (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bi, hi, qi, ki, g=groups:
                         (bi, ki, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
