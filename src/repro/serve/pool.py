"""Pre-forked multi-process serving: N workers behind one listener.

``repro.serve`` (PR 6) funnels every parse and ``VirtualSampler`` replay
through one CPython process, so the GIL — not the hardware — bounds
diagnosis throughput.  :class:`LeoWorkerPool` removes that ceiling with
the classic pre-fork shape every production inference front-end uses:

  * **bind once, fork N** — the parent binds the listening socket, then
    forks N workers that each run the existing :class:`LeoHttpd` engine
    over the *inherited* socket; the kernel load-balances ``accept()``
    across them.  Where inheriting is unsuitable, ``mode="reuseport"``
    gives every worker its own ``SO_REUSEPORT`` socket on the same port
    (the parent keeps a bound-but-not-listening anchor so ``port=0``
    resolves once and the port stays claimed across respawns).
  * **supervision** — each worker heartbeats over a control socketpair
    (a JSON line carrying readiness, queue depth, its metrics-registry
    dump, and its service cache stats).  The parent reaps crashed
    workers and SIGKILLs hung ones (stale heartbeat), then respawns
    with a restart-storm backoff so a crash-looping worker cannot spin
    the host.
  * **rolling drain** — SIGTERM drains workers one at a time: each gets
    SIGTERM, runs the PR 6 ``begin_drain``/``drain`` machinery (in-flight
    diagnoses finish into the shared disk cache), and exits 0 before the
    next worker is told to stop — capacity falls gradually, never to
    zero until the last worker.
  * **aggregated observability** — the parent's control endpoints
    (``/metrics``, ``/stats``, ``/healthz``, ``/readyz`` on a separate
    control port) merge the per-worker registry dumps:
    counters/histograms summed, gauges labeled ``worker="k"`` (see
    :func:`repro.serve.metrics.aggregate_dumps`).

The shared ``cache_dir`` is the cross-process warm tier: a trace parsed
by worker 3 is a disk hit for workers 1..N (atomic publish + sweep
lockfile live in :mod:`repro.core.caching`).

POSIX-only (needs ``os.fork``); ``--workers 1`` never constructs a pool,
so single-worker serving stays byte-identical to PR 6.
"""
from __future__ import annotations

import json
import os
import select
import signal
import socket
import sys
import threading
import time
import traceback
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .metrics import aggregate_dumps

#: Seconds between worker heartbeats on the control socket.
HEARTBEAT_INTERVAL = 0.25
#: A worker silent this long is presumed hung and is SIGKILLed.
DEFAULT_HANG_TIMEOUT = 15.0


def respawn_delay(history: Sequence[float], now: float, *,
                  base: float = 0.5, cap: float = 5.0,
                  window: float = 30.0, free_restarts: int = 3) -> float:
    """Restart-storm backoff: the first ``free_restarts`` respawns inside
    ``window`` seconds are immediate, then the delay doubles per extra
    respawn up to ``cap``.  Pure function (unit-tested directly)."""
    recent = [t for t in history if now - t <= window]
    if len(recent) < free_restarts:
        return 0.0
    return min(cap, base * (2 ** (len(recent) - free_restarts)))


class _Worker:
    """Parent-side record of one forked worker."""

    __slots__ = ("idx", "pid", "ctrl", "buf", "last_seen", "snapshot",
                 "exit_code", "spawned_at")

    def __init__(self, idx: int, pid: int, ctrl: socket.socket,
                 now: float) -> None:
        self.idx = idx
        self.pid = pid
        self.ctrl = ctrl
        self.buf = b""
        self.last_seen = now
        self.snapshot: Optional[Dict[str, Any]] = None
        self.exit_code: Optional[int] = None
        self.spawned_at = now

    @property
    def alive(self) -> bool:
        return self.exit_code is None


class LeoWorkerPool:
    """Bind once, pre-fork N :class:`LeoHttpd` workers, supervise them.

    ``mode`` selects how workers share the port: ``"inherit"`` (default
    via ``"auto"``) forks over one parent-bound listener;
    ``"reuseport"`` gives each worker its own ``SO_REUSEPORT`` socket.
    ``control_port`` (0 = ephemeral, ``None`` = disabled) serves the
    aggregated ``/metrics`` / ``/stats`` / ``/healthz`` / ``/readyz``.
    """

    def __init__(self, workers: int = 2, host: str = "127.0.0.1",
                 port: int = 0, *, slots: int = 2, max_queue: int = 16,
                 retry_after_seconds: float = 0.25,
                 default_deadline_seconds: Optional[float] = None,
                 cache_dir: Optional[str] = None,
                 mode: str = "auto",
                 control_port: Optional[int] = 0,
                 heartbeat_interval: float = HEARTBEAT_INTERVAL,
                 hang_timeout: float = DEFAULT_HANG_TIMEOUT,
                 drain_timeout_seconds: float = 30.0,
                 respawn_backoff_base: float = 0.5,
                 respawn_backoff_cap: float = 5.0,
                 respawn_storm_window: float = 30.0,
                 respawn_free_restarts: int = 3):
        if workers < 1:
            raise ValueError(f"need >= 1 worker, got {workers}")
        if mode not in ("auto", "inherit", "reuseport"):
            raise ValueError(f"unknown pool mode {mode!r}")
        if mode == "reuseport" and not hasattr(socket, "SO_REUSEPORT"):
            raise ValueError("SO_REUSEPORT unsupported on this platform")
        if not hasattr(os, "fork"):
            raise RuntimeError("LeoWorkerPool needs os.fork (POSIX)")
        self.workers = workers
        self.host = host
        self.port = port
        self.slots = slots
        self.max_queue = max_queue
        self.retry_after_seconds = retry_after_seconds
        self.default_deadline_seconds = default_deadline_seconds
        self.cache_dir = cache_dir
        self.mode = "inherit" if mode == "auto" else mode
        self.control_port_request = control_port
        self.control_port: Optional[int] = None
        self.heartbeat_interval = heartbeat_interval
        self.hang_timeout = hang_timeout
        self.drain_timeout_seconds = drain_timeout_seconds
        self._backoff = dict(base=respawn_backoff_base,
                             cap=respawn_backoff_cap,
                             window=respawn_storm_window,
                             free_restarts=respawn_free_restarts)

        self.respawns_total = 0
        self.drain_events: List[Tuple[str, int, float]] = []
        self._respawn_times: List[float] = []
        self._pending_respawn: Dict[int, float] = {}
        self._records: Dict[int, _Worker] = {}
        self._lock = threading.Lock()
        self._draining = False
        self._stop = threading.Event()
        self._listen_sock: Optional[socket.socket] = None
        self._anchor_sock: Optional[socket.socket] = None
        self._supervisor: Optional[threading.Thread] = None
        self._control_httpd: Optional[ThreadingHTTPServer] = None
        self._control_thread: Optional[threading.Thread] = None
        self._started = False
        self._drained = False

    # -- socket setup ----------------------------------------------------------

    def _bind(self) -> None:
        if self.mode == "inherit":
            sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            sock.bind((self.host, self.port))
            sock.listen(128)
            self._listen_sock = sock
            self.port = sock.getsockname()[1]
        else:
            # Anchor: bound but NOT listening, so it claims the port
            # (and resolves port=0) without stealing connections from
            # the workers' listening SO_REUSEPORT sockets.
            sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEPORT, 1)
            sock.bind((self.host, self.port))
            self._anchor_sock = sock
            self.port = sock.getsockname()[1]

    def _worker_listener(self) -> socket.socket:
        """The socket a worker serves on (called in the child)."""
        if self.mode == "inherit":
            assert self._listen_sock is not None
            return self._listen_sock
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEPORT, 1)
        sock.bind((self.host, self.port))
        sock.listen(128)
        return sock

    # -- lifecycle -------------------------------------------------------------

    def start(self) -> "LeoWorkerPool":
        if self._started:
            raise RuntimeError("pool already started")
        self._started = True
        # Import the whole worker stack BEFORE the first fork: the heavy
        # modules (repro.launch pulls jax in) load once in the parent and
        # are shared copy-on-write by every worker, making respawns cheap.
        from . import httpd as _httpd                      # noqa: F401
        from ..core import service as _service             # noqa: F401
        from ..launch import analysis_server as _engine    # noqa: F401
        self._bind()
        now = time.monotonic()
        for idx in range(self.workers):
            self._spawn(idx, now)
        self._supervisor = threading.Thread(
            target=self._supervise, daemon=True, name="leo-pool-supervisor")
        self._supervisor.start()
        if self.control_port_request is not None:
            self._start_control_httpd()
        return self

    def _spawn(self, idx: int, now: float) -> None:
        parent_sock, child_sock = socket.socketpair()
        sys.stdout.flush()
        sys.stderr.flush()
        pid = os.fork()
        if pid == 0:
            # ---- child ----
            try:
                parent_sock.close()
                # Drop inherited fds that belong to the parent or to
                # sibling workers: their control sockets (else a dead
                # sibling never EOFs for the parent) and the parent's
                # control HTTP listener.
                for rec in list(self._records.values()):
                    try:
                        rec.ctrl.close()
                    except OSError:
                        pass
                if self._control_httpd is not None:
                    try:
                        self._control_httpd.socket.close()
                    except OSError:
                        pass
                if self._anchor_sock is not None:
                    try:
                        self._anchor_sock.close()
                    except OSError:
                        pass
                self._worker_main(idx, child_sock)
            except BaseException:       # noqa: BLE001 - last-resort report
                traceback.print_exc()
                sys.stderr.flush()
            finally:
                os._exit(2)             # only reached on crash
        # ---- parent ----
        child_sock.close()
        with self._lock:
            self._records[idx] = _Worker(idx, pid, parent_sock, now)

    # -- the worker process ----------------------------------------------------

    def _worker_main(self, idx: int, ctrl: socket.socket) -> None:
        stop = threading.Event()
        signal.signal(signal.SIGTERM, lambda *_: stop.set())
        # Parent coordinates the rolling drain; a tty Ctrl-C (SIGINT to
        # the whole foreground group) must not make every worker drain
        # at once.
        signal.signal(signal.SIGINT, signal.SIG_IGN)

        from ..core.service import LeoService
        from .httpd import LeoHttpd
        from .metrics import MetricsRegistry

        metrics = MetricsRegistry()
        service = LeoService(cache_dir=self.cache_dir,
                             max_workers=max(self.slots, 2),
                             metrics=metrics)
        app = LeoHttpd(service=service, host=self.host, port=self.port,
                       slots=self.slots, max_queue=self.max_queue,
                       retry_after_seconds=self.retry_after_seconds,
                       default_deadline_seconds=self.default_deadline_seconds,
                       metrics=metrics,
                       listen_socket=self._worker_listener())
        app.start()

        def snapshot(**extra: Any) -> Dict[str, Any]:
            snap: Dict[str, Any] = {
                "worker": idx, "pid": os.getpid(),
                "ready": not app.draining,
                "queue_depth": app.engine.queue_depth,
                "in_flight": app.engine.in_flight,
                "metrics": metrics.dump(),
                "stats": service.stats_dict(),
            }
            snap.update(extra)
            return snap

        ctrl.settimeout(self.heartbeat_interval)
        orphaned = False
        while not stop.is_set():
            try:
                ctrl.sendall(json.dumps(snapshot()).encode() + b"\n")
            except OSError:
                orphaned = True         # parent is gone: drain and exit
                break
            try:
                data = ctrl.recv(4096)
                if not data:            # parent closed its end
                    orphaned = True
                    break
                # any inbound bytes are a "snapshot now" nudge; the next
                # loop iteration sends one regardless
            except socket.timeout:
                continue
            except OSError:
                orphaned = True
                break

        ok = app.drain(timeout=self.drain_timeout_seconds)
        if not ok:
            print(f"leo-pool: worker {idx} (pid {os.getpid()}) drain "
                  f"timed out with queue_depth={app.engine.queue_depth} "
                  f"in_flight={app.engine.in_flight}",
                  file=sys.stderr, flush=True)
        try:
            ctrl.sendall(json.dumps(
                snapshot(draining=True, drained=ok)).encode() + b"\n")
            ctrl.close()
        except OSError:
            pass
        os._exit(0 if (ok or orphaned) else 3)

    # -- parent-side supervision ----------------------------------------------

    def _supervise(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                live = [r for r in self._records.values()
                        if r.alive and r.ctrl is not None]
            try:
                readable, _, _ = select.select(
                    [r.ctrl for r in live], [], [], 0.1)
            except (OSError, ValueError):
                readable = []
            now = time.monotonic()
            for rec in live:
                if rec.ctrl in readable:
                    self._read_heartbeats(rec, now)
            self._reap(now)
            if not self._draining:
                self._kill_hung(now)
                self._do_pending_respawns(now)

    def _read_heartbeats(self, rec: _Worker, now: float) -> None:
        try:
            data = rec.ctrl.recv(1 << 20)
        except OSError:
            return
        if not data:
            return                      # EOF: the reaper handles exit
        rec.buf += data
        *lines, rec.buf = rec.buf.split(b"\n")
        for line in lines:
            if not line.strip():
                continue
            try:
                rec.snapshot = json.loads(line)
            except ValueError:
                continue
            rec.last_seen = now

    def _reap(self, now: float) -> None:
        with self._lock:
            records = list(self._records.values())
        for rec in records:
            if not rec.alive:
                continue
            try:
                pid, status = os.waitpid(rec.pid, os.WNOHANG)
            except ChildProcessError:
                pid, status = rec.pid, 0
            if pid == 0:
                continue
            rec.exit_code = os.waitstatus_to_exitcode(status)
            try:
                rec.ctrl.close()
            except OSError:
                pass
            self.drain_events.append(("exit", rec.idx, now))
            if not self._draining:
                print(f"leo-pool: worker {rec.idx} (pid {rec.pid}) exited "
                      f"with {rec.exit_code}; respawning",
                      file=sys.stderr, flush=True)
                delay = respawn_delay(self._respawn_times, now,
                                      **self._backoff)
                self._pending_respawn[rec.idx] = now + delay

    def _kill_hung(self, now: float) -> None:
        with self._lock:
            records = list(self._records.values())
        for rec in records:
            if not rec.alive or rec.idx in self._pending_respawn:
                continue
            if now - rec.last_seen > self.hang_timeout:
                print(f"leo-pool: worker {rec.idx} (pid {rec.pid}) silent "
                      f"for {now - rec.last_seen:.1f}s; killing",
                      file=sys.stderr, flush=True)
                try:
                    os.kill(rec.pid, signal.SIGKILL)
                except OSError:
                    pass
                # the reaper notices the exit and schedules the respawn

    def _do_pending_respawns(self, now: float) -> None:
        due = [idx for idx, t in self._pending_respawn.items() if now >= t]
        for idx in due:
            del self._pending_respawn[idx]
            self._respawn_times.append(now)
            self.respawns_total += 1
            self._spawn(idx, now)

    # -- drain -----------------------------------------------------------------

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Rolling graceful shutdown: workers are drained one at a time
        (SIGTERM -> worker ``begin_drain``/``drain`` -> exit 0) so serving
        capacity steps down instead of vanishing.  True when every worker
        exited 0 inside the timeout."""
        if self._drained:
            return True
        self._drained = True
        self._draining = True
        timeout = timeout if timeout is not None \
            else self.drain_timeout_seconds
        deadline = time.monotonic() + timeout
        clean = True
        with self._lock:
            records = [self._records[i] for i in sorted(self._records)]
        for rec in records:
            if not rec.alive:
                clean = clean and rec.exit_code == 0
                continue
            self.drain_events.append(("sigterm", rec.idx, time.monotonic()))
            try:
                os.kill(rec.pid, signal.SIGTERM)
            except OSError:
                pass
            while rec.alive and time.monotonic() < deadline:
                time.sleep(0.02)
            if rec.alive:               # over deadline: stop waiting nicely
                clean = False
                print(f"leo-pool: worker {rec.idx} (pid {rec.pid}) missed "
                      f"the drain deadline; killing", file=sys.stderr,
                      flush=True)
                try:
                    os.kill(rec.pid, signal.SIGKILL)
                except OSError:
                    pass
                t0 = time.monotonic()
                while rec.alive and time.monotonic() - t0 < 5.0:
                    time.sleep(0.02)
            else:
                if rec.exit_code != 0:
                    print(f"leo-pool: worker {rec.idx} exited "
                          f"{rec.exit_code} during drain (3 = worker-side "
                          f"drain timeout)", file=sys.stderr, flush=True)
                clean = clean and rec.exit_code == 0
        self._stop.set()
        if self._supervisor is not None:
            self._supervisor.join(timeout=5.0)
            self._supervisor = None
        if self._control_httpd is not None:
            self._control_httpd.shutdown()
            self._control_httpd.server_close()
            if self._control_thread is not None:
                self._control_thread.join(timeout=5.0)
        for sock in (self._listen_sock, self._anchor_sock):
            if sock is not None:
                try:
                    sock.close()
                except OSError:
                    pass
        with self._lock:
            for rec in self._records.values():
                try:
                    rec.ctrl.close()
                except OSError:
                    pass
        return clean

    def __enter__(self) -> "LeoWorkerPool":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.drain()

    # -- introspection (and the control endpoints' data) -----------------------

    @property
    def worker_pids(self) -> Dict[int, int]:
        with self._lock:
            return {idx: rec.pid for idx, rec in self._records.items()
                    if rec.alive}

    @property
    def healthy(self) -> bool:
        with self._lock:
            return any(rec.alive for rec in self._records.values())

    @property
    def ready(self) -> bool:
        if self._draining:
            return False
        with self._lock:
            return any(rec.alive and rec.snapshot is not None
                       and rec.snapshot.get("ready")
                       for rec in self._records.values())

    def wait_ready(self, timeout: float = 30.0) -> bool:
        """True once every worker slot is live and has reported ready."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                recs = list(self._records.values())
            if len(recs) == self.workers and all(
                    r.alive and r.snapshot is not None
                    and r.snapshot.get("ready") for r in recs):
                return True
            time.sleep(0.02)
        return False

    def worker_snapshots(self) -> Dict[int, Dict[str, Any]]:
        with self._lock:
            return {idx: dict(rec.snapshot) for idx, rec in
                    self._records.items() if rec.snapshot is not None}

    def aggregate_metrics_text(self) -> str:
        """The fleet-wide ``/metrics`` page: per-worker registry dumps
        merged (counters/histograms summed, gauges ``worker="k"``), plus
        the pool's own supervision gauges."""
        snaps = self.worker_snapshots()
        text = aggregate_dumps({str(idx): snap["metrics"]
                                for idx, snap in snaps.items()
                                if "metrics" in snap})
        pool_lines = [
            "# HELP leo_pool_workers Configured worker count",
            "# TYPE leo_pool_workers gauge",
            f"leo_pool_workers {self.workers}",
            "# HELP leo_pool_alive_workers Live worker processes",
            "# TYPE leo_pool_alive_workers gauge",
            f"leo_pool_alive_workers {len(self.worker_pids)}",
            "# HELP leo_pool_respawns_total Workers respawned after a "
            "crash or hang",
            "# TYPE leo_pool_respawns_total counter",
            f"leo_pool_respawns_total {self.respawns_total}",
            "# HELP leo_pool_ready 1 while admitting, 0 while draining",
            "# TYPE leo_pool_ready gauge",
            f"leo_pool_ready {0 if self._draining else 1}",
        ]
        return text + "\n".join(pool_lines) + "\n"

    def stats_snapshot(self) -> Dict[str, Any]:
        now = time.monotonic()
        with self._lock:
            workers = {
                str(idx): {
                    "pid": rec.pid,
                    "alive": rec.alive,
                    "exit_code": rec.exit_code,
                    "heartbeat_age_seconds": round(now - rec.last_seen, 3),
                    "ready": bool(rec.snapshot and rec.snapshot.get("ready")),
                    "stats": (rec.snapshot or {}).get("stats"),
                }
                for idx, rec in self._records.items()
            }
        return {"workers": workers, "respawns_total": self.respawns_total,
                "draining": self._draining, "mode": self.mode,
                "port": self.port}

    # -- the parent's control HTTP endpoints -----------------------------------

    def _start_control_httpd(self) -> None:
        pool = self

        class _ControlHandler(BaseHTTPRequestHandler):
            server_version = "leo-pool/1"
            protocol_version = "HTTP/1.1"

            def log_message(self, format: str, *args: Any) -> None:
                pass

            def _send(self, status: int, body: bytes,
                      content_type: str) -> None:
                self.send_response(status)
                self.send_header("Content-Type", content_type)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self) -> None:
                path = self.path.split("?", 1)[0]
                if path == "/metrics":
                    self._send(200,
                               pool.aggregate_metrics_text().encode(),
                               "text/plain; version=0.0.4; charset=utf-8")
                elif path == "/stats":
                    self._send(200,
                               json.dumps(pool.stats_snapshot(),
                                          sort_keys=True).encode(),
                               "application/json")
                elif path == "/healthz":
                    if pool.healthy:
                        self._send(200, b"ok\n",
                                   "text/plain; charset=utf-8")
                    else:
                        self._send(503, b"no live workers\n",
                                   "text/plain; charset=utf-8")
                elif path == "/readyz":
                    if pool.ready:
                        self._send(200, b"ready\n",
                                   "text/plain; charset=utf-8")
                    else:
                        self._send(503, b"not ready\n",
                                   "text/plain; charset=utf-8")
                else:
                    self._send(404, b"not found\n",
                               "text/plain; charset=utf-8")

        class _ControlHttpd(ThreadingHTTPServer):
            daemon_threads = True

        self._control_httpd = _ControlHttpd(
            (self.host, self.control_port_request), _ControlHandler)
        self.control_port = self._control_httpd.server_address[1]
        self._control_thread = threading.Thread(
            target=self._control_httpd.serve_forever,
            kwargs={"poll_interval": 0.05},
            daemon=True, name="leo-pool-control")
        self._control_thread.start()

    def __repr__(self) -> str:
        return (f"LeoWorkerPool(http://{self.host}:{self.port}, "
                f"workers={self.workers}, mode={self.mode!r}, "
                f"alive={sorted(self.worker_pids)})")


def serve_pool_forever(pool: LeoWorkerPool, *,
                       install_signal_handlers: bool = True) -> bool:
    """Run until SIGTERM/SIGINT, then perform the rolling drain.  The
    entry point behind ``analysis_server --serve PORT --workers N``."""
    stop = threading.Event()
    if install_signal_handlers and \
            threading.current_thread() is threading.main_thread():
        for sig in (signal.SIGTERM, signal.SIGINT):
            signal.signal(sig, lambda *_: stop.set())
    if not pool._started:       # callers may pre-start to learn the port
        pool.start()
    try:
        while not stop.is_set():
            stop.wait(0.2)
    finally:
        return pool.drain()
