"""repro.serve — networked diagnosis serving.

The stdlib-only network layer over the analysis stack:

  * :mod:`repro.serve.protocol` — versioned JSON wire format with
    Diagnosis schema negotiation (v1–v3 migration across the wire);
  * :mod:`repro.serve.httpd` — backpressure-aware HTTP front-end
    (bounded admission, 429 + Retry-After shedding, per-request
    deadlines, graceful SIGTERM drain);
  * :mod:`repro.serve.client` — retrying ``LeoClient`` with capped
    jittered backoff, a pipelined ``diagnose_batch``, and client-side
    load balancing across replicas (``endpoints=[...]``:
    power-of-two-choices over an EWMA of observed queue wait, ejection
    with half-open probing);
  * :mod:`repro.serve.metrics` — counter/gauge/histogram registry with
    a Prometheus-text ``/metrics`` renderer and cross-worker
    aggregation (:func:`~repro.serve.metrics.aggregate_dumps`);
  * :mod:`repro.serve.pool` — pre-forked multi-process serving
    (``LeoWorkerPool``: bind once, fork N workers, supervise/respawn,
    rolling SIGTERM drain, aggregated control endpoints).

This module stays import-light: ``repro.serve`` pulls no accelerator
dependencies (the slot engine under ``repro.launch`` is imported lazily
by the front-end at construction time).
"""
from .client import LeoClient, LeoClientError, RetriesExceeded
from .httpd import LeoHttpd, serve_forever
from .metrics import (
    LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    aggregate_dumps,
)
from .pool import LeoWorkerPool, serve_pool_forever
from .protocol import (
    ERROR_CODES,
    MIN_PROTOCOL_VERSION,
    PROTOCOL_VERSION,
    ProtocolError,
    WireRequest,
    WireResponse,
    decode_request,
    decode_response,
    downgrade_diagnosis_dict,
    encode_error,
    encode_request,
    encode_result,
    negotiate_schema,
)

__all__ = [
    "LeoClient",
    "LeoClientError",
    "RetriesExceeded",
    "LeoHttpd",
    "serve_forever",
    "LeoWorkerPool",
    "serve_pool_forever",
    "LATENCY_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "aggregate_dumps",
    "ERROR_CODES",
    "MIN_PROTOCOL_VERSION",
    "PROTOCOL_VERSION",
    "ProtocolError",
    "WireRequest",
    "WireResponse",
    "decode_request",
    "decode_response",
    "downgrade_diagnosis_dict",
    "encode_error",
    "encode_request",
    "encode_result",
    "negotiate_schema",
]
