"""repro.serve — networked diagnosis serving.

The stdlib-only network layer over the analysis stack:

  * :mod:`repro.serve.protocol` — versioned JSON wire format with
    Diagnosis schema negotiation (v1–v3 migration across the wire);
  * :mod:`repro.serve.httpd` — backpressure-aware HTTP front-end
    (bounded admission, 429 + Retry-After shedding, per-request
    deadlines, graceful SIGTERM drain);
  * :mod:`repro.serve.client` — retrying ``LeoClient`` with capped
    jittered backoff and a pipelined ``diagnose_batch``;
  * :mod:`repro.serve.metrics` — counter/gauge/histogram registry with
    a Prometheus-text ``/metrics`` renderer.

This module stays import-light: ``repro.serve`` pulls no accelerator
dependencies (the slot engine under ``repro.launch`` is imported lazily
by the front-end at construction time).
"""
from .client import LeoClient, LeoClientError, RetriesExceeded
from .httpd import LeoHttpd, serve_forever
from .metrics import (
    LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from .protocol import (
    ERROR_CODES,
    MIN_PROTOCOL_VERSION,
    PROTOCOL_VERSION,
    ProtocolError,
    WireRequest,
    WireResponse,
    decode_request,
    decode_response,
    downgrade_diagnosis_dict,
    encode_error,
    encode_request,
    encode_result,
    negotiate_schema,
)

__all__ = [
    "LeoClient",
    "LeoClientError",
    "RetriesExceeded",
    "LeoHttpd",
    "serve_forever",
    "LATENCY_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ERROR_CODES",
    "MIN_PROTOCOL_VERSION",
    "PROTOCOL_VERSION",
    "ProtocolError",
    "WireRequest",
    "WireResponse",
    "decode_request",
    "decode_response",
    "downgrade_diagnosis_dict",
    "encode_error",
    "encode_request",
    "encode_result",
    "negotiate_schema",
]
