"""`LeoClient`: the retrying, pipelining HTTP client for `repro.serve`.

The consumer half of the wire protocol: typed
:class:`~repro.core.service.AnalyzeRequest` in, migrated
:class:`~repro.core.report.Diagnosis` out, with the transport behavior a
production caller needs and the core schema deliberately does not carry:

  * **timeouts** — one socket timeout for connect/read; per-request
    ``deadline_seconds`` rides the wire envelope so the *server* also
    stops working on an abandoned request;
  * **retries** — capped exponential backoff with equal jitter on 429 /
    503 / 5xx / connection errors, honoring the server's ``Retry-After``
    hint when it is larger than the computed backoff.  4xx protocol and
    validation errors never retry (they will not get better);
  * **pipelining** — ``diagnose_batch`` fans a request list over a small
    pool of persistent keep-alive connections (order-preserving);
  * **load balancing** — ``endpoints=["host:port", ...]`` spreads
    requests across replicas: each attempt picks by power-of-two-choices
    over an EWMA of the ``queue_seconds`` each endpoint reported in its
    wire ``timing``, a connection failure ejects the endpoint for a
    (doubling) cool-off, and an expired ejection admits exactly one
    half-open probe before the endpoint rejoins the rotation.  Retries
    re-pick, so a dead replica's traffic flows to the survivors;
  * **schema negotiation** — the client advertises ``accept_schema``
    (its own generation by default); older-generation responses are
    migrated forward by ``Diagnosis.from_dict`` exactly like a warm
    disk cache surviving a schema bump.

::

    with LeoClient(port=8321) as client:
        diag = client.diagnose(hlo_text, backend="tpu_v5e")
        per_vendor = client.diagnose(hlo_text, backends=["tpu_v5e",
                                                         "amd_mi300a"])
        diags = client.diagnose_batch(requests)     # pipelined

    with LeoClient(endpoints=["10.0.0.1:8321", "10.0.0.2:8321"]) as c:
        diags = c.diagnose_batch(requests)  # balanced across replicas
"""
from __future__ import annotations

import http.client
import random
import socket
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from ..core.report import SCHEMA_VERSION, Diagnosis
from ..core.service import AnalyzeRequest, DiagnoseOptions
from .protocol import (
    ProtocolError,
    WireResponse,
    decode_response,
    encode_request,
)

#: HTTP statuses worth retrying: shed (429), draining (503), transient
#: server trouble (other 5xx).
RETRYABLE_STATUSES = frozenset({429, 500, 502, 503, 504})

#: Exception classes that mean "this connection (or endpoint) is bad".
_CONN_ERRORS = (ConnectionError, socket.timeout, socket.gaierror,
                http.client.HTTPException, OSError)


class LeoClientError(Exception):
    """Terminal client-side failure (non-retryable status, or retry
    budget exhausted).  ``status``/``code`` carry the last server
    answer when there was one."""

    def __init__(self, message: str, status: Optional[int] = None,
                 code: Optional[str] = None):
        super().__init__(message)
        self.status = status
        self.code = code


class RetriesExceeded(LeoClientError):
    """Every attempt failed retryably; ``attempts`` made, ``last`` holds
    the final error."""

    def __init__(self, attempts: int, last: Exception):
        status = getattr(last, "status", None)
        code = getattr(last, "code", None)
        super().__init__(
            f"request failed after {attempts} attempt(s); last error: "
            f"{type(last).__name__}: {last}", status=status, code=code)
        self.attempts = attempts
        self.last = last


class _Endpoint:
    """Per-replica balancer state.  ``ewma_queue_seconds`` tracks the
    server-reported queue wait (None until first observation — an
    untried endpoint looks maximally attractive); ``ejected_until`` > now
    takes it out of rotation; an expired ejection admits one half-open
    probe (``probing``) before full reinstatement."""

    __slots__ = ("host", "port", "ewma_queue_seconds", "failures",
                 "ejected_until", "probing")

    def __init__(self, host: str, port: int) -> None:
        self.host = host
        self.port = port
        self.ewma_queue_seconds: Optional[float] = None
        self.failures = 0
        self.ejected_until = 0.0
        self.probing = False

    def __repr__(self) -> str:
        return (f"_Endpoint({self.host}:{self.port}, "
                f"ewma={self.ewma_queue_seconds}, "
                f"failures={self.failures})")


def _parse_endpoint(spec: Union[str, Tuple[str, int]]) -> Tuple[str, int]:
    if isinstance(spec, str):
        host, _, port = spec.rpartition(":")
        if not host or not port.isdigit():
            raise ValueError(f"endpoint {spec!r} is not 'host:port'")
        return host, int(port)
    host, port = spec
    return str(host), int(port)


class LeoClient:
    """HTTP client for one ``repro.serve`` front-end or a replica fleet.

    ``max_retries`` counts *re*-tries (0 = single attempt).  Backoff for
    attempt ``k`` is equal-jittered ``min(cap, base * 2**k)`` — half
    deterministic, half uniform-random — then raised to the server's
    ``Retry-After`` hint if that is larger.  Pass ``rng`` (any
    ``random.Random``) to make backoff and endpoint sampling
    deterministic in tests.

    ``endpoints`` (list of ``"host:port"`` strings or ``(host, port)``
    pairs) enables client-side load balancing; ``host``/``port`` remain
    the single-endpoint shorthand.  ``ewma_alpha`` weights the newest
    ``queue_seconds`` observation; ``eject_seconds`` is the base
    ejection cool-off after a connection failure (doubles per
    consecutive failure, capped at 8x).
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 8321, *,
                 endpoints: Optional[Sequence[Union[str, Tuple[str, int]]]]
                 = None,
                 timeout: float = 60.0,
                 max_retries: int = 5,
                 backoff_base_seconds: float = 0.05,
                 backoff_cap_seconds: float = 2.0,
                 accept_schema: int = SCHEMA_VERSION,
                 rng: Optional[random.Random] = None,
                 ewma_alpha: float = 0.3,
                 eject_seconds: float = 0.5):
        if endpoints:
            pairs = [_parse_endpoint(e) for e in endpoints]
        else:
            pairs = [(host, port)]
        self.endpoints: List[_Endpoint] = [_Endpoint(h, p)
                                           for h, p in pairs]
        self.host, self.port = pairs[0]     # primary, for repr/back-compat
        self.timeout = timeout
        self.max_retries = max_retries
        self.backoff_base_seconds = backoff_base_seconds
        self.backoff_cap_seconds = backoff_cap_seconds
        self.accept_schema = accept_schema
        self.ewma_alpha = ewma_alpha
        self.eject_seconds = eject_seconds
        self._rng = rng or random.Random()
        self._rng_lock = threading.Lock()
        self._lb_lock = threading.Lock()
        self._local = threading.local()     # per-thread per-endpoint conns
        # Registry of every live connection, keyed by id(conn): close()
        # must reach conns owned by *other* (possibly dead) threads —
        # thread-local storage alone cannot enumerate them.
        self._conns: Dict[int, Tuple[threading.Thread,
                                     http.client.HTTPConnection]] = {}
        self._conns_lock = threading.Lock()
        self.stats: Dict[str, int] = {
            "attempts": 0, "retries": 0, "sheds_seen": 0,
            "errors_5xx": 0, "connect_errors": 0, "deadline_hits": 0,
        }
        self._stats_lock = threading.Lock()

    # -- connection plumbing ---------------------------------------------------

    def _conn(self, idx: int) -> http.client.HTTPConnection:
        conns: Optional[Dict[int, http.client.HTTPConnection]] = \
            getattr(self._local, "conns", None)
        if conns is None:
            conns = self._local.conns = {}
        conn = conns.get(idx)
        if conn is None:
            ep = self.endpoints[idx]
            conn = http.client.HTTPConnection(ep.host, ep.port,
                                              timeout=self.timeout)
            conns[idx] = conn
        with self._conns_lock:
            # (re-)register: a close() may have emptied the registry while
            # this thread's cached conn lives on and reconnects
            self._conns.setdefault(id(conn),
                                   (threading.current_thread(), conn))
        return conn

    def _reset_conn(self, idx: int) -> None:
        conns = getattr(self._local, "conns", None)
        if not conns:
            return
        conn = conns.pop(idx, None)
        if conn is not None:
            conn.close()
            with self._conns_lock:
                self._conns.pop(id(conn), None)

    def _prune_dead(self) -> None:
        """Close and drop connections owned by threads that have exited
        (e.g. a finished ``diagnose_batch`` pool) — keep-alive sockets
        must not outlive their worker threads."""
        with self._conns_lock:
            dead = [key for key, (thread, _) in self._conns.items()
                    if not thread.is_alive()]
            closing = [self._conns.pop(key)[1] for key in dead]
        for conn in closing:
            conn.close()

    def open_connection_count(self) -> int:
        """Registered connections with a live socket (diagnostic; the
        socket-leak regression test pins this at 0 after a batch)."""
        self._prune_dead()
        with self._conns_lock:
            return sum(1 for _, conn in self._conns.values()
                       if conn.sock is not None)

    def close(self) -> None:
        """Close every registered connection — including those created
        by other (possibly already-dead) worker threads."""
        with self._conns_lock:
            conns = [conn for _, conn in self._conns.values()]
            self._conns.clear()
        for conn in conns:
            conn.close()
        local_conns = getattr(self._local, "conns", None)
        if local_conns:
            local_conns.clear()

    def __enter__(self) -> "LeoClient":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    def _bump(self, field: str, by: int = 1) -> None:
        with self._stats_lock:
            self.stats[field] += by

    # -- endpoint selection ----------------------------------------------------

    def _pick_endpoint(self, now: Optional[float] = None) -> int:
        """Power-of-two-choices over the EWMA of observed queue wait.

        Ejected endpoints are out of rotation until their cool-off
        expires; an expired ejection admits exactly one in-flight
        half-open probe.  With every endpoint dead, the least-recently
        ejected one is tried anyway (better a likely-failing attempt
        that updates state than certain failure)."""
        now = time.monotonic() if now is None else now
        with self._lb_lock:
            healthy: List[int] = []
            half_open: List[int] = []
            for i, ep in enumerate(self.endpoints):
                if ep.ejected_until <= 0.0:
                    healthy.append(i)
                elif ep.ejected_until <= now and not ep.probing:
                    half_open.append(i)
            if half_open:
                # probe first: a recovered replica should rejoin the
                # rotation as soon as its cool-off expires
                idx = half_open[0]
                self.endpoints[idx].probing = True
                return idx
            if not healthy:
                return min(range(len(self.endpoints)),
                           key=lambda i: self.endpoints[i].ejected_until)
            if len(healthy) == 1:
                return healthy[0]
            with self._rng_lock:
                a, b = self._rng.sample(healthy, 2)

            def load(i: int) -> float:
                ewma = self.endpoints[i].ewma_queue_seconds
                return ewma if ewma is not None else -1.0
            return a if load(a) <= load(b) else b

    def _observe_queue(self, idx: int, queue_seconds: float) -> None:
        with self._lb_lock:
            ep = self.endpoints[idx]
            if ep.ewma_queue_seconds is None:
                ep.ewma_queue_seconds = queue_seconds
            else:
                ep.ewma_queue_seconds = (
                    self.ewma_alpha * queue_seconds
                    + (1.0 - self.ewma_alpha) * ep.ewma_queue_seconds)

    def _note_success(self, idx: int) -> None:
        with self._lb_lock:
            ep = self.endpoints[idx]
            ep.failures = 0
            ep.ejected_until = 0.0
            ep.probing = False

    def _note_conn_failure(self, idx: int,
                           now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        with self._lb_lock:
            ep = self.endpoints[idx]
            ep.failures += 1
            ep.probing = False
            cooloff = self.eject_seconds * min(2 ** (ep.failures - 1), 8)
            ep.ejected_until = now + cooloff

    def lb_snapshot(self) -> List[Dict[str, Any]]:
        """Balancer state per endpoint (tests and debugging)."""
        now = time.monotonic()
        with self._lb_lock:
            return [{"host": ep.host, "port": ep.port,
                     "ewma_queue_seconds": ep.ewma_queue_seconds,
                     "failures": ep.failures,
                     "ejected": ep.ejected_until > now,
                     "ejected_for_seconds":
                         max(0.0, ep.ejected_until - now),
                     "probing": ep.probing}
                    for ep in self.endpoints]

    # -- raw HTTP with retry ---------------------------------------------------

    def _backoff(self, attempt: int,
                 retry_after: Optional[float]) -> float:
        ceiling = min(self.backoff_cap_seconds,
                      self.backoff_base_seconds * (2 ** attempt))
        with self._rng_lock:
            jittered = ceiling / 2 + self._rng.uniform(0, ceiling / 2)
        if retry_after is not None:
            jittered = max(jittered, retry_after)
        return jittered

    def _once(self, method: str, path: str,
              body: Optional[bytes] = None,
              idx: int = 0) -> "tuple[int, dict, bytes]":
        conn = self._conn(idx)
        headers = {"Content-Type": "application/json"} if body else {}
        try:
            conn.request(method, path, body=body, headers=headers)
            resp = conn.getresponse()
            payload = resp.read()       # drain: keep-alive stays usable
            return resp.status, dict(resp.headers.items()), payload
        except _CONN_ERRORS:
            # a broken keep-alive conn poisons every later request on
            # this thread — drop it before the retry layer reconnects
            self._reset_conn(idx)
            raise

    def _request(self, method: str, path: str,
                 body: Optional[bytes] = None
                 ) -> "tuple[int, dict, bytes, int]":
        """One logical request: up to ``1 + max_retries`` attempts with
        backoff on retryable failures.  Each attempt re-picks the
        endpoint, so retries route around ejected replicas.  Returns
        ``(status, headers, payload, endpoint_index)``."""
        last_error: Optional[Exception] = None
        for attempt in range(self.max_retries + 1):
            if attempt > 0:
                retry_after = None
                if isinstance(last_error, LeoClientError) and \
                        getattr(last_error, "retry_after", None) is not None:
                    retry_after = last_error.retry_after
                time.sleep(self._backoff(attempt - 1, retry_after))
                self._bump("retries")
            self._bump("attempts")
            idx = self._pick_endpoint()
            try:
                status, headers, payload = self._once(method, path, body,
                                                      idx)
            except _CONN_ERRORS as e:
                self._bump("connect_errors")
                self._note_conn_failure(idx)
                last_error = e
                continue
            if status in RETRYABLE_STATUSES:
                if status == 429:
                    self._bump("sheds_seen")
                elif status >= 500:
                    self._bump("errors_5xx")
                if status == 504:
                    self._bump("deadline_hits")
                err = LeoClientError(
                    f"{method} {path} -> {status}", status=status)
                retry_after = headers.get("Retry-After")
                err.retry_after = float(retry_after) \
                    if retry_after is not None else None   # type: ignore
                # the endpoint answered (it is alive — no ejection), but
                # a shed means its queue is deep: fold the Retry-After
                # hint into the EWMA so the balancer steers elsewhere
                if status == 429:
                    self._observe_queue(
                        idx, err.retry_after                # type: ignore
                        if err.retry_after is not None      # type: ignore
                        else self.retry_penalty_seconds)
                self._note_success(idx)     # connectivity-wise healthy
                last_error = err
                continue
            if status >= 400:
                # non-retryable (4xx): surface the typed error envelope
                # when the server sent one — the caller gets the machine
                # code, not a stringly wrapper
                self._note_success(idx)
                try:
                    decode_response(payload).result()
                except ProtocolError:
                    raise
                except Exception:   # noqa: BLE001 - not an envelope
                    pass
                raise LeoClientError(
                    f"{method} {path} -> {status}: "
                    f"{payload[:200].decode('utf-8', 'replace')}",
                    status=status)
            self._note_success(idx)
            return status, headers, payload, idx
        raise RetriesExceeded(self.max_retries + 1, last_error)

    #: EWMA penalty charged for a 429 without a Retry-After hint.
    retry_penalty_seconds = 0.25

    # -- typed surface ---------------------------------------------------------

    def submit(self, request: AnalyzeRequest, *,
               deadline_seconds: Optional[float] = None
               ) -> Union[Diagnosis, Dict[str, Diagnosis]]:
        """Serve one typed request over the wire: a ``Diagnosis``, or a
        ``{backend: Diagnosis}`` map for fan-out requests — the same
        contract as ``LeoService.submit`` in-process."""
        resp = self.submit_wire(request, deadline_seconds=deadline_seconds)
        return resp.result()

    def submit_wire(self, request: AnalyzeRequest, *,
                    deadline_seconds: Optional[float] = None
                    ) -> WireResponse:
        """Like :meth:`submit` but returns the decoded envelope — for
        callers that want the negotiated ``schema_version`` and server
        ``timing`` alongside the payload."""
        body = encode_request(request, accept_schema=self.accept_schema,
                              deadline_seconds=deadline_seconds)
        _, _, payload, idx = self._request("POST", "/v1/analyze", body)
        resp = decode_response(payload)
        timing = getattr(resp, "timing", None) or {}
        queue_seconds = timing.get("queue_seconds")
        if isinstance(queue_seconds, (int, float)):
            self._observe_queue(idx, float(queue_seconds))
        return resp

    def diagnose(self, hlo_text: str, *,
                 backend: Optional[str] = None,
                 backends: Optional[Sequence[str]] = None,
                 hints: Optional[Dict[str, Any]] = None,
                 options: Optional[DiagnoseOptions] = None,
                 n_chains: Optional[int] = None,
                 prune_unexecuted: Optional[bool] = None,
                 advise: Optional[bool] = None,
                 rewrite: Optional[bool] = None,
                 occupancy: Optional[bool] = None,
                 deadline_seconds: Optional[float] = None
                 ) -> Union[Diagnosis, Dict[str, Diagnosis]]:
        """One-call diagnosis over the wire.  Analysis knobs ride a typed
        ``options=DiagnoseOptions(...)`` (the flat keywords remain as
        warn-once deprecation shims), mirroring ``LeoService.diagnose``."""
        opts = DiagnoseOptions.coalesce(
            options, "LeoClient.diagnose", n_chains=n_chains,
            prune_unexecuted=prune_unexecuted, advise=advise,
            rewrite=rewrite, occupancy=occupancy)
        return self.submit(AnalyzeRequest(
            hlo_text=hlo_text, backend=backend,
            backends=list(backends) if backends is not None else None,
            hints=hints, options=opts),
            deadline_seconds=deadline_seconds)

    def diagnose_batch(self, requests: Sequence[AnalyzeRequest], *,
                       max_connections: int = 4,
                       deadline_seconds: Optional[float] = None
                       ) -> List[Union[Diagnosis, Dict[str, Diagnosis]]]:
        """Pipeline a batch over up to ``max_connections`` persistent
        connections (one per worker thread), balanced across endpoints;
        order-preserving — ``results[i]`` answers ``requests[i]`` no
        matter which replica served it.  The first terminal failure
        propagates after the batch settles.  The pool threads' keep-alive
        connections are closed when the batch finishes (no socket
        leaks)."""
        requests = list(requests)
        if len(requests) <= 1:
            return [self.submit(r, deadline_seconds=deadline_seconds)
                    for r in requests]
        try:
            with ThreadPoolExecutor(
                    max_workers=min(max_connections, len(requests)),
                    thread_name_prefix="leo-client") as pool:
                futs = [pool.submit(self.submit, r,
                                    deadline_seconds=deadline_seconds)
                        for r in requests]
                return [f.result() for f in futs]
        finally:
            self._prune_dead()

    # -- health / telemetry ----------------------------------------------------

    def healthz(self) -> bool:
        status, _, _, _ = self._request("GET", "/healthz")
        return status == 200

    def readyz(self) -> bool:
        """True when at least one endpoint is admitting.  Unlike other
        calls, a 503 here is an *answer*, not a failure — no retries
        burned, no ejection bookkeeping."""
        for idx in range(len(self.endpoints)):
            try:
                status, _, _ = self._once("GET", "/readyz", idx=idx)
            except _CONN_ERRORS:
                continue
            if status == 200:
                return True
        return False

    def metrics_text(self) -> str:
        _, _, payload, _ = self._request("GET", "/metrics")
        return payload.decode("utf-8")

    def server_stats(self) -> Dict[str, Any]:
        import json
        _, _, payload, _ = self._request("GET", "/stats")
        return json.loads(payload)

    def wait_ready(self, timeout: float = 10.0,
                   poll_seconds: float = 0.05) -> bool:
        """Poll ``/readyz`` until the server admits (fresh processes
        take a moment to bind + warm); True when it did."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.readyz():
                return True
            time.sleep(poll_seconds)
        return False

    def __repr__(self) -> str:
        targets = ",".join(f"{ep.host}:{ep.port}" for ep in self.endpoints)
        return (f"LeoClient(http://{targets}, "
                f"retries={self.max_retries}, stats={self.stats})")
