"""`LeoClient`: the retrying, pipelining HTTP client for `repro.serve`.

The consumer half of the wire protocol: typed
:class:`~repro.core.service.AnalyzeRequest` in, migrated
:class:`~repro.core.report.Diagnosis` out, with the transport behavior a
production caller needs and the core schema deliberately does not carry:

  * **timeouts** — one socket timeout for connect/read; per-request
    ``deadline_seconds`` rides the wire envelope so the *server* also
    stops working on an abandoned request;
  * **retries** — capped exponential backoff with equal jitter on 429 /
    503 / 5xx / connection errors, honoring the server's ``Retry-After``
    hint when it is larger than the computed backoff.  4xx protocol and
    validation errors never retry (they will not get better);
  * **pipelining** — ``diagnose_batch`` fans a request list over a small
    pool of persistent keep-alive connections (order-preserving);
  * **schema negotiation** — the client advertises ``accept_schema``
    (its own generation by default); older-generation responses are
    migrated forward by ``Diagnosis.from_dict`` exactly like a warm
    disk cache surviving a schema bump.

::

    with LeoClient(port=8321) as client:
        diag = client.diagnose(hlo_text, backend="tpu_v5e")
        per_vendor = client.diagnose(hlo_text, backends=["tpu_v5e",
                                                         "amd_mi300a"])
        diags = client.diagnose_batch(requests)     # pipelined
"""
from __future__ import annotations

import http.client
import random
import socket
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Union

from ..core.report import SCHEMA_VERSION, Diagnosis
from ..core.service import AnalyzeRequest, DiagnoseOptions
from .protocol import (
    ProtocolError,
    WireResponse,
    decode_response,
    encode_request,
)

#: HTTP statuses worth retrying: shed (429), draining (503), transient
#: server trouble (other 5xx).
RETRYABLE_STATUSES = frozenset({429, 500, 502, 503, 504})


class LeoClientError(Exception):
    """Terminal client-side failure (non-retryable status, or retry
    budget exhausted).  ``status``/``code`` carry the last server
    answer when there was one."""

    def __init__(self, message: str, status: Optional[int] = None,
                 code: Optional[str] = None):
        super().__init__(message)
        self.status = status
        self.code = code


class RetriesExceeded(LeoClientError):
    """Every attempt failed retryably; ``attempts`` made, ``last`` holds
    the final error."""

    def __init__(self, attempts: int, last: Exception):
        status = getattr(last, "status", None)
        code = getattr(last, "code", None)
        super().__init__(
            f"request failed after {attempts} attempt(s); last error: "
            f"{type(last).__name__}: {last}", status=status, code=code)
        self.attempts = attempts
        self.last = last


class LeoClient:
    """HTTP client for a live ``repro.serve`` front-end.

    ``max_retries`` counts *re*-tries (0 = single attempt).  Backoff for
    attempt ``k`` is equal-jittered ``min(cap, base * 2**k)`` — half
    deterministic, half uniform-random — then raised to the server's
    ``Retry-After`` hint if that is larger.  Pass ``rng`` (any
    ``random.Random``) to make backoff deterministic in tests.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 8321, *,
                 timeout: float = 60.0,
                 max_retries: int = 5,
                 backoff_base_seconds: float = 0.05,
                 backoff_cap_seconds: float = 2.0,
                 accept_schema: int = SCHEMA_VERSION,
                 rng: Optional[random.Random] = None):
        self.host = host
        self.port = port
        self.timeout = timeout
        self.max_retries = max_retries
        self.backoff_base_seconds = backoff_base_seconds
        self.backoff_cap_seconds = backoff_cap_seconds
        self.accept_schema = accept_schema
        self._rng = rng or random.Random()
        self._rng_lock = threading.Lock()
        self._local = threading.local()     # one persistent conn per thread
        self._conns: List[http.client.HTTPConnection] = []
        self._conns_lock = threading.Lock()
        self.stats: Dict[str, int] = {
            "attempts": 0, "retries": 0, "sheds_seen": 0,
            "errors_5xx": 0, "connect_errors": 0, "deadline_hits": 0,
        }
        self._stats_lock = threading.Lock()

    # -- connection plumbing ---------------------------------------------------

    def _conn(self) -> http.client.HTTPConnection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = http.client.HTTPConnection(self.host, self.port,
                                              timeout=self.timeout)
            self._local.conn = conn
            with self._conns_lock:
                self._conns.append(conn)
        return conn

    def _reset_conn(self) -> None:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            conn.close()

    def close(self) -> None:
        with self._conns_lock:
            conns, self._conns = self._conns, []
        for conn in conns:
            conn.close()

    def __enter__(self) -> "LeoClient":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    def _bump(self, field: str, by: int = 1) -> None:
        with self._stats_lock:
            self.stats[field] += by

    # -- raw HTTP with retry ---------------------------------------------------

    def _backoff(self, attempt: int,
                 retry_after: Optional[float]) -> float:
        ceiling = min(self.backoff_cap_seconds,
                      self.backoff_base_seconds * (2 ** attempt))
        with self._rng_lock:
            jittered = ceiling / 2 + self._rng.uniform(0, ceiling / 2)
        if retry_after is not None:
            jittered = max(jittered, retry_after)
        return jittered

    def _once(self, method: str, path: str,
              body: Optional[bytes] = None) -> "tuple[int, dict, bytes]":
        conn = self._conn()
        headers = {"Content-Type": "application/json"} if body else {}
        try:
            conn.request(method, path, body=body, headers=headers)
            resp = conn.getresponse()
            payload = resp.read()       # drain: keep-alive stays usable
            return resp.status, dict(resp.headers.items()), payload
        except (ConnectionError, socket.timeout, socket.gaierror,
                http.client.HTTPException, OSError):
            # a broken keep-alive conn poisons every later request on
            # this thread — drop it before the retry layer reconnects
            self._reset_conn()
            self._local.conn = None
            raise

    def _request(self, method: str, path: str,
                 body: Optional[bytes] = None) -> "tuple[int, dict, bytes]":
        """One logical request: up to ``1 + max_retries`` attempts with
        backoff on retryable failures."""
        last_error: Optional[Exception] = None
        for attempt in range(self.max_retries + 1):
            if attempt > 0:
                retry_after = None
                if isinstance(last_error, LeoClientError) and \
                        getattr(last_error, "retry_after", None) is not None:
                    retry_after = last_error.retry_after
                time.sleep(self._backoff(attempt - 1, retry_after))
                self._bump("retries")
            self._bump("attempts")
            try:
                status, headers, payload = self._once(method, path, body)
            except (ConnectionError, socket.timeout, socket.gaierror,
                    http.client.HTTPException, OSError) as e:
                self._bump("connect_errors")
                last_error = e
                continue
            if status in RETRYABLE_STATUSES:
                if status == 429:
                    self._bump("sheds_seen")
                elif status >= 500:
                    self._bump("errors_5xx")
                if status == 504:
                    self._bump("deadline_hits")
                err = LeoClientError(
                    f"{method} {path} -> {status}", status=status)
                retry_after = headers.get("Retry-After")
                err.retry_after = float(retry_after) \
                    if retry_after is not None else None   # type: ignore
                last_error = err
                continue
            if status >= 400:
                # non-retryable (4xx): surface the typed error envelope
                # when the server sent one — the caller gets the machine
                # code, not a stringly wrapper
                try:
                    decode_response(payload).result()
                except ProtocolError:
                    raise
                except Exception:   # noqa: BLE001 - not an envelope
                    pass
                raise LeoClientError(
                    f"{method} {path} -> {status}: "
                    f"{payload[:200].decode('utf-8', 'replace')}",
                    status=status)
            return status, headers, payload
        raise RetriesExceeded(self.max_retries + 1, last_error)

    # -- typed surface ---------------------------------------------------------

    def submit(self, request: AnalyzeRequest, *,
               deadline_seconds: Optional[float] = None
               ) -> Union[Diagnosis, Dict[str, Diagnosis]]:
        """Serve one typed request over the wire: a ``Diagnosis``, or a
        ``{backend: Diagnosis}`` map for fan-out requests — the same
        contract as ``LeoService.submit`` in-process."""
        resp = self.submit_wire(request, deadline_seconds=deadline_seconds)
        return resp.result()

    def submit_wire(self, request: AnalyzeRequest, *,
                    deadline_seconds: Optional[float] = None
                    ) -> WireResponse:
        """Like :meth:`submit` but returns the decoded envelope — for
        callers that want the negotiated ``schema_version`` and server
        ``timing`` alongside the payload."""
        body = encode_request(request, accept_schema=self.accept_schema,
                              deadline_seconds=deadline_seconds)
        _, _, payload = self._request("POST", "/v1/analyze", body)
        return decode_response(payload)

    def diagnose(self, hlo_text: str, *,
                 backend: Optional[str] = None,
                 backends: Optional[Sequence[str]] = None,
                 hints: Optional[Dict[str, Any]] = None,
                 options: Optional[DiagnoseOptions] = None,
                 n_chains: Optional[int] = None,
                 prune_unexecuted: Optional[bool] = None,
                 advise: Optional[bool] = None,
                 rewrite: Optional[bool] = None,
                 occupancy: Optional[bool] = None,
                 deadline_seconds: Optional[float] = None
                 ) -> Union[Diagnosis, Dict[str, Diagnosis]]:
        """One-call diagnosis over the wire.  Analysis knobs ride a typed
        ``options=DiagnoseOptions(...)`` (the flat keywords remain as
        warn-once deprecation shims), mirroring ``LeoService.diagnose``."""
        opts = DiagnoseOptions.coalesce(
            options, "LeoClient.diagnose", n_chains=n_chains,
            prune_unexecuted=prune_unexecuted, advise=advise,
            rewrite=rewrite, occupancy=occupancy)
        return self.submit(AnalyzeRequest(
            hlo_text=hlo_text, backend=backend,
            backends=list(backends) if backends is not None else None,
            hints=hints, options=opts),
            deadline_seconds=deadline_seconds)

    def diagnose_batch(self, requests: Sequence[AnalyzeRequest], *,
                       max_connections: int = 4,
                       deadline_seconds: Optional[float] = None
                       ) -> List[Union[Diagnosis, Dict[str, Diagnosis]]]:
        """Pipeline a batch over up to ``max_connections`` persistent
        connections (one per worker thread); order-preserving.  The
        first terminal failure propagates after the batch settles."""
        requests = list(requests)
        if len(requests) <= 1:
            return [self.submit(r, deadline_seconds=deadline_seconds)
                    for r in requests]
        with ThreadPoolExecutor(
                max_workers=min(max_connections, len(requests)),
                thread_name_prefix="leo-client") as pool:
            futs = [pool.submit(self.submit, r,
                                deadline_seconds=deadline_seconds)
                    for r in requests]
            return [f.result() for f in futs]

    # -- health / telemetry ----------------------------------------------------

    def healthz(self) -> bool:
        status, _, _ = self._request("GET", "/healthz")
        return status == 200

    def readyz(self) -> bool:
        """True when the server is admitting.  Unlike other calls, a
        503 here is an *answer*, not a failure — no retries burned."""
        try:
            status, _, _ = self._once("GET", "/readyz")
        except (ConnectionError, socket.timeout,
                http.client.HTTPException, OSError):
            return False
        return status == 200

    def metrics_text(self) -> str:
        _, _, payload = self._request("GET", "/metrics")
        return payload.decode("utf-8")

    def server_stats(self) -> Dict[str, Any]:
        import json
        _, _, payload = self._request("GET", "/stats")
        return json.loads(payload)

    def wait_ready(self, timeout: float = 10.0,
                   poll_seconds: float = 0.05) -> bool:
        """Poll ``/readyz`` until the server admits (fresh processes
        take a moment to bind + warm); True when it did."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.readyz():
                return True
            time.sleep(poll_seconds)
        return False

    def __repr__(self) -> str:
        return (f"LeoClient(http://{self.host}:{self.port}, "
                f"retries={self.max_retries}, stats={self.stats})")
