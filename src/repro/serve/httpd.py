"""Backpressure-aware HTTP front-end over the slot-based analysis engine.

A stdlib :class:`~http.server.ThreadingHTTPServer` wrapping
:class:`~repro.launch.analysis_server.AnalysisServer`: handler threads
admit requests into the engine's **bounded queue** and block in
``engine.wait`` while a background ticker drives the slots.  The serving
semantics a front-end owes its callers:

  * **load shedding** — a full admission queue answers 429 with a
    ``Retry-After`` hint instead of buffering unboundedly; a draining
    server answers 503.  Both carry the machine-readable error envelope
    from :mod:`repro.serve.protocol`.
  * **deadlines** — a request's ``deadline_seconds`` (or the server
    default) bounds its total time in the system.  Overdue-in-queue
    requests are cancelled without ever occupying a slot; overdue
    in-flight requests are *abandoned* (504 to the caller; the analysis
    finishes into the warm cache, so the retry is cheap).
  * **health** — ``GET /healthz`` (process liveness, always 200) vs
    ``GET /readyz`` (admission readiness: 503 while draining).
  * **telemetry** — ``GET /metrics`` renders the shared
    :class:`~repro.serve.metrics.MetricsRegistry` in Prometheus text
    format; ``GET /stats`` dumps the service cache counters as JSON.
  * **graceful drain** — SIGTERM (via :func:`serve_forever`) or
    :meth:`LeoHttpd.drain`: stop admitting, finish in-flight analyses,
    flush the disk cache, then stop listening.

Endpoints: ``POST /v1/analyze`` (single or fan-out, per the request),
``GET /healthz`` | ``/readyz`` | ``/metrics`` | ``/stats``.

::

    app = LeoHttpd(service=LeoService(cache_dir=".leo_cache"), port=0)
    app.start()                      # app.port is the bound port
    ...
    app.drain()                      # or serve_forever(app) + SIGTERM
"""
from __future__ import annotations

import json
import signal
import socket
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional

from ..core.service import LeoService
from .metrics import MetricsRegistry
from .protocol import (
    ProtocolError,
    decode_request,
    encode_error,
    encode_result,
)


class _Httpd(ThreadingHTTPServer):
    """ThreadingHTTPServer that can adopt an already-listening socket.

    The pre-fork pool (:mod:`repro.serve.pool`) binds the listener once
    in the parent and hands the inherited socket to each forked worker;
    ``listen_socket`` skips bind/listen and serves on the given socket
    instead.  Without it the behavior is byte-identical to PR 6.
    """

    daemon_threads = True
    app: "LeoHttpd"                     # set by LeoHttpd.__init__

    def __init__(self, server_address: Any, handler_class: Any,
                 listen_socket: Optional[socket.socket] = None):
        if listen_socket is None:
            super().__init__(server_address, handler_class)
            return
        super().__init__(server_address, handler_class,
                         bind_and_activate=False)
        self.socket.close()             # drop the unused placeholder
        self.socket = listen_socket
        # N workers share one listener, so a select() wakeup is only a
        # hint: a sibling may win the accept() race, and a blocking
        # accept would then wedge serve_forever past shutdown().  Non-
        # blocking turns the lost race into a BlockingIOError, which
        # _handle_request_noblock() already treats as "nothing to do".
        listen_socket.setblocking(False)
        # What server_bind()/server_activate() would have set, minus the
        # reverse-DNS lookup (socket.getfqdn) — a forked worker must not
        # stall on a resolver during spawn.
        self.server_address = listen_socket.getsockname()
        host, port = self.server_address[:2]
        self.server_name = host
        self.server_port = port


class LeoHttpd:
    """The networked diagnosis server: HTTP admission over engine slots.

    ``slots`` bounds concurrent analyses, ``max_queue`` bounds waiting
    admissions — together the whole memory footprint of pending work.
    ``metrics`` (shared with the :class:`LeoService` for the cache/
    latency instruments) feeds ``/metrics``.
    """

    def __init__(self, service: Optional[LeoService] = None,
                 engine: Optional[Any] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 slots: int = 4, max_queue: int = 16,
                 retry_after_seconds: float = 0.25,
                 default_deadline_seconds: Optional[float] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 drain_timeout_seconds: Optional[float] = 30.0,
                 listen_socket: Optional[Any] = None):
        # imported here, not at module top: repro.launch pulls jax in via
        # its package __init__, and repro.serve stays stdlib-light until
        # a server is actually constructed
        from ..launch.analysis_server import AnalysisServer
        self.metrics = metrics or MetricsRegistry()
        if service is None:
            service = LeoService(max_workers=max(slots, 2),
                                 metrics=self.metrics)
        self.service = service
        self.engine = engine or AnalysisServer(service, slots=slots,
                                               max_queue=max_queue)
        self.retry_after_seconds = retry_after_seconds
        self.default_deadline_seconds = default_deadline_seconds
        self.drain_timeout_seconds = drain_timeout_seconds
        self._drained = threading.Event()
        self._serve_thread: Optional[threading.Thread] = None

        m = self.metrics
        self.m_requests = m.counter(
            "leo_requests_total", "HTTP requests served, by endpoint and "
            "status code", labelnames=("endpoint", "code"))
        self.m_admissions = m.counter(
            "leo_admissions_total", "Requests admitted into the engine "
            "queue")
        self.m_sheds = m.counter(
            "leo_sheds_total", "Requests shed with 429 (admission queue "
            "full)")
        self.m_deadline = m.counter(
            "leo_deadline_exceeded_total", "Requests that missed their "
            "deadline (cancelled in queue or abandoned in flight)")
        self.m_queue_seconds = m.histogram(
            "leo_queue_seconds", "Queue wait per served request "
            "(submit to slot admission)")
        self.m_service_seconds = m.histogram(
            "leo_service_seconds", "Service time per served request "
            "(slot admission to completion)")
        m.gauge("leo_queue_depth", "Requests waiting for a slot right "
                "now").set_function(lambda: self.engine.queue_depth)
        m.gauge("leo_inflight_requests", "Requests occupying a slot "
                "right now").set_function(lambda: self.engine.in_flight)
        m.gauge("leo_slots", "Configured engine slots").set_function(
            lambda: len(self.engine.slots))
        m.gauge("leo_ready", "1 while admitting, 0 while draining"
                ).set_function(lambda: 0.0 if self.draining else 1.0)

        self.httpd = _Httpd((host, port), _Handler,
                            listen_socket=listen_socket)
        self.httpd.app = self
        self.host = self.httpd.server_address[0]
        self.port = self.httpd.server_address[1]

    # -- lifecycle -------------------------------------------------------------

    @property
    def draining(self) -> bool:
        return self.engine._draining

    def start(self) -> "LeoHttpd":
        """Start the engine ticker and the HTTP accept loop (both on
        daemon threads); returns self so ``LeoHttpd(...).start()`` reads
        naturally."""
        self.engine.start_ticker()
        self._serve_thread = threading.Thread(
            target=self.httpd.serve_forever, kwargs={"poll_interval": 0.05},
            daemon=True, name="leo-httpd")
        self._serve_thread.start()
        return self

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Graceful shutdown: stop admitting (new POSTs get 503, readyz
        flips), let queued + in-flight analyses finish, flush the disk
        cache, then close the listener.  True when everything finished
        inside the timeout."""
        timeout = timeout if timeout is not None \
            else self.drain_timeout_seconds
        drained = self.engine.drain(timeout=timeout)
        self.engine.stop_ticker()
        self.service.flush()
        self.httpd.shutdown()
        self.httpd.server_close()
        if self._serve_thread is not None:
            self._serve_thread.join(timeout=5.0)
            self._serve_thread = None
        self._drained.set()
        return drained

    def __enter__(self) -> "LeoHttpd":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        if not self._drained.is_set():
            self.drain()

    def __repr__(self) -> str:
        return (f"LeoHttpd(http://{self.host}:{self.port}, "
                f"slots={len(self.engine.slots)}, "
                f"max_queue={self.engine.max_queue})")


class _Handler(BaseHTTPRequestHandler):
    server_version = "leo-serve/1"
    protocol_version = "HTTP/1.1"       # keep-alive: clients pipeline

    # quiet by default: the access log is what /metrics is for
    def log_message(self, format: str, *args: Any) -> None:
        pass

    @property
    def app(self) -> LeoHttpd:
        return self.server.app          # type: ignore[attr-defined]

    def _send(self, status: int, body: bytes, content_type: str,
              extra_headers: Optional[Dict[str, str]] = None) -> None:
        self.send_response(status)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        for name, value in (extra_headers or {}).items():
            self.send_header(name, value)
        self.end_headers()
        self.wfile.write(body)

    def _send_error_envelope(self, endpoint: str, code: str, message: str,
                             retry_after: Optional[float] = None,
                             request_id: Optional[str] = None) -> None:
        body, status = encode_error(code, message, retry_after=retry_after,
                                    request_id=request_id)
        headers = {}
        if retry_after is not None:
            # ceil-ish text form; proxies expect integral seconds but
            # fractional is widely accepted — keep the precise hint
            headers["Retry-After"] = f"{retry_after:g}"
        self.app.m_requests.inc(endpoint=endpoint, code=str(status))
        self._send(status, body, "application/json", headers)

    # -- GET: health / telemetry ----------------------------------------------

    def do_GET(self) -> None:
        app = self.app
        path = self.path.split("?", 1)[0]
        if path == "/healthz":
            self.app.m_requests.inc(endpoint="healthz", code="200")
            self._send(200, b"ok\n", "text/plain; charset=utf-8")
        elif path == "/readyz":
            if app.draining:
                app.m_requests.inc(endpoint="readyz", code="503")
                self._send(503, b"draining\n",
                           "text/plain; charset=utf-8",
                           {"Retry-After": f"{app.retry_after_seconds:g}"})
            else:
                app.m_requests.inc(endpoint="readyz", code="200")
                body = (f"ready queue={app.engine.queue_depth}/"
                        f"{app.engine.max_queue} "
                        f"inflight={app.engine.in_flight}/"
                        f"{len(app.engine.slots)}\n").encode()
                self._send(200, body, "text/plain; charset=utf-8")
        elif path == "/metrics":
            body = app.metrics.render().encode("utf-8")
            app.m_requests.inc(endpoint="metrics", code="200")
            self._send(200, body,
                       "text/plain; version=0.0.4; charset=utf-8")
        elif path == "/stats":
            body = json.dumps(app.service.stats_dict(),
                              sort_keys=True).encode("utf-8")
            app.m_requests.inc(endpoint="stats", code="200")
            self._send(200, body, "application/json")
        else:
            self._send_error_envelope("unknown", "not_found",
                                      f"no such endpoint {path!r}")

    # -- POST: the analysis endpoint ------------------------------------------

    def do_POST(self) -> None:
        app = self.app
        path = self.path.split("?", 1)[0]
        if path != "/v1/analyze":
            self._send_error_envelope("unknown", "not_found",
                                      f"no such endpoint {path!r}")
            return
        from ..launch.analysis_server import QueueFull, ServerDraining
        try:
            length = int(self.headers.get("Content-Length", 0))
            wire = decode_request(self.rfile.read(length))
        except ProtocolError as e:
            self._send_error_envelope("analyze", e.code, e.message)
            return

        deadline = wire.deadline_seconds \
            if wire.deadline_seconds is not None \
            else app.default_deadline_seconds
        try:
            rid = app.engine.submit(wire.request,
                                    deadline_seconds=deadline)
        except QueueFull as e:
            app.m_sheds.inc()
            self._send_error_envelope(
                "analyze", "overloaded", str(e),
                retry_after=app.retry_after_seconds)
            return
        except ServerDraining as e:
            self._send_error_envelope(
                "analyze", "draining", str(e),
                retry_after=app.retry_after_seconds)
            return
        except ValueError as e:
            self._send_error_envelope("analyze", "invalid_request", str(e))
            return
        app.m_admissions.inc()

        # small grace past the deadline: the engine's own expiry (queue
        # cancellation) is the authoritative result and races the
        # handler's timeout by up to one tick; the handler timeout is
        # the backstop for overdue *in-flight* work
        res = app.engine.wait(
            rid, timeout=deadline + 0.05 if deadline is not None else None)
        if res is None:
            # overdue in flight: abandon (the slot finishes into the
            # warm cache; this caller stops waiting)
            res = app.engine.abandon(rid)
            if res is None:
                app.m_deadline.inc()
                self._send_error_envelope(
                    "analyze", "deadline_exceeded",
                    f"request {rid} exceeded its {deadline:g}s deadline "
                    f"in flight; abandoned",
                    retry_after=app.retry_after_seconds, request_id=rid)
                return
        app.m_queue_seconds.observe(res.queue_seconds)
        if res.error is not None:
            if res.error.startswith("deadline_exceeded"):
                app.m_deadline.inc()
                self._send_error_envelope(
                    "analyze", "deadline_exceeded", res.error,
                    retry_after=app.retry_after_seconds, request_id=rid)
            else:
                self._send_error_envelope("analyze", "internal", res.error,
                                          request_id=rid)
            return
        app.m_service_seconds.observe(res.service_seconds)
        body = encode_result(
            res.fanout if res.fanout is not None else res.diagnosis,
            schema_version=wire.negotiated_schema, request_id=rid,
            timing={"queue_seconds": res.queue_seconds,
                    "service_seconds": res.service_seconds,
                    "seconds": res.seconds})
        app.m_requests.inc(endpoint="analyze", code="200")
        self._send(200, body, "application/json")


def serve_forever(app: LeoHttpd, *,
                  install_signal_handlers: bool = True) -> None:
    """Run until SIGTERM/SIGINT, then drain gracefully: stop admitting,
    finish in-flight analyses, flush the disk cache, close the listener.
    The entry point behind ``analysis_server --serve PORT``."""
    stop = threading.Event()
    if install_signal_handlers and \
            threading.current_thread() is threading.main_thread():
        for sig in (signal.SIGTERM, signal.SIGINT):
            signal.signal(sig, lambda *_: stop.set())
    app.start()
    try:
        while not stop.is_set():
            stop.wait(0.2)
    finally:
        app.drain()
