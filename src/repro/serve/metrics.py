"""Prometheus-style metrics registry (stdlib-only) for the serving layer.

Three instrument kinds, the minimum a scrape-based operator needs:

  * :class:`Counter` — monotonically increasing totals (requests,
    admissions, sheds, cache hits/misses, per-backend diagnoses);
  * :class:`Gauge` — point-in-time values, either set explicitly or
    backed by a callback sampled at scrape time (queue depth, in-flight
    requests, session cache hit counters);
  * :class:`Histogram` — cumulative-bucket latency distributions
    (parse / pipeline / queue-wait / service time), rendered with the
    standard ``_bucket{le=...}`` / ``_sum`` / ``_count`` triplet.

All instruments support fixed label names; a
:class:`MetricsRegistry` is the factory and renderer — creation is
get-or-create, so any layer (``LeoService``, the HTTP front-end, the
slot engine) can ask for the same metric and share it.
:meth:`MetricsRegistry.render` emits the Prometheus text exposition
format (version 0.0.4), which is what the ``/metrics`` endpoint serves.

Everything is thread-safe: one lock per registry guards creation, one
lock per metric guards its label children.  See ``docs/serving.md`` for
the full metric catalog the serving stack emits.

For multi-process serving every instrument also supports a structured
:meth:`~_Metric.dump` (JSON-serializable snapshot), and
:func:`aggregate_dumps` merges the per-worker registry dumps into one
Prometheus page: counters and histogram buckets/sums/counts are SUMMED
across workers, gauges keep one sample per worker labeled
``worker="k"`` (summing a queue depth across workers is meaningful, but
summing e.g. ``leo_ready`` flags is not — the operator gets both views:
the per-worker gauge samples and the summed counters).
"""
from __future__ import annotations

import math
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

#: Default latency buckets (seconds): sub-millisecond cache hits through
#: multi-second cold compiles/analyses.
LATENCY_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)

_LabelKey = Tuple[str, ...]


def _escape_label_value(value: str) -> str:
    return (value.replace("\\", "\\\\").replace("\n", "\\n")
            .replace('"', '\\"'))


def _escape_help(text: str) -> str:
    return text.replace("\\", "\\\\").replace("\n", "\\n")


def _format_value(value: float) -> str:
    if value == math.inf:
        return "+Inf"
    if value == -math.inf:
        return "-Inf"
    if isinstance(value, float) and value.is_integer():
        return str(int(value))
    return repr(value)


def _labels_suffix(labelnames: Sequence[str], labelvalues: _LabelKey,
                   extra: Optional[Tuple[str, str]] = None) -> str:
    pairs = [(n, v) for n, v in zip(labelnames, labelvalues)]
    if extra is not None:
        pairs.append(extra)
    if not pairs:
        return ""
    body = ",".join(f'{n}="{_escape_label_value(str(v))}"'
                    for n, v in pairs)
    return "{" + body + "}"


class _Metric:
    """Shared label-children plumbing for all three instrument kinds."""

    kind = "untyped"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()

    def _key(self, labels: Dict[str, str]) -> _LabelKey:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.labelnames}, "
                f"got {tuple(sorted(labels))}")
        return tuple(str(labels[n]) for n in self.labelnames)

    def header(self) -> List[str]:
        return [f"# HELP {self.name} {_escape_help(self.help)}",
                f"# TYPE {self.name} {self.kind}"]

    def render(self) -> List[str]:  # pragma: no cover - overridden
        raise NotImplementedError

    def dump(self) -> Dict[str, Any]:  # pragma: no cover - overridden
        raise NotImplementedError


class Counter(_Metric):
    """Monotonic total.  ``inc()`` on the bare metric (no labels) or with
    every declared label: ``c.inc(backend="tpu_v5e")``."""

    kind = "counter"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = ()):
        super().__init__(name, help, labelnames)
        self._values: Dict[_LabelKey, float] = {}

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels: str) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)

    def render(self) -> List[str]:
        out = self.header()
        with self._lock:
            items = sorted(self._values.items())
        if not items and not self.labelnames:
            items = [((), 0.0)]     # label-less counters render at 0
        for key, value in items:
            out.append(f"{self.name}"
                       f"{_labels_suffix(self.labelnames, key)} "
                       f"{_format_value(value)}")
        return out

    def dump(self) -> Dict[str, Any]:
        with self._lock:
            values = [[list(k), v] for k, v in sorted(self._values.items())]
        return {"kind": self.kind, "help": self.help,
                "labelnames": list(self.labelnames), "values": values}


class Gauge(_Metric):
    """Point-in-time value.  ``set``/``inc``/``dec`` for explicit values,
    or ``set_function`` to sample a callback at scrape time (queue depth,
    cache-stat snapshots — values owned by another object)."""

    kind = "gauge"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = ()):
        super().__init__(name, help, labelnames)
        self._values: Dict[_LabelKey, float] = {}
        self._functions: Dict[_LabelKey, Callable[[], float]] = {}

    def set(self, value: float, **labels: str) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = float(value)

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels: str) -> None:
        self.inc(-amount, **labels)

    def set_function(self, fn: Callable[[], float], **labels: str) -> None:
        key = self._key(labels)
        with self._lock:
            self._functions[key] = fn

    def value(self, **labels: str) -> float:
        key = self._key(labels)
        with self._lock:
            fn = self._functions.get(key)
            if fn is None:
                return self._values.get(key, 0.0)
        return float(fn())

    def render(self) -> List[str]:
        out = self.header()
        with self._lock:
            values = dict(self._values)
            functions = dict(self._functions)
        for key, fn in functions.items():
            try:
                values[key] = float(fn())
            except Exception:   # noqa: BLE001 - a dead callback must not
                pass            # take the whole scrape down
        if not values and not self.labelnames:
            values = {(): 0.0}
        for key, value in sorted(values.items()):
            out.append(f"{self.name}"
                       f"{_labels_suffix(self.labelnames, key)} "
                       f"{_format_value(value)}")
        return out

    def dump(self) -> Dict[str, Any]:
        """Snapshot with callback gauges sampled at dump time — the
        control-pipe heartbeat ships live queue depths, not stale sets."""
        with self._lock:
            values = dict(self._values)
            functions = dict(self._functions)
        for key, fn in functions.items():
            try:
                values[key] = float(fn())
            except Exception:   # noqa: BLE001 - mirror render()
                pass
        return {"kind": self.kind, "help": self.help,
                "labelnames": list(self.labelnames),
                "values": [[list(k), v] for k, v in sorted(values.items())]}


class Histogram(_Metric):
    """Cumulative-bucket distribution (Prometheus semantics: each
    ``le`` bucket counts observations <= its bound, ``+Inf`` counts
    all)."""

    kind = "histogram"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = LATENCY_BUCKETS):
        super().__init__(name, help, labelnames)
        bounds = sorted(float(b) for b in buckets)
        if not bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self.bounds = tuple(bounds)
        self._counts: Dict[_LabelKey, List[int]] = {}
        self._sums: Dict[_LabelKey, float] = {}
        self._totals: Dict[_LabelKey, int] = {}

    def observe(self, value: float, **labels: str) -> None:
        key = self._key(labels)
        with self._lock:
            counts = self._counts.get(key)
            if counts is None:
                counts = self._counts[key] = [0] * len(self.bounds)
            for i, bound in enumerate(self.bounds):
                if value <= bound:
                    counts[i] += 1
            self._sums[key] = self._sums.get(key, 0.0) + value
            self._totals[key] = self._totals.get(key, 0) + 1

    def count(self, **labels: str) -> int:
        with self._lock:
            return self._totals.get(self._key(labels), 0)

    def sum(self, **labels: str) -> float:
        with self._lock:
            return self._sums.get(self._key(labels), 0.0)

    def render(self) -> List[str]:
        out = self.header()
        with self._lock:
            keys = sorted(self._counts)
            counts = {k: list(v) for k, v in self._counts.items()}
            sums = dict(self._sums)
            totals = dict(self._totals)
        if not keys and not self.labelnames:
            keys = [()]
            counts[()] = [0] * len(self.bounds)
            sums[()] = 0.0
            totals[()] = 0
        for key in keys:
            for bound, cum in zip(self.bounds, counts[key]):
                out.append(
                    f"{self.name}_bucket"
                    f"{_labels_suffix(self.labelnames, key, ('le', _format_value(bound)))}"
                    f" {cum}")
            out.append(
                f"{self.name}_bucket"
                f"{_labels_suffix(self.labelnames, key, ('le', '+Inf'))}"
                f" {totals[key]}")
            out.append(f"{self.name}_sum"
                       f"{_labels_suffix(self.labelnames, key)} "
                       f"{_format_value(sums[key])}")
            out.append(f"{self.name}_count"
                       f"{_labels_suffix(self.labelnames, key)} "
                       f"{totals[key]}")
        return out

    def dump(self) -> Dict[str, Any]:
        with self._lock:
            rows = [[list(k), list(self._counts[k]), self._sums[k],
                     self._totals[k]] for k in sorted(self._counts)]
        return {"kind": self.kind, "help": self.help,
                "labelnames": list(self.labelnames),
                "bounds": list(self.bounds), "rows": rows}


class MetricsRegistry:
    """Get-or-create factory plus the ``/metrics`` renderer.

    Re-requesting a metric by name returns the existing instrument (so
    independent layers share totals); re-requesting with a *different*
    kind or label set is a programming error and raises.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: "Dict[str, _Metric]" = {}

    def _get_or_create(self, cls: type, name: str, help: str,
                       labelnames: Sequence[str], **kwargs: Any) -> Any:
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if not isinstance(existing, cls) or \
                        existing.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{existing.kind} with labels "
                        f"{existing.labelnames}")
                return existing
            metric = cls(name, help, labelnames=labelnames, **kwargs)
            self._metrics[name] = metric
            return metric

    def counter(self, name: str, help: str,
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str,
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str,
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    def render(self) -> str:
        """Prometheus text exposition format, metrics in name order."""
        with self._lock:
            metrics = [self._metrics[n] for n in sorted(self._metrics)]
        lines: List[str] = []
        for metric in metrics:
            lines.extend(metric.render())
        return "\n".join(lines) + "\n"

    def dump(self) -> Dict[str, Dict[str, Any]]:
        """JSON-serializable snapshot of every registered metric — the
        unit a pool worker ships over its control pipe each heartbeat."""
        with self._lock:
            metrics = [self._metrics[n] for n in sorted(self._metrics)]
        return {m.name: m.dump() for m in metrics}

    def __repr__(self) -> str:
        with self._lock:
            return f"MetricsRegistry({sorted(self._metrics)})"


def _merge_counter(name: str, dumps: List[Dict[str, Any]]) -> List[str]:
    first = dumps[0]
    labelnames = tuple(first["labelnames"])
    merged: Dict[_LabelKey, float] = {}
    for d in dumps:
        for key, value in d["values"]:
            k = tuple(key)
            merged[k] = merged.get(k, 0.0) + float(value)
    out = [f"# HELP {name} {_escape_help(first['help'])}",
           f"# TYPE {name} counter"]
    items = sorted(merged.items()) or ([((), 0.0)] if not labelnames else [])
    for key, value in items:
        out.append(f"{name}{_labels_suffix(labelnames, key)} "
                   f"{_format_value(value)}")
    return out


def _merge_gauge(name: str, worker_dumps: List[Tuple[str, Dict[str, Any]]]
                 ) -> List[str]:
    first = worker_dumps[0][1]
    labelnames = tuple(first["labelnames"])
    out = [f"# HELP {name} {_escape_help(first['help'])}",
           f"# TYPE {name} gauge"]
    for worker, d in worker_dumps:
        for key, value in d["values"]:
            out.append(
                f"{name}"
                f"{_labels_suffix(labelnames, tuple(key), ('worker', worker))}"
                f" {_format_value(float(value))}")
    return out


def _merge_histogram(name: str, dumps: List[Dict[str, Any]]) -> List[str]:
    first = dumps[0]
    labelnames = tuple(first["labelnames"])
    bounds = tuple(float(b) for b in first["bounds"])
    counts: Dict[_LabelKey, List[int]] = {}
    sums: Dict[_LabelKey, float] = {}
    totals: Dict[_LabelKey, int] = {}
    for d in dumps:
        if tuple(float(b) for b in d["bounds"]) != bounds:
            continue    # mismatched buckets (mid-upgrade worker): skip
        for key, row_counts, row_sum, row_total in d["rows"]:
            k = tuple(key)
            if k not in counts:
                counts[k] = [0] * len(bounds)
            for i, c in enumerate(row_counts):
                counts[k][i] += int(c)
            sums[k] = sums.get(k, 0.0) + float(row_sum)
            totals[k] = totals.get(k, 0) + int(row_total)
    out = [f"# HELP {name} {_escape_help(first['help'])}",
           f"# TYPE {name} histogram"]
    for key in sorted(counts):
        for bound, cum in zip(bounds, counts[key]):
            out.append(
                f"{name}_bucket"
                f"{_labels_suffix(labelnames, key, ('le', _format_value(bound)))}"
                f" {cum}")
        out.append(f"{name}_bucket"
                   f"{_labels_suffix(labelnames, key, ('le', '+Inf'))}"
                   f" {totals[key]}")
        out.append(f"{name}_sum{_labels_suffix(labelnames, key)} "
                   f"{_format_value(sums[key])}")
        out.append(f"{name}_count{_labels_suffix(labelnames, key)} "
                   f"{totals[key]}")
    return out


def aggregate_dumps(dumps: Dict[str, Dict[str, Dict[str, Any]]]) -> str:
    """Merge per-worker :meth:`MetricsRegistry.dump` snapshots into one
    Prometheus text page.

    ``dumps`` maps a worker id (e.g. ``"0"``, ``"1"``) to that worker's
    registry dump.  Counters and histograms are summed across workers —
    the fleet-wide ``leo_requests_total`` equals the sum of per-worker
    totals by construction.  Gauges are NOT summed: each worker's sample
    is kept and tagged with an extra ``worker="k"`` label, because most
    gauges (readiness flags, slot counts) are meaningless as sums.
    Workers missing a metric simply contribute nothing to it.
    """
    names: Dict[str, str] = {}
    for d in dumps.values():
        for name, md in d.items():
            names.setdefault(name, md["kind"])
    lines: List[str] = []
    for name in sorted(names):
        kind = names[name]
        present = [(w, dumps[w][name]) for w in sorted(dumps)
                   if name in dumps[w] and dumps[w][name]["kind"] == kind]
        if not present:
            continue
        if kind == "counter":
            lines.extend(_merge_counter(name, [d for _, d in present]))
        elif kind == "gauge":
            lines.extend(_merge_gauge(name, present))
        elif kind == "histogram":
            lines.extend(_merge_histogram(name, [d for _, d in present]))
    return "\n".join(lines) + "\n"
