"""Versioned JSON wire format for networked diagnosis serving.

One envelope generation (:data:`PROTOCOL_VERSION`) carries three payload
kinds between :class:`~repro.serve.client.LeoClient` and the HTTP
front-end:

  * **requests** — an :class:`~repro.core.service.AnalyzeRequest` dict
    plus transport concerns the core schema deliberately does not know
    about: the client's *accepted Diagnosis schema range* and an optional
    per-request deadline;
  * **results** — a single ``Diagnosis`` dict or a ``{backend: dict}``
    fan-out map, stamped with the negotiated schema version and the
    server-side queue/service timings;
  * **errors** — machine-readable ``code`` + message + optional
    ``retry_after`` hint, mirrored into the HTTP status / ``Retry-After``
    header by the front-end.

Schema-version negotiation (the v1–v6 ``Diagnosis`` migration, across
the wire): the client advertises ``accept_schema`` — the newest
Diagnosis schema generation it understands.  The server answers at
``min(SCHEMA_VERSION, accept_schema)``, **downgrading** the payload by
dropping the sections newer generations added (``occupancy`` for
pre-v6, ``rewrites`` for pre-v5, ``advice`` for pre-v4,
``issue_pressure`` for pre-v3, ``sync_resources`` for pre-v2) —
exactly the inverse of the ``Diagnosis.from_dict`` forward migration,
so:

  * an old (v5) client against a v6 server receives a genuine v5 payload
    its own ``from_dict`` accepts;
  * a new (v6) client against an old (v5) server receives a v5 payload
    that its ``from_dict`` migrates forward with explicit "not recorded"
    defaults.

Either direction round-trips without either side knowing the other's
version in advance — asserted in ``tests/test_serve_net.py``.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple, Union

from ..core.report import MIN_SCHEMA_VERSION, SCHEMA_VERSION, Diagnosis
from ..core.service import AnalyzeRequest

#: Envelope generation.  Bump when the *envelope* layout changes
#: incompatibly (the Diagnosis schema inside it has its own version and
#: its own negotiation).
PROTOCOL_VERSION = 1

#: Oldest envelope generation the server still decodes.
MIN_PROTOCOL_VERSION = 1

#: Machine-readable error codes carried in error envelopes.  The server
#: maps them onto HTTP statuses; the client maps them back onto
#: retry/no-retry decisions.
ERROR_CODES = {
    "bad_json": 400,
    "protocol_version": 400,
    "schema_negotiation": 400,
    "invalid_request": 400,
    "not_found": 404,
    "method_not_allowed": 405,
    "overloaded": 429,
    "internal": 500,
    "draining": 503,
    "deadline_exceeded": 504,
}


class ProtocolError(Exception):
    """A wire payload the peer cannot serve; carries the machine code
    and the HTTP status the front-end should answer with."""

    def __init__(self, code: str, message: str,
                 retry_after: Optional[float] = None):
        super().__init__(message)
        self.code = code
        self.message = message
        self.retry_after = retry_after
        self.http_status = ERROR_CODES.get(code, 500)


def negotiate_schema(accept_schema: int) -> int:
    """The Diagnosis schema version the server will answer with: the
    newest generation both sides understand."""
    if accept_schema < MIN_SCHEMA_VERSION:
        raise ProtocolError(
            "schema_negotiation",
            f"client accepts Diagnosis schema <= {accept_schema}, but the "
            f"oldest this server can emit is {MIN_SCHEMA_VERSION}")
    return min(SCHEMA_VERSION, accept_schema)


def downgrade_diagnosis_dict(data: Dict[str, Any],
                             target: int) -> Dict[str, Any]:
    """Re-shape a Diagnosis dict as an older schema generation by
    dropping the sections newer generations added (the inverse of the
    ``from_dict`` forward migration).  Shallow-copies; never mutates the
    input."""
    current = data.get("schema_version", SCHEMA_VERSION)
    if target > current:
        raise ProtocolError(
            "schema_negotiation",
            f"cannot upgrade a v{current} payload to v{target} on the "
            f"wire; upgrading is the reader's from_dict migration")
    if target < MIN_SCHEMA_VERSION:
        raise ProtocolError(
            "schema_negotiation",
            f"cannot downgrade below schema v{MIN_SCHEMA_VERSION}")
    if target == current:
        return data
    out = dict(data)
    if target < 6:
        out.pop("occupancy", None)
    if target < 5:
        out.pop("rewrites", None)
    if target < 4:
        out.pop("advice", None)
    if target < 3:
        out.pop("issue_pressure", None)
    if target < 2:
        out.pop("sync_resources", None)
    out["schema_version"] = target
    return out


# --------------------------------------------------------------------------
# Requests.
# --------------------------------------------------------------------------

@dataclass
class WireRequest:
    """A decoded request envelope: the core request plus transport
    concerns (negotiated schema, deadline)."""

    request: AnalyzeRequest
    accept_schema: int = SCHEMA_VERSION
    negotiated_schema: int = SCHEMA_VERSION
    deadline_seconds: Optional[float] = None
    protocol_version: int = PROTOCOL_VERSION


def encode_request(request: AnalyzeRequest, *,
                   accept_schema: int = SCHEMA_VERSION,
                   deadline_seconds: Optional[float] = None) -> bytes:
    """Client side: wrap an ``AnalyzeRequest`` in the envelope.  The
    request's own ``schema_version`` is deliberately NOT sent — request
    fields are stable across Diagnosis schema generations, and pinning
    the sender's constant would make every cross-version call fail
    ``validate()`` on the other side.  The envelope's ``accept_schema``
    is the version negotiation."""
    body = request.to_dict()
    body.pop("schema_version", None)
    return json.dumps({
        "protocol_version": PROTOCOL_VERSION,
        "accept_schema": accept_schema,
        "deadline_seconds": deadline_seconds,
        "request": body,
    }, sort_keys=False).encode("utf-8")


def decode_request(payload: Union[bytes, str]) -> WireRequest:
    """Server side: decode + validate an envelope, negotiating the
    response schema.  Raises :class:`ProtocolError` with the right HTTP
    status for every malformed shape."""
    try:
        data = json.loads(payload)
    except (ValueError, UnicodeDecodeError) as e:
        raise ProtocolError("bad_json", f"request body is not JSON: {e}")
    if not isinstance(data, dict):
        raise ProtocolError("bad_json", "request envelope must be an object")
    version = data.get("protocol_version")
    if not isinstance(version, int) or \
            not (MIN_PROTOCOL_VERSION <= version <= PROTOCOL_VERSION):
        raise ProtocolError(
            "protocol_version",
            f"protocol_version {version!r} outside supported range "
            f"[{MIN_PROTOCOL_VERSION}, {PROTOCOL_VERSION}]")
    accept = data.get("accept_schema", SCHEMA_VERSION)
    if not isinstance(accept, int):
        raise ProtocolError("schema_negotiation",
                            f"accept_schema must be an int, "
                            f"got {accept!r}")
    negotiated = negotiate_schema(accept)
    deadline = data.get("deadline_seconds")
    if deadline is not None and (not isinstance(deadline, (int, float))
                                 or deadline <= 0):
        raise ProtocolError("invalid_request",
                            f"deadline_seconds must be a positive number, "
                            f"got {deadline!r}")
    body = data.get("request")
    if not isinstance(body, dict):
        raise ProtocolError("invalid_request",
                            "envelope is missing the request object")
    body = dict(body)
    # the request schema rides the envelope negotiation: rebuild against
    # THIS server's generation so AnalyzeRequest.validate() checks fields,
    # not the sender's constant
    body["schema_version"] = SCHEMA_VERSION
    try:
        request = AnalyzeRequest.from_dict(body)
        request.validate()
    except (ValueError, TypeError, KeyError) as e:
        raise ProtocolError("invalid_request", str(e))
    return WireRequest(request=request, accept_schema=accept,
                       negotiated_schema=negotiated,
                       deadline_seconds=float(deadline)
                       if deadline is not None else None,
                       protocol_version=version)


# --------------------------------------------------------------------------
# Responses.
# --------------------------------------------------------------------------

@dataclass
class WireResponse:
    """A decoded response envelope (success or error)."""

    ok: bool
    kind: str = ""                      # "diagnosis" | "fanout" | "error"
    schema_version: int = SCHEMA_VERSION
    request_id: Optional[str] = None
    payload: Optional[Dict[str, Any]] = None   # raw dict(s), pre-migration
    timing: Dict[str, float] = field(default_factory=dict)
    error_code: Optional[str] = None
    error_message: Optional[str] = None
    retry_after: Optional[float] = None

    def result(self) -> Union[Diagnosis, Dict[str, Diagnosis]]:
        """Materialize typed results, running each payload through the
        reader-side ``from_dict`` migration (older-generation payloads
        gain their explicit "not recorded" defaults here)."""
        if not self.ok:
            raise ProtocolError(self.error_code or "internal",
                                self.error_message or "server error",
                                retry_after=self.retry_after)
        if self.kind == "diagnosis":
            return Diagnosis.from_dict(self.payload)
        if self.kind == "fanout":
            return {name: Diagnosis.from_dict(d)
                    for name, d in self.payload.items()}
        raise ProtocolError("bad_json",
                            f"unknown response kind {self.kind!r}")


def encode_result(result: Union[Diagnosis, Dict[str, Diagnosis]], *,
                  schema_version: int = SCHEMA_VERSION,
                  request_id: Optional[str] = None,
                  timing: Optional[Dict[str, float]] = None) -> bytes:
    """Server side: envelope a submit() result, downgraded to the
    negotiated schema."""
    if isinstance(result, Diagnosis):
        kind = "diagnosis"
        payload: Dict[str, Any] = downgrade_diagnosis_dict(
            result.to_dict(), schema_version)
    else:
        kind = "fanout"
        payload = {name: downgrade_diagnosis_dict(d.to_dict(),
                                                  schema_version)
                   for name, d in result.items()}
    return json.dumps({
        "protocol_version": PROTOCOL_VERSION,
        "ok": True,
        "kind": kind,
        "schema_version": schema_version,
        "request_id": request_id,
        "timing": timing or {},
        kind: payload,
    }, sort_keys=False).encode("utf-8")


def encode_error(code: str, message: str, *,
                 retry_after: Optional[float] = None,
                 request_id: Optional[str] = None) -> Tuple[bytes, int]:
    """Server side: (error envelope, HTTP status)."""
    payload = json.dumps({
        "protocol_version": PROTOCOL_VERSION,
        "ok": False,
        "kind": "error",
        "request_id": request_id,
        "error": {"code": code, "message": message,
                  "retry_after": retry_after},
    }, sort_keys=False).encode("utf-8")
    return payload, ERROR_CODES.get(code, 500)


def decode_response(payload: Union[bytes, str]) -> WireResponse:
    """Client side: decode either envelope shape.  Raises
    :class:`ProtocolError` only for undecodable bytes; a well-formed
    *error* envelope decodes fine and raises from :meth:`WireResponse.
    result` so the caller sees code/retry_after."""
    try:
        data = json.loads(payload)
    except (ValueError, UnicodeDecodeError) as e:
        raise ProtocolError("bad_json", f"response body is not JSON: {e}")
    if not isinstance(data, dict) or "ok" not in data:
        raise ProtocolError("bad_json", "response envelope malformed")
    if not data["ok"]:
        err = data.get("error") or {}
        return WireResponse(
            ok=False, kind="error", request_id=data.get("request_id"),
            error_code=err.get("code", "internal"),
            error_message=err.get("message", "server error"),
            retry_after=err.get("retry_after"))
    kind = data.get("kind")
    if kind not in ("diagnosis", "fanout") or kind not in data:
        raise ProtocolError("bad_json",
                            f"response kind {kind!r} malformed")
    return WireResponse(
        ok=True, kind=kind,
        schema_version=data.get("schema_version", SCHEMA_VERSION),
        request_id=data.get("request_id"),
        payload=data[kind],
        timing=data.get("timing") or {})
