"""Crash-consistent sharded checkpointing.

Layout per step:
    <dir>/step_<n>.tmp/...      (in progress; ignored by restore)
    <dir>/step_<n>/
        arrays.npz              (flattened leaves, path-keyed)
        manifest.json           (step, tree paths, shapes/dtypes, checksums)
    <dir>/LATEST                (atomic pointer file)

Writes go to a `.tmp` directory first and are renamed into place only after
the manifest (with per-array adler32 checksums) is fsynced — a torn write
can never be mistaken for a valid checkpoint.  Restore validates checksums
and falls back to the previous checkpoint on corruption.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str, step: int, state) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    arrays: Dict[str, np.ndarray] = {}
    manifest: Dict[str, Any] = {"step": step, "arrays": {}}
    for key, leaf in _flatten(state):
        arr = np.asarray(jax.device_get(leaf))
        raw = np.ascontiguousarray(arr).tobytes()
        # Store raw bytes: ml_dtypes (bfloat16/f8) do not survive npz
        # round-trips as typed arrays; the manifest carries the real dtype.
        arrays[key] = np.frombuffer(raw, dtype=np.uint8)
        manifest["arrays"][key] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "adler32": zlib.adler32(raw),
        }
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    latest = os.path.join(directory, "LATEST")
    with open(latest + ".tmp", "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.rename(latest + ".tmp", latest)
    return final


def list_checkpoints(directory: str) -> List[str]:
    if not os.path.isdir(directory):
        return []
    out = [d for d in sorted(os.listdir(directory))
           if d.startswith("step_") and not d.endswith(".tmp") and
           os.path.isfile(os.path.join(directory, d, "manifest.json"))]
    return out


def _validate(path: str) -> Optional[Dict[str, Any]]:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as npz:
            for key, meta in manifest["arrays"].items():
                if zlib.adler32(npz[key].tobytes()) != meta["adler32"]:
                    return None
        return manifest
    except Exception:
        return None


def _decode(raw: np.ndarray, meta: Dict[str, Any]) -> np.ndarray:
    import ml_dtypes  # noqa: F401 - registers bfloat16/f8 dtype names
    dtype = np.dtype(meta["dtype"])
    return np.frombuffer(raw.tobytes(), dtype=dtype).reshape(meta["shape"])


def restore_checkpoint(directory: str, like, step: Optional[int] = None,
                       shardings=None) -> Tuple[Any, int]:
    """Restore into the structure of `like`.  Picks the latest valid
    checkpoint (or `step`), skipping corrupt ones.  With `shardings`
    (matching pytree of NamedSharding) leaves are device_put sharded — this
    is also the resharding path for elastic restarts on a new mesh."""
    cands = list_checkpoints(directory)
    if step is not None:
        cands = [c for c in cands if c == f"step_{step:08d}"]
    for name in reversed(cands):
        path = os.path.join(directory, name)
        manifest = _validate(path)
        if manifest is None:
            continue
        with np.load(os.path.join(path, "arrays.npz")) as npz:
            flat_like = _flatten(like)
            leaves = []
            ok = True
            for key, leaf in flat_like:
                if key not in npz:
                    ok = False
                    break
                leaves.append(_decode(npz[key], manifest["arrays"][key]))
            if not ok:
                continue
        treedef = jax.tree_util.tree_structure(like)
        if shardings is not None:
            flat_sh = [s for _, s in _flatten(shardings)]
            leaves = [jax.device_put(a, s) for a, s in zip(leaves, flat_sh)]
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        return state, int(manifest["step"])
    raise FileNotFoundError(f"no valid checkpoint in {directory}")
