"""Checkpoint manager: async writes, rotation, latest-pointer resume."""
from __future__ import annotations

import os
import shutil
import threading
from typing import Any, Optional, Tuple

from .checkpointer import (
    list_checkpoints,
    restore_checkpoint,
    save_checkpoint,
)


class CheckpointManager:
    """Keep-K rotating checkpoints with optional async (background) saves.

    Async saves snapshot the state on the caller's thread (device_get) and
    write on a worker thread so the train loop only blocks for the host
    copy, not the disk write — `wait()` joins before exit/restore.
    """

    def __init__(self, directory: str, keep: int = 3,
                 async_saves: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_saves = async_saves
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save -----------------------------------------------------------------

    def save(self, step: int, state) -> None:
        self.wait()
        if self.async_saves:
            import jax
            snapshot = jax.tree.map(lambda x: jax.device_get(x), state)

            def work():
                try:
                    save_checkpoint(self.directory, step, snapshot)
                    self._rotate()
                except BaseException as e:  # noqa: BLE001
                    self._error = e

            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            save_checkpoint(self.directory, step, state)
            self._rotate()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _rotate(self) -> None:
        ckpts = list_checkpoints(self.directory)
        for old in ckpts[: max(0, len(ckpts) - self.keep)]:
            shutil.rmtree(os.path.join(self.directory, old),
                          ignore_errors=True)

    # -- restore -----------------------------------------------------------------

    def restore_latest(self, like, shardings=None) -> Tuple[Any, int]:
        self.wait()
        return restore_checkpoint(self.directory, like, shardings=shardings)

    def has_checkpoint(self) -> bool:
        return bool(list_checkpoints(self.directory))
