from .checkpointer import restore_checkpoint, save_checkpoint, list_checkpoints
from .manager import CheckpointManager
