from .steps import (
    TrainOptions,
    default_microbatch,
    init_train_state,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from .fault import (
    ElasticController,
    FaultTolerantLoop,
    HeartbeatMonitor,
    MeshPlan,
    StragglerPolicy,
)
