"""Fault tolerance at scale: heartbeats, stragglers, elastic re-meshing.

Single-process simulation of the multi-host control plane (this container
has one host); the interfaces mirror what `jax.distributed` + a cluster
coordinator provide on real pods, and all decision logic (what to do, when)
is host-side Python that transfers unchanged:

* `HeartbeatMonitor` — per-host step heartbeats with an injectable clock;
  declares hosts *straggling* (> `straggler_factor` x median step time) or
  *failed* (no heartbeat for `timeout`).
* `StragglerPolicy` — what the loop does about stragglers: "wait" (default
  synchronous SPMD behavior), or "flag" (surface for ops tooling).
* `ElasticController` — given surviving host count, picks the largest valid
  (data x model) mesh <= survivors (keeping TP intact, shrinking DP),
  yielding the resharding plan; recovery = restore latest checkpoint with
  the new mesh's shardings (`CheckpointManager.restore_latest(shardings=…)`)
  and resume from the checkpointed step (the data pipeline is stateless
  beyond the step index).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


@dataclass
class HostStatus:
    host: int
    last_step: int = -1
    last_seen: float = 0.0
    step_seconds: float = 0.0


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, timeout: float = 60.0,
                 straggler_factor: float = 2.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.straggler_factor = straggler_factor
        self.clock = clock
        self.hosts: Dict[int, HostStatus] = {
            h: HostStatus(host=h) for h in range(n_hosts)}

    def heartbeat(self, host: int, step: int) -> None:
        now = self.clock()
        st = self.hosts[host]
        if st.last_step >= 0 and step > st.last_step:
            dt = (now - st.last_seen) / max(step - st.last_step, 1)
            st.step_seconds = 0.5 * st.step_seconds + 0.5 * dt \
                if st.step_seconds else dt
        st.last_step = step
        st.last_seen = now

    def failed_hosts(self) -> List[int]:
        now = self.clock()
        return [h for h, st in self.hosts.items()
                if st.last_step >= 0 and now - st.last_seen > self.timeout]

    def stragglers(self) -> List[int]:
        times = sorted(st.step_seconds for st in self.hosts.values()
                       if st.step_seconds > 0)
        if not times:
            return []
        median = times[len(times) // 2]
        if median <= 0:
            return []
        return [h for h, st in self.hosts.items()
                if st.step_seconds > self.straggler_factor * median]


@dataclass(frozen=True)
class StragglerPolicy:
    mode: str = "wait"   # wait | flag

    def act(self, stragglers: List[int]) -> Optional[str]:
        if not stragglers:
            return None
        if self.mode == "flag":
            return f"stragglers detected: {stragglers}"
        return None  # synchronous SPMD waits by construction


@dataclass
class MeshPlan:
    data: int
    model: int
    dropped_hosts: Tuple[int, ...] = ()

    @property
    def devices(self) -> int:
        return self.data * self.model


class ElasticController:
    """Pick the largest valid mesh after failures; drive recovery."""

    def __init__(self, devices_per_host: int, model_parallel: int):
        self.devices_per_host = devices_per_host
        self.model_parallel = model_parallel

    def plan(self, surviving_hosts: List[int], failed: List[int]) -> MeshPlan:
        devices = len(surviving_hosts) * self.devices_per_host
        tp = self.model_parallel
        if devices < tp:
            raise RuntimeError(
                f"cannot keep model_parallel={tp} with {devices} devices")
        dp = devices // tp
        # largest power-of-two DP for stable collectives
        p = 1
        while p * 2 <= dp:
            p *= 2
        return MeshPlan(data=p, model=tp, dropped_hosts=tuple(failed))


@dataclass
class RecoveryEvent:
    step: int
    reason: str
    plan: MeshPlan


class FaultTolerantLoop:
    """Wraps a step function with detection + recovery orchestration.

    `recover_fn(plan) -> (state, step)` rebuilds mesh/shardings and restores
    the latest checkpoint; used by launch/train.py and unit-tested with
    injected failures.
    """

    def __init__(self, monitor: HeartbeatMonitor,
                 controller: ElasticController,
                 recover_fn: Callable[[MeshPlan], Tuple[object, int]],
                 straggler_policy: StragglerPolicy = StragglerPolicy()):
        self.monitor = monitor
        self.controller = controller
        self.recover_fn = recover_fn
        self.straggler_policy = straggler_policy
        self.events: List[RecoveryEvent] = []

    def check_and_recover(self, state, step: int):
        failed = self.monitor.failed_hosts()
        if failed:
            surviving = [h for h in self.monitor.hosts if h not in failed]
            plan = self.controller.plan(surviving, failed)
            state, step = self.recover_fn(plan)
            self.events.append(RecoveryEvent(
                step=step, reason=f"hosts failed: {failed}", plan=plan))
            for h in failed:
                del self.monitor.hosts[h]
        note = self.straggler_policy.act(self.monitor.stragglers())
        return state, step, note
