"""Train / serve step builders (the programs the launcher lowers).

`make_train_step` returns a pure function
    (train_state, batch) -> (train_state, metrics)
with loss, global-norm clipping, lr schedule, and AdamW update.  Options:
activation remat policy, gradient-compression (error-feedback int8 for the
DP all-reduce), microbatch accumulation via `lax.scan`.

`make_serve_step` returns
    (params, decode_state, token, pos) -> (next_token, logits, decode_state)
one-token greedy decode against the KV cache / recurrent state.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import decode_step as model_decode_step
from ..models import init_decode_state, init_params, loss_fn
from ..optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_gradients,
    linear_warmup_cosine,
)


@dataclass(frozen=True)
class TrainOptions:
    remat: str = "group"          # none | group
    chunk: int = 512              # attention chunk size
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_compression: bool = False
    grad_dtype: str = "f32"       # "bf16" halves the DP all-reduce bytes
    microbatch: int = 1           # accumulation steps via lax.scan


def default_microbatch(cfg: ArchConfig, global_batch: int, seq_len: int,
                       dp_size: int, target_bytes: float = 2e9) -> int:
    """Gradient-accumulation factor keeping layer-boundary activations
    (the tensors kept live across the backward pass under per-group remat)
    around `target_bytes` per device: B/dp/mb * S * d * 2 bytes * L."""
    per_dev = max(1, global_batch // max(dp_size, 1))
    boundary = per_dev * seq_len * cfg.d_model * 2 * cfg.n_layers
    mb = 1
    while boundary / mb > target_bytes and mb < per_dev:
        mb *= 2
    return mb


def init_train_state(rng, cfg: ArchConfig) -> Dict[str, Any]:
    params = init_params(rng, cfg)
    return {"params": params, "opt": adamw_init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                    options: TrainOptions = TrainOptions()):
    def loss_of(params, batch):
        return loss_fn(params, cfg, batch, chunk=options.chunk,
                       remat=options.remat)

    def train_step(state: Dict[str, Any], batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[Dict[str, Any], Dict[str, jnp.ndarray]]:
        params = state["params"]
        if options.microbatch > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(options.microbatch,
                                 b // options.microbatch, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                loss, g = jax.value_and_grad(loss_of)(params, mb)
                return (acc[0] + loss,
                        jax.tree.map(jnp.add, acc[1], g)), None
            zeros = jax.tree.map(jnp.zeros_like, params)
            (loss_sum, gsum), _ = jax.lax.scan(
                body, (jnp.zeros(()), zeros), micro)
            loss = loss_sum / options.microbatch
            grads = jax.tree.map(lambda g: g / options.microbatch, gsum)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)

        if options.grad_dtype == "bf16":
            # bf16 gradient all-reduce (Megatron-style): halves DP wire
            # bytes; the f32 master update re-upcasts afterwards.
            grads = jax.tree.map(
                lambda g: g.astype(jnp.bfloat16), grads)
        if options.grad_compression:
            ef = state.get("grad_ef")
            grads, new_ef = compress_gradients(grads, ef)
        grads, gnorm = clip_by_global_norm(grads, options.clip_norm)
        lr_scale = linear_warmup_cosine(state["step"], options.warmup_steps,
                                        options.total_steps)
        new_params, new_opt = adamw_update(opt_cfg, grads, state["opt"],
                                           params, lr_scale)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if options.grad_compression:
            new_state["grad_ef"] = new_ef
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr_scale": lr_scale}
        return new_state, metrics

    return train_step


def make_serve_step(cfg: ArchConfig, chunk: int = 512,
                    per_slot_pos: bool = False):
    """Single-token decode step.

    ``per_slot_pos=False`` (legacy): ``pos`` is a scalar shared by every
    batch row — fine when all slots advance in lockstep.  With
    ``per_slot_pos=True`` ``pos`` is a ``(B,)`` vector and each batch slot
    decodes at its own position (vmapped over the batch axis; per-slot KV
    writes lower to scatters), which is what continuous batching needs:
    a freed slot admits a new request at pos=0 while its neighbors keep
    decoding mid-stream.
    """
    if per_slot_pos:
        def one_slot(params, state, token, pos):
            # re-insert the batch axis (=1) that vmap strips, so the
            # model sees its normal (L, B, ...) state layout
            state_b = jax.tree.map(lambda l: l[:, None], state)
            logits, new_state = model_decode_step(
                params, state_b, cfg, token[None], pos)
            return logits[0], jax.tree.map(lambda l: l[:, 0], new_state)

        vstep = jax.vmap(one_slot, in_axes=(None, 1, 0, 0),
                         out_axes=(0, 1))

        def serve_step(params, state, token: jnp.ndarray,
                       pos: jnp.ndarray):
            logits, new_state = vstep(params, state, token, pos)
            next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_token, logits, new_state

        return serve_step

    def serve_step(params, state, token: jnp.ndarray, pos: jnp.ndarray):
        logits, new_state = model_decode_step(params, state, cfg, token, pos)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, new_state

    return serve_step


def make_prefill_step(cfg: ArchConfig, chunk: int = 512):
    """Full-sequence forward used for the prefill shapes (logits only —
    cache construction for generation lives in examples/serve_demo.py)."""
    from ..models import forward

    def prefill_step(params, batch):
        logits, _ = forward(params, cfg, tokens=batch.get("tokens"),
                            embeds=batch.get("embeds"), chunk=chunk,
                            remat="none")
        return logits

    return prefill_step
