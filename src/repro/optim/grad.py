"""Gradient utilities: clipping, micro-batch accumulation, compression.

`compress_gradients` implements error-feedback int8 compression for the
DP all-reduce (a distributed-optimization trick for bandwidth-bound meshes):
gradients are quantized to int8 with a per-tensor scale before the reduce
and the quantization error is fed back into the next step's gradients, which
keeps convergence while cutting DP collective bytes 4x for f32 / 2x for bf16.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


class GradAccumulator:
    """Host-side micro-batch accumulation driver.

    The jitted step takes (params, microbatch) -> grads; this accumulates
    `n_micro` of them before the optimizer update — how large global batches
    run on meshes whose per-device memory can't hold them at once.
    """

    def __init__(self, n_micro: int):
        self.n_micro = n_micro

    def split(self, batch):
        def sp(x):
            b = x.shape[0]
            assert b % self.n_micro == 0
            return x.reshape(self.n_micro, b // self.n_micro, *x.shape[1:])
        return jax.tree.map(sp, batch)

    @staticmethod
    def accumulate_scan(grad_fn, params, micro_batches):
        """jit-friendly accumulation via lax.scan over the micro axis."""
        def body(acc, mb):
            g = grad_fn(params, mb)
            return jax.tree.map(jnp.add, acc, g), None
        g0 = jax.tree.map(
            lambda mb: None, micro_batches)  # placeholder (unused)
        first = grad_fn(params, jax.tree.map(lambda x: x[0], micro_batches))
        rest = jax.tree.map(lambda x: x[1:], micro_batches)
        acc, _ = jax.lax.scan(body, first, rest)
        n = jax.tree.leaves(micro_batches)[0].shape[0]
        return jax.tree.map(lambda g: g / n, acc)


def compress_gradients(grads, error_feedback: Optional[Any] = None
                       ) -> Tuple[Any, Any]:
    """Int8 quantization with error feedback. Returns (q_grads_f, new_ef).

    The returned gradients are dequantized back to the original dtype (the
    quantization round-trip models the wire format); `new_ef` carries the
    residual to add into the next step.
    """
    if error_feedback is None:
        error_feedback = jax.tree.map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def leaf(g, ef):
        gf = g.astype(jnp.float32) + ef
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_feedback)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
