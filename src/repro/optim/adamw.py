"""AdamW with bf16 params + f32 master/moment states (ZeRO-shardable).

State layout mirrors the param tree leaf-for-leaf so the sharding rules in
`repro.parallel.sharding` apply to optimizer state directly (with optional
extra data-parallel sharding = ZeRO-1).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    keep_master: bool = True   # fp32 master copy when params are bf16


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }
    state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(cfg: AdamWConfig, grads, state, params,
                 lr_scale: jnp.ndarray | float = 1.0
                 ) -> Tuple[Any, Dict[str, Any]]:
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def leaf(g, mu, nu, master):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        update = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        master = master - lr * (update + cfg.weight_decay * master)
        return mu, nu, master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    flat_ma = treedef.flatten_up_to(state["master"])
    out = [leaf(g, m, n, w) for g, m, n, w in
           zip(flat_g, flat_mu, flat_nu, flat_ma)]
    mu = jax.tree.unflatten(treedef, [o[0] for o in out])
    nu = jax.tree.unflatten(treedef, [o[1] for o in out])
    master = jax.tree.unflatten(treedef, [o[2] for o in out])
    flat_p = treedef.flatten_up_to(params)
    new_params = jax.tree.unflatten(
        treedef, [m.astype(p.dtype) for m, p in
                  zip([o[2] for o in out], flat_p)])
    return new_params, {"mu": mu, "nu": nu, "master": master, "count": count}
