from .adamw import AdamWConfig, adamw_init, adamw_update
from .schedule import cosine_schedule, linear_warmup_cosine
from .grad import clip_by_global_norm, GradAccumulator, compress_gradients

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
    "linear_warmup_cosine", "clip_by_global_norm", "GradAccumulator",
    "compress_gradients",
]
