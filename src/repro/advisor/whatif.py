"""Counterfactual what-if replay over the LEO model stack.

The paper's payoff is not the diagnosis but the *optimization it guides*
(§case studies: 1.73x-1.82x geomean from LEO-guided fixes).  This module
supplies the estimate-backed half of that loop, GPA-style: a declarative
:class:`Mutation` describes one candidate change to the modeled world —
grow a :class:`SyncResourcePool`, switch the :class:`IssueModel`, scale a
latency class, batch or pipeline an async-copy chain, relax a sync edge —
and :class:`WhatIfEngine` replays the *same* program through the mutated
model and reports the modeled cycle delta.

Everything here is a pure function of ``(module, backend, mutation)``:
mutations clone via ``dataclasses.replace`` / :func:`clone_module` and
never touch the originals, and the replayed :class:`VirtualSampler` is fully
deterministic — the :class:`Identity` mutation reproduces the baseline
:class:`StallProfile` byte-for-byte (asserted by
:func:`profile_fingerprint` equality in tests and goldens).
"""
from __future__ import annotations

import copy
import hashlib
import pickle
import json
from dataclasses import dataclass, field, replace as _dc_replace
from typing import Any, Dict, List, Optional, Tuple

from ..core.backends import Backend
from ..core.hwmodel import IssueModel, OccupancyModel
from ..core.isa import Instruction, Module, OpClass
from ..core.sampler import StallClass, StallProfile, VirtualSampler


def clone_module(module: Module) -> Module:
    """Deep-clone a parsed module without sharing any mutable state.

    A pickle round-trip: ~5x faster than ``copy.deepcopy`` on the plain
    dataclass graph a :class:`Module` is (every mutation pays one clone
    per replay, so this is the what-if engine's hot path), and equality
    by the module's own ``__eq__`` is preserved exactly."""
    return pickle.loads(pickle.dumps(module, pickle.HIGHEST_PROTOCOL))


__all__ = [
    "Mutation",
    "Identity",
    "ResizePool",
    "SetIssue",
    "SetOccupancy",
    "ScaleLatency",
    "CoalesceSyncTags",
    "PipelineAsyncChain",
    "clone_module",
    "TreeReduceChain",
    "RelaxSyncEdge",
    "Compose",
    "WhatIfResult",
    "WhatIfEngine",
    "mutation_from_dict",
    "profile_fingerprint",
    "sync_resource_stall_cycles",
]

#: HardwareModel fields ScaleLatency may touch — numeric latency/bandwidth
#: classes only, never structural fields (name/issue/clock identity).
SCALABLE_FIELDS = (
    "hbm_bw", "dma_setup_cycles", "sync_realloc_cycles",
    "issue_overhead_cycles", "peak_flops_bf16", "peak_flops_f32",
    "collective_setup_cycles",
)


@dataclass(frozen=True)
class Mutation:
    """One declarative counterfactual edit to the modeled world.

    Subclasses override :meth:`apply_backend` (hardware/sync/issue edits)
    and/or :meth:`apply_module` (program edits).  Both must be pure:
    return clones, never mutate the argument."""

    @property
    def kind(self) -> str:
        return type(self).__name__

    def apply_backend(self, backend: Backend) -> Backend:
        return backend

    def apply_module(self, module: Module) -> Module:
        return module

    def describe(self) -> str:
        return self.kind

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"kind": self.kind}
        out.update({k: v for k, v in self.__dict__.items()})
        return out


def _rename(backend: Backend, suffix: str) -> Backend:
    """Derived backends get distinct names (the `with_issue` convention)
    so name-keyed session/service caches can never alias a mutant with
    the real part."""
    return _dc_replace(backend, name=f"{backend.name}~{suffix}")


@dataclass(frozen=True)
class Identity(Mutation):
    """The null mutation: replay must be byte-identical to baseline."""

    def describe(self) -> str:
        return "identity (baseline replay)"


@dataclass(frozen=True)
class ResizePool(Mutation):
    """Grow or shrink one named :class:`SyncResourcePool` to ``capacity``.

    Growing answers the counterfactual "would more barriers / waitcnt
    counters / SBIDs help?" — the modeled speedup quantifies how much of
    the makespan is §III-E oldest-(M-N) serialization on that pool, which
    is exactly what a software fix (batching syncs) can claw back."""

    pool: str = ""
    capacity: int = 1

    def apply_backend(self, backend: Backend) -> Backend:
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")
        pools = []
        hit = False
        for p in backend.sync.pools:
            if p.name != self.pool:
                pools.append(p)
                continue
            hit = True
            if self.capacity <= p.capacity:
                instances = p.instances[:self.capacity]
            else:
                extra = tuple(f"{p.name}[{i}]"
                              for i in range(p.capacity, self.capacity))
                instances = p.instances + extra
            pools.append(_dc_replace(p, instances=instances))
        if not hit:
            raise KeyError(
                f"backend {backend.name!r} has no sync pool {self.pool!r}; "
                f"pools: {[p.name for p in backend.sync.pools]}")
        sync = _dc_replace(backend.sync, pools=tuple(pools))
        return _dc_replace(_rename(backend, f"pool.{self.pool}x{self.capacity}"),
                           sync=sync)

    def describe(self) -> str:
        return f"resize sync pool {self.pool!r} to capacity {self.capacity}"


@dataclass(frozen=True)
class SetIssue(Mutation):
    """Swap the issue fabric: any of queues/width/policy, rest inherited."""

    queues: Optional[int] = None
    width: Optional[int] = None
    policy: Optional[str] = None

    def apply_backend(self, backend: Backend) -> Backend:
        cur = backend.issue
        issue = IssueModel(
            queues=self.queues if self.queues is not None else cur.queues,
            width=self.width if self.width is not None else cur.width,
            policy=self.policy if self.policy is not None else cur.policy)
        return backend.with_issue(issue)

    def describe(self) -> str:
        parts = [f"{k}={v}" for k, v in (("queues", self.queues),
                                         ("width", self.width),
                                         ("policy", self.policy))
                 if v is not None]
        return "set issue " + ", ".join(parts or ["(unchanged)"])


@dataclass(frozen=True)
class SetOccupancy(Mutation):
    """Engage or re-size the wave-occupancy model: W resident waves per
    issue queue hiding each other's latency.

    With no arguments, engages the backend's *native* residency
    (``Backend.native_occupancy`` — what the vendor's launch knobs give
    an unconstrained kernel); explicit fields override.  This is the
    counterfactual behind "raise occupancy" advice: the modeled speedup
    prices how much of the exposed latency co-resident waves would
    actually hide — which is NOT always positive, because W waves also
    share the device-scoped sync pools (a copy storm that fits 6
    barriers at W=1 fights over 6//8 of them at W=8)."""

    waves: Optional[int] = None
    limiter: Optional[str] = None
    window_cycles: Optional[float] = None

    def apply_backend(self, backend: Backend) -> Backend:
        cur = backend.occupancy if backend.occupancy.multi_wave \
            else backend.native_occupancy
        occ = OccupancyModel(
            waves=self.waves if self.waves is not None else cur.waves,
            limiter=self.limiter if self.limiter is not None
            else cur.limiter,
            window_cycles=self.window_cycles
            if self.window_cycles is not None else cur.window_cycles)
        return backend.with_occupancy(occ)

    def describe(self) -> str:
        parts = [f"{k}={v}" for k, v in (("waves", self.waves),
                                         ("limiter", self.limiter),
                                         ("window_cycles",
                                          self.window_cycles))
                 if v is not None]
        return "set occupancy " + ", ".join(parts or ["(native residency)"])


@dataclass(frozen=True)
class ScaleLatency(Mutation):
    """Scale one numeric latency/bandwidth class of the HardwareModel.

    ``ScaleLatency("hbm_bw", 2.0)`` models "hide half the exposed memory
    latency" (prefetch / double-buffering); ``("sync_realloc_cycles",
    0.5)`` models a cheaper barrier re-arm, and so on."""

    hw_field: str = ""
    factor: float = 1.0

    def apply_backend(self, backend: Backend) -> Backend:
        if self.hw_field not in SCALABLE_FIELDS:
            raise KeyError(
                f"{self.hw_field!r} is not a scalable latency class; "
                f"known: {SCALABLE_FIELDS}")
        if self.factor <= 0:
            raise ValueError(f"factor must be > 0, got {self.factor}")
        cur = getattr(backend.hw, self.hw_field)
        hw = _dc_replace(backend.hw, **{self.hw_field: cur * self.factor})
        return _dc_replace(_rename(backend, f"{self.hw_field}x{self.factor:g}"),
                           hw=hw)

    def describe(self) -> str:
        return f"scale hw.{self.hw_field} by {self.factor:g}x"


def _sync_starts(comp) -> List[Instruction]:
    """Async-start ops that claim a sync resource, in program order."""
    return [i for i in comp.instructions
            if i.sync.sets and i.op_class is OpClass.SYNC_SET]


@dataclass(frozen=True)
class CoalesceSyncTags(Mutation):
    """Batch barriers: guard groups of ``group`` async starts with ONE
    sync identifier instead of one each.

    This is the software fix the §III-E rule points at: a re-armed live
    identifier is a free counter-style increment on the same physical
    instance (no allocation), so a 12-copy storm that oversubscribes 6
    named barriers fits comfortably once copies share tags pairwise.
    Data dependencies ride the operand edges and are untouched — only the
    resource accounting changes."""

    group: int = 2

    def apply_module(self, module: Module) -> Module:
        if self.group < 1:
            raise ValueError(f"group must be >= 1, got {self.group}")
        if self.group == 1:
            return module
        mod = clone_module(module)
        for comp in mod.computations.values():
            starts = _sync_starts(comp)
            remap: Dict[str, str] = {}
            for i, instr in enumerate(starts):
                leader = starts[(i // self.group) * self.group]
                for tag in instr.sync.sets:
                    remap[tag] = leader.name
            if not remap:
                continue
            for instr in comp.instructions:
                si = instr.sync
                if si.kind is None:
                    continue
                sets = tuple(remap.get(t, t) for t in si.sets)
                waits = tuple(remap.get(t, t) for t in si.waits)
                if sets != si.sets or waits != si.waits:
                    instr.sync = _dc_replace(si, sets=sets, waits=waits)
        return mod

    def describe(self) -> str:
        return (f"batch sync: share one identifier across groups of "
                f"{self.group} async starts")


@dataclass(frozen=True)
class PipelineAsyncChain(Mutation):
    """Software-pipeline an async chain to at most ``window`` starts in
    flight: starts beyond the window are sunk to just before their first
    consumer.  Bounds resource pressure at the cost of overlap — what-if
    replay decides whether that trade wins on a given part."""

    window: int = 4

    def apply_module(self, module: Module) -> Module:
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        mod = clone_module(module)
        for comp in mod.computations.values():
            starts = _sync_starts(comp)
            if len(starts) <= self.window:
                continue
            instrs = list(comp.instructions)
            for s in starts[self.window:]:
                consumer = None
                for other in instrs:
                    if other is s:
                        continue
                    if s.name in other.operands or s.name in other.sync.waits:
                        consumer = other
                        break
                if consumer is None:
                    continue
                instrs.remove(s)
                instrs.insert(instrs.index(consumer), s)
            for idx, instr in enumerate(instrs):
                instr.index = idx
            comp.instructions = instrs
        return mod

    def describe(self) -> str:
        return f"pipeline async chain: <= {self.window} starts in flight"


#: Binary elementwise opcodes safe to rebalance associatively.
_ASSOCIATIVE_OPCODES = ("add", "multiply", "maximum", "minimum",
                        "and", "or", "xor")


@dataclass(frozen=True)
class TreeReduceChain(Mutation):
    """Rebalance serial associative reduction chains into balanced trees.

    ``c1 = add(x0, x1); c2 = add(c1, x2); ...`` is one long dependence
    chain — a wide issue fabric sits idle behind it.  The tree shape
    computes the same value in ``ceil(log2)`` levels of independent ops,
    which is exactly the "issue-side" restructuring an uncontended part
    (Intel-class: 16 SBIDs free, 8x2 ports starved) wants.  Only maximal
    chains of length >= ``min_length`` whose leaves all precede the chain
    in program order are rewired; instruction count and names never
    change, so downstream consumers and profile records stay stable."""

    min_length: int = 4

    def apply_module(self, module: Module) -> Module:
        mod = clone_module(module)
        for comp in mod.computations.values():
            self._rebalance_comp(comp)
        return mod

    def _rebalance_comp(self, comp) -> None:
        users: Dict[str, List[Instruction]] = {}
        for instr in comp.instructions:
            for op in set(instr.operands):
                users.setdefault(op, []).append(instr)

        def chainable(instr: Instruction) -> bool:
            return (instr.opcode in _ASSOCIATIVE_OPCODES
                    and len(instr.operands) == 2)

        def chain_pred(instr: Instruction) -> Optional[Instruction]:
            for op in instr.operands:
                prev = comp.get(op)
                if prev is not None and chainable(prev) \
                        and prev.opcode == instr.opcode \
                        and len(users.get(prev.name, ())) == 1:
                    return prev
            return None

        in_chain: set = set()
        for instr in comp.instructions:
            if not chainable(instr) or instr.name in in_chain \
                    or chain_pred(instr) is not None:
                continue
            # walk the successors: the single same-opcode user
            nodes = [instr]
            while True:
                nxt = [u for u in users.get(nodes[-1].name, ())
                       if chainable(u) and u.opcode == instr.opcode
                       and chain_pred(u) is nodes[-1]]
                if len(nxt) != 1 or len(users.get(nodes[-1].name, ())) != 1:
                    break
                nodes.append(nxt[0])
            if len(nodes) < self.min_length:
                continue
            # leaves: both operands of the head, plus each later node's
            # non-chain operand, in chain order
            leaves = list(nodes[0].operands)
            for prev, node in zip(nodes, nodes[1:]):
                leaves.extend(op for op in node.operands
                              if op != prev.name)
            if len(leaves) != len(nodes) + 1:
                continue    # irregular shape (e.g. squaring); leave it
            first_idx = min(n.index for n in nodes)
            leaf_instrs = [comp.get(l) for l in leaves]
            if any(l is None or l.index >= first_idx for l in leaf_instrs):
                continue    # a leaf defined mid-chain: unsafe to rewire
            in_chain.update(n.name for n in nodes)
            # pair values level by level, reusing the chain's own nodes
            # in program order — the last node keeps computing the root,
            # so every downstream consumer is untouched
            vals = leaves
            k = 0
            while len(vals) > 1:
                level: List[str] = []
                for i in range(0, len(vals) - 1, 2):
                    node = nodes[k]
                    k += 1
                    node.operands = (vals[i], vals[i + 1])
                    level.append(node.name)
                if len(vals) % 2:
                    level.append(vals[-1])
                vals = level

    def describe(self) -> str:
        return (f"tree-reduce serial chains (length >= {self.min_length}) "
                f"into balanced reductions")


@dataclass(frozen=True)
class RelaxSyncEdge(Mutation):
    """Drop the sync-wait edges of instructions whose name contains
    ``match`` (models removing a redundant wait, e.g. over-conservative
    token threading).  Data operands still order the program."""

    match: str = ""

    def apply_module(self, module: Module) -> Module:
        mod = clone_module(module)
        for comp in mod.computations.values():
            for instr in comp.instructions:
                if self.match and self.match not in instr.name:
                    continue
                if instr.sync.waits:
                    instr.sync = _dc_replace(instr.sync, waits=(),
                                             counter=None)
        return mod

    def describe(self) -> str:
        return f"relax sync waits on instructions matching {self.match!r}"


@dataclass(frozen=True)
class Compose(Mutation):
    """Apply several mutations as ONE candidate and price them jointly.

    Stacked fixes do not add linearly — coalescing sync tags can erase
    the serialization a pool resize would have bought, and pipelining a
    chain changes which tags are live to coalesce.  A single joint
    replay through the composed world is the only honest price.  Parts
    apply in order (program edits chain, backend edits chain), so
    ``Compose((a, b))`` models "do a, then b"."""

    parts: Tuple[Mutation, ...] = ()

    def apply_backend(self, backend: Backend) -> Backend:
        for part in self.parts:
            backend = part.apply_backend(backend)
        return backend

    def apply_module(self, module: Module) -> Module:
        for part in self.parts:
            module = part.apply_module(module)
        return module

    def describe(self) -> str:
        if not self.parts:
            return "compose (empty)"
        return "stack: " + " + ".join(p.describe() for p in self.parts)

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind,
                "parts": [p.to_dict() for p in self.parts]}


_MUTATION_KINDS = {
    cls.__name__: cls
    for cls in (Identity, ResizePool, SetIssue, SetOccupancy, ScaleLatency,
                CoalesceSyncTags, PipelineAsyncChain, TreeReduceChain,
                RelaxSyncEdge, Compose)
}


def mutation_from_dict(data: Dict[str, Any]) -> Mutation:
    """Inverse of ``Mutation.to_dict`` (wire/JSON round-trips)."""
    data = dict(data)
    kind = data.pop("kind", None)
    try:
        cls = _MUTATION_KINDS[kind]
    except KeyError:
        raise KeyError(f"unknown mutation kind {kind!r}; "
                       f"known: {sorted(_MUTATION_KINDS)}") from None
    if cls is Compose:
        return Compose(parts=tuple(mutation_from_dict(p)
                                   for p in data.get("parts", ())))
    return cls(**data)


# -- deterministic profile identity -------------------------------------------

def _canonical_profile(profile: StallProfile) -> Dict[str, Any]:
    records = {}
    for q, rec in sorted(profile.records.items()):
        records[q] = {
            "total_samples": rec.total_samples,
            "latency_samples": rec.latency_samples,
            "exec_count": rec.exec_count,
            "stall_breakdown": {cls.value: cyc for cls, cyc in
                                sorted(rec.stall_breakdown.items(),
                                       key=lambda kv: kv[0].value)},
            "blockers": dict(sorted(rec.blockers.items())),
        }
    out: Dict[str, Any] = {
        "hw_name": profile.hw_name,
        "makespan_cycles": profile.makespan_cycles,
        "clock_hz": profile.clock_hz,
        "records": records,
    }
    for name in ("sync_pressure", "issue_pressure", "occupancy_pressure"):
        report = getattr(profile, name, None)
        if report is not None and hasattr(report, "to_dict"):
            out[name] = report.to_dict()
    return out


def profile_fingerprint(profile: StallProfile) -> str:
    """Content hash of everything a StallProfile asserts; two profiles
    with equal fingerprints are byte-identical for golden purposes."""
    blob = json.dumps(_canonical_profile(profile), sort_keys=True,
                      separators=(",", ":"), default=str)
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()


def sync_resource_stall_cycles(profile: StallProfile) -> float:
    """Total §III-E serialization cycles across the profile."""
    return sum(rec.stall_breakdown.get(StallClass.SYNC_RESOURCE, 0.0)
               for rec in profile.records.values())


# -- the replay engine --------------------------------------------------------

@dataclass
class WhatIfResult:
    """Modeled outcome of replaying one mutation."""

    mutation: Mutation
    backend_name: str
    baseline_makespan_cycles: float
    mutated_makespan_cycles: float
    profile: StallProfile = field(repr=False, default=None)  # type: ignore

    @property
    def delta_cycles(self) -> float:
        """Positive = the mutation removed cycles."""
        return self.baseline_makespan_cycles - self.mutated_makespan_cycles

    @property
    def modeled_speedup(self) -> float:
        if self.mutated_makespan_cycles <= 0:
            return 1.0
        return self.baseline_makespan_cycles / self.mutated_makespan_cycles

    def to_dict(self) -> Dict[str, Any]:
        return {
            "mutation": self.mutation.to_dict(),
            "backend": self.backend_name,
            "baseline_makespan_cycles": self.baseline_makespan_cycles,
            "mutated_makespan_cycles": self.mutated_makespan_cycles,
            "delta_cycles": self.delta_cycles,
            "modeled_speedup": self.modeled_speedup,
        }


class WhatIfEngine:
    """Replay ``(module, backend)`` under mutations; memoizes the baseline.

    ``replays`` counts every sampler run (baseline included) — the
    advisor's bench lane and the hillclimb evaluation budget both read
    it, so nothing gets to hide simulation work."""

    def __init__(self, module: Module, backend: Backend):
        self.module = module
        self.backend = backend
        self.replays = 0
        self._baseline: Optional[StallProfile] = None

    def _run(self, module: Module, backend: Backend) -> StallProfile:
        self.replays += 1
        return VirtualSampler(module, backend.hw, sync=backend.sync).run()

    def baseline(self) -> StallProfile:
        if self._baseline is None:
            self._baseline = self._run(self.module, self.backend)
        return self._baseline

    def replay(self, mutation: Mutation) -> WhatIfResult:
        base = self.baseline()
        mutated = self._run(mutation.apply_module(self.module),
                            mutation.apply_backend(self.backend))
        return WhatIfResult(
            mutation=mutation,
            backend_name=self.backend.name,
            baseline_makespan_cycles=base.makespan_cycles,
            mutated_makespan_cycles=mutated.makespan_cycles,
            profile=mutated)
