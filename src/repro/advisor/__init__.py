"""`repro.advisor` — what-if replay + cross-vendor optimization advice.

The subsystem that turns LEO's evidence channels (backward-slice blame,
``sync_resources``, ``issue_pressure``) into ranked, speedup-quantified
optimization advice — the paper's headline payoff (LEO-guided fixes:
1.73x-1.82x geomean), in three layers:

  * :mod:`repro.advisor.whatif`  — declarative :class:`Mutation`s over the
    model stack, replayed deterministically by :class:`WhatIfEngine`;
  * :mod:`repro.advisor.rules`   — evidence-pattern matchers with
    vendor-native phrasing (barriers vs waitcnt vs SBIDs);
  * :mod:`repro.advisor.advisor` — ranks priced candidates into typed
    :class:`Advice`, landed in Diagnosis schema v4.

::

    from repro.advisor import Advisor
    report = Advisor().report(module, backend)
    for a in report.advice:
        print(f"{a.modeled_speedup:5.2f}x  {a.rule}: {a.description}")
"""
from .advisor import Advice, Advisor, AdvisorReport, advice_section
from .rules import RULES, Evidence, Rule, match_rules, rule_by_name
from .whatif import (
    CoalesceSyncTags,
    Compose,
    Identity,
    Mutation,
    PipelineAsyncChain,
    RelaxSyncEdge,
    ResizePool,
    ScaleLatency,
    SetIssue,
    SetOccupancy,
    TreeReduceChain,
    WhatIfEngine,
    WhatIfResult,
    mutation_from_dict,
    profile_fingerprint,
    sync_resource_stall_cycles,
)

__all__ = [
    "Advice", "Advisor", "AdvisorReport", "advice_section",
    "RULES", "Evidence", "Rule", "match_rules", "rule_by_name",
    "Mutation", "Identity", "ResizePool", "SetIssue", "SetOccupancy",
    "ScaleLatency",
    "CoalesceSyncTags", "PipelineAsyncChain", "RelaxSyncEdge",
    "TreeReduceChain", "Compose",
    "WhatIfEngine", "WhatIfResult", "mutation_from_dict",
    "profile_fingerprint", "sync_resource_stall_cycles",
]
