"""The optimization advisor: matched rules, priced by what-if replay.

Ties the two lower layers together, GPA-style (estimate-backed
optimizers): :func:`repro.advisor.rules.match_rules` proposes candidate
:class:`Mutation`s from the diagnosed evidence, the
:class:`~repro.advisor.whatif.WhatIfEngine` replays each one through the
virtual sampler, and every matched rule becomes one typed :class:`Advice`
carrying its best candidate's modeled speedup.  Advice ranks by
``modeled_speedup x confidence`` so a confident rule with a priced-in
2x counterfactual outranks a speculative one with 2.1x.

The advice list lands in ``Diagnosis`` schema v4 as the JSON-pure
``advice`` section (see :data:`repro.core.report.ADVICE_NOT_RECORDED` for
the not-run / pre-v4 default) and renders through
``Diagnosis.to_markdown`` / ``to_llm_context("C+L(S,A)")``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from ..core.backends import Backend
from ..core.isa import Module
from ..core.sampler import StallProfile
from .rules import RULES, Evidence, Rule, match_rules
from .whatif import Compose, Mutation, WhatIfEngine, mutation_from_dict

__all__ = ["Advice", "AdvisorReport", "Advisor", "advice_section"]


@dataclass
class Advice:
    """One ranked recommendation: rule + priced mutation + evidence."""

    rule: str                       # Rule.name
    mutation: Dict[str, Any]        # Mutation.to_dict() of the best candidate
    description: str                # vendor-native phrasing
    modeled_speedup: float
    modeled_delta_cycles: float
    confidence: float
    evidence: List[str] = field(default_factory=list)

    @property
    def score(self) -> float:
        return self.modeled_speedup * self.confidence

    def to_mutation(self) -> Mutation:
        return mutation_from_dict(self.mutation)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "rule": self.rule,
            "mutation": dict(self.mutation),
            "description": self.description,
            "modeled_speedup": self.modeled_speedup,
            "modeled_delta_cycles": self.modeled_delta_cycles,
            "confidence": self.confidence,
            "score": self.score,
            "evidence": list(self.evidence),
        }

    @classmethod
    def from_dict(cls, data: Dict[str, Any]) -> "Advice":
        return cls(
            rule=data["rule"],
            mutation=dict(data["mutation"]),
            description=data["description"],
            modeled_speedup=float(data["modeled_speedup"]),
            modeled_delta_cycles=float(data["modeled_delta_cycles"]),
            confidence=float(data["confidence"]),
            evidence=list(data.get("evidence", ())),
        )


@dataclass
class AdvisorReport:
    """Full advisor outcome for one ``(module, backend)`` pair."""

    backend: str
    advice: List[Advice]
    baseline_makespan_cycles: float
    rules_matched: int
    candidates_replayed: int
    advisor_seconds: float

    @property
    def top(self) -> Optional[Advice]:
        return self.advice[0] if self.advice else None

    def to_dict(self) -> Dict[str, Any]:
        return {
            "backend": self.backend,
            "advice": [a.to_dict() for a in self.advice],
            "baseline_makespan_cycles": self.baseline_makespan_cycles,
            "rules_matched": self.rules_matched,
            "candidates_replayed": self.candidates_replayed,
            "advisor_seconds": self.advisor_seconds,
        }


class Advisor:
    """Match rules against evidence, price candidates, rank advice.

    ``max_candidates_per_rule`` bounds replay cost (the bench lane gates
    advise=True at < 3x plain pipeline time); ``min_speedup`` drops
    candidates whose counterfactual does not move the makespan at all
    (an unpriced rule is noise, not advice)."""

    def __init__(self, rules: Optional[List[Rule]] = None, *,
                 max_candidates_per_rule: int = 3,
                 min_speedup: float = 1.0 + 1e-9):
        self.rules = list(rules) if rules is not None else list(RULES)
        self.max_candidates_per_rule = max_candidates_per_rule
        self.min_speedup = min_speedup

    def report(self, module: Module, backend: Backend, *,
               profile: Optional[StallProfile] = None,
               blame: Optional[object] = None) -> AdvisorReport:
        t0 = time.perf_counter()
        engine = WhatIfEngine(module, backend)
        if profile is None:
            profile = engine.baseline()
        else:
            # reuse the pipeline's profile: the advisor must not re-pay
            # the baseline sampler run the diagnosis already did
            engine._baseline = profile
        evidence = Evidence(backend=backend, profile=profile, blame=blame)
        ev_lines = evidence.lines()
        matched = match_rules(evidence, self.rules)
        advice: List[Advice] = []
        replayed = 0
        for rule in matched:
            best = None
            for mutation in rule.candidates(evidence)[
                    :self.max_candidates_per_rule]:
                result = engine.replay(mutation)
                replayed += 1
                if best is None or \
                        result.modeled_speedup > best.modeled_speedup:
                    best = result
            if best is None or best.modeled_speedup < self.min_speedup:
                continue
            advice.append(Advice(
                rule=rule.name,
                mutation=best.mutation.to_dict(),
                description=rule.phrase(backend),
                modeled_speedup=best.modeled_speedup,
                modeled_delta_cycles=best.delta_cycles,
                confidence=rule.confidence,
                evidence=ev_lines,
            ))
        advice.sort(key=lambda a: (-a.score, a.rule))
        return AdvisorReport(
            backend=backend.name,
            advice=advice,
            baseline_makespan_cycles=engine.baseline().makespan_cycles,
            rules_matched=len(matched),
            candidates_replayed=replayed,
            advisor_seconds=time.perf_counter() - t0,
        )

    def advise(self, module: Module, backend: Backend, *,
               profile: Optional[StallProfile] = None,
               blame: Optional[object] = None) -> List[Advice]:
        return self.report(module, backend, profile=profile,
                           blame=blame).advice

    def compose(self, module: Module, backend: Backend, *,
                top_k: int = 2,
                profile: Optional[StallProfile] = None,
                blame: Optional[object] = None,
                report: Optional[AdvisorReport] = None,
                mutations: Optional[List[Mutation]] = None) -> AdvisorReport:
        """Price the top-k advice *stacked* and rank the composed
        candidate alongside the singles.

        Stacked fixes do not add linearly (coalescing tags can erase the
        serialization a pool resize would have bought), so the composed
        :class:`~repro.advisor.whatif.Compose` gets exactly ONE joint
        what-if replay through the fully-mutated world — never a sum of
        per-part deltas.  Pass ``report`` to extend an advisor run you
        already paid for, and ``mutations`` to stack an explicit list
        (the rewrite loop does, with its applied program rewrites)
        instead of the top-k advice mutations.  Returns a new
        :class:`AdvisorReport`; the input ``report`` is not mutated."""
        t0 = time.perf_counter()
        if report is None:
            report = self.report(module, backend, profile=profile,
                                 blame=blame)
        if mutations is not None:
            parts = list(mutations)
            stacked = [a for a in report.advice
                       if any(a.mutation == p.to_dict() for p in parts)]
        else:
            stacked = report.advice[:top_k]
            parts = [a.to_mutation() for a in stacked]
        if len(parts) < 2:
            # nothing to stack: composing 0-1 mutations is the single
            return report
        engine = WhatIfEngine(module, backend)
        if profile is not None:
            engine._baseline = profile
        composed = Compose(parts=tuple(parts))
        result = engine.replay(composed)
        rule_name = "compose(" + "+".join(
            a.rule for a in stacked) + ")" if stacked else "compose"
        advice = list(report.advice)
        if result.modeled_speedup >= self.min_speedup:
            advice.append(Advice(
                rule=rule_name,
                mutation=composed.to_dict(),
                description="stacked: " + "; ".join(
                    p.describe() for p in parts),
                modeled_speedup=result.modeled_speedup,
                modeled_delta_cycles=result.delta_cycles,
                confidence=min((a.confidence for a in stacked), default=0.5),
                evidence=[f"joint replay of {len(parts)} stacked "
                          f"mutations (one sampler run, not a sum of "
                          f"per-part deltas)"],
            ))
        advice.sort(key=lambda a: (-a.score, a.rule))
        return AdvisorReport(
            backend=report.backend,
            advice=advice,
            baseline_makespan_cycles=report.baseline_makespan_cycles,
            rules_matched=report.rules_matched,
            candidates_replayed=report.candidates_replayed + engine.replays,
            advisor_seconds=report.advisor_seconds
            + (time.perf_counter() - t0),
        )


def advice_section(advice: List[Advice],
                   report: Optional[AdvisorReport] = None) -> Dict[str, Any]:
    """The JSON-pure Diagnosis-v4 ``advice`` section for a ran advisor
    (contrast :data:`repro.core.report.ADVICE_NOT_RECORDED`)."""
    out: Dict[str, Any] = {
        "recorded": True,
        "count": len(advice),
        "items": [a.to_dict() for a in advice],
    }
    if report is not None:
        out["rules_matched"] = report.rules_matched
        out["candidates_replayed"] = report.candidates_replayed
        out["baseline_makespan_cycles"] = report.baseline_makespan_cycles
    return out
