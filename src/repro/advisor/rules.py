"""Evidence-pattern matchers mapping LEO diagnoses to candidate mutations.

The paper's case study 1 shows the same kernel wants *different* fixes per
vendor: contended named barriers on NVIDIA-class parts want batched
``bar.sync``, two oversubscribed waitcnt counters on AMD-class parts want
coalesced ``s_waitcnt``, and an Intel-class part whose 16 SBIDs never
contend wants issue-side restructuring instead.  Each :class:`Rule` here
encodes one such evidence pattern -> advice mapping:

  * ``matches(evidence)``    — does the diagnosed pressure shape fit?
  * ``candidates(evidence)`` — concrete :class:`Mutation` counterfactuals
    for the what-if engine to price;
  * ``phrase(evidence)``     — the advice text in the *vendor's* language
    (barriers vs waitcnt vs SBIDs), falling back to unified phrasing for
    vendors without a native entry.

Rules never rank themselves; :mod:`repro.advisor.advisor` replays every
candidate and ranks by modeled speedup x confidence, GPA-style.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from ..core.backends import Backend
from ..core.isa import StallClass
from ..core.sampler import StallProfile
from .whatif import (
    CoalesceSyncTags,
    Mutation,
    ResizePool,
    ScaleLatency,
    SetIssue,
    SetOccupancy,
    TreeReduceChain,
)

__all__ = ["Evidence", "Rule", "RULES", "rule_by_name", "match_rules"]


@dataclass
class Evidence:
    """Everything a matcher may inspect, pre-digested from one analysis."""

    backend: Backend
    profile: StallProfile
    blame: Optional[object] = None      # BlameResult when the full pipeline ran

    # -- sync-resource evidence -----------------------------------------------

    def contended_pools(self) -> List[Dict[str, Any]]:
        sp = self.profile.sync_pressure
        if sp is None:
            return []
        return [p for p in sp.pools if p.get("evictions", 0) > 0]

    def pools_of_kind(self, kind: str) -> List[Dict[str, Any]]:
        return [p for p in self.contended_pools() if p.get("kind") == kind]

    # -- issue-fabric evidence ------------------------------------------------

    @property
    def issue(self):
        return self.backend.issue

    @property
    def not_selected_cycles(self) -> float:
        ip = self.profile.issue_pressure
        return ip.not_selected_cycles if ip is not None else 0.0

    @property
    def pipe_busy_cycles(self) -> float:
        ip = self.profile.issue_pressure
        return ip.pipe_busy_cycles if ip is not None else 0.0

    # -- wave-occupancy evidence ----------------------------------------------

    @property
    def native_occupancy(self):
        from ..core.hwmodel import SINGLE_WAVE
        return getattr(self.backend, "native_occupancy", None) or SINGLE_WAVE

    @property
    def occupancy_engaged(self) -> bool:
        """True when this analysis already modeled multi-wave residency."""
        return self.backend.occupancy.multi_wave

    @property
    def occupancy_limited_cycles(self) -> float:
        op = getattr(self.profile, "occupancy_pressure", None)
        return op.occupancy_limited_cycles if op is not None else 0.0

    @property
    def hidden_fraction(self) -> float:
        op = getattr(self.profile, "occupancy_pressure", None)
        return op.hidden_fraction if op is not None else 0.0

    # -- stall anatomy --------------------------------------------------------

    def stall_cycles(self, cls: StallClass) -> float:
        return sum(r.stall_breakdown.get(cls, 0.0)
                   for r in self.profile.records.values())

    @property
    def total_stall_cycles(self) -> float:
        return self.profile.total_stall_cycles

    def stall_share(self, cls: StallClass) -> float:
        total = self.total_stall_cycles
        return self.stall_cycles(cls) / total if total > 0 else 0.0

    def _count_starts(self) -> int:
        # profile records carry qualified names only; count *-start records
        return sum(1 for q in self.profile.records
                   if "-start" in q.rsplit("::", 1)[-1])

    def lines(self) -> List[str]:
        """Human-readable evidence summary attached to every Advice."""
        out: List[str] = []
        for p in self.contended_pools():
            out.append(
                f"pool {p['pool']!r} ({p['kind']}, {p.get('scope', '?')}-"
                f"scoped, capacity {p['capacity']}): {p['evictions']} "
                f"evictions, {p['contention_cycles']:.0f} contention "
                f"cycles, peak {p['peak_in_flight']} in flight")
        ip = self.profile.issue_pressure
        if ip is not None and ip.contended:
            out.append(
                f"issue fabric {self.issue.queues}x{self.issue.width} "
                f"({self.issue.policy}): not_selected "
                f"{ip.not_selected_cycles:.0f}, pipe_busy "
                f"{ip.pipe_busy_cycles:.0f} cycles")
        mem = self.stall_cycles(StallClass.MEM_DEP)
        if mem > 0:
            out.append(f"mem_dep stalls: {mem:.0f} cycles "
                       f"({self.stall_share(StallClass.MEM_DEP):.0%} of "
                       f"all stalls)")
        sync_res = self.stall_cycles(StallClass.SYNC_RESOURCE)
        if sync_res > 0:
            out.append(f"sync_resource stalls: {sync_res:.0f} cycles")
        op = getattr(self.profile, "occupancy_pressure", None)
        if op is not None:
            out.append(
                f"wave occupancy {op.waves}w ({op.limiter}-limited): "
                f"{op.hidden_cycles:.0f} cycles hidden "
                f"({op.hidden_fraction:.0%}), {op.exposed_cycles:.0f} "
                f"exposed past the resident waves")
        return out


@dataclass(frozen=True)
class Rule:
    """One evidence pattern -> candidate-mutation mapping."""

    name: str
    summary: str                        # unified (vendor-neutral) phrasing
    confidence: float                   # prior in (0, 1]; ranks with speedup
    matches: Callable[[Evidence], bool] = field(repr=False)
    candidates: Callable[[Evidence], List[Mutation]] = field(repr=False)
    #: vendor -> native phrasing; key is ``Backend.vendor``.
    vendor_phrasing: Dict[str, str] = field(default_factory=dict)

    def phrase(self, backend: Backend) -> str:
        return self.vendor_phrasing.get(backend.vendor, self.summary)


# -- matchers -----------------------------------------------------------------

def _m_barrier_storm(ev: Evidence) -> bool:
    """Device-scoped barrier/token pool at peak capacity with evictions."""
    return any(p.get("scope") == "device" for p in
               ev.pools_of_kind("barrier") + ev.pools_of_kind("token"))


def _coalesce_group(ev: Evidence, pool: Dict[str, Any]) -> int:
    """Group size that fits the storm back into the pool: enough async
    starts per shared identifier that distinct live tags <= what the part
    actually has (replicated per queue for queue-scoped pools)."""
    starts = max(1, ev._count_starts())
    effective = pool["capacity"]
    if pool.get("scope") == "queue":
        effective *= max(1, pool.get("queues", 1))
    return max(2, -(-starts // max(1, effective)))   # ceil div


def _grow_capacity(pool: Dict[str, Any]) -> int:
    """The grow-counterfactual target: peak live + every eviction is an
    upper bound on concurrent demand (peak_in_flight saturates at
    capacity, so it alone cannot size the grow)."""
    return pool["capacity"] + max(1, pool["evictions"])


def _c_batch_barriers(ev: Evidence) -> List[Mutation]:
    out: List[Mutation] = []
    for p in ev.contended_pools():
        if p.get("scope") == "device":
            out.append(CoalesceSyncTags(group=_coalesce_group(ev, p)))
            out.append(ResizePool(pool=p["pool"],
                                  capacity=_grow_capacity(p)))
    out.append(CoalesceSyncTags(group=2))
    return out


def _m_waitcnt_storm(ev: Evidence) -> bool:
    return bool(ev.pools_of_kind("waitcnt"))


def _c_coalesce_waits(ev: Evidence) -> List[Mutation]:
    out: List[Mutation] = []
    for p in ev.pools_of_kind("waitcnt"):
        out.append(CoalesceSyncTags(group=_coalesce_group(ev, p)))
        out.append(ResizePool(pool=p["pool"], capacity=_grow_capacity(p)))
    out.append(CoalesceSyncTags(group=2))
    return out


def _m_token_recycle(ev: Evidence) -> bool:
    """Queue-scoped token/SBID pool oversubscribed."""
    return any(p.get("scope") == "queue" for p in ev.pools_of_kind("token"))


def _c_recycle_tokens(ev: Evidence) -> List[Mutation]:
    out: List[Mutation] = []
    for p in ev.pools_of_kind("token"):
        if p.get("scope") == "queue":
            out.append(CoalesceSyncTags(group=_coalesce_group(ev, p)))
            out.append(ResizePool(pool=p["pool"],
                                  capacity=_grow_capacity(p)))
    return out


def _m_rebalance(ev: Evidence) -> bool:
    return (ev.issue.policy == "greedy_oldest"
            and ev.not_selected_cycles > 0
            and ev.not_selected_cycles >= ev.pipe_busy_cycles)


def _c_rebalance(ev: Evidence) -> List[Mutation]:
    q = ev.issue.queues
    out: List[Mutation] = [SetIssue(policy="round_robin"),
                           SetIssue(queues=max(2, q * 2)),
                           SetIssue(width=ev.issue.width + 1)]
    if ev.native_occupancy.multi_wave and not ev.occupancy_engaged:
        # more resident waves = more arbitration choices; priced jointly
        # with the sync-pool sharing it costs (never assumed to win)
        out.append(SetOccupancy())
    return out


def _m_pipe_pressure(ev: Evidence) -> bool:
    return (ev.pipe_busy_cycles > 0
            and ev.pipe_busy_cycles > ev.not_selected_cycles)


def _c_pipe_pressure(ev: Evidence) -> List[Mutation]:
    return [SetIssue(width=ev.issue.width * 2),
            SetIssue(policy="greedy_oldest")
            if ev.issue.policy == "round_robin"
            else SetIssue(policy="round_robin")]


def _m_raise_occupancy(ev: Evidence) -> bool:
    """Latency hiding is under-provisioned: either residency is not
    engaged while hideable latency dominates on a part that has wave
    slots to spend, or it IS engaged and stalls still leak past the
    resident waves (OCCUPANCY_LIMITED present)."""
    if not ev.native_occupancy.multi_wave:
        return False            # single-wave parts have no residency knob
    if ev.occupancy_engaged:
        return ev.occupancy_limited_cycles > 0
    # Mirror what the sampler's wave credit can actually absorb: the
    # _HIDEABLE_STALLS dependence waits plus SYNC_RESOURCE (the sampler
    # drains credit against resource serialization too).  Scheduler
    # contention (PIPE_BUSY / NOT_SELECTED) stays out — another wave
    # loses the same arbitration.
    hideable = (ev.stall_share(StallClass.MEM_DEP)
                + ev.stall_share(StallClass.EXEC_DEP)
                + ev.stall_share(StallClass.COLLECTIVE_WAIT)
                + ev.stall_share(StallClass.SYNC_WAIT)
                + ev.stall_share(StallClass.SYNC_RESOURCE))
    return hideable >= 0.25


def _c_raise_occupancy(ev: Evidence) -> List[Mutation]:
    native = ev.native_occupancy
    if ev.occupancy_engaged:
        cur = ev.backend.occupancy
        return [SetOccupancy(waves=cur.waves * 2),
                SetOccupancy(window_cycles=cur.window_cycles * 2)]
    return [SetOccupancy(),     # engage at the part's native residency
            SetOccupancy(waves=max(2, native.waves // 2))]


def _m_exposed_memory(ev: Evidence) -> bool:
    """Memory latency dominates while sync resources are NOT the problem:
    the copies fit the part's scoreboards, their latency is just exposed
    at the consumers — prefetch / software-pipeline territory."""
    if ev.contended_pools():
        return False
    return (ev.stall_share(StallClass.MEM_DEP) >= 0.15
            and ev._count_starts() > 0)


def _c_exposed_memory(ev: Evidence) -> List[Mutation]:
    return [ScaleLatency(hw_field="hbm_bw", factor=2.0),
            ScaleLatency(hw_field="dma_setup_cycles", factor=0.5)]


def _m_serial_chain(ev: Evidence) -> bool:
    """A wide, uncontended issue fabric starved by serial dependence
    chains: every sync scoreboard has slack (no evictions), the part has
    real issue width, and exec_dep dominates the stall anatomy — the
    bottleneck is issue-side program shape, not resources."""
    if ev.contended_pools():
        return False
    return (ev.issue.ports >= 4
            and ev.stall_share(StallClass.EXEC_DEP) >= 0.4)


def _c_serial_chain(ev: Evidence) -> List[Mutation]:
    return [TreeReduceChain(min_length=4),
            SetIssue(width=ev.issue.width * 2)]


#: The rule catalog, in match-check order (ranking is by replay outcome,
#: not catalog position).
RULES: List[Rule] = [
    Rule(
        name="batch_sync_allocations",
        summary=("reduce in-flight async copies: guard groups of transfers "
                 "with one synchronization point (batch barriers)"),
        confidence=0.9,
        matches=_m_barrier_storm,
        candidates=_c_batch_barriers,
        vendor_phrasing={
            "nvidia": ("named barriers B1-B6 are device-shared and "
                       "oversubscribed: batch bar.sync — guard groups of "
                       "cp.async transfers with one barrier instead of one "
                       "each"),
            "amd": ("s_barrier is device-shared and oversubscribed: batch "
                    "barrier use across wavefronts"),
            "intel": ("named barriers (nbar) are oversubscribed: batch "
                      "barrier signals across async transfers"),
        },
    ),
    Rule(
        name="coalesce_outstanding_waits",
        summary=("coalesce counter-style waits: drain several outstanding "
                 "transfers per wait instead of one wait per transfer"),
        confidence=0.9,
        matches=_m_waitcnt_storm,
        candidates=_c_coalesce_waits,
        vendor_phrasing={
            "amd": ("vmcnt/lgkmcnt counters are oversubscribed: coalesce "
                    "s_waitcnt — issue groups of global loads, then one "
                    "s_waitcnt(vmcnt <= N) drains the group"),
            "nvidia": ("commit-group depth exceeded: batch cp.async.commit_"
                       "group and wait on groups, not single copies"),
        },
    ),
    Rule(
        name="recycle_scoreboard_tokens",
        summary=("recycle in-order scoreboard tokens: reuse one token "
                 "across dependent async ops instead of allocating fresh"),
        confidence=0.85,
        matches=_m_token_recycle,
        candidates=_c_recycle_tokens,
        vendor_phrasing={
            "intel": ("SWSB SBIDs ($0-$15) are oversubscribed on a vector "
                      "engine: reuse one SBID across grouped sends ({$N.dst} "
                      "on the group's last consumer)"),
        },
    ),
    Rule(
        name="rebalance_issue_queues",
        summary=("rebalance independent chains across issue queues: ready "
                 "work keeps losing greedy-oldest arbitration"),
        confidence=0.75,
        matches=_m_rebalance,
        candidates=_c_rebalance,
        vendor_phrasing={
            "nvidia": ("warps lose scheduler arbitration (not_selected): "
                       "spread independent chains across warps/schedulers, "
                       "or raise occupancy — cap registers with "
                       "__launch_bounds__ / -maxrregcount so more warps "
                       "fit the register file and greedy-oldest has "
                       "choices"),
        },
    ),
    Rule(
        name="raise_occupancy",
        summary=("raise wave residency: co-resident waves would hide the "
                 "exposed latency the single wave keeps eating — lower "
                 "per-wave resource usage so more waves fit"),
        confidence=0.8,
        matches=_m_raise_occupancy,
        candidates=_c_raise_occupancy,
        vendor_phrasing={
            "nvidia": ("raise resident warps per SM: cap the register "
                       "budget with __launch_bounds__(threads, minBlocks) "
                       "or -maxrregcount so more warps fit the register "
                       "file; the priced counterfactual also charges the "
                       "shared named-barrier cost extra warps bring"),
            "amd": ("raise waves-per-EU: trim VGPR/LDS usage (or pin "
                    "amdgpu-waves-per-eu) so more wavefronts occupy the "
                    "wavefront slots and hide vmcnt latency"),
            "intel": ("raise thread residency per Xe vector engine: "
                      "compile for the small-GRF mode so the full 8 "
                      "hardware threads stay resident instead of the "
                      "large-GRF half"),
        },
    ),
    Rule(
        name="spread_same_pipe_work",
        summary=("interleave work across execution pipes: one pipe is "
                 "saturated while others idle (pipe_busy-heavy)"),
        confidence=0.7,
        matches=_m_pipe_pressure,
        candidates=_c_pipe_pressure,
        vendor_phrasing={
            "amd": ("one SIMD's pipe is saturated: interleave VALU and MFMA "
                    "work so the round-robin rotation finds mixed-pipe "
                    "instructions"),
            "intel": ("a shared execution pipe is saturated: co-issue "
                      "different-pipe instructions on the paired ALUs"),
        },
    ),
    Rule(
        name="expose_ilp_tree_reduce",
        summary=("expose instruction-level parallelism: the issue fabric "
                 "is idle behind a serial dependence chain — restructure "
                 "reductions as balanced trees"),
        confidence=0.8,
        matches=_m_serial_chain,
        candidates=_c_serial_chain,
        vendor_phrasing={
            "intel": ("16 SBIDs uncontended and the 8x2 issue fabric is "
                      "starved by one serial chain: tree-reduce so "
                      "independent adds co-issue across vector engines "
                      "(issue-side, not a sync problem)"),
            "nvidia": ("schedulers are starved by a serial dependence "
                       "chain: tree-reduce so independent warps make "
                       "progress"),
            "amd": ("SIMD rotation is starved by a serial dependence "
                    "chain: tree-reduce so every SIMD sees ready work"),
        },
    ),
    Rule(
        name="prefetch_software_pipeline",
        summary=("prefetch / software-pipeline: transfer latency is exposed "
                 "at consumers although sync resources are uncontended — "
                 "issue copies earlier and overlap compute with the tail"),
        confidence=0.8,
        matches=_m_exposed_memory,
        candidates=_c_exposed_memory,
        vendor_phrasing={
            "intel": ("16 SBIDs are uncontended — the bottleneck is issue-"
                      "side: software-pipeline the consumer chain so "
                      "prefetched transfers overlap compute (double-buffer "
                      "in SLM)"),
            "nvidia": ("prefetch with cp.async into a double buffer and "
                       "software-pipeline the consumer loop"),
            "amd": ("prefetch with global_load_dword into a second buffer "
                    "and software-pipeline the MFMA loop"),
        },
    ),
]


def rule_by_name(name: str) -> Rule:
    for r in RULES:
        if r.name == name:
            return r
    raise KeyError(f"unknown rule {name!r}; known: {[r.name for r in RULES]}")


def match_rules(evidence: Evidence,
                rules: Optional[List[Rule]] = None) -> List[Rule]:
    """Every rule whose evidence pattern fits this diagnosis."""
    return [r for r in (rules if rules is not None else RULES)
            if r.matches(evidence)]
