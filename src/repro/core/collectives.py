"""Collective-traffic extraction from compiled HLO (roofline + LEO shared).

`compiled.cost_analysis()` does not expose collective bytes, so the roofline
collective term is derived by parsing the HLO text and summing operand sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (the deliverable's prescription).  Two views:

* `collective_operand_bytes(text)` — the literal prescription: sum of
  operand bytes per collective opcode, trip-count-unaware (one pass of the
  program text).
* `collective_summary(module)` — the trip-aware, per-chip *wire* bytes LEO's
  sampler uses (ring-algorithm effective bytes, scaled by loop trip counts),
  per opcode with op counts.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

from .hlo_parser import parse_hlo, parse_shape, _take_shape_prefix
from .isa import Module, OpClass

COLLECTIVE_OPCODES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%[^\s=]+\s*=\s*(?P<shape>\([^=]*?\)|[a-z0-9]+(?:\[[^\]]*\])?(?:\{[^}]*\})?)\s+"
    r"(?P<opcode>" + "|".join(COLLECTIVE_OPCODES) + r")(?:-start|-done)?\(")


@dataclass
class CollectiveStats:
    op_count: int = 0
    operand_bytes: float = 0.0   # raw operand sizes (prescription)
    wire_bytes: float = 0.0      # effective per-chip ICI bytes (trip-aware)


def collective_operand_bytes(hlo_text: str) -> Dict[str, CollectiveStats]:
    """Literal prescription: sum operand sizes of collective ops in the text.

    Operand sizes are recovered from the producing instructions' shapes, so
    we parse the module once and walk collective instructions.
    """
    module = parse_hlo(hlo_text)
    return _operand_bytes_from_module(module, trip_aware=False)


def collective_summary(module: Module,
                       trip_aware: bool = True) -> Dict[str, CollectiveStats]:
    return _operand_bytes_from_module(module, trip_aware=trip_aware)


def _operand_bytes_from_module(module: Module,
                               trip_aware: bool) -> Dict[str, CollectiveStats]:
    stats: Dict[str, CollectiveStats] = {}
    mults = _trip_multipliers(module) if trip_aware else {}
    for comp in module.computations.values():
        mult = mults.get(comp.name, 1.0) if trip_aware else 1.0
        for instr in comp.instructions:
            base = instr.opcode.replace("-start", "").replace("-done", "")
            if base not in COLLECTIVE_OPCODES:
                continue
            if instr.opcode.endswith("-done"):
                continue  # counted at the start op
            s = stats.setdefault(base, CollectiveStats())
            s.op_count += int(mult) if trip_aware else 1
            operand_bytes = sum(
                comp.get(o).shape.byte_size for o in instr.operands
                if comp.get(o) is not None)
            s.operand_bytes += mult * operand_bytes
            s.wire_bytes += mult * instr.comm_bytes
    return stats


def _trip_multipliers(module: Module) -> Dict[str, float]:
    """Execution multiplier per computation (product of enclosing trips)."""
    mults: Dict[str, float] = {}

    def visit(comp_name: str, mult: float, depth: int) -> None:
        if depth > 16 or comp_name not in module.computations:
            return
        mults[comp_name] = max(mults.get(comp_name, 0.0), mult)
        for instr in module.computations[comp_name].instructions:
            inner = mult * (instr.trip_count if instr.opcode == "while" else 1)
            for callee in instr.called_computations:
                visit(callee, inner, depth + 1)

    if module.entry:
        visit(module.entry, 1.0, 0)
    return mults


def total_collective_bytes(module_or_text, trip_aware: bool = True) -> float:
    if isinstance(module_or_text, str):
        module = parse_hlo(module_or_text)
    else:
        module = module_or_text
    return sum(s.wire_bytes for s in
               collective_summary(module, trip_aware).values())
