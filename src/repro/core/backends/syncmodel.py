"""First-class vendor synchronization resources (paper §III-E).

The paper's central observation is that stall root causes hinge on
*vendor-specific* synchronization mechanisms backed by **finite hardware
resources**: NVIDIA exposes six named barriers B1-B6, AMD drains
``s_waitcnt`` memory counters (vmcnt/lgkmcnt), Intel allocates SWSB
scoreboard IDs $0..$15.  A kernel that keeps more transfers in flight than
the part has resources *serializes* — the hardware reuses the oldest
in-flight resource, and the reusing instruction inherits its latency (the
oldest-(M-N) rule §III-E).

This module makes those resources behavioral:

* :class:`SyncResourcePool` — one finite, *named* set of physical resource
  instances (``B1..B6``, ``vmcnt``/``lgkmcnt``, ``$0..$15``);
* :class:`SyncModel` — a backend's immutable descriptor: its pools plus a
  routing table mapping each abstract :class:`~repro.core.isa.SyncKind`
  (what the unified IR records) onto the pool that physically implements
  it on this vendor.  Async-copy barriers ride named barriers on
  NVIDIA-class parts, waitcnt counters on AMD-class parts, and SBID
  tokens on Intel-class parts — which is exactly why the same kernel
  blames differently per vendor;
* :class:`SyncScoreboard` — the *stateful* allocator the virtual sampler
  drives: ``acquire`` claims an instance (serializing against the oldest
  holder when the pool is exhausted), ``complete`` records when the
  underlying transfer lands, ``retire`` returns the instance.  It never
  exceeds capacity and a full allocate→retire round-trip drains to empty
  (property-tested in ``tests/test_syncmodel.py``);
* :class:`SyncPressureReport` — the JSON-pure per-pool pressure summary
  that flows into ``LeoAnalysis.sync_pressure`` and the ``Diagnosis``
  ``sync_resources`` section ("barrier slots 6/6 in flight at peak").

:class:`SyncSemantics` — the pre-SyncModel knob bag whose counts nothing
read — survives as a parity-tested deprecation shim: constructing one
warns and any :class:`~repro.core.backends.Backend` built with it is
converted via :meth:`SyncSemantics.to_model`.
"""
from __future__ import annotations

import warnings
from collections import OrderedDict
from dataclasses import dataclass, field, replace as _dc_replace
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Union

from ..isa import SyncKind

#: Contention events retained per pool (bounds report size on pathological
#: programs; the counters keep aggregating past the cap).
_MAX_EVENTS_PER_POOL = 64


# --------------------------------------------------------------------------
# Descriptors (immutable).
# --------------------------------------------------------------------------

#: Valid sync-resource pool scopes (see :class:`SyncResourcePool.scope`).
POOL_SCOPES: Tuple[str, ...] = ("device", "queue")


@dataclass(frozen=True)
class SyncResourcePool:
    """A finite, named set of physical sync-resource instances.

    ``scope`` says how the pool replicates under a multi-queue issue model
    (:class:`~repro.core.hwmodel.IssueModel`):

    * ``"device"`` — one physical pool shared by every issue queue.  This
      is NVIDIA's named barriers (CTA-scoped: all four warp schedulers of
      an SM allocate from the same B1-B6) and the TPU pools (per-core
      resources behind a single VLIW stream).
    * ``"queue"``  — each issue queue owns a private copy of the pool.
      This is AMD's ``s_waitcnt`` counters (architecturally per-wave:
      every wave slot tracks its own vmcnt/lgkmcnt) and Intel's SWSB
      scoreboard IDs (per-thread).

    With a single issue queue the distinction vanishes — both scopes
    behave as one pool, which is what keeps K=1 profiles byte-identical
    to the pre-multi-stream sampler.
    """

    name: str                   # registry key, e.g. "named_barrier"
    kind: SyncKind              # native mechanism this pool implements
    label: str                  # human label, e.g. "named barriers B1-B6"
    instances: Tuple[str, ...]  # concrete instance names; len == capacity
    scope: str = "device"       # "device" (shared) | "queue" (per-queue)

    def __post_init__(self) -> None:
        if not self.instances:
            raise ValueError(f"pool {self.name!r} needs >= 1 instance")
        if len(set(self.instances)) != len(self.instances):
            raise ValueError(f"pool {self.name!r} has duplicate instances")
        if self.scope not in POOL_SCOPES:
            raise ValueError(f"pool {self.name!r} scope {self.scope!r} not "
                             f"in {POOL_SCOPES}")

    @property
    def capacity(self) -> int:
        return len(self.instances)

    @classmethod
    def counted(cls, name: str, kind: SyncKind, label: str, prefix: str,
                capacity: int, start: int = 0,
                scope: str = "device") -> "SyncResourcePool":
        return cls(name=name, kind=kind, label=label,
                   instances=tuple(f"{prefix}{i}"
                                   for i in range(start, start + capacity)),
                   scope=scope)


@dataclass(frozen=True)
class SyncModel:
    """A backend's synchronization-resource descriptor.

    ``routing`` maps each abstract mechanism the unified IR can record
    (async-pair BARRIER, DMA-counter WAITCNT, token-threading TOKEN) onto
    the pool that physically backs it on this vendor.  Kinds left out of
    the routing fall back to the first declared pool (emulation).
    ``scoreboard()`` mints a fresh stateful allocator.
    """

    pools: Tuple[SyncResourcePool, ...] = ()
    routing: Tuple[Tuple[SyncKind, str], ...] = ()
    async_collectives: bool = True

    def __post_init__(self) -> None:
        # accept a Mapping for ergonomic construction; store a sorted
        # tuple so repr/fingerprints are deterministic
        routing = self.routing
        if isinstance(routing, Mapping):
            routing = tuple(sorted(routing.items(), key=lambda kv: kv[0].value))
        object.__setattr__(self, "routing", tuple(routing))
        names = [p.name for p in self.pools]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pool names: {names}")
        for _, pname in self.routing:
            if pname not in names:
                raise ValueError(
                    f"routing targets unknown pool {pname!r}; have {names}")

    # -- lookups ---------------------------------------------------------------

    def pool(self, name: str) -> SyncResourcePool:
        for p in self.pools:
            if p.name == name:
                return p
        raise KeyError(f"no pool named {name!r}")

    def pool_for(self, kind: SyncKind) -> Optional[SyncResourcePool]:
        """The pool physically backing `kind` (first pool when unrouted)."""
        for k, pname in self.routing:
            if k is kind:
                return self.pool(pname)
        return self.pools[0] if self.pools else None

    def serves(self, pool_name: str) -> Tuple[SyncKind, ...]:
        """Which abstract kinds route onto `pool_name`."""
        return tuple(k for k, p in self.routing if p == pool_name)

    # -- legacy knob views (SyncSemantics compatibility) -----------------------

    def _capacity_of_kind(self, kind: SyncKind) -> int:
        return sum(p.capacity for p in self.pools if p.kind is kind)

    @property
    def barrier_slots(self) -> int:
        return self._capacity_of_kind(SyncKind.BARRIER)

    @property
    def waitcnt_counters(self) -> int:
        return self._capacity_of_kind(SyncKind.WAITCNT)

    @property
    def swsb_tokens(self) -> int:
        return self._capacity_of_kind(SyncKind.TOKEN)

    @property
    def mechanisms(self) -> Tuple[SyncKind, ...]:
        seen: List[SyncKind] = []
        for p in self.pools:
            if p.kind not in seen:
                seen.append(p.kind)
        return tuple(seen)

    # -- factories -------------------------------------------------------------

    def scoreboard(self, realloc_cycles: float = 0.0,
                   queues: int = 1, waves: int = 1) -> "SyncScoreboard":
        """Mint a stateful allocator; ``queues`` > 1 replicates every
        ``scope="queue"`` pool per issue queue (ROADMAP's "one scoreboard
        per simulated core/queue") while ``scope="device"`` pools stay
        shared.  ``waves`` > 1 gives the simulated wave its fair share of
        every ``scope="device"`` pool (W symmetric co-resident waves
        contend for the same physical instances), while ``scope="queue"``
        pools stay per-wave private — the per-wave scoreboard view."""
        return SyncScoreboard(self, realloc_cycles=realloc_cycles,
                              queues=queues, waves=waves)

    @classmethod
    def from_semantics(cls, sem: "SyncSemantics") -> "SyncModel":
        return _model_from_knobs(sem.mechanisms, sem.barrier_slots,
                                 sem.waitcnt_counters, sem.swsb_tokens,
                                 sem.async_collectives)


#: AMD-style counter names used when synthesizing waitcnt pools from knobs.
_WAITCNT_NAMES = ("vmcnt", "lgkmcnt", "expcnt")


def _model_from_knobs(mechanisms: Sequence[SyncKind], barrier_slots: int,
                      waitcnt_counters: int, swsb_tokens: int,
                      async_collectives: bool) -> SyncModel:
    """Build a SyncModel from legacy SyncSemantics knob values."""
    pools: List[SyncResourcePool] = []
    if barrier_slots > 0:
        pools.append(SyncResourcePool.counted(
            "named_barrier", SyncKind.BARRIER,
            f"named barriers B1-B{barrier_slots}", "B", barrier_slots,
            start=1))
    if waitcnt_counters > 0:
        names = (_WAITCNT_NAMES[:waitcnt_counters]
                 + tuple(f"cnt{i}" for i in range(len(_WAITCNT_NAMES),
                                                  waitcnt_counters)))
        pools.append(SyncResourcePool(
            name="waitcnt_counter", kind=SyncKind.WAITCNT,
            label="s_waitcnt-style outstanding-op counters",
            instances=tuple(names)))
    if swsb_tokens > 0:
        pools.append(SyncResourcePool.counted(
            "swsb_token", SyncKind.TOKEN,
            f"SWSB scoreboard IDs $0-${swsb_tokens - 1}", "$", swsb_tokens))
    by_kind = {p.kind: p.name for p in pools}
    primary: Optional[str] = None
    for m in mechanisms:
        if m in by_kind:
            primary = by_kind[m]
            break
    if primary is None and pools:
        primary = pools[0].name
    routing: Dict[SyncKind, str] = {}
    for kind in (SyncKind.BARRIER, SyncKind.WAITCNT, SyncKind.TOKEN):
        target = by_kind.get(kind) if kind in mechanisms else None
        target = target or primary
        if target is not None:
            routing[kind] = target
    return SyncModel(pools=tuple(pools), routing=routing,
                     async_collectives=async_collectives)


# --------------------------------------------------------------------------
# Deprecated knob bag (parity-tested shim).
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class SyncSemantics:
    """Deprecated: inert sync knobs.  Use :class:`SyncModel` instead.

    Kept as a parity-tested shim (like ``analyze_module`` and
    ``structured_report`` before it): constructing one warns, and a
    :class:`~repro.core.backends.Backend` handed a ``SyncSemantics``
    transparently converts it via :meth:`to_model` — the resulting
    scoreboard behaves identically to the equivalent hand-built model
    (``tests/test_syncmodel.py::TestSyncSemanticsShim``).
    """

    mechanisms: Tuple[SyncKind, ...] = (SyncKind.BARRIER, SyncKind.WAITCNT,
                                        SyncKind.TOKEN)
    barrier_slots: int = 6        # named-barrier resources (NVIDIA: B1..B6)
    waitcnt_counters: int = 2     # outstanding-op counters (AMD: vmcnt/lgkmcnt)
    swsb_tokens: int = 16         # scoreboard token IDs (Intel SWSB: $0..$15)
    async_collectives: bool = True

    def __post_init__(self) -> None:
        warnings.warn(
            "SyncSemantics is deprecated; build a SyncModel (finite, "
            "behavioral sync resources) instead — see docs/sync_resources.md "
            "(shim slated for removal two releases after the SyncModel API "
            "landed)", DeprecationWarning, stacklevel=3)

    def to_model(self) -> SyncModel:
        return SyncModel.from_semantics(self)


SyncLike = Union[SyncModel, SyncSemantics]


def resolve_sync_model(sync: Optional[SyncLike]) -> SyncModel:
    """Coerce a SyncModel / legacy SyncSemantics / None to a SyncModel."""
    if sync is None:
        return DEFAULT_SYNC_MODEL
    if isinstance(sync, SyncModel):
        return sync
    if isinstance(sync, SyncSemantics):
        return sync.to_model()
    raise TypeError(f"cannot resolve a SyncModel from {type(sync).__name__}")


#: Default model for backends that do not declare one: all three mechanisms
#: natively, with the legacy default capacities.
DEFAULT_SYNC_MODEL = _model_from_knobs(
    (SyncKind.BARRIER, SyncKind.WAITCNT, SyncKind.TOKEN),
    barrier_slots=6, waitcnt_counters=2, swsb_tokens=16,
    async_collectives=True)


# --------------------------------------------------------------------------
# Stateful scoreboard.
# --------------------------------------------------------------------------

@dataclass
class SyncAcquire:
    """Result of one scoreboard acquisition."""

    pool: str
    kind: SyncKind
    tag: str
    instance: str
    available_at: float = 0.0        # when the instance can actually be used
    stall_cycles: float = 0.0        # serialization charged to the acquirer
    evicted_tag: Optional[str] = None
    evicted_holder: Optional[str] = None   # qualified instr that held it


@dataclass
class _Alloc:
    tag: str
    instance: str
    holder: str          # qualified name of the acquiring instruction
    busy_until: float    # completion time of the underlying transfer
    count: int = 1       # outstanding ops sharing this instance (counters)


class _PoolBoard:
    """Allocator state for one pool: never exceeds capacity; exhaustion
    serializes against the oldest in-flight allocation (§III-E)."""

    def __init__(self, spec: SyncResourcePool, realloc_cycles: float = 0.0,
                 queue: Optional[int] = None):
        self.spec = spec
        self.realloc_cycles = realloc_cycles
        self.queue = queue     # replica index when the pool is queue-scoped
        self._free: List[str] = list(spec.instances)
        self._live: "OrderedDict[str, _Alloc]" = OrderedDict()
        self.acquisitions = 0
        self.evictions = 0
        self.peak_in_flight = 0
        self.contention_cycles = 0.0
        self.events: List[Dict[str, Any]] = []

    @property
    def in_flight(self) -> int:
        return len(self._live)

    def acquire(self, kind: SyncKind, tag: str, consumer: str, now: float,
                weight: float) -> SyncAcquire:
        self.acquisitions += 1
        live = self._live.get(tag)
        if live is not None:
            # same identifier re-armed while in flight: a counter-style
            # increment on the same physical instance (free)
            live.count += 1
            return SyncAcquire(pool=self.spec.name, kind=kind, tag=tag,
                               instance=live.instance, available_at=now)
        if self._free:
            instance = self._free.pop(0)
            self._live[tag] = _Alloc(tag=tag, instance=instance,
                                     holder=consumer, busy_until=now)
            self.peak_in_flight = max(self.peak_in_flight, len(self._live))
            return SyncAcquire(pool=self.spec.name, kind=kind, tag=tag,
                               instance=instance, available_at=now)
        # Exhausted: reuse the OLDEST in-flight instance; the acquirer
        # inherits its remaining latency, plus the hardware recycle cost
        # (drain/re-arm) that every reuse pays even when the holder's
        # transfer already landed.
        old_tag, old = self._live.popitem(last=False)
        stall = max(0.0, old.busy_until - now) + self.realloc_cycles
        self.evictions += 1
        if stall > 0:
            self.contention_cycles += stall * weight
            if len(self.events) < _MAX_EVENTS_PER_POOL:
                ev = {
                    "consumer": consumer, "instance": old.instance,
                    "holder": old.holder, "evicted_tag": old_tag,
                    "stall_cycles": stall, "at": now, "weight": weight,
                }
                if self.queue is not None:
                    ev["queue"] = self.queue
                self.events.append(ev)
        self._live[tag] = _Alloc(tag=tag, instance=old.instance,
                                 holder=consumer, busy_until=now + stall)
        self.peak_in_flight = max(self.peak_in_flight, len(self._live))
        return SyncAcquire(pool=self.spec.name, kind=kind, tag=tag,
                           instance=old.instance, available_at=now + stall,
                           stall_cycles=stall, evicted_tag=old_tag,
                           evicted_holder=old.holder)

    def complete(self, tag: str, t: float) -> None:
        live = self._live.get(tag)
        if live is not None:
            live.busy_until = max(live.busy_until, t)

    def retire(self, tag: str, drain_to: Optional[int] = None) -> bool:
        live = self._live.get(tag)
        if live is None:
            return False
        if drain_to is None:
            live.count -= 1
        else:
            live.count = min(live.count, max(0, drain_to))
        if live.count <= 0:
            del self._live[tag]
            self._free.append(live.instance)
        return True

    def fork(self) -> "_PoolBoard":
        """Copy the mutable allocator state; the spec is shared."""
        clone = _PoolBoard(self.spec, self.realloc_cycles, queue=self.queue)
        clone._free = list(self._free)
        clone._live = OrderedDict(
            (tag, _Alloc(tag=a.tag, instance=a.instance, holder=a.holder,
                         busy_until=a.busy_until, count=a.count))
            for tag, a in self._live.items())
        clone.acquisitions = self.acquisitions
        clone.evictions = self.evictions
        clone.peak_in_flight = self.peak_in_flight
        clone.contention_cycles = self.contention_cycles
        clone.events = [dict(e) for e in self.events]
        return clone

    def snapshot(self, serves: Tuple[SyncKind, ...]) -> Dict[str, Any]:
        return {
            "pool": self.spec.name,
            "kind": self.spec.kind.value,
            "label": self.spec.label,
            "capacity": self.spec.capacity,
            "instances": list(self.spec.instances),
            "serves": [k.value for k in serves],
            "acquisitions": self.acquisitions,
            "peak_in_flight": self.peak_in_flight,
            "in_flight_at_end": self.in_flight,
            "evictions": self.evictions,
            "contention_cycles": self.contention_cycles,
            "events": list(self.events),
        }


class SyncScoreboard:
    """Stateful allocator over every pool of one :class:`SyncModel`.

    One scoreboard per simulated device; with ``queues > 1`` every
    ``scope="queue"`` pool is replicated per issue queue (ROADMAP's "one
    scoreboard per simulated core/queue") — its instances are exposed as
    ``q<i>:<name>`` — while ``scope="device"`` pools keep a single board
    every queue allocates from.  All methods take the *abstract* kind
    recorded in the IR; routing picks the physical pool, and ``queue``
    picks the replica (ignored for device-scoped pools).  Tags are
    namespaced by kind so barrier and token identifiers sharing a pool
    cannot collide; a live tag is always found on whichever replica holds
    it, so counter-style re-arms land on their original board regardless
    of the issuing queue.
    """

    def __init__(self, model: SyncModel, realloc_cycles: float = 0.0,
                 queues: int = 1, waves: int = 1):
        if queues < 1:
            raise ValueError(f"queues must be >= 1, got {queues}")
        if waves < 1:
            raise ValueError(f"waves must be >= 1, got {waves}")
        self.model = model
        self.realloc_cycles = realloc_cycles
        self.queues = queues
        self.waves = waves
        self._boards: Dict[str, List[_PoolBoard]] = {}
        for p in model.pools:
            if p.scope == "device" and waves > 1:
                # W symmetric co-resident waves share a device-scoped pool;
                # the simulated wave sees its fair share of the instances
                # (floored at one) — raising occupancy RAISES barrier-style
                # pressure, the cross-vendor tradeoff §III-E predicts.
                share = max(1, p.capacity // waves)
                p = _dc_replace(p, instances=p.instances[:share])
            if p.scope == "queue" and queues > 1:
                self._boards[p.name] = [
                    _PoolBoard(_dc_replace(p, instances=tuple(
                        f"q{i}:{inst}" for inst in p.instances)),
                        realloc_cycles, queue=i)
                    for i in range(queues)]
            else:
                self._boards[p.name] = [_PoolBoard(p, realloc_cycles)]

    def _pool_boards(self, kind: SyncKind) -> Optional[List[_PoolBoard]]:
        pool = self.model.pool_for(kind)
        return self._boards[pool.name] if pool is not None else None

    @staticmethod
    def _key(kind: SyncKind, tag: str) -> str:
        return f"{kind.value}:{tag}"

    @staticmethod
    def _holding(boards: List[_PoolBoard], key: str) -> Optional[_PoolBoard]:
        for b in boards:
            if key in b._live:
                return b
        return None

    # -- allocation lifecycle --------------------------------------------------

    def acquire(self, kind: SyncKind, tag: str, consumer: str = "",
                now: float = 0.0, weight: float = 1.0,
                queue: int = 0) -> Optional[SyncAcquire]:
        boards = self._pool_boards(kind)
        if boards is None:
            return None
        key = self._key(kind, tag)
        # a live tag re-armed from another queue is a counter increment on
        # the replica that holds it, not a fresh allocation elsewhere
        board = self._holding(boards, key) or boards[queue % len(boards)]
        return board.acquire(kind, key, consumer, now, weight)

    def complete(self, kind: SyncKind, tag: str, t: float) -> None:
        boards = self._pool_boards(kind)
        if boards is None:
            return
        board = self._holding(boards, self._key(kind, tag))
        if board is not None:
            board.complete(self._key(kind, tag), t)

    def retire(self, kind: SyncKind, tag: str,
               drain_to: Optional[int] = None) -> bool:
        boards = self._pool_boards(kind)
        if boards is None:
            return False
        board = self._holding(boards, self._key(kind, tag))
        if board is None:
            return False
        return board.retire(self._key(kind, tag), drain_to=drain_to)

    # -- introspection ---------------------------------------------------------

    def in_flight(self, kind: SyncKind, queue: Optional[int] = None) -> int:
        boards = self._pool_boards(kind)
        if boards is None:
            return 0
        if queue is not None and len(boards) > 1:
            return boards[queue % len(boards)].in_flight
        return sum(b.in_flight for b in boards)

    def peak(self, kind: SyncKind) -> int:
        boards = self._pool_boards(kind)
        return max((b.peak_in_flight for b in boards), default=0) \
            if boards is not None else 0

    @property
    def total_in_flight(self) -> int:
        return sum(b.in_flight for boards in self._boards.values()
                   for b in boards)

    def fork(self) -> "SyncScoreboard":
        """Independent copy of the mutable allocator state, sharing the
        immutable model (the sampler's while-loop warm-up pass must not
        pollute steady-state pressure stats)."""
        clone = SyncScoreboard.__new__(SyncScoreboard)
        clone.model = self.model
        clone.realloc_cycles = self.realloc_cycles
        clone.queues = self.queues
        clone.waves = self.waves
        clone._boards = {name: [b.fork() for b in boards]
                         for name, boards in self._boards.items()}
        return clone

    def report(self) -> "SyncPressureReport":
        return SyncPressureReport(pools=[
            self._pool_snapshot(p) for p in self.model.pools])

    def _pool_snapshot(self, pool: SyncResourcePool) -> Dict[str, Any]:
        boards = self._boards[pool.name]
        serves = self.model.serves(pool.name)
        if len(boards) == 1:
            snap = boards[0].snapshot(serves)
            snap["scope"] = pool.scope
            snap["queues"] = 1
            return snap
        # merge per-queue replicas: capacity stays the per-queue capacity
        # (the §III-E oversubscription threshold a single stream sees),
        # instances carry the q<i>: prefix, counters aggregate, and the
        # per_queue breakdown preserves each replica's pressure.
        snaps = [b.snapshot(serves) for b in boards]
        # Every per-board field must be merged explicitly below (sum, max,
        # or concat is a semantic choice a generic fold cannot make);
        # fail loudly if _PoolBoard.snapshot grows a field this merge
        # doesn't know, instead of silently dropping it from multi-queue
        # reports only.
        unmerged = set(snaps[0]) - {
            "pool", "kind", "label", "capacity", "instances", "serves",
            "acquisitions", "peak_in_flight", "in_flight_at_end",
            "evictions", "contention_cycles", "events"}
        if unmerged:
            raise AssertionError(
                f"_PoolBoard.snapshot grew fields {sorted(unmerged)} that "
                f"the multi-queue merge does not aggregate; extend "
                f"SyncScoreboard._pool_snapshot")
        merged: Dict[str, Any] = {
            "pool": pool.name,
            "kind": pool.kind.value,
            "label": pool.label,
            "capacity": pool.capacity,
            "instances": [i for s in snaps for i in s["instances"]],
            "serves": [k.value for k in serves],
            "acquisitions": sum(s["acquisitions"] for s in snaps),
            "peak_in_flight": max(s["peak_in_flight"] for s in snaps),
            "in_flight_at_end": sum(s["in_flight_at_end"] for s in snaps),
            "evictions": sum(s["evictions"] for s in snaps),
            "contention_cycles": sum(s["contention_cycles"] for s in snaps),
            "events": [e for s in snaps for e in s["events"]],
            "scope": pool.scope,
            "queues": len(boards),
            "per_queue": [{
                "queue": i,
                "acquisitions": s["acquisitions"],
                "peak_in_flight": s["peak_in_flight"],
                "evictions": s["evictions"],
                "contention_cycles": s["contention_cycles"],
            } for i, s in enumerate(snaps)],
        }
        merged["events"].sort(key=lambda e: (e.get("at", 0.0),
                                             e.get("consumer", "")))
        return merged


# --------------------------------------------------------------------------
# Pressure report (JSON-pure).
# --------------------------------------------------------------------------

@dataclass
class SyncPressureReport:
    """Per-pool pressure stats; every value is a plain JSON type so the
    report embeds directly into the ``Diagnosis`` schema."""

    pools: List[Dict[str, Any]] = field(default_factory=list)

    @property
    def contended(self) -> bool:
        return any(p.get("contention_cycles", 0.0) > 0 for p in self.pools)

    @property
    def total_contention_cycles(self) -> float:
        return sum(p.get("contention_cycles", 0.0) for p in self.pools)

    def pool(self, name: str) -> Optional[Dict[str, Any]]:
        for p in self.pools:
            if p.get("pool") == name:
                return p
        return None

    def to_dict(self) -> Dict[str, Any]:
        return {"contended": self.contended,
                "contention_cycles": self.total_contention_cycles,
                "pools": self.pools}


__all__ = [
    "DEFAULT_SYNC_MODEL", "SyncAcquire", "SyncModel", "SyncPressureReport",
    "SyncResourcePool", "SyncScoreboard", "SyncSemantics", "SyncLike",
    "resolve_sync_model",
]
