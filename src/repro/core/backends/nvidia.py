"""NVIDIA-class backend descriptor (GH200/H100-class constants).

Numbers are public-spec class estimates, not measurements: dense bf16 tensor
FLOPs, HBM3 bandwidth, NVLink4 (18 links x 50 GB/s per direction).  The
taxonomy is CUPTI PC-sampling's stall-reason vocabulary (the paper's primary
platform), and the sync semantics are named barriers B1-B6 — the mechanism
LEO's barrier tracing models (§III-E).
"""
from __future__ import annotations

from ..hwmodel import HardwareModel, IssueModel, OccupancyModel
from ..isa import StallClass, SyncKind
from . import Backend, SyncModel, SyncResourcePool, register_backend

# Four warp schedulers per SM, greedy-then-oldest arbitration (GTO): a
# ready warp waits only when every scheduler is occupied, and that wait is
# what CUPTI reports as `not_selected`.
NVIDIA_ISSUE = IssueModel(queues=4, width=1, policy="greedy_oldest")

# High residency, register-limited: an SM hosts up to 64 warps (16 per
# scheduler) but register allocation caps a realistic kernel near 8 per
# scheduler — the `__launch_bounds__` / maxrregcount tradeoff.  Deep warp
# pools give each co-resident warp a long independent-issue horizon, so
# NVIDIA hides the most latency per stall of the three GPU-class parts.
NVIDIA_OCCUPANCY = OccupancyModel(waves=8, limiter="register_file",
                                  window_cycles=48.0)

NVIDIA_GH200 = HardwareModel(
    name="nvidia_gh200",
    issue=NVIDIA_ISSUE,
    peak_flops_bf16=989e12,          # dense tensor-core bf16
    peak_flops_f32=67e12,            # CUDA-core fp32 vector path
    hbm_bw=4000e9,                   # HBM3e, GH200-class
    hbm_bytes=96 * 2**30,
    ici_bw_per_link=50e9,            # NVLink4 per link per direction
    ici_links=18,
    vmem_bytes=50 * 2**20,           # L2-resident working set
    clock_hz=1830e6,
    issue_overhead_cycles=1.0,
    dma_setup_cycles=20.0,           # TMA/cp.async launch
    collective_setup_cycles=9000.0,  # NCCL kernel launch ~5us @ 1.8 GHz
    mxu_pipe_depth_cycles=32.0,      # tensor-core result latency
    vpu_pipe_depth_cycles=24.0,      # dependent-issue ALU latency
    sync_realloc_cycles=8.0,         # bar.sync drain before slot reuse
)

# CUPTI PC-sampling stall reasons (§II-D table).
CUPTI_TAXONOMY = {
    StallClass.NONE: "selected",
    StallClass.MEM_DEP: "long_scoreboard",
    StallClass.EXEC_DEP: "short_scoreboard",
    StallClass.SYNC_WAIT: "barrier",
    StallClass.SYNC_RESOURCE: "barrier_alloc",   # named-barrier slot reuse
    StallClass.COLLECTIVE_WAIT: "membar",
    StallClass.FETCH: "no_instruction",
    StallClass.PIPE_BUSY: "math_pipe_throttle",
    StallClass.NOT_SELECTED: "not_selected",
    StallClass.OCCUPANCY_LIMITED: "no_eligible_warp",
    StallClass.SELF: "misc",
}

# Every §III-E mechanism the unified IR records rides the B1-B6 named
# barriers on an NVIDIA-class part: 7+ async copies in flight oversubscribe
# and serialize (the paper's oldest-(M-N) rule).  The pool is CTA-scoped
# (`scope="device"`): all four warp schedulers allocate from the SAME six
# barriers, so multi-queue issue does not relieve barrier pressure.
NVIDIA_SYNC = SyncModel(
    pools=(SyncResourcePool.counted(
        "named_barrier", SyncKind.BARRIER, "named barriers B1-B6",
        "B", 6, start=1, scope="device"),),
    routing={SyncKind.BARRIER: "named_barrier",
             SyncKind.WAITCNT: "named_barrier",
             SyncKind.TOKEN: "named_barrier"},
    async_collectives=True,   # NCCL on copy engines / SM subsets
)

NVIDIA_GH200_BACKEND = register_backend(Backend(
    name="nvidia_gh200", vendor="nvidia", hw=NVIDIA_GH200,
    stall_taxonomy=CUPTI_TAXONOMY, sync=NVIDIA_SYNC,
    native_occupancy=NVIDIA_OCCUPANCY,
    description="GH200-class: dominant tensor FLOPs, mid-pack HBM ratio, "
                "fat NVLink — compute-rich, memory-ratio-poor."))
