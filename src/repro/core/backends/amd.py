"""AMD-class backend descriptor (MI300A-class constants).

Class estimates from public specs: CDNA3 bf16 matrix FLOPs, 5.3 TB/s HBM3,
Infinity Fabric links.  Taxonomy follows rocprofiler / GCN ISA vocabulary;
the signature sync mechanism is ``s_waitcnt`` counter draining, which LEO's
waitcnt tracing reproduces exactly (§III-E oldest-(M-N) rule).
"""
from __future__ import annotations

from ..hwmodel import HardwareModel
from ..isa import StallClass, SyncKind
from . import Backend, SyncSemantics, register_backend

AMD_MI300A = HardwareModel(
    name="amd_mi300a",
    peak_flops_bf16=980e12,          # CDNA3 matrix-core bf16
    peak_flops_f32=122e12,           # vector fp32
    hbm_bw=5300e9,                   # HBM3, widest in class
    hbm_bytes=128 * 2**30,
    ici_bw_per_link=64e9,            # Infinity Fabric per link
    ici_links=8,
    vmem_bytes=64 * 2**20,           # LDS + L2-resident tiles
    clock_hz=2100e6,
    issue_overhead_cycles=1.0,
    dma_setup_cycles=16.0,
    collective_setup_cycles=12000.0,  # RCCL launch cost @ 2.1 GHz
    mxu_pipe_depth_cycles=16.0,       # MFMA result latency
    vpu_pipe_depth_cycles=8.0,        # VALU forwarding latency
)

# rocprofiler / GCN wait vocabulary.
ROCM_TAXONOMY = {
    StallClass.NONE: "issued",
    StallClass.MEM_DEP: "s_waitcnt_vmcnt",
    StallClass.EXEC_DEP: "s_waitcnt_lgkmcnt",
    StallClass.SYNC_WAIT: "s_barrier",
    StallClass.COLLECTIVE_WAIT: "xgmi_wait",
    StallClass.FETCH: "instruction_fetch",
    StallClass.PIPE_BUSY: "mfma_pipe_busy",
    StallClass.NOT_SELECTED: "arbiter_not_selected",
    StallClass.SELF: "other",
}

AMD_SYNC = SyncSemantics(
    mechanisms=(SyncKind.WAITCNT, SyncKind.BARRIER),
    barrier_slots=1,          # single workgroup s_barrier
    waitcnt_counters=3,       # vmcnt / lgkmcnt / expcnt
    swsb_tokens=0,
    async_collectives=True,
)

AMD_MI300A_BACKEND = register_backend(Backend(
    name="amd_mi300a", vendor="amd", hw=AMD_MI300A,
    stall_taxonomy=ROCM_TAXONOMY, sync=AMD_SYNC,
    description="MI300A-class: widest HBM (5.3 TB/s) per FLOP — memory-"
                "bound kernels flip compute-bound here first."))
