"""AMD-class backend descriptor (MI300A-class constants).

Class estimates from public specs: CDNA3 bf16 matrix FLOPs, 5.3 TB/s HBM3,
Infinity Fabric links.  Taxonomy follows rocprofiler / GCN ISA vocabulary;
the signature sync mechanism is ``s_waitcnt`` counter draining, which LEO's
waitcnt tracing reproduces exactly (§III-E oldest-(M-N) rule).
"""
from __future__ import annotations

from ..hwmodel import HardwareModel, IssueModel, OccupancyModel
from ..isa import StallClass, SyncKind
from . import Backend, SyncModel, SyncResourcePool, register_backend

# Four SIMD units per CU; the CU front-end rotates across them round-robin
# (one SIMD considered per cycle), so a ready wave on a busy SIMD waits for
# its slot even when a sibling SIMD idles — rocprofiler's
# `arbiter_not_selected`.
AMD_ISSUE = IssueModel(queues=4, width=1, policy="round_robin")

# Mid residency, wavefront-slot-limited: each SIMD hosts up to 8-10 wave
# slots architecturally but VGPR/LDS budgets cap CDNA3 compute kernels
# near 4 per SIMD.  Fewer waves than NVIDIA, but the wide 64-lane waves
# carry more independent memory work each — a longer per-wave hiding
# window compensating the shallower pool.
AMD_OCCUPANCY = OccupancyModel(waves=4, limiter="wavefront_slots",
                               window_cycles=96.0)

AMD_MI300A = HardwareModel(
    name="amd_mi300a",
    issue=AMD_ISSUE,
    peak_flops_bf16=980e12,          # CDNA3 matrix-core bf16
    peak_flops_f32=122e12,           # vector fp32
    hbm_bw=5300e9,                   # HBM3, widest in class
    hbm_bytes=128 * 2**30,
    ici_bw_per_link=64e9,            # Infinity Fabric per link
    ici_links=8,
    vmem_bytes=64 * 2**20,           # LDS + L2-resident tiles
    clock_hz=2100e6,
    issue_overhead_cycles=1.0,
    dma_setup_cycles=16.0,
    collective_setup_cycles=12000.0,  # RCCL launch cost @ 2.1 GHz
    mxu_pipe_depth_cycles=16.0,       # MFMA result latency
    vpu_pipe_depth_cycles=8.0,        # VALU forwarding latency
    sync_realloc_cycles=6.0,          # s_waitcnt 0 full-drain before reuse
)

# rocprofiler / GCN wait vocabulary.
ROCM_TAXONOMY = {
    StallClass.NONE: "issued",
    StallClass.MEM_DEP: "s_waitcnt_vmcnt",
    StallClass.EXEC_DEP: "s_waitcnt_lgkmcnt",
    StallClass.SYNC_WAIT: "s_barrier",
    StallClass.SYNC_RESOURCE: "s_waitcnt_alias",  # streams sharing a counter
    StallClass.COLLECTIVE_WAIT: "xgmi_wait",
    StallClass.FETCH: "instruction_fetch",
    StallClass.PIPE_BUSY: "mfma_pipe_busy",
    StallClass.NOT_SELECTED: "arbiter_not_selected",
    StallClass.OCCUPANCY_LIMITED: "no_ready_wave",
    StallClass.SELF: "other",
}

# Async copies on a GCN-class part are tracked by the two memory waitcnt
# counters (vmcnt for HBM, lgkmcnt for LDS/scalar; expcnt tracks exports
# and cannot carry copies), so barrier-style async pairs AND token chains
# all route onto those two counters — independent streams beyond two alias
# a counter, and a drain on the shared counter serializes both (§III-E).
# The single workgroup s_barrier is an execution barrier, not a transfer-
# tracking resource; it is declared but nothing routes to it.  The waitcnt
# counters are per-wave (`scope="queue"`): every SIMD's wave slot tracks
# its own vmcnt/lgkmcnt, so pressure is per issue queue, while the
# workgroup s_barrier stays device-global.
AMD_SYNC = SyncModel(
    pools=(SyncResourcePool(
               name="waitcnt_counter", kind=SyncKind.WAITCNT,
               label="s_waitcnt memory counters",
               instances=("vmcnt", "lgkmcnt"), scope="queue"),
           SyncResourcePool(
               name="s_barrier", kind=SyncKind.BARRIER,
               label="workgroup s_barrier", instances=("s_barrier",),
               scope="device")),
    routing={SyncKind.BARRIER: "waitcnt_counter",
             SyncKind.WAITCNT: "waitcnt_counter",
             SyncKind.TOKEN: "waitcnt_counter"},
    async_collectives=True,
)

AMD_MI300A_BACKEND = register_backend(Backend(
    name="amd_mi300a", vendor="amd", hw=AMD_MI300A,
    stall_taxonomy=ROCM_TAXONOMY, sync=AMD_SYNC,
    native_occupancy=AMD_OCCUPANCY,
    description="MI300A-class: widest HBM (5.3 TB/s) per FLOP — memory-"
                "bound kernels flip compute-bound here first."))
