"""TPU backend descriptors (the seed's three hardware models).

The TPU stall taxonomy speaks xplane/trace-viewer vocabulary: stalls show up
as wait-time buckets on the TensorCore timeline rather than warp-scheduler
counters.
"""
from __future__ import annotations

from ..hwmodel import TPU_V4, TPU_V5E, TPU_V5P
from ..isa import StallClass, SyncKind
from . import Backend, SyncModel, SyncResourcePool, register_backend

TPU_TAXONOMY = {
    StallClass.NONE: "idle",
    StallClass.MEM_DEP: "hbm_wait",
    StallClass.EXEC_DEP: "scalar_pipeline_wait",
    StallClass.SYNC_WAIT: "dma_semaphore_wait",
    StallClass.SYNC_RESOURCE: "dma_slot_wait",   # async context exhausted
    StallClass.COLLECTIVE_WAIT: "ici_wait",
    StallClass.FETCH: "program_fetch",
    StallClass.PIPE_BUSY: "mxu_occupied",
    # TPU cores run one compiler-scheduled VLIW program — there is no wave
    # residency to raise, so these buckets are structurally empty (the
    # native_occupancy default SINGLE_WAVE).
    StallClass.NOT_SELECTED: "not_selected",
    StallClass.OCCUPANCY_LIMITED: "occupancy_limited",
    StallClass.SELF: "self",
}

# TPUs expose all three §III-E mechanisms through XLA/Pallas, each backed
# by its own finite pool: async start/done pairs ride per-core async copy
# contexts, Pallas DMA streams ride hardware semaphores, and token threads
# ride in-flight token registers.  Routing is the identity — TPU is the
# only backend where no mechanism is emulated on another's resource.  All
# three pools are per-core device resources behind the single VLIW issue
# stream (`scope="device"`; the issue model is `queues=1`, so scoping is
# moot today but documented for when Megacore-style dual streams land).
TPU_SYNC = SyncModel(
    pools=(SyncResourcePool.counted(
               "async_context", SyncKind.BARRIER, "async copy contexts",
               "ctx", 32, scope="device"),
           SyncResourcePool.counted(
               "dma_semaphore", SyncKind.WAITCNT, "Pallas DMA semaphores",
               "sem", 16, scope="device"),
           SyncResourcePool.counted(
               "token_slot", SyncKind.TOKEN, "XLA token slots", "tok", 8,
               scope="device")),
    routing={SyncKind.BARRIER: "async_context",
             SyncKind.WAITCNT: "dma_semaphore",
             SyncKind.TOKEN: "token_slot"},
    async_collectives=True,
)

TPU_V5E_BACKEND = register_backend(Backend(
    name="tpu_v5e", vendor="google", hw=TPU_V5E,
    stall_taxonomy=TPU_TAXONOMY, sync=TPU_SYNC,
    description="TPU v5e: cost-optimized, narrow HBM (819 GB/s), 4 ICI "
                "links — collective- and memory-sensitive."))

TPU_V5P_BACKEND = register_backend(Backend(
    name="tpu_v5p", vendor="google", hw=TPU_V5P,
    stall_taxonomy=TPU_TAXONOMY, sync=TPU_SYNC,
    description="TPU v5p: training flagship, fat HBM (2.8 TB/s) + 6 ICI "
                "links — the same kernel often flips compute-bound here."))

TPU_V4_BACKEND = register_backend(Backend(
    name="tpu_v4", vendor="google", hw=TPU_V4,
    stall_taxonomy=TPU_TAXONOMY, sync=TPU_SYNC,
    description="TPU v4: balanced mid-generation part."))
