"""Intel-class backend descriptor (Ponte Vecchio / Max 1550-class constants).

Class estimates: XMX bf16 FLOPs, HBM2e bandwidth, Xe-Link fabric (many thin
links — the weakest per-link interconnect of the three vendors, which is
what makes collective-heavy programs diverge here, paper Observation 1).
Taxonomy follows Level Zero / GTPin vocabulary; synchronization is SWSB
software scoreboarding — the token-threading mechanism LEO traces (§III-E).
"""
from __future__ import annotations

from ..hwmodel import HardwareModel, IssueModel, OccupancyModel
from ..isa import StallClass, SyncKind
from . import Backend, SyncModel, SyncResourcePool, register_backend

# Eight Xe vector engines per Xe-core, each co-issuing to paired
# vector/matrix ports (width 2): the widest issue fabric of the three
# GPU-class parts — wide independent-op workloads that choke a 4-queue
# part sail through here (the PR-4 wide-ops divergence golden).
INTEL_ISSUE = IssueModel(queues=8, width=2, policy="round_robin")

# Low residency, thread-limited: a Xe vector engine hosts 8 hardware
# threads but large-GRF kernels (the XMX-heavy mode) halve that, and the
# wide issue fabric already spreads work across 8 engines — so per-queue
# residency is the shallowest of the three GPU-class parts.  Latency-bound
# kernels that NVIDIA hides behind warps stay exposed here.
INTEL_OCCUPANCY = OccupancyModel(waves=2, limiter="thread_slots",
                                 window_cycles=32.0)

INTEL_PVC = HardwareModel(
    name="intel_pvc",
    issue=INTEL_ISSUE,
    peak_flops_bf16=839e12,          # XMX bf16, Max 1550-class
    peak_flops_f32=52e12,            # vector fp32
    hbm_bw=3280e9,                   # HBM2e
    hbm_bytes=128 * 2**30,
    ici_bw_per_link=26.5e9,          # Xe-Link per link — thin
    ici_links=16,
    vmem_bytes=128 * 2**20,          # large Rambo/L2 cache
    clock_hz=1600e6,
    issue_overhead_cycles=1.0,
    dma_setup_cycles=24.0,
    collective_setup_cycles=16000.0,  # oneCCL launch @ 1.6 GHz
    mxu_pipe_depth_cycles=8.0,        # XMX systolic depth (8-deep)
    vpu_pipe_depth_cycles=10.0,
    sync_realloc_cycles=2.0,          # SBID release is a cheap sync.allrd
)

# Level Zero / GTPin stall vocabulary (SWSB scoreboard waits).
LEVELZERO_TAXONOMY = {
    StallClass.NONE: "active",
    StallClass.MEM_DEP: "sbid_wait_load",
    StallClass.EXEC_DEP: "swsb_dist_wait",
    StallClass.SYNC_WAIT: "sync_func_wait",
    StallClass.SYNC_RESOURCE: "sbid_alloc_wait",  # SBID reuse serialization
    StallClass.COLLECTIVE_WAIT: "xelink_wait",
    StallClass.FETCH: "instruction_fetch",
    StallClass.PIPE_BUSY: "pipe_stall",
    StallClass.NOT_SELECTED: "thread_not_selected",
    StallClass.OCCUPANCY_LIMITED: "no_ready_thread",
    StallClass.SELF: "other",
}

# Every in-flight async operation on a Xe-class part claims one of the 16
# SWSB scoreboard IDs; the compiler spills to serialization only past $15,
# so a copy storm that chokes NVIDIA's 6 named barriers sails through here
# (the cross-vendor divergence the §VI case study reports).  The 32
# per-subslice named barriers exist but carry execution barriers, not
# transfer tracking.
# SWSB scoreboard IDs are per-thread (`scope="queue"`): each hardware
# thread's compiler allocates its own $0-$15, so under multi-queue issue
# every queue owns a private token file; the subslice named barriers are
# shared (`scope="device"`).
INTEL_SYNC = SyncModel(
    pools=(SyncResourcePool.counted(
               "swsb_token", SyncKind.TOKEN, "SWSB scoreboard IDs $0-$15",
               "$", 16, scope="queue"),
           SyncResourcePool.counted(
               "named_barrier", SyncKind.BARRIER,
               "subslice named barriers", "nbar", 32, scope="device")),
    routing={SyncKind.BARRIER: "swsb_token",
             SyncKind.WAITCNT: "swsb_token",
             SyncKind.TOKEN: "swsb_token"},
    async_collectives=False,  # oneCCL collectives block the queue
)

INTEL_PVC_BACKEND = register_backend(Backend(
    name="intel_pvc", vendor="intel", hw=INTEL_PVC,
    stall_taxonomy=LEVELZERO_TAXONOMY, sync=INTEL_SYNC,
    native_occupancy=INTEL_OCCUPANCY,
    description="PVC-class: thin per-link Xe-Link fabric and slow "
                "collective launch — communication-heavy programs "
                "bottleneck here first."))
