"""Intel-class backend descriptor (Ponte Vecchio / Max 1550-class constants).

Class estimates: XMX bf16 FLOPs, HBM2e bandwidth, Xe-Link fabric (many thin
links — the weakest per-link interconnect of the three vendors, which is
what makes collective-heavy programs diverge here, paper Observation 1).
Taxonomy follows Level Zero / GTPin vocabulary; synchronization is SWSB
software scoreboarding — the token-threading mechanism LEO traces (§III-E).
"""
from __future__ import annotations

from ..hwmodel import HardwareModel
from ..isa import StallClass, SyncKind
from . import Backend, SyncSemantics, register_backend

INTEL_PVC = HardwareModel(
    name="intel_pvc",
    peak_flops_bf16=839e12,          # XMX bf16, Max 1550-class
    peak_flops_f32=52e12,            # vector fp32
    hbm_bw=3280e9,                   # HBM2e
    hbm_bytes=128 * 2**30,
    ici_bw_per_link=26.5e9,          # Xe-Link per link — thin
    ici_links=16,
    vmem_bytes=128 * 2**20,          # large Rambo/L2 cache
    clock_hz=1600e6,
    issue_overhead_cycles=1.0,
    dma_setup_cycles=24.0,
    collective_setup_cycles=16000.0,  # oneCCL launch @ 1.6 GHz
    mxu_pipe_depth_cycles=8.0,        # XMX systolic depth (8-deep)
    vpu_pipe_depth_cycles=10.0,
)

# Level Zero / GTPin stall vocabulary (SWSB scoreboard waits).
LEVELZERO_TAXONOMY = {
    StallClass.NONE: "active",
    StallClass.MEM_DEP: "sbid_wait_load",
    StallClass.EXEC_DEP: "swsb_dist_wait",
    StallClass.SYNC_WAIT: "sync_func_wait",
    StallClass.COLLECTIVE_WAIT: "xelink_wait",
    StallClass.FETCH: "instruction_fetch",
    StallClass.PIPE_BUSY: "pipe_stall",
    StallClass.NOT_SELECTED: "thread_not_selected",
    StallClass.SELF: "other",
}

INTEL_SYNC = SyncSemantics(
    mechanisms=(SyncKind.TOKEN, SyncKind.BARRIER),
    barrier_slots=32,         # named barriers per subslice
    waitcnt_counters=0,
    swsb_tokens=16,           # SWSB scoreboard IDs $0..$15
    async_collectives=False,  # oneCCL collectives block the queue
)

INTEL_PVC_BACKEND = register_backend(Backend(
    name="intel_pvc", vendor="intel", hw=INTEL_PVC,
    stall_taxonomy=LEVELZERO_TAXONOMY, sync=INTEL_SYNC,
    description="PVC-class: thin per-link Xe-Link fabric and slow "
                "collective launch — communication-heavy programs "
                "bottleneck here first."))
