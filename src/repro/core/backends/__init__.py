"""Pluggable cross-vendor backend registry (paper §II / Observation 1).

A :class:`Backend` bundles everything LEO needs to analyze a program *as if*
it ran on one vendor's part:

  * an analytical :class:`~repro.core.hwmodel.HardwareModel` (roofline and
    latency constants — the per-vendor FLOP:HBM:interconnect ratios that make
    the same kernel bottleneck differently per platform);
  * a *stall-class taxonomy*: the mapping from LEO's unified
    :class:`~repro.core.isa.StallClass` buckets back to the vendor-native
    profiler counter names (CUPTI / rocprofiler / Level Zero / TPU xplane),
    so reports can speak each vendor's language;
  * a :class:`SyncModel` describing the §III-E synchronization resources
    the vendor's ISA exposes (named barriers, waitcnt counters, SWSB-style
    tokens) as *finite, named pools* with a stateful scoreboard, plus how
    collectives launch.  The deprecated :class:`SyncSemantics` knob bag is
    accepted and converted transparently.

Backends register into a process-global :class:`BackendRegistry`; third
parties add vendors with :func:`register_backend` without touching core
files.  Six descriptors ship by default — three TPU generations (the seed's
models) plus NVIDIA-, AMD- and Intel-class parts — so
``LeoSession.compare_backends`` exercises genuinely divergent vendors.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace as _dc_replace
from typing import Dict, Iterator, List, Mapping, Optional, Tuple, Union

from ..hwmodel import (SINGLE_ISSUE, SINGLE_WAVE, HardwareModel, IssueModel,
                       OccupancyModel)
from ..isa import StallClass, SyncKind
from .syncmodel import (
    DEFAULT_SYNC_MODEL,
    SyncAcquire,
    SyncLike,
    SyncModel,
    SyncPressureReport,
    SyncResourcePool,
    SyncScoreboard,
    SyncSemantics,
    resolve_sync_model,
)


@dataclass(frozen=True)
class Backend:
    """One vendor/part descriptor: hardware model + taxonomy + sync model."""

    name: str
    vendor: str                               # "google" | "nvidia" | ...
    hw: HardwareModel
    stall_taxonomy: Mapping[StallClass, str]  # unified -> native counter name
    sync: SyncModel = DEFAULT_SYNC_MODEL
    description: str = ""
    # The part's NATIVE wave residency (a capability, not an engagement):
    # `hw.occupancy` stays SINGLE_WAVE on every registered backend so plain
    # profiles are byte-identical to the pre-occupancy sampler; analysis
    # under native residency goes through `with_occupancy()`.
    native_occupancy: OccupancyModel = SINGLE_WAVE

    def __post_init__(self) -> None:
        # Legacy callers hand us the deprecated SyncSemantics knob bag;
        # convert so everything downstream sees one behavioral type.
        if not isinstance(self.sync, SyncModel):
            object.__setattr__(self, "sync", resolve_sync_model(self.sync))

    def native_stall_name(self, cls: StallClass) -> str:
        """Vendor-native profiler name for a unified stall class."""
        return self.stall_taxonomy.get(cls, cls.value)

    def taxonomy_table(self) -> Dict[str, str]:
        return {cls.value: name for cls, name in self.stall_taxonomy.items()}

    @property
    def issue(self) -> IssueModel:
        """The hardware model's issue-stream descriptor."""
        return getattr(self.hw, "issue", SINGLE_ISSUE) or SINGLE_ISSUE

    def with_issue(self, issue: IssueModel,
                   name: Optional[str] = None) -> "Backend":
        """Derive a backend with a different issue model (e.g. the K=1
        single-stream variant anchoring the pre-multi-stream goldens).
        The derived descriptor gets a distinct name — covering every
        IssueModel field, policy included — so session/service caches
        (keyed on backend name) cannot alias two variants."""
        derived = name or (f"{self.name}@q{issue.queues}x{issue.width}-"
                           f"{issue.policy}")
        return _dc_replace(self, name=derived,
                           hw=_dc_replace(self.hw, issue=issue))

    @property
    def occupancy(self) -> OccupancyModel:
        """The hardware model's ACTIVE wave-residency descriptor."""
        return getattr(self.hw, "occupancy", SINGLE_WAVE) or SINGLE_WAVE

    def with_occupancy(self, occ: Optional[OccupancyModel] = None,
                       name: Optional[str] = None) -> "Backend":
        """Derive a backend whose sampler runs under wave residency ``occ``
        (default: this part's native residency).  As with ``with_issue``,
        the derived descriptor gets a distinct name covering every
        OccupancyModel field so session/service caches (keyed on backend
        name) can never alias the W=1 and native-W variants."""
        occ = occ if occ is not None else self.native_occupancy
        derived = name or (f"{self.name}@w{occ.waves}-{occ.limiter}-"
                           f"h{occ.window_cycles:g}")
        return _dc_replace(self, name=derived,
                           hw=_dc_replace(self.hw, occupancy=occ))


class UnknownBackendError(KeyError):
    """Raised for lookups of unregistered backend names."""

    def __init__(self, name: str, known: List[str]):
        super().__init__(
            f"unknown backend {name!r}; registered: {sorted(known)}")
        self.name = name
        self.known = sorted(known)

    def __str__(self) -> str:  # KeyError quotes its arg; keep it readable
        return self.args[0]


class BackendRegistry:
    """Name -> :class:`Backend` mapping with third-party registration."""

    def __init__(self) -> None:
        self._backends: Dict[str, Backend] = {}

    def register(self, backend: Backend, *, overwrite: bool = False) -> Backend:
        if not overwrite and backend.name in self._backends:
            raise ValueError(
                f"backend {backend.name!r} already registered; pass "
                f"overwrite=True to replace it")
        self._backends[backend.name] = backend
        return backend

    def unregister(self, name: str) -> None:
        self._backends.pop(name, None)

    def get(self, name: str) -> Backend:
        try:
            return self._backends[name]
        except KeyError:
            raise UnknownBackendError(name, list(self._backends)) from None

    def names(self) -> List[str]:
        return list(self._backends)

    def by_vendor(self, vendor: str) -> List[Backend]:
        return [b for b in self._backends.values() if b.vendor == vendor]

    def __contains__(self, name: str) -> bool:
        return name in self._backends

    def __iter__(self) -> Iterator[Backend]:
        return iter(self._backends.values())

    def __len__(self) -> int:
        return len(self._backends)


#: Process-global default registry; `register_backend` and `LeoSession`
#: operate on this unless handed an explicit registry.
REGISTRY = BackendRegistry()


def register_backend(backend: Backend, *, overwrite: bool = False) -> Backend:
    return REGISTRY.register(backend, overwrite=overwrite)


def get_backend(name: str) -> Backend:
    return REGISTRY.get(name)


def list_backends() -> List[Backend]:
    return list(REGISTRY)


BackendLike = Union[Backend, HardwareModel, str]


def resolve_backend(spec: BackendLike) -> Backend:
    """Coerce a backend name / Backend / bare HardwareModel to a Backend.

    Bare hardware models (the legacy ``hw=TPU_V5E`` calling convention)
    resolve to their registered backend when one carries the same model,
    otherwise wrap into an anonymous descriptor with the generic taxonomy —
    legacy callers keep working without registering anything.
    """
    if isinstance(spec, Backend):
        return spec
    if isinstance(spec, str):
        return get_backend(spec)
    if isinstance(spec, HardwareModel):
        for backend in REGISTRY:
            if backend.hw is spec or backend.hw == spec:
                return backend
        return Backend(name=spec.name, vendor="custom", hw=spec,
                       stall_taxonomy=GENERIC_TAXONOMY,
                       description="ad-hoc backend wrapping a bare "
                                   "HardwareModel")
    raise TypeError(f"cannot resolve backend from {type(spec).__name__}")


#: Fallback taxonomy: unified names map to themselves.
GENERIC_TAXONOMY: Mapping[StallClass, str] = {
    cls: cls.value for cls in StallClass
}


# -- default registrations ---------------------------------------------------
# Imported last: the vendor modules call register_backend() at import time.
from . import amd, intel, nvidia, tpu  # noqa: E402,F401  (registration side effect)

__all__ = [
    "Backend", "BackendRegistry", "BackendLike", "IssueModel",
    "OccupancyModel", "SINGLE_ISSUE", "SINGLE_WAVE",
    "DEFAULT_SYNC_MODEL", "SyncAcquire", "SyncLike", "SyncModel",
    "SyncPressureReport", "SyncResourcePool", "SyncScoreboard",
    "SyncSemantics", "resolve_sync_model",
    "UnknownBackendError", "REGISTRY", "GENERIC_TAXONOMY",
    "register_backend", "get_backend", "list_backends", "resolve_backend",
]
