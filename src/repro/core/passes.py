"""Composable analysis-pass pipeline (the redesigned core API).

The seed inlined LEO's phases into one monolithic ``analyze_module``; here
each phase is a named, reorderable, individually-testable
:class:`AnalysisPass` that reads/writes a shared :class:`AnalysisContext`:

    sample -> depgraph -> coverage_before -> sync_edges -> prune
           -> coverage_after -> blame -> chains -> cct

A :class:`Pipeline` validates data-flow order (a pass may only require what
an earlier pass provides), times every pass, and records per-pass stats.
``Pipeline`` instances are immutable; ``with_pass`` / ``without`` /
``replaced`` / ``reordered`` derive variants, so third parties insert
custom passes without editing core files — the same extension contract the
backend registry gives vendors.

:class:`LeoAnalysis` (the result object every benchmark and report consumes)
lives here; ``repro.core.analyzer`` re-exports it and keeps the legacy
``analyze_*`` functions as thin shims over :func:`default_pipeline`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .backends import Backend, BackendLike, resolve_backend
from .blame import BlameResult, attribute_blame
from .cct import CCTNode, build_cct
from .coverage import CoverageReport, single_dependency_coverage
from .depgraph import DependencyGraph, build_dependency_graph
from .hwmodel import HardwareModel
from .isa import Module
from .pruning import PruneStats, prune
from .sampler import StallProfile, VirtualSampler
from .slicing import StallChain, top_chains
from .sync_trace import add_sync_edges


# --------------------------------------------------------------------------
# Result object (moved from analyzer.py; analyzer re-exports it).
# --------------------------------------------------------------------------

@dataclass
class LeoAnalysis:
    module: Module
    hw: HardwareModel
    profile: StallProfile
    graph: DependencyGraph
    prune_stats: PruneStats
    blame: BlameResult
    chains: List[StallChain]
    coverage_before: CoverageReport
    coverage_after: CoverageReport
    cct: CCTNode
    sync_edges_added: int = 0
    analysis_seconds: float = 0.0
    backend: Optional[Backend] = None
    pass_seconds: Dict[str, float] = field(default_factory=dict)
    # Per-pool §III-E sync-resource pressure (SyncPressureReport): dynamic
    # scoreboard stats from the sampler merged with per-instance sync-edge
    # counts from the sync_edges pass; None when the pipeline ran without
    # the sync_edges pass or the backend declares no resource pools.
    sync_pressure: Optional[Any] = None
    # Per-queue issue-port pressure (IssuePressureReport) from the
    # sampler's multi-stream issue model; None for measured profiles.
    issue_pressure: Optional[Any] = None
    # Per-queue latency-hiding pressure (OccupancyPressureReport) from the
    # sampler's multi-wave occupancy model; None for measured profiles and
    # for W=1 runs.
    occupancy_pressure: Optional[Any] = None

    @property
    def estimated_step_seconds(self) -> float:
        return self.profile.makespan_seconds

    def top_root_causes(self, n: int = 10):
        return self.blame.top_root_causes(n)

    def summary(self) -> str:
        lines = [
            f"LEO analysis [{self.hw.name}] module={self.module.name}",
            f"  instructions={sum(len(c.instructions) for c in self.module.computations.values())}"
            f" edges={self.prune_stats.initial_edges}"
            f" (+{self.sync_edges_added} sync)"
            f" -> {self.prune_stats.surviving_edges} after pruning "
            f"{dict(self.prune_stats.pruned_by_stage)}",
            f"  est. step time: {self.estimated_step_seconds*1e3:.3f} ms, "
            f"total stall cycles: {self.profile.total_stall_cycles:,.0f}",
            f"  single-dep coverage: {self.coverage_before.coverage:.0%} -> "
            f"{self.coverage_after.coverage:.0%}",
            "  top root causes:",
        ]
        for q, cycles in self.top_root_causes(5):
            instr = self.module.find(q)
            where = instr.op_name if instr is not None else ""
            lines.append(f"    {cycles:14,.0f} cyc  {q}  [{where}]")
        if self.blame.self_blame:
            top_self = sorted(self.blame.self_blame, key=lambda s: -s.cycles)[:3]
            lines.append("  self-blame:")
            for s in top_self:
                lines.append(f"    {s.cycles:14,.0f} cyc  {s.qualified}  "
                             f"({s.subcategory})")
        return "\n".join(lines)


# --------------------------------------------------------------------------
# Shared pass state.
# --------------------------------------------------------------------------

#: Context fields available before any pass runs.
_INITIAL_FIELDS = ("module", "backend", "options")


@dataclass
class PassStat:
    name: str
    seconds: float
    provided: Tuple[str, ...]


@dataclass
class AnalysisContext:
    """Mutable state threaded through the pipeline.

    Passes read the fields named in their ``requires`` and fill the fields
    named in their ``provides``; ``options`` carries tuning knobs
    (``n_chains``, ``prune_unexecuted``); ``cache`` is an optional
    session-owned object giving passes memoized artifacts (see
    ``LeoSession``).
    """

    module: Module
    backend: Backend
    options: Dict[str, Any] = field(default_factory=dict)
    profile: Optional[StallProfile] = None
    graph: Optional[DependencyGraph] = None
    coverage_before: Optional[CoverageReport] = None
    coverage_after: Optional[CoverageReport] = None
    sync_edges_added: Optional[int] = None
    sync_pressure: Optional[Any] = None
    prune_stats: Optional[PruneStats] = None
    blame: Optional[BlameResult] = None
    chains: Optional[List[StallChain]] = None
    cct: Optional[CCTNode] = None
    pass_stats: List[PassStat] = field(default_factory=list)
    cache: Optional[Any] = None       # session cache hook (duck-typed)
    module_key: Optional[str] = None  # content hash when session-managed

    @property
    def hw(self) -> HardwareModel:
        return self.backend.hw

    def provided(self, name: str) -> bool:
        return getattr(self, name, None) is not None

    def to_analysis(self, analysis_seconds: float = 0.0) -> LeoAnalysis:
        missing = [f for f in ("profile", "graph", "prune_stats", "blame",
                               "chains", "coverage_before", "coverage_after",
                               "cct") if not self.provided(f)]
        if missing:
            raise IncompletePipelineError(
                f"pipeline finished without providing {missing}; add the "
                f"passes that produce them or consume the context directly")
        return LeoAnalysis(
            module=self.module, hw=self.hw, profile=self.profile,
            graph=self.graph, prune_stats=self.prune_stats, blame=self.blame,
            chains=self.chains, coverage_before=self.coverage_before,
            coverage_after=self.coverage_after, cct=self.cct,
            sync_edges_added=self.sync_edges_added or 0,
            analysis_seconds=analysis_seconds, backend=self.backend,
            pass_seconds={s.name: s.seconds for s in self.pass_stats},
            sync_pressure=self.sync_pressure,
            issue_pressure=getattr(self.profile, "issue_pressure", None),
            occupancy_pressure=getattr(self.profile, "occupancy_pressure",
                                       None))


class PipelineOrderError(ValueError):
    """A pass requires a field no earlier pass (or initial state) provides."""


class IncompletePipelineError(ValueError):
    """`to_analysis` called on a context missing required artifacts."""


# --------------------------------------------------------------------------
# Pass objects.
# --------------------------------------------------------------------------

class AnalysisPass:
    """One named pipeline stage.

    Subclasses declare ``name``, data-flow contracts (``requires`` /
    ``provides`` — AnalysisContext field names), and implement ``run``.
    """

    name: str = "<unnamed>"
    requires: Tuple[str, ...] = ()
    provides: Tuple[str, ...] = ()

    def run(self, ctx: AnalysisContext) -> None:
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r}>"


class SamplePass(AnalysisPass):
    """Phase 1: virtual PC sampling (skipped when a measured profile was
    supplied — the paper's real-hardware input path)."""

    name = "sample"
    provides = ("profile",)

    def run(self, ctx: AnalysisContext) -> None:
        if ctx.profile is None:
            ctx.profile = VirtualSampler(ctx.module, ctx.hw,
                                         sync=ctx.backend.sync).run()


class DepGraphPass(AnalysisPass):
    """Phase 3a: CCT dependency graph from SSA/region dataflow."""

    name = "depgraph"
    provides = ("graph",)

    def run(self, ctx: AnalysisContext) -> None:
        if ctx.cache is not None and ctx.module_key is not None:
            ctx.graph = ctx.cache.graph_for(ctx.module_key, ctx.module,
                                            ctx.backend)
        else:
            ctx.graph = build_dependency_graph(ctx.module, ctx.hw)


class CoverageSnapshotPass(AnalysisPass):
    """Single-dependency coverage of the graph *as it stands now* — placed
    twice in the default pipeline (before sync/prune, and after)."""

    requires = ("graph",)

    def __init__(self, label: str):
        if label not in ("before", "after"):
            raise ValueError(f"coverage snapshot label must be "
                             f"'before'/'after', got {label!r}")
        self.label = label
        self.name = f"coverage_{label}"
        self.provides = (f"coverage_{label}",)

    def run(self, ctx: AnalysisContext) -> None:
        setattr(ctx, f"coverage_{self.label}",
                single_dependency_coverage(ctx.graph))


class SyncEdgesPass(AnalysisPass):
    """Phase 3b: §III-E synchronization edges (barrier / waitcnt / token).

    With a backend ``SyncModel``, every sync edge is annotated with the
    concrete resource instance it consumed, and the pass exports
    ``sync_pressure``: the sampler's dynamic scoreboard report (peak
    in-flight, oversubscription events) extended with per-instance
    sync-edge counts."""

    name = "sync_edges"
    requires = ("graph",)
    provides = ("sync_edges_added", "sync_pressure")

    def run(self, ctx: AnalysisContext) -> None:
        sync = getattr(ctx.backend, "sync", None)
        assignment = getattr(ctx.profile, "sync_assignment", None) \
            if ctx.profile is not None else None
        queues = getattr(ctx.backend, "issue", None)
        ctx.sync_edges_added = add_sync_edges(
            ctx.graph, sync=sync, assignment=assignment,
            queues=queues.queues if queues is not None else 1)
        ctx.sync_pressure = self._pressure_report(ctx, sync)

    def _pressure_report(self, ctx: AnalysisContext, sync):
        if sync is None or not getattr(sync, "pools", ()):
            return None
        report = getattr(ctx.profile, "sync_pressure", None) \
            if ctx.profile is not None else None
        if report is None:
            # measured profile (or sample pass removed): static-only view,
            # minted at the backend's queue count so its instance
            # namespace matches the q-prefixed edge annotations
            issue = getattr(ctx.backend, "issue", None)
            report = sync.scoreboard(
                queues=issue.queues if issue is not None else 1).report()
        by_instance: Dict[str, int] = {}
        for e in ctx.graph.edges:
            if e.kind.is_sync and e.resource is not None:
                by_instance[e.resource] = by_instance.get(e.resource, 0) + 1
        for pool in report.pools:
            pool["edges_per_instance"] = {
                inst: by_instance[inst] for inst in pool["instances"]
                if inst in by_instance}
        return report


class PrunePass(AnalysisPass):
    """Phase 4: four-stage pruning (opcode/barrier/latency/execution)."""

    name = "prune"
    requires = ("graph", "profile")
    provides = ("prune_stats",)

    def run(self, ctx: AnalysisContext) -> None:
        ctx.prune_stats = prune(
            ctx.graph, ctx.profile, ctx.hw,
            prune_unexecuted=ctx.options.get("prune_unexecuted", True))


class BlamePass(AnalysisPass):
    """Phase 5: inverse-distance four-factor blame attribution."""

    name = "blame"
    requires = ("graph", "profile")
    provides = ("blame",)

    def run(self, ctx: AnalysisContext) -> None:
        ctx.blame = attribute_blame(ctx.graph, ctx.profile, ctx.hw)


class ChainsPass(AnalysisPass):
    """Backward slicing: ranked symptom->root-cause dependency chains."""

    name = "chains"
    requires = ("graph", "profile", "blame")
    provides = ("chains",)

    def run(self, ctx: AnalysisContext) -> None:
        ctx.chains = top_chains(ctx.graph, ctx.profile, ctx.blame,
                                n=ctx.options.get("n_chains", 5))


class CCTPass(AnalysisPass):
    """Calling-context tree with per-scope stall aggregation."""

    name = "cct"
    requires = ("profile",)
    provides = ("cct",)

    def run(self, ctx: AnalysisContext) -> None:
        ctx.cct = build_cct(ctx.module, ctx.profile)


# --------------------------------------------------------------------------
# Pipeline.
# --------------------------------------------------------------------------

#: hook signatures: on_pass_start(pass_, ctx); on_pass_end(pass_, ctx, secs)
PassStartHook = Callable[[AnalysisPass, AnalysisContext], None]
PassEndHook = Callable[[AnalysisPass, AnalysisContext, float], None]


class Pipeline:
    """An ordered, validated sequence of analysis passes."""

    def __init__(self, passes: Sequence[AnalysisPass],
                 on_pass_start: Optional[PassStartHook] = None,
                 on_pass_end: Optional[PassEndHook] = None):
        names = [p.name for p in passes]
        dupes = {n for n in names if names.count(n) > 1}
        if dupes:
            raise ValueError(f"duplicate pass names: {sorted(dupes)}")
        self.passes: Tuple[AnalysisPass, ...] = tuple(passes)
        self.on_pass_start = on_pass_start
        self.on_pass_end = on_pass_end
        self._validate()

    # -- construction helpers (all return new Pipelines) ---------------------

    def _derive(self, passes: Sequence[AnalysisPass]) -> "Pipeline":
        return Pipeline(passes, self.on_pass_start, self.on_pass_end)

    def with_pass(self, pass_: AnalysisPass, *, before: Optional[str] = None,
                  after: Optional[str] = None) -> "Pipeline":
        if (before is None) == (after is None):
            raise ValueError("specify exactly one of before=/after=")
        anchor = before if before is not None else after
        idx = self.index(anchor)
        at = idx if before is not None else idx + 1
        return self._derive(self.passes[:at] + (pass_,) + self.passes[at:])

    def without(self, name: str) -> "Pipeline":
        idx = self.index(name)
        return self._derive(self.passes[:idx] + self.passes[idx + 1:])

    def replaced(self, name: str, pass_: AnalysisPass) -> "Pipeline":
        idx = self.index(name)
        return self._derive(self.passes[:idx] + (pass_,)
                            + self.passes[idx + 1:])

    def reordered(self, names: Sequence[str]) -> "Pipeline":
        if sorted(names) != sorted(p.name for p in self.passes):
            raise ValueError(
                f"reorder must permute exactly {[p.name for p in self.passes]}")
        by_name = {p.name: p for p in self.passes}
        return self._derive([by_name[n] for n in names])

    def index(self, name: str) -> int:
        for i, p in enumerate(self.passes):
            if p.name == name:
                return i
        raise KeyError(f"no pass named {name!r}; have "
                       f"{[p.name for p in self.passes]}")

    @property
    def names(self) -> List[str]:
        return [p.name for p in self.passes]

    # -- validation / execution ----------------------------------------------

    def _validate(self) -> None:
        available = set(_INITIAL_FIELDS)
        for p in self.passes:
            missing = [r for r in p.requires if r not in available]
            if missing:
                raise PipelineOrderError(
                    f"pass {p.name!r} requires {missing} but only "
                    f"{sorted(available)} are available at its position")
            available.update(p.provides)

    def run(self, module: Module, backend: BackendLike,
            profile: Optional[StallProfile] = None,
            cache: Optional[Any] = None,
            module_key: Optional[str] = None,
            **options: Any) -> AnalysisContext:
        ctx = AnalysisContext(module=module,
                              backend=resolve_backend(backend),
                              options=dict(options), profile=profile,
                              cache=cache, module_key=module_key)
        for p in self.passes:
            if self.on_pass_start is not None:
                self.on_pass_start(p, ctx)
            t0 = time.perf_counter()
            p.run(ctx)
            dt = time.perf_counter() - t0
            ctx.pass_stats.append(PassStat(name=p.name, seconds=dt,
                                           provided=p.provides))
            if self.on_pass_end is not None:
                self.on_pass_end(p, ctx, dt)
        return ctx

    def analyze(self, module: Module, backend: BackendLike,
                profile: Optional[StallProfile] = None,
                **options: Any) -> LeoAnalysis:
        t0 = time.perf_counter()
        ctx = self.run(module, backend, profile=profile, **options)
        return ctx.to_analysis(analysis_seconds=time.perf_counter() - t0)

    def __repr__(self) -> str:
        return f"Pipeline({' -> '.join(self.names)})"


def default_pipeline(on_pass_start: Optional[PassStartHook] = None,
                     on_pass_end: Optional[PassEndHook] = None) -> Pipeline:
    """The paper's 5-phase workflow as the canonical pass sequence."""
    return Pipeline([
        SamplePass(),
        DepGraphPass(),
        CoverageSnapshotPass("before"),
        SyncEdgesPass(),
        PrunePass(),
        CoverageSnapshotPass("after"),
        BlamePass(),
        ChainsPass(),
        CCTPass(),
    ], on_pass_start=on_pass_start, on_pass_end=on_pass_end)


#: Shared default instance used by the legacy shims and new sessions.
DEFAULT_PIPELINE = default_pipeline()
