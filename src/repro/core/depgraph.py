"""CCT dependency-graph construction (§III-B).

Edges point *backward in execution*: from a stalled instruction (effect) to
the instruction(s) that may have produced its source operands (cause).  The
resolver below is the SSA/region equivalent of the paper's two-pass scheme
(block-level reaching definitions + per-use intra-block walk):

  * within a computation, SSA gives exact per-use reaching definitions;
  * tuple/get-tuple-element/bitcast glue is traversed transparently with
    element-index tracking, so blame lands on real producers;
  * at region boundaries it unions reaching definitions exactly as the paper
    unions at CFG joins: a use of loop state reaches both the init value
    (preheader path) and the body-root value of the previous iteration
    (back-edge path, `LOOP_CARRIED`); a use of a `conditional` result
    reaches every branch root;
  * uses inside fusion/call bodies resolve through the call site to caller
    operands (this is what makes chains cross framework layers — the CCT);
  * producers with no profile samples are retained as unsampled dependency
    sources (address-generation chains must be blameable).

Predicate guards (`select` / `conditional` predicates — the P0-P6 analogue)
get `PREDICATE` edges.  The backward-liveness filter from `cfg.py` removes
loop-carried candidates whose slot is never read in the body.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .cfg import DistanceModel, LoopSlotDataflow, PathInfo
from .hwmodel import HardwareModel
from .isa import (
    Computation,
    EdgeKind,
    Instruction,
    Module,
    OpClass,
)

# Glue opcodes traversed transparently during def resolution.
_TRANSPARENT = {"bitcast", "get-tuple-element", "tuple", "copy-done"}
# copy-done is transparent for *value* identity but its sync edge is added by
# sync_trace.py; seeing through it lets register chains continue.

_MAX_RESOLVE_DEPTH = 64


@dataclass
class Edge:
    producer: str                 # qualified name (cause)
    consumer: str                 # qualified name (effect; the stalled instr)
    kind: EdgeKind
    paths: List[PathInfo] = field(default_factory=list)
    pruned_by: Optional[str] = None   # pruning stage that removed it, if any
    # Concrete §III-E sync-resource instance this edge rode (e.g. "B3",
    # "vmcnt", "$5"); set by sync_trace when the backend carries a
    # SyncModel, None for register/predicate/loop edges.
    resource: Optional[str] = None

    @property
    def alive(self) -> bool:
        return self.pruned_by is None

    @property
    def min_cycles(self) -> float:
        return min((p.issue_cycles for p in self.paths), default=0.0)

    @property
    def avg_instr_distance(self) -> float:
        alive = [p for p in self.paths] or [PathInfo(1.0, 1.0, "straight")]
        return sum(p.instr_count for p in alive) / len(alive)


@dataclass
class DependencyGraph:
    module: Module
    edges: List[Edge] = field(default_factory=list)
    in_edges: Dict[str, List[Edge]] = field(default_factory=dict)   # by consumer
    out_edges: Dict[str, List[Edge]] = field(default_factory=dict)  # by producer

    def add(self, edge: Edge) -> None:
        self.edges.append(edge)
        self.in_edges.setdefault(edge.consumer, []).append(edge)
        self.out_edges.setdefault(edge.producer, []).append(edge)

    def deps_of(self, qualified: str, alive_only: bool = True) -> List[Edge]:
        edges = self.in_edges.get(qualified, [])
        return [e for e in edges if e.alive] if alive_only else list(edges)

    def instruction(self, qualified: str) -> Optional[Instruction]:
        return self.module.find(qualified)

    @property
    def alive_edges(self) -> List[Edge]:
        return [e for e in self.edges if e.alive]


@dataclass(frozen=True)
class _Resolved:
    instr: Instruction
    kind: EdgeKind
    path: PathInfo


class GraphBuilder:
    def __init__(self, module: Module, hw: HardwareModel):
        self.module = module
        self.hw = hw
        self.distance = DistanceModel(module, hw)
        self.loops = LoopSlotDataflow(module)
        # call-site lookup: computation name -> (caller Instruction)
        self.call_sites: Dict[str, Instruction] = {}
        for comp in module.computations.values():
            for instr in comp.instructions:
                for callee in instr.called_computations:
                    self.call_sites.setdefault(callee, instr)

    # -- public -------------------------------------------------------------

    def build(self) -> DependencyGraph:
        graph = DependencyGraph(module=self.module)
        seen: Set[Tuple[str, str, EdgeKind]] = set()
        for comp in self.module.computations.values():
            for instr in comp.instructions:
                if instr.op_class in (OpClass.PARAMETER, OpClass.CONSTANT):
                    continue
                pred_ops = set(self._predicate_positions(instr))
                for pos, operand in enumerate(instr.operands):
                    kind = EdgeKind.PREDICATE if pos in pred_ops \
                        else EdgeKind.REG_RAW
                    for res in self._resolve(comp, operand, None, instr, 0):
                        ekind = res.kind if res.kind is not EdgeKind.REG_RAW \
                            else kind
                        key = (res.instr.qualified_name,
                               instr.qualified_name, ekind)
                        # Self-edges are only meaningful as cross-iteration
                        # (loop-carried) dependencies — e.g. acc = f(acc).
                        if key in seen or (res.instr is instr and
                                           ekind is not EdgeKind.LOOP_CARRIED):
                            continue
                        seen.add(key)
                        graph.add(Edge(producer=res.instr.qualified_name,
                                       consumer=instr.qualified_name,
                                       kind=ekind, paths=[res.path]))
        return graph

    # -- predicate positions --------------------------------------------------

    def _predicate_positions(self, instr: Instruction) -> List[int]:
        if instr.opcode in ("select", "conditional", "select-and-scatter"):
            return [0]
        return []

    # -- definition resolution -------------------------------------------------

    def _resolve(self, comp: Computation, name: str,
                 elem_index: Optional[int], consumer: Instruction,
                 depth: int) -> List[_Resolved]:
        """All reaching definitions for `name` (element `elem_index` if the
        value is a tuple), as real producer instructions + path info."""
        if depth > _MAX_RESOLVE_DEPTH:
            return []
        instr = comp.get(name)
        if instr is None:
            return []

        if instr.opcode == "get-tuple-element":
            idx = int(instr.attributes.get("index", 0))
            return self._resolve(comp, instr.operands[0], idx, consumer,
                                 depth + 1)
        if instr.opcode == "tuple":
            if elem_index is not None and elem_index < len(instr.operands):
                return self._resolve(comp, instr.operands[elem_index], None,
                                     consumer, depth + 1)
            out: List[_Resolved] = []
            for op in instr.operands:
                out.extend(self._resolve(comp, op, None, consumer, depth + 1))
            return out
        if instr.opcode in ("bitcast", "copy-done") and instr.operands:
            inner = self._resolve(comp, instr.operands[0], elem_index,
                                  consumer, depth + 1)
            if inner:
                return inner
            return [self._make(instr, consumer, EdgeKind.REG_RAW)]

        if instr.op_class is OpClass.PARAMETER:
            return self._resolve_parameter(comp, instr, elem_index, consumer,
                                           depth)

        if instr.opcode == "while":
            return self._resolve_while_result(comp, instr, elem_index,
                                              consumer, depth)
        if instr.opcode == "conditional":
            return self._resolve_conditional(comp, instr, elem_index,
                                             consumer, depth)

        return [self._make(instr, consumer, EdgeKind.REG_RAW)]

    def _make(self, producer: Instruction, consumer: Instruction,
              kind: EdgeKind, path: Optional[PathInfo] = None) -> _Resolved:
        if path is None:
            if producer.computation == consumer.computation:
                if producer.index <= consumer.index:
                    path = self.distance.straight(producer, consumer)
                else:
                    path = self.distance.loop_carried(producer, consumer)
            else:
                call = self.call_sites.get(consumer.computation)
                if call is not None and \
                        call.computation == producer.computation and \
                        producer.index <= call.index:
                    path = self.distance.cross_comp(producer, call, consumer)
                else:
                    path = PathInfo(instr_count=1.0, issue_cycles=0.0,
                                    kind="cross_comp")
        return _Resolved(instr=producer, kind=kind, path=path)

    def _resolve_parameter(self, comp: Computation, param: Instruction,
                           elem_index: Optional[int], consumer: Instruction,
                           depth: int) -> List[_Resolved]:
        call = self.call_sites.get(comp.name)
        if call is None:
            # Entry parameter: terminal producer — a real HBM source.
            return [self._make(param, consumer, EdgeKind.REG_RAW)]
        caller_comp = self.module.computations[call.computation]
        pidx = int(param.attributes.get("literal", "0") or 0)

        if comp.kind in ("loop_body", "loop_cond"):
            return self._resolve_loop_param(caller_comp, call, comp,
                                            elem_index, consumer, depth)
        if comp.kind == "branch":
            # conditional(%pred, %arg0, %arg1, ...): branch k gets arg k+1.
            branches = call.called_computations
            try:
                k = branches.index(comp.name)
            except ValueError:
                k = 0
            arg_pos = k + 1
            if arg_pos < len(call.operands):
                return self._resolve(caller_comp, call.operands[arg_pos],
                                     elem_index, consumer, depth + 1)
            return []
        # fusion / call / reduce bodies: param i <- call-site operand i.
        if pidx < len(call.operands):
            return self._resolve(caller_comp, call.operands[pidx],
                                 elem_index, consumer, depth + 1)
        return []

    def _resolve_loop_param(self, caller_comp: Computation,
                            while_instr: Instruction, body: Computation,
                            elem_index: Optional[int], consumer: Instruction,
                            depth: int) -> List[_Resolved]:
        slot = elem_index if elem_index is not None else 0
        out: List[_Resolved] = []
        # Backward-liveness filter (paper §III-B): skip dead slots.
        body_name = body.name if body.kind == "loop_body" else None
        if body.kind == "loop_body" and \
                not self.loops.slot_live_in_body(body.name, slot):
            return out
        defs = self.loops.reaching_defs(
            body.name, while_instr.qualified_name, slot)
        if defs:
            for def_qualified, carried in defs:
                producer = self.module.find(def_qualified)
                if producer is None:
                    continue
                if carried:
                    path = self.distance.loop_carried(producer, consumer) \
                        if producer.computation == consumer.computation else \
                        PathInfo(1.0, 0.0, "loop_carried")
                    out.append(_Resolved(producer, EdgeKind.LOOP_CARRIED, path))
                else:
                    out.extend(self._resolve_through_init(
                        caller_comp, while_instr, slot, consumer, depth))
            return out
        return self._resolve_through_init(caller_comp, while_instr, slot,
                                          consumer, depth)

    def _resolve_through_init(self, caller_comp: Computation,
                              while_instr: Instruction, slot: int,
                              consumer: Instruction,
                              depth: int) -> List[_Resolved]:
        if not while_instr.operands:
            return []
        return self._resolve(caller_comp, while_instr.operands[0], slot,
                             consumer, depth + 1)

    def _resolve_while_result(self, comp: Computation, while_instr: Instruction,
                              elem_index: Optional[int], consumer: Instruction,
                              depth: int) -> List[_Resolved]:
        """Use of gte(while, i) after the loop: reaches the body root element
        (final iteration) and — paper-style union — the init value (zero-trip
        path)."""
        out: List[_Resolved] = []
        slot = elem_index if elem_index is not None else 0
        for cname in while_instr.called_computations:
            callee = self.module.computations.get(cname)
            if callee is None or callee.kind != "loop_body":
                continue
            root = callee.root
            if root is None:
                continue
            if root.opcode == "tuple" and slot < len(root.operands):
                for res in self._resolve(callee, root.operands[slot], None,
                                         consumer, depth + 1):
                    out.append(_Resolved(res.instr, res.kind,
                                         PathInfo(res.path.instr_count + 1,
                                                  res.path.issue_cycles,
                                                  "cross_comp")))
            else:
                out.append(self._make(root, consumer, EdgeKind.REG_RAW,
                                      PathInfo(1.0, 0.0, "cross_comp")))
        if not out:
            out.extend(self._resolve_through_init(
                comp, while_instr, slot, consumer, depth))
        return out

    def _resolve_conditional(self, comp: Computation, cond: Instruction,
                             elem_index: Optional[int], consumer: Instruction,
                             depth: int) -> List[_Resolved]:
        out: List[_Resolved] = []
        for cname in cond.called_computations:
            callee = self.module.computations.get(cname)
            if callee is None or callee.root is None:
                continue
            out.extend(self._resolve(callee, callee.root.name, elem_index,
                                     consumer, depth + 1))
        return out


def build_dependency_graph(module: Module, hw: HardwareModel) -> DependencyGraph:
    return GraphBuilder(module, hw).build()
