"""Single-dependency coverage (§V-C, Fig. 5).

The fraction of dependency-graph nodes whose surviving incoming edges all
belong to one dependency class (memory vs execution vs synchronization), so
blame can be assigned without apportionment.  Reported before and after
LEO's workflow (synchronization tracing + four-stage pruning).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Set

from .depgraph import DependencyGraph
from .isa import EdgeKind, OpClass


def _edge_class(graph: DependencyGraph, edge) -> str:
    if edge.kind.is_sync:
        return "sync"
    producer = graph.instruction(edge.producer)
    if producer is None:
        return "execution"
    if producer.op_class in (OpClass.MEMORY_LOAD, OpClass.MEMORY_STORE,
                             OpClass.DATA_MOVEMENT, OpClass.PARAMETER,
                             OpClass.CONSTANT):
        return "memory"
    if producer.op_class in (OpClass.COLLECTIVE, OpClass.SYNC_SET,
                             OpClass.SYNC_WAIT):
        return "sync"
    return "execution"


@dataclass
class CoverageReport:
    nodes_with_deps: int
    single_class_nodes: int

    @property
    def coverage(self) -> float:
        if self.nodes_with_deps == 0:
            return 1.0
        return self.single_class_nodes / self.nodes_with_deps


def single_dependency_coverage(graph: DependencyGraph,
                               alive_only: bool = True) -> CoverageReport:
    classes_by_node: Dict[str, Set[str]] = {}
    for edge in graph.edges:
        if alive_only and not edge.alive:
            continue
        classes_by_node.setdefault(edge.consumer, set()).add(
            _edge_class(graph, edge))
    nodes = len(classes_by_node)
    single = sum(1 for s in classes_by_node.values() if len(s) == 1)
    return CoverageReport(nodes_with_deps=nodes, single_class_nodes=single)
