"""Blame attribution (§III-D, Eq. 1) with self-blame subcategories.

After pruning, each stalled instruction j distributes its stall cycles S_j
over surviving incoming dependencies with four multiplicative factors:

    blame_i = S_j * (Rd_i * Re_i * Ri_i * Rm_i) / sum_k(Rd_k * Re_k * Ri_k * Rm_k)

  Rd (distance)   = d_min / d_i       — closer producers hide less latency
  Re (efficiency) = e_min / e_i       — inefficient producers blamed more
  Ri (issue)      = n_i / sum_k n_k   — frequently-executed producers blamed more
  Rm (match)      = stall-category match: the edge's dependency type weighted
                    by the consumer's hardware-reported stall breakdown
                    (LEO's extension over GPA's three factors).

When no dependency survives pruning the stall self-blames with a diagnostic
subcategory derived from the dominant stall class and the instruction's own
character (memory latency / compute saturation / synchronization overhead /
collective wait / instruction fetch / indirect addressing).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .depgraph import DependencyGraph, Edge
from .hwmodel import HardwareModel
from .isa import EdgeKind, Instruction, OpClass, StallClass
from .sampler import StallProfile

_EPS = 1e-12
_MATCH_FLOOR = 0.05  # keep a floor so a single factor cannot zero an edge


def edge_stall_classes(edge: Edge, producer: Instruction) -> Tuple[StallClass, ...]:
    """Which observed stall classes this dependency type can explain."""
    if edge.kind.is_sync:
        if producer.comm_bytes > 0 or producer.op_class is OpClass.COLLECTIVE:
            return (StallClass.COLLECTIVE_WAIT, StallClass.SYNC_WAIT,
                    StallClass.SYNC_RESOURCE, StallClass.MEM_DEP)
        return (StallClass.SYNC_WAIT, StallClass.SYNC_RESOURCE,
                StallClass.MEM_DEP)
    cls = producer.op_class
    if cls in (OpClass.MEMORY_LOAD, OpClass.MEMORY_STORE,
               OpClass.DATA_MOVEMENT, OpClass.PARAMETER, OpClass.CONSTANT):
        return (StallClass.MEM_DEP,)
    if cls is OpClass.COLLECTIVE or (cls is OpClass.SYNC_SET and
                                     producer.comm_bytes > 0):
        return (StallClass.COLLECTIVE_WAIT, StallClass.SYNC_WAIT)
    if cls in (OpClass.SYNC_SET, OpClass.SYNC_WAIT):
        return (StallClass.SYNC_WAIT, StallClass.SYNC_RESOURCE,
                StallClass.MEM_DEP)
    return (StallClass.EXEC_DEP,)


def producer_efficiency(instr: Instruction, hw: HardwareModel) -> float:
    """Fraction of the producer's occupancy that is useful resource time.

    Setup/overhead-dominated ops (tiny DMAs, skinny matmuls, per-element
    gathers) score low and attract blame — the analogue of "uncoalesced
    accesses receive more blame"."""
    useful = hw.latency_seconds(instr) * hw.clock_hz
    total = useful + hw.issue_overhead_cycles + (
        hw.dma_setup_cycles if instr.is_memory or
        instr.op_class is OpClass.DATA_MOVEMENT else 0.0)
    if total <= 0:
        return 1.0
    eff = useful / total
    # Sub-lane-width memory rows are additionally penalized (uncoalesced
    # analogue: HBM moves >=256B granules regardless of the useful payload).
    if instr.is_memory and instr.shape.dims:
        row = instr.shape.dims[-1] * max(instr.shape.byte_size //
                                         max(instr.shape.num_elements, 1), 1)
        eff *= min(1.0, row / 256.0)
    return max(eff, _EPS)


@dataclass
class BlameEntry:
    producer: str
    consumer: str
    kind: EdgeKind
    cycles: float
    factors: Dict[str, float] = field(default_factory=dict)


@dataclass
class SelfBlame:
    qualified: str
    cycles: float
    subcategory: str


@dataclass
class SyncResourceBlame:
    """One §III-E resource-oversubscription event: `consumer` serialized on
    physical instance `resource` (pool `pool`) still held by `holder`."""

    consumer: str
    resource: str      # concrete instance, e.g. "B3" / "vmcnt" / "$5"
    pool: str          # pool name, e.g. "named_barrier"
    holder: str        # qualified instruction that held the instance
    cycles: float


@dataclass
class SchedulerContentionBlame:
    """One issue-port arbitration loss: `consumer` was data-ready but queue
    `queue`'s issue slot was still occupied by `holder` — charged as
    `not_selected` (different execution pipe: the scheduler picked other
    work) or `pipe_busy` (same pipe: the functional unit is saturated)."""

    consumer: str
    holder: str        # qualified instruction occupying the issue slot
    queue: int         # issue queue index
    pipe: str          # consumer's execution-pipe family (mxu/vpu/lsu/...)
    stall_class: str   # "not_selected" | "pipe_busy"
    cycles: float


@dataclass
class OccupancyLimitedBlame:
    """One failed-latency-hiding event: `consumer` stalled on `blocker` and
    the co-resident waves of queue `queue` ran out of issue credit mid-wait
    — `hidden` cycles were covered, `exposed` cycles leaked through as
    `StallClass.OCCUPANCY_LIMITED`."""

    consumer: str
    blocker: str       # qualified producer whose latency leaked through
    queue: int         # issue queue index
    stall_class: str   # original hideable class ("mem_dep", "sync_wait", ...)
    hidden_cycles: float
    exposed_cycles: float

    @property
    def cycles(self) -> float:
        return self.exposed_cycles


@dataclass
class BlameResult:
    entries: List[BlameEntry] = field(default_factory=list)
    by_producer: Dict[str, float] = field(default_factory=dict)
    self_blame: List[SelfBlame] = field(default_factory=list)
    # Occupancy diagnosis: instructions that dominate the issue stream
    # without dependency stalls (a lone memory-bound kernel has nothing to
    # wait on — the bottleneck is itself).  Kept separate from self_blame so
    # stall-cycle conservation (sum(entries)+sum(self)==total stalls) holds.
    occupancy_blame: List[SelfBlame] = field(default_factory=list)
    # SYNC_RESOURCE evidence channel: scoreboard oversubscription events
    # naming the exact resource instance consumed.  Evidence *about* stall
    # cycles already attributed through entries/self_blame (the same cycles
    # viewed through the resource lens), so conservation still holds.
    sync_resource: List[SyncResourceBlame] = field(default_factory=list)
    # Scheduler-contention evidence channel: issue-port arbitration events
    # from the multi-stream sampler (NOT_SELECTED / PIPE_BUSY cycles viewed
    # through the queue lens); same conservation caveat as sync_resource.
    scheduler_contention: List[SchedulerContentionBlame] = \
        field(default_factory=list)
    # Failed-latency-hiding evidence channel: OCCUPANCY_LIMITED events from
    # the multi-wave sampler (partially-hidden stalls viewed through the
    # wave-residency lens); same conservation caveat as sync_resource.
    occupancy_limited: List[OccupancyLimitedBlame] = \
        field(default_factory=list)

    @property
    def total_attributed(self) -> float:
        return sum(self.by_producer.values())

    def top_root_causes(self, n: int = 10) -> List[Tuple[str, float]]:
        return sorted(self.by_producer.items(), key=lambda kv: -kv[1])[:n]

    def contributions_to(self, consumer: str) -> List[BlameEntry]:
        return sorted((e for e in self.entries if e.consumer == consumer),
                      key=lambda e: -e.cycles)


_SELF_SUBCATEGORY = {
    StallClass.MEM_DEP: "memory latency",
    StallClass.EXEC_DEP: "compute saturation",
    StallClass.SYNC_WAIT: "synchronization overhead",
    StallClass.SYNC_RESOURCE: "sync resource exhaustion",
    StallClass.COLLECTIVE_WAIT: "collective wait",
    StallClass.FETCH: "instruction fetch",
    StallClass.PIPE_BUSY: "pipeline contention",
    StallClass.NOT_SELECTED: "scheduler contention",
    StallClass.OCCUPANCY_LIMITED: "occupancy limited",
}


def _self_subcategory(instr: Optional[Instruction],
                      dominant: StallClass) -> str:
    if instr is not None and instr.opcode in ("gather", "dynamic-slice",
                                              "scatter",
                                              "dynamic-update-slice"):
        return "indirect addressing"
    return _SELF_SUBCATEGORY.get(dominant, "unclassified")


class BlameAttributor:
    def __init__(self, graph: DependencyGraph, profile: StallProfile,
                 hw: HardwareModel):
        self.graph = graph
        self.profile = profile
        self.hw = hw

    def run(self) -> BlameResult:
        result = BlameResult()
        for qualified, rec in self.profile.records.items():
            if rec.latency_samples <= 0:
                continue
            edges = self.graph.deps_of(qualified, alive_only=True)
            consumer = self.graph.instruction(qualified)
            if not edges:
                result.self_blame.append(SelfBlame(
                    qualified=qualified, cycles=rec.latency_samples,
                    subcategory=_self_subcategory(consumer,
                                                  rec.dominant_stall)))
                continue
            self._attribute(result, qualified, rec.latency_samples, edges)
        self._occupancy_blame(result)
        self._sync_resource_blame(result)
        self._scheduler_contention_blame(result)
        self._occupancy_limited_blame(result)
        return result

    def _occupancy_limited_blame(self, result: BlameResult) -> None:
        """Surface failed-latency-hiding events as a typed evidence channel
        naming the stalled consumer, its producer, and the hidden/exposed
        split (only present under a multi-wave OccupancyModel)."""
        pressure = getattr(self.profile, "occupancy_pressure", None)
        if pressure is None:
            return
        for ev in getattr(pressure, "events", []):
            w = ev.get("weight", 1.0)
            result.occupancy_limited.append(OccupancyLimitedBlame(
                consumer=ev["consumer"], blocker=ev.get("blocker") or "",
                queue=ev.get("queue", 0), stall_class=ev["stall_class"],
                hidden_cycles=ev["hidden_cycles"] * w,
                exposed_cycles=ev["exposed_cycles"] * w))
        result.occupancy_limited.sort(key=lambda b: -b.cycles)

    def _scheduler_contention_blame(self, result: BlameResult) -> None:
        """Surface issue-port arbitration events as a typed evidence
        channel naming the queue and the occupying instruction."""
        pressure = getattr(self.profile, "issue_pressure", None)
        if pressure is None:
            return
        for ev in getattr(pressure, "events", []):
            result.scheduler_contention.append(SchedulerContentionBlame(
                consumer=ev["consumer"], holder=ev.get("holder") or "",
                queue=ev.get("queue", 0), pipe=ev.get("pipe", ""),
                stall_class=ev["stall_class"],
                cycles=ev["stall_cycles"] * ev.get("weight", 1.0)))
        result.scheduler_contention.sort(key=lambda b: -b.cycles)

    def _sync_resource_blame(self, result: BlameResult) -> None:
        """Surface scoreboard oversubscription events (§III-E) as a typed
        evidence channel naming the exact resource instance consumed."""
        pressure = getattr(self.profile, "sync_pressure", None)
        if pressure is None:
            return
        for pool in pressure.pools:
            for ev in pool.get("events", []):
                result.sync_resource.append(SyncResourceBlame(
                    consumer=ev["consumer"], resource=ev["instance"],
                    pool=pool["pool"], holder=ev.get("holder") or "",
                    cycles=ev["stall_cycles"] * ev.get("weight", 1.0)))
        result.sync_resource.sort(key=lambda b: -b.cycles)

    def _occupancy_blame(self, result: BlameResult) -> None:
        """Diagnose issue-stream dominators with no dependency stalls."""
        makespan = max(self.profile.makespan_cycles, 1.0)
        for qualified, rec in self.profile.records.items():
            if rec.latency_samples > 0 or rec.total_samples < 0.15 * makespan:
                continue
            instr = self.graph.instruction(qualified)
            if instr is None or instr.op_class in (
                    OpClass.CONTROL, OpClass.TUPLE, OpClass.PARAMETER,
                    OpClass.CONSTANT):
                continue  # control wrappers absorb their body's occupancy
            sub = self._occupancy_subcategory(instr)
            result.occupancy_blame.append(SelfBlame(
                qualified=qualified, cycles=rec.total_samples,
                subcategory=sub))
        result.occupancy_blame.sort(key=lambda s: -s.cycles)

    def _occupancy_subcategory(self, instr: Instruction) -> str:
        if instr.opcode in ("gather", "dynamic-slice", "scatter",
                            "dynamic-update-slice"):
            return "indirect addressing"
        if instr.opcode == "fusion":
            for cname in instr.called_computations:
                callee = self.graph.module.computations.get(cname)
                if callee is None:
                    continue
                if any(i.opcode in ("gather", "scatter")
                       for i in callee.instructions):
                    return "indirect addressing"
        mem_s = self.hw.memory_seconds(instr)
        comp_s = self.hw.compute_seconds(instr)
        coll_s = self.hw.collective_seconds(instr)
        best = max(mem_s, comp_s, coll_s)
        if best == coll_s and coll_s > 0:
            return "collective wait"
        if best == mem_s and mem_s > 0:
            return "memory latency"
        return "compute saturation"

    def _attribute(self, result: BlameResult, consumer_q: str, s_j: float,
                   edges: List[Edge]) -> None:
        rec = self.profile.records.get(consumer_q)
        dists, effs, issues, matches = [], [], [], []
        producers: List[Optional[Instruction]] = []
        for e in edges:
            producer = self.graph.instruction(e.producer)
            producers.append(producer)
            dists.append(max(e.avg_instr_distance, 1.0))
            effs.append(producer_efficiency(producer, self.hw)
                        if producer is not None else 1.0)
            prec = self.profile.records.get(e.producer)
            issues.append(prec.exec_count if prec is not None else 0.0)
            if rec is not None and producer is not None:
                m = sum(rec.stall_fraction(c)
                        for c in edge_stall_classes(e, producer))
                matches.append(max(m, _MATCH_FLOOR))
            else:
                matches.append(1.0)

        d_min = min(dists)
        e_min = min(effs)
        n_sum = sum(issues) or 1.0
        weights = []
        for d, eff, n, m in zip(dists, effs, issues, matches):
            rd = d_min / d
            re_ = e_min / eff
            ri = (n / n_sum) if n_sum > 0 else 1.0 / len(edges)
            weights.append(rd * re_ * ri * m)
        wsum = sum(weights)
        if wsum <= _EPS:
            weights = [1.0] * len(edges)
            wsum = float(len(edges))
        for e, producer, w, d, eff, n, m in zip(
                edges, producers, weights, dists, effs, issues, matches):
            cycles = s_j * w / wsum
            if cycles <= 0:
                continue
            result.entries.append(BlameEntry(
                producer=e.producer, consumer=consumer_q, kind=e.kind,
                cycles=cycles,
                factors={"dist": d_min / d, "eff": e_min / eff,
                         "issue": n / n_sum, "match": m}))
            result.by_producer[e.producer] = \
                result.by_producer.get(e.producer, 0.0) + cycles


def attribute_blame(graph: DependencyGraph, profile: StallProfile,
                    hw: HardwareModel) -> BlameResult:
    return BlameAttributor(graph, profile, hw).run()
