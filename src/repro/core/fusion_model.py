"""Virtual fusion clustering: TPU-fusion-aware HBM byte accounting.

The dry-run HLO comes from the CPU backend, whose fusion is far less
aggressive than TPU's — naively counting every top-level operand as HBM
traffic overstates the memory roofline term ~20x.  This pass approximates
XLA-TPU behavior:

* producer-consumer clustering (union-find): elementwise/data-movement ops
  (and matmuls, as absorbing sinks) merge with a producer when they are its
  only consumer; HBM bytes are charged only when a read crosses a cluster
  boundary;
* tuple/get-tuple-element glue and loop-body parameters are *aliases*, not
  traffic: they cost nothing themselves, but a consumer crossing a boundary
  is charged the size of the value it actually consumes (the gte output,
  never the whole loop-state tuple);
* fusion nodes are inspected through their called computation: an operand
  whose callee parameter feeds only slice/dynamic-slice/gather ops is
  charged the slice sizes (XLA slice fusion reads only the slice); a root
  dynamic-update-slice writes only the update (in-place);
* while-loop *carried values* that a body iteration reads/writes in full DO
  count every iteration: XLA does not fuse across while iterations, so an
  online-softmax accumulator round-trips HBM per key block — which is
  exactly the traffic a hand-written Pallas flash-attention kernel
  eliminates (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Dict, List, Optional

from .isa import Computation, Instruction, Module, OpClass

_FUSABLE = {OpClass.COMPUTE, OpClass.DATA_MOVEMENT, OpClass.REDUCE,
            OpClass.FUSION, OpClass.MATMUL}
# Never fuse; keep parser-assigned costs.
_KEEP_COST = {OpClass.COLLECTIVE, OpClass.SYNC_SET, OpClass.SYNC_WAIT,
              OpClass.MEMORY_LOAD, OpClass.MEMORY_STORE}
_SLICE_OPS = {"slice", "dynamic-slice", "gather"}


class _UF:
    def __init__(self, n: int):
        self.p = list(range(n))

    def find(self, x: int) -> int:
        while self.p[x] != x:
            self.p[x] = self.p[self.p[x]]
            x = self.p[x]
        return x

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.p[rb] = ra


FUSED_REGION_MARK = "pallas_fused_region"


def apply_virtual_fusion(module: Module) -> None:
    """Rewrite per-instruction bytes_read/bytes_written in place."""
    for comp in module.computations.values():
        if comp.kind in ("fusion", "reduce"):
            continue  # inner bodies already zeroed by the parser
        _cluster_computation(module, comp)
    _apply_fused_regions(module)


def _apply_fused_regions(module: Module) -> None:
    """Regions tagged with FUSED_REGION_MARK execute as one Pallas kernel:
    everything inside is VMEM-resident (no intra-region HBM traffic); the
    region's inputs/outputs are still charged at their producers/consumers
    outside the mark.  FLOPs are untouched — the MXU work is identical."""
    for comp in module.computations.values():
        for instr in comp.instructions:
            if FUSED_REGION_MARK in instr.op_name:
                instr.bytes_read = 0.0
                instr.bytes_written = 0.0
                instr.raw_bytes_read = 0.0


# The CPU backend legalizes bf16 compute through f32 convert chains that a
# TPU build never emits; inspection below is transparent to that glue.
_GLUE_OPS = {"convert", "bitcast", "copy", "reshape"}


def _real_consumers(callee: Computation, name: str,
                    depth: int = 0) -> List[Instruction]:
    """Consumers of `name`, traversing convert/bitcast/copy glue."""
    out: List[Instruction] = []
    if depth > 8:
        return out
    for instr in callee.instructions:
        if name not in instr.operands:
            continue
        if instr.opcode in _GLUE_OPS:
            out.extend(_real_consumers(callee, instr.name, depth + 1))
        else:
            out.append(instr)
    return out


def _through_glue(callee: Computation,
                  instr: Optional[Instruction]) -> Optional[Instruction]:
    seen = 0
    while instr is not None and instr.opcode in _GLUE_OPS and \
            instr.operands and seen < 8:
        instr = callee.get(instr.operands[0])
        seen += 1
    return instr


def _fusion_read_bytes(module: Module, fusion: Instruction,
                       operand_pos: int, default: float) -> float:
    """Charge slice sizes when the callee only slices this operand."""
    for cname in fusion.called_computations:
        callee = module.computations.get(cname)
        if callee is None:
            continue
        param = None
        for instr in callee.instructions:
            if instr.op_class is OpClass.PARAMETER and \
                    int(instr.attributes.get("literal", -1) or -1) == \
                    operand_pos:
                param = instr
                break
        if param is None:
            continue
        consumers = _real_consumers(callee, param.name)
        if not consumers:
            continue
        total = 0.0
        ok = True
        for c in consumers:
            if c.opcode in _SLICE_OPS:
                # keep the parser's granule-penalized cost when present
                total += max(c.raw_bytes_read, c.bytes_read,
                             float(c.shape.byte_size))
            elif c.opcode == "dynamic-update-slice" and c.operands and \
                    _through_glue(callee, callee.get(c.operands[0])) is param:
                total += 0.0  # in-place destination alias, not a read
            else:
                ok = False
                break
        if ok:
            return float(total)
    return default


def _fusion_write_bytes(module: Module, fusion: Instruction,
                        default: float) -> float:
    """Root dynamic-update-slice writes only the update (in-place)."""
    for cname in fusion.called_computations:
        callee = module.computations.get(cname)
        if callee is None or callee.root is None:
            continue
        root = _through_glue(callee, callee.root)
        if root is not None and root.opcode == "dynamic-update-slice" and \
                len(root.operands) > 1:
            upd = callee.get(root.operands[1])
            if upd is not None:
                return float(upd.shape.byte_size)
    return default


def _cluster_computation(module: Module, comp: Computation) -> None:
    instrs = comp.instructions
    index = {i.name: idx for idx, i in enumerate(instrs)}
    consumers: Dict[str, List[int]] = {}
    for idx, instr in enumerate(instrs):
        for op in instr.operands:
            consumers.setdefault(op, []).append(idx)

    uf = _UF(len(instrs))
    for idx, instr in enumerate(instrs):
        if instr.op_class not in _FUSABLE:
            continue
        for op in instr.operands:
            pidx = index.get(op)
            if pidx is None:
                continue
            producer = instrs[pidx]
            if producer.op_class not in _FUSABLE or \
                    producer.op_class is OpClass.MATMUL:
                continue  # matmuls absorb producers, not the other way
            if len(consumers.get(op, ())) == 1:
                uf.union(pidx, idx)

    # Sibling / multi-output fusion: XLA TPU fuses a cheap producer into all
    # of its consumers when they are themselves fusable elementwise work
    # (select feeding both max and subtract in an online softmax, say).
    for idx, instr in enumerate(instrs):
        if instr.op_class not in _FUSABLE or \
                instr.op_class is OpClass.MATMUL:
            continue
        cons = consumers.get(instr.name, [])
        if 1 < len(cons) <= 4 and all(
                instrs[c].op_class in _FUSABLE and
                instrs[c].op_class is not OpClass.MATMUL for c in cons):
            for c in cons:
                uf.union(idx, c)

    is_entry = comp.kind == "entry"
    for idx, instr in enumerate(instrs):
        cls = instr.op_class
        if cls in _KEEP_COST:
            continue
        if cls is OpClass.PARAMETER:
            # Parameters are buffer bindings, not traffic: each consuming
            # kernel pays for its own read (incl. gather amplification).
            instr.bytes_read = 0.0
            instr.bytes_written = 0.0
            continue
        if cls in (OpClass.TUPLE, OpClass.CONTROL, OpClass.CONSTANT):
            instr.bytes_read = 0.0
            instr.bytes_written = 0.0
            continue
        cid = uf.find(idx)
        reads = 0.0
        for pos, op in enumerate(instr.operands):
            pidx = index.get(op)
            if pidx is None:
                continue
            producer = instrs[pidx]
            crossing = uf.find(pidx) != cid or \
                producer.op_class not in _FUSABLE
            if not crossing:
                continue
            if producer.op_class in (OpClass.CONSTANT,):
                continue
            size = float(producer.shape.byte_size)
            if instr.opcode == "fusion":
                size = _fusion_read_bytes(module, instr, pos, size)
            reads += size
        cons = consumers.get(instr.name, [])
        outside = instr.is_root or not cons or any(
            uf.find(c) != cid for c in cons)
        writes = float(instr.shape.byte_size) if outside else 0.0
        if instr.opcode == "fusion" and writes > 0:
            writes = _fusion_write_bytes(module, instr, writes)
        instr.bytes_read = reads
        instr.bytes_written = writes
