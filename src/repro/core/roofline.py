"""Three-term roofline analysis from compiled dry-run artifacts (§Roofline).

    compute term    = HLO_FLOPs       / (chips x peak FLOP/s)
    memory term     = HLO_bytes       / (chips x HBM bandwidth)
    collective term = collective bytes / (chips x ICI link bandwidth)

All quantities are *per-device* here: the parsed HLO is post-SPMD, so its
shapes are the local shards — dividing global totals by `chips` is the same
as using per-device numbers directly (we cross-check against XLA's
`cost_analysis()`, which reports per-device numbers too but counts while-loop
bodies exactly once; the parser's trip-aware totals correct that, which
matters enormously for scanned layer stacks).

`useful_ratio` = MODEL_FLOPS / HLO_FLOPs catches remat/redundancy waste.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, asdict
from typing import Dict, Optional

from .collectives import collective_summary
from .hwmodel import HardwareModel, TPU_V5E
from .isa import Module, OpClass


@dataclass
class RooflineReport:
    label: str
    hw_name: str
    chips: int
    # Per-device quantities (trip-aware)
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    # Terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    # Usefulness
    model_flops: float = 0.0            # 6*N*D (or 6*N_active*D), global
    model_flops_per_device: float = 0.0
    useful_ratio: float = 0.0
    # Cross-checks
    xla_flops_per_device: float = 0.0   # raw cost_analysis (loop bodies x1)
    xla_bytes_per_device: float = 0.0
    memory_stats: Dict[str, float] = field(default_factory=dict)
    collective_breakdown: Dict[str, float] = field(default_factory=dict)
    notes: str = ""

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step bound that is pure-compute: how close an
        ideal executor would be to the compute roofline."""
        if self.bound_s <= 0:
            return 0.0
        return self.compute_s / self.bound_s

    def to_dict(self) -> dict:
        d = asdict(self)
        d["bound_s"] = self.bound_s
        d["roofline_fraction"] = self.roofline_fraction
        return d

    def summary_row(self) -> str:
        return (f"{self.label:<40s} c={self.compute_s*1e3:9.3f}ms "
                f"m={self.memory_s*1e3:9.3f}ms x={self.collective_s*1e3:9.3f}ms "
                f"dom={self.dominant:<10s} useful={self.useful_ratio:5.2f} "
                f"frac={self.roofline_fraction:5.2f}")


def _trip_aware_bytes(module: Module) -> float:
    """Per-device HBM bytes, expanding loop trip counts."""
    total = 0.0

    def visit(comp_name: str, mult: float, depth: int, stack: frozenset) -> None:
        nonlocal total
        if depth > 16 or comp_name in stack or \
                comp_name not in module.computations:
            return
        comp = module.computations[comp_name]
        for instr in comp.instructions:
            total += mult * (instr.bytes_read + instr.bytes_written)
            inner = mult * (instr.trip_count if instr.opcode == "while" else 1)
            for callee in instr.called_computations:
                visit(callee, inner, depth + 1, stack | {comp_name})

    visit(module.entry, 1.0, 0, frozenset())
    return total


def compute_roofline(
    module: Module,
    hw: HardwareModel = TPU_V5E,
    chips: int = 1,
    label: str = "",
    model_flops: float = 0.0,
    cost_analysis: Optional[dict] = None,
    memory_analysis: Optional[object] = None,
    dtype_peak: str = "bf16",
) -> RooflineReport:
    flops = module.total_flops(trip_aware=True)
    hbm_bytes = _trip_aware_bytes(module)
    colls = collective_summary(module, trip_aware=True)
    coll_bytes = sum(s.wire_bytes for s in colls.values())

    peak = hw.peak_flops_bf16 if dtype_peak == "bf16" else hw.peak_flops_f32
    compute_s = flops / peak
    memory_s = hbm_bytes / hw.hbm_bw
    collective_s = coll_bytes / hw.ici_bw_per_link

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get) if any(terms.values()) else "compute"

    mfpd = model_flops / chips if chips else 0.0
    report = RooflineReport(
        label=label, hw_name=hw.name, chips=chips,
        hlo_flops=flops, hlo_bytes=hbm_bytes, collective_bytes=coll_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops, model_flops_per_device=mfpd,
        useful_ratio=(mfpd / flops) if flops > 0 else 0.0,
        collective_breakdown={k: v.wire_bytes for k, v in colls.items()},
    )
    if cost_analysis:
        # jax >= 0.4.30 returns a one-element list of per-module dicts
        if isinstance(cost_analysis, (list, tuple)):
            cost_analysis = cost_analysis[0] if cost_analysis else {}
        report.xla_flops_per_device = float(cost_analysis.get("flops", 0.0))
        report.xla_bytes_per_device = float(
            cost_analysis.get("bytes accessed", 0.0))
    if memory_analysis is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            report.memory_stats[attr] = float(
                getattr(memory_analysis, attr, 0.0))
    return report


def save_report(report: RooflineReport, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report.to_dict(), f, indent=2)
