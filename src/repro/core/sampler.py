"""Virtual PC sampling: a deterministic in-order issue simulator.

The paper consumes hardware PC-sampling stall profiles (CUPTI / ROCprofiler /
Level Zero).  This container is CPU-only with the TPU as *target*, so LEO's
input profile is produced by an analytical simulator that plays the role of
the sampling hardware:

* instructions issue in program order; each occupies the issue slot for its
  throughput cost (`hw.issue_cycles`) and produces its result after its
  roofline latency (`hw.latency_cycles`);
* HBM traffic, async copies and async collective starts retire early and
  complete in the background — the TPU analogue of latency hiding — so their
  latency is only *exposed* when a consumer catches up with them;
* when an instruction cannot issue because an operand (or synchronization
  source) is not ready, the gap is recorded as *latency samples* against
  that instruction, classified by the blocking producer's class into the
  unified stall taxonomy (§II-D);
* while-loops are simulated with a warm-up pass then a steady-state pass in
  which loop-carried operands become available at (previous-iteration
  completion − body makespan), and per-op statistics scale by trip count.

The records mimic NVIDIA's two-level counters: ``total_samples`` (issue +
stall occupancy, "samples") and ``latency_samples`` (stall-only).  The
resulting profile is *shared ground truth* with `roofline.py` — the same
hardware model produces the roofline terms, the stall profile, and the
makespan used as estimated step time by the benchmark harness.

On real hardware, `StallProfile` can instead be populated from measured
xplane/profiler data — everything downstream of this interface is unchanged
(the paper's modular "hpcanalysis" boundary).

Multi-stream issue (the GPA-style scheduler model): each backend's
:class:`~repro.core.hwmodel.IssueModel` declares K concurrent issue queues
of a given width plus a scheduler policy (static ``round_robin`` vs
work-conserving ``greedy_oldest``).  Independently-schedulable instructions
interleave across queues, each queue drives its own per-queue view of the
backend's :class:`~repro.core.backends.SyncScoreboard` (pools replicate or
stay device-global per their declared scope), and *issue-port contention*
— an instruction whose operands are ready but whose queue is still
occupied — is charged as `StallClass.NOT_SELECTED` (occupant on a
different execution pipe: the arbiter picked other work) or
`StallClass.PIPE_BUSY` (occupant on the same pipe: the functional unit
itself is saturated).  With one single-width queue there is no
arbitration, so the model degenerates *byte-identically* to the in-order
single-stream simulator — the parity anchor for all pre-multi-stream
goldens.

Known simplifications (mirroring paper §Limitations): branch probabilities
are not modeled (all `conditional` branches simulate as executed); on a
``queues=1, width=1`` backend (the TPUs' in-order VLIW stream) the
`not_selected`/`pipe_busy` buckets stay structurally empty.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from .hwmodel import (HardwareModel, IssueModel, OccupancyModel,
                      SINGLE_ISSUE, SINGLE_WAVE)
from .isa import Instruction, Module, OpClass, StallClass, SyncKind

#: Issue-port contention events retained per report (aggregate counters
#: keep accumulating past the cap), mirroring the sync scoreboard's cap.
_MAX_ISSUE_EVENTS = 64

#: Stall classes co-resident waves can hide (dependence/sync waits — the
#: machine switches to another wave while this one waits on a producer).
#: Scheduler-contention classes are NOT hideable: another wave would lose
#: the same arbitration, so `not_selected`/`pipe_busy` keep their class.
_HIDEABLE_STALLS = frozenset({
    StallClass.MEM_DEP, StallClass.EXEC_DEP, StallClass.COLLECTIVE_WAIT,
    StallClass.SYNC_WAIT,
})

#: Execution-pipe families used to split port contention into
#: `pipe_busy` (same pipe saturated) vs `not_selected` (arbitration loss).
_PIPE_OF = {
    OpClass.MATMUL: "mxu",
    OpClass.COMPUTE: "vpu",
    OpClass.REDUCE: "vpu",
    OpClass.FUSION: "vpu",
    OpClass.MEMORY_LOAD: "lsu",
    OpClass.MEMORY_STORE: "lsu",
    OpClass.DATA_MOVEMENT: "lsu",
    OpClass.SYNC_SET: "lsu",
    OpClass.SYNC_WAIT: "lsu",
    OpClass.COLLECTIVE: "ici",
}


def pipe_of(instr: Instruction) -> str:
    """Execution-pipe family an instruction occupies."""
    return _PIPE_OF.get(instr.op_class, "ctl")


def classify_blocker(consumer: Instruction,
                     blocker: Optional[Instruction]) -> StallClass:
    if blocker is None:
        return StallClass.NONE
    cls = blocker.op_class
    if cls in (OpClass.MEMORY_LOAD, OpClass.MEMORY_STORE,
               OpClass.DATA_MOVEMENT, OpClass.PARAMETER, OpClass.CONSTANT):
        return StallClass.MEM_DEP
    if cls is OpClass.COLLECTIVE:
        return StallClass.COLLECTIVE_WAIT
    if cls is OpClass.SYNC_SET:
        return StallClass.COLLECTIVE_WAIT if blocker.comm_bytes > 0 \
            else StallClass.MEM_DEP
    if cls in (OpClass.SYNC_WAIT, OpClass.TUPLE, OpClass.CONTROL):
        return StallClass.SYNC_WAIT
    return StallClass.EXEC_DEP


@dataclass
class PCSampleRecord:
    qualified: str
    total_samples: float = 0.0     # issue occupancy + stalls (NVIDIA "samples")
    latency_samples: float = 0.0   # stall-only ("latency samples")
    stall_breakdown: Dict[StallClass, float] = field(default_factory=dict)
    exec_count: float = 0.0
    blockers: Dict[str, float] = field(default_factory=dict)  # qualified -> cycles

    def add_stall(self, cls: StallClass, cycles: float,
                  blocker: Optional[str]) -> None:
        if cycles <= 0:
            return
        self.latency_samples += cycles
        self.stall_breakdown[cls] = self.stall_breakdown.get(cls, 0.0) + cycles
        if blocker:
            self.blockers[blocker] = self.blockers.get(blocker, 0.0) + cycles

    @property
    def dominant_stall(self) -> StallClass:
        if not self.stall_breakdown:
            return StallClass.NONE
        return max(self.stall_breakdown.items(), key=lambda kv: kv[1])[0]

    def stall_fraction(self, cls: StallClass) -> float:
        if self.latency_samples <= 0:
            return 0.0
        return self.stall_breakdown.get(cls, 0.0) / self.latency_samples


@dataclass
class IssuePressureReport:
    """Per-queue issue-port pressure (JSON-pure, Diagnosis-embeddable).

    The scheduler-contention counterpart of
    :class:`~repro.core.backends.SyncPressureReport`: per queue, how much
    work it issued, how long it was occupied, and how many cycles ready
    instructions spent losing arbitration (`not_selected`) or waiting on a
    saturated execution pipe (`pipe_busy`), plus capped per-event detail
    naming the blocking occupant.
    """

    queues: int = 1
    width: int = 1
    policy: str = "round_robin"
    per_queue: List[Dict[str, Any]] = field(default_factory=list)
    events: List[Dict[str, Any]] = field(default_factory=list)

    @property
    def not_selected_cycles(self) -> float:
        return sum(q.get("not_selected_cycles", 0.0) for q in self.per_queue)

    @property
    def pipe_busy_cycles(self) -> float:
        return sum(q.get("pipe_busy_cycles", 0.0) for q in self.per_queue)

    @property
    def contention_cycles(self) -> float:
        return self.not_selected_cycles + self.pipe_busy_cycles

    @property
    def contended(self) -> bool:
        return self.contention_cycles > 0

    def to_dict(self) -> Dict[str, Any]:
        return {
            "queues": self.queues,
            "width": self.width,
            "policy": self.policy,
            "contended": self.contended,
            "contention_cycles": self.contention_cycles,
            "not_selected_cycles": self.not_selected_cycles,
            "pipe_busy_cycles": self.pipe_busy_cycles,
            "per_queue": self.per_queue,
            "events": self.events,
        }


class _IssueState:
    """Mutable per-run collector behind an :class:`IssuePressureReport`."""

    def __init__(self, issue: IssueModel):
        self.issue = issue
        k = issue.queues
        self.issued = [0.0] * k
        self.busy_cycles = [0.0] * k
        self.not_selected = [0.0] * k
        self.pipe_busy = [0.0] * k
        self.events: List[Dict[str, Any]] = []

    def note_issue(self, queue: int, weight: float, cost: float) -> None:
        self.issued[queue] += weight
        self.busy_cycles[queue] += weight * cost

    def note_contention(self, queue: int, cls: StallClass, cycles: float,
                        weight: float, consumer: str, holder: Optional[str],
                        pipe: str, at: float) -> None:
        if cls is StallClass.PIPE_BUSY:
            self.pipe_busy[queue] += cycles * weight
        else:
            self.not_selected[queue] += cycles * weight
        if len(self.events) < _MAX_ISSUE_EVENTS:
            self.events.append({
                "consumer": consumer, "holder": holder or "",
                "queue": queue, "pipe": pipe, "stall_class": cls.value,
                "stall_cycles": cycles, "at": at, "weight": weight,
            })

    def report(self) -> IssuePressureReport:
        return IssuePressureReport(
            queues=self.issue.queues, width=self.issue.width,
            policy=self.issue.policy,
            per_queue=[{
                "queue": i,
                "issued": self.issued[i],
                "busy_cycles": self.busy_cycles[i],
                "not_selected_cycles": self.not_selected[i],
                "pipe_busy_cycles": self.pipe_busy[i],
            } for i in range(self.issue.queues)],
            events=list(self.events))


@dataclass
class OccupancyPressureReport:
    """Per-queue latency-hiding pressure (JSON-pure, Diagnosis-embeddable).

    The wave-residency counterpart of :class:`IssuePressureReport`: per
    issue queue, how many hideable stall cycles co-resident waves covered
    (``hidden_cycles``), how many leaked through (``exposed_cycles``), and
    how many of the leaked cycles were *partially* hidden — the
    `StallClass.OCCUPANCY_LIMITED` signature of latency hiding that ran
    out of waves (``occupancy_limited_cycles``) — plus capped per-event
    detail naming the stalled consumer and its producer.
    """

    waves: int = 1
    limiter: str = "none"
    window_cycles: float = 0.0
    per_queue: List[Dict[str, Any]] = field(default_factory=list)
    events: List[Dict[str, Any]] = field(default_factory=list)

    @property
    def hidden_cycles(self) -> float:
        return sum(q.get("hidden_cycles", 0.0) for q in self.per_queue)

    @property
    def exposed_cycles(self) -> float:
        return sum(q.get("exposed_cycles", 0.0) for q in self.per_queue)

    @property
    def occupancy_limited_cycles(self) -> float:
        return sum(q.get("occupancy_limited_cycles", 0.0)
                   for q in self.per_queue)

    @property
    def hidden_fraction(self) -> float:
        """Fraction of hideable stall cycles co-resident waves covered."""
        total = self.hidden_cycles + self.exposed_cycles
        return self.hidden_cycles / total if total > 0 else 0.0

    @property
    def limited(self) -> bool:
        """True when latency hiding ran out of waves mid-stall."""
        return self.occupancy_limited_cycles > 0

    def to_dict(self) -> Dict[str, Any]:
        return {
            "waves": self.waves,
            "limiter": self.limiter,
            "window_cycles": self.window_cycles,
            "limited": self.limited,
            "hidden_cycles": self.hidden_cycles,
            "exposed_cycles": self.exposed_cycles,
            "occupancy_limited_cycles": self.occupancy_limited_cycles,
            "hidden_fraction": self.hidden_fraction,
            "per_queue": self.per_queue,
            "events": self.events,
        }


class _OccState:
    """Mutable per-run collector behind an :class:`OccupancyPressureReport`.

    Credit-based analytical hiding: every issued instruction banks
    ``(W-1) * issue_cost`` cycles of co-resident-wave issue capacity on its
    queue (capped at ``(W-1) * window_cycles`` — each sibling wave only has
    so much independent work), and a hideable stall first drains that bank
    before charging the machine.  Single-pass, so native-W analysis costs
    the same as the W=1 sampler.
    """

    def __init__(self, occ: OccupancyModel, queues: int):
        self.occ = occ
        self.queues = queues
        self.credit = [0.0] * queues
        self.hidden = [0.0] * queues
        self.exposed = [0.0] * queues
        self.limited = [0.0] * queues
        self.events: List[Dict[str, Any]] = []
        self._cap = (occ.waves - 1) * occ.window_cycles

    def note_issue(self, queue: int, cost: float) -> None:
        if cost <= 0:
            return
        self.credit[queue] = min(
            self.credit[queue] + (self.occ.waves - 1) * cost, self._cap)

    def absorb(self, queue: int, stall: float, weight: float, consumer: str,
               blocker: Optional[str], cls: StallClass,
               at: float) -> Tuple[float, float]:
        """Drain hiding credit against one stall; returns (hidden, exposed)
        in unweighted cycles."""
        hidden = min(stall, self.credit[queue])
        self.credit[queue] -= hidden
        exposed = stall - hidden
        self.hidden[queue] += hidden * weight
        self.exposed[queue] += exposed * weight
        if hidden > 0 and exposed > 0:
            # partial hiding: the OCCUPANCY_LIMITED signature
            self.limited[queue] += exposed * weight
            if len(self.events) < _MAX_ISSUE_EVENTS:
                self.events.append({
                    "consumer": consumer, "blocker": blocker or "",
                    "queue": queue, "stall_class": cls.value,
                    "hidden_cycles": hidden, "exposed_cycles": exposed,
                    "at": at, "weight": weight,
                })
        return hidden, exposed

    def report(self) -> OccupancyPressureReport:
        return OccupancyPressureReport(
            waves=self.occ.waves, limiter=self.occ.limiter,
            window_cycles=self.occ.window_cycles,
            per_queue=[{
                "queue": i,
                "hidden_cycles": self.hidden[i],
                "exposed_cycles": self.exposed[i],
                "occupancy_limited_cycles": self.limited[i],
            } for i in range(self.queues)],
            events=list(self.events))


class _Ports:
    """Issue slots of one simulated computation activation: K queues of
    `width` slots each, every slot tracking when it frees and what
    occupies it.  One activation's ports are independent of its callees'
    (a `call`/`while` op occupies its caller's slot for the whole body)."""

    def __init__(self, issue: IssueModel, t0: float):
        self.issue = issue
        n = issue.queues * issue.width
        self.free = [t0] * n
        self.occupant: List[Optional[str]] = [None] * n
        self.pipe: List[Optional[str]] = [None] * n
        self._rr = 0

    def pick(self) -> int:
        """Choose a slot per the scheduler policy; returns its index."""
        w = self.issue.width
        if self.issue.policy == "greedy_oldest":
            # work-conserving: the earliest-freeing slot anywhere
            return min(range(len(self.free)), key=lambda i: (self.free[i], i))
        # static round-robin queue assignment; earliest slot within it
        q = self._rr % self.issue.queues
        self._rr += 1
        base = q * w
        return min(range(base, base + w), key=lambda i: (self.free[i], i))

    def queue_of(self, slot: int) -> int:
        return slot // self.issue.width

    def occupy(self, slot: int, until: float, qualified: str,
               pipe: str) -> None:
        self.free[slot] = until
        self.occupant[slot] = qualified
        self.pipe[slot] = pipe


@dataclass
class StallProfile:
    hw_name: str
    records: Dict[str, PCSampleRecord] = field(default_factory=dict)
    makespan_cycles: float = 0.0
    clock_hz: float = 1e9
    # Per-pool §III-E resource pressure (SyncPressureReport) when the
    # profile was produced by a sampler driving a SyncModel scoreboard;
    # None for measured profiles and sync-less backends.
    sync_pressure: Optional[object] = None
    # Per-queue issue-port pressure (IssuePressureReport) when produced by
    # the virtual sampler; None for measured profiles.
    issue_pressure: Optional[object] = None
    # Per-queue latency-hiding pressure (OccupancyPressureReport) when the
    # profile was produced under a multi-wave OccupancyModel; None for
    # measured profiles and for W=1 runs (keeping single-wave profile
    # fingerprints byte-identical to the pre-occupancy sampler).
    occupancy_pressure: Optional[object] = None
    # (SyncKind, computation, tag) -> concrete resource instance actually
    # assigned by the sampler's scoreboard; consumed by the sync_edges
    # pass so static edge annotations name the same hardware the dynamic
    # SYNC_RESOURCE events blame.  None for measured profiles.
    sync_assignment: Optional[Dict] = None

    @property
    def makespan_seconds(self) -> float:
        return self.makespan_cycles / self.clock_hz

    @property
    def total_stall_cycles(self) -> float:
        return sum(r.latency_samples for r in self.records.values())

    def record(self, qualified: str) -> PCSampleRecord:
        if qualified not in self.records:
            self.records[qualified] = PCSampleRecord(qualified=qualified)
        return self.records[qualified]

    def top_stalled(self, n: int = 10) -> List[PCSampleRecord]:
        return sorted((r for r in self.records.values()
                       if r.latency_samples > 0),
                      key=lambda r: -r.latency_samples)[:n]


# Computation kinds that are not independently scheduled streams: their cost
# is folded into the calling op (fusions) or they are scalar glue (reduce
# combiners, loop conditions).
_SKIP_KINDS = ("fusion", "reduce", "loop_cond")


class VirtualSampler:
    def __init__(self, module: Module, hw: HardwareModel, sync=None):
        self.module = module
        self.hw = hw
        self.issue: IssueModel = getattr(hw, "issue", SINGLE_ISSUE) \
            or SINGLE_ISSUE
        self.occupancy: OccupancyModel = getattr(hw, "occupancy",
                                                 SINGLE_WAVE) or SINGLE_WAVE
        # Optional backend SyncModel (duck-typed to avoid an import cycle
        # with repro.core.backends).  Two behaviors: the async_collectives
        # knob (vendors whose collectives block the issuing queue, e.g.
        # queue-ordered oneCCL, pay the transfer latency at *issue*), and —
        # when the model carries resource pools — a stateful scoreboard
        # that serializes oversubscribed sync resources (§III-E): an async
        # start with every barrier slot / waitcnt counter / SWSB token in
        # flight inherits the oldest holder's remaining latency, recorded
        # as SYNC_RESOURCE stall cycles.  Under a multi-queue issue model
        # the scoreboard replicates queue-scoped pools per queue.
        self.sync = sync
        self.scoreboard = None
        if sync is not None and hasattr(sync, "scoreboard") \
                and getattr(sync, "pools", ()):
            self.scoreboard = sync.scoreboard(
                realloc_cycles=getattr(hw, "sync_realloc_cycles", 0.0),
                queues=self.issue.queues, waves=self.occupancy.waves)
        self._istate = _IssueState(self.issue)
        # Latency-hiding credit tracker; None at W=1 so the single-wave
        # path is bit-for-bit the pre-occupancy sampler.
        self._wavestate: Optional[_OccState] = (
            _OccState(self.occupancy, self.issue.queues)
            if self.occupancy.multi_wave else None)
        self._assignment: Dict[Tuple[SyncKind, str, str], str] = {}

    # -- public ---------------------------------------------------------------

    def run(self) -> StallProfile:
        profile = StallProfile(hw_name=self.hw.name, clock_hz=self.hw.clock_hz)
        entry = self.module.entry_computation
        makespan = self._simulate(entry, 0.0, {}, 1.0, profile, depth=0,
                                  board=self.scoreboard)
        if self._wavestate is not None:
            # Multi-wave makespan: hidden stall cycles are covered by
            # co-resident wave issue, so they compress the critical path —
            # floored by raw/W (waves can at best W-fold overlap the
            # program) and by the busiest queue's issue occupancy (work
            # that must be issued cannot be hidden).
            occ_report = self._wavestate.report()
            profile.occupancy_pressure = occ_report
            busy_floor = max(self._istate.busy_cycles, default=0.0)
            makespan = max(makespan - occ_report.hidden_cycles,
                           makespan / self.occupancy.waves, busy_floor)
        profile.makespan_cycles = makespan
        if self.scoreboard is not None:
            profile.sync_pressure = self.scoreboard.report()
            profile.sync_assignment = dict(self._assignment)
        profile.issue_pressure = self._istate.report()
        self._seed_unsampled(profile)
        return profile

    # -- simulation -------------------------------------------------------------

    def _simulate(self, comp, t0: float, env: Dict[str, float], mult: float,
                  profile: StallProfile, depth: int,
                  loop_ctx: Optional[Dict[int, float]] = None,
                  board=None) -> float:
        """Simulate one computation; returns its end time (cycles)."""
        if depth > 32:
            return t0
        local_env = env
        params = {p.name: p for p in comp.parameters}
        ports = _Ports(self.issue, t0)
        multi = self.issue.multi_stream
        end = t0
        for instr in comp.instructions:
            q = instr.qualified_name
            if instr.op_class in (OpClass.PARAMETER, OpClass.CONSTANT):
                local_env[q] = t0
                rec = profile.record(q)
                rec.exec_count += mult
                continue

            ready, blocker = self._ready_time(comp, instr, local_env, params,
                                              loop_ctx, t0)
            slot = ports.pick()
            pf = ports.free[slot]
            data_ready = max(pf, ready)
            qidx = ports.queue_of(slot)
            res_ready, res_blocker, acquired = self._acquire_sync(
                board, instr, q, data_ready, mult, queue=qidx)
            issue_at = max(data_ready, res_ready)
            rec = profile.record(q)
            rec.exec_count += mult
            issue_cost = self._issue_cycles(instr, env, profile, issue_at,
                                            mult, depth, board)
            # Stall anatomy: data wait (measured from when the issue slot
            # freed — the single-stream convention), issue-port contention
            # (data ready, slot busy; only meaningful with >1 port: a lone
            # in-order stream has no arbiter to lose), and sync-resource
            # serialization on top.
            data_stall = max(0.0, ready - pf)
            port_stall = max(0.0, pf - ready) if multi else 0.0
            res_stall = issue_at - data_ready
            wstate = self._wavestate
            if data_stall > 0:
                cls = classify_blocker(instr, blocker)
                bname = blocker.qualified_name if blocker else None
                if wstate is not None and cls in _HIDEABLE_STALLS:
                    # Co-resident waves absorb the wait from banked issue
                    # credit; a fully-hidden stall charges nothing, a
                    # partially-hidden one reclassifies its exposed tail
                    # as OCCUPANCY_LIMITED (hiding ran out of waves).
                    hidden, data_stall = wstate.absorb(
                        qidx, data_stall, mult, consumer=q, blocker=bname,
                        cls=cls, at=ready)
                    if hidden > 0 and data_stall > 0:
                        cls = StallClass.OCCUPANCY_LIMITED
                if data_stall > 0:
                    rec.add_stall(cls, mult * data_stall, bname)
            if port_stall > 0:
                pipe = pipe_of(instr)
                occupant = ports.occupant[slot]
                cls = StallClass.PIPE_BUSY if ports.pipe[slot] == pipe \
                    else StallClass.NOT_SELECTED
                rec.add_stall(cls, mult * port_stall, occupant)
                self._istate.note_contention(qidx, cls, port_stall, mult,
                                             consumer=q, holder=occupant,
                                             pipe=pipe, at=ready)
            if res_stall > 0:
                res_cls = StallClass.SYNC_RESOURCE
                if wstate is not None:
                    hidden, res_stall = wstate.absorb(
                        qidx, res_stall, mult, consumer=q,
                        blocker=res_blocker, cls=res_cls, at=data_ready)
                    if hidden > 0 and res_stall > 0:
                        res_cls = StallClass.OCCUPANCY_LIMITED
                if res_stall > 0:
                    rec.add_stall(res_cls, mult * res_stall, res_blocker)
            rec.total_samples += mult * (data_stall + port_stall + res_stall
                                         + issue_cost)
            completion = issue_at + self._latency_cycles(instr, env, profile,
                                                         issue_at, mult,
                                                         depth)
            local_env[q] = completion
            for kind, tag in acquired:
                board.complete(kind, tag, completion)
            ports.occupy(slot, issue_at + issue_cost, q, pipe_of(instr))
            # Control ops' issue_cost is their simulated body's makespan;
            # the body's own instructions already charge their queues'
            # occupancy, so the wrapper records an issue event but no
            # busy cycles (otherwise per-queue busy would double-count
            # and could exceed the makespan on loop-heavy programs).
            queue_cost = 0.0 \
                if instr.opcode in ("while", "call", "conditional") \
                else issue_cost
            self._istate.note_issue(qidx, mult, queue_cost)
            if wstate is not None:
                # Each issued instruction banks (W-1) x its cost of
                # co-resident-wave issue capacity on this queue (control
                # ops excluded: their bodies' instructions already bank).
                wstate.note_issue(qidx, queue_cost)
            end = max(end, issue_at + issue_cost)
        return end

    def _acquire_sync(self, board, instr: Instruction, q: str, now: float,
                      mult: float, queue: int = 0):
        """Retire waited resources and claim set ones on the scoreboard.

        Returns (resource_ready, blocking holder qualified-name or None,
        [(kind, tag)] acquired — their completion is noted once known)."""
        si = instr.sync
        if board is None or si.kind is None:
            return now, None, ()
        # Tags are computation-scoped: identifiers are instruction/value
        # names, which are only unique within their computation — without
        # the scope, same-named sync ops in different computations would
        # alias one allocation.
        scope = instr.computation
        for tag in si.waits:
            board.retire(si.kind, f"{scope}::{tag}", drain_to=si.counter)
        res_ready, res_blocker = now, None
        acquired = []
        for tag in si.sets:
            scoped = f"{scope}::{tag}"
            acq = board.acquire(si.kind, scoped, consumer=q, now=now,
                                weight=mult, queue=queue)
            if acq is None:
                continue
            acquired.append((si.kind, scoped))
            self._assignment[(si.kind, scope, tag)] = acq.instance
            if acq.available_at > res_ready:
                res_ready = acq.available_at
                res_blocker = acq.evicted_holder
        return res_ready, res_blocker, acquired

    def _ready_time(self, comp, instr: Instruction, env: Dict[str, float],
                    params: Dict[str, Instruction],
                    loop_ctx: Optional[Dict[int, float]],
                    t0: float) -> Tuple[float, Optional[Instruction]]:
        ready = t0
        blocker: Optional[Instruction] = None

        def consider(name: str, time: float) -> None:
            nonlocal ready, blocker
            if time > ready:
                ready = time
                blocker = comp.get(name) or self.module.find(name)

        # Loop-carried values: gte(state_param, i) in steady state.
        if loop_ctx is not None and instr.opcode == "get-tuple-element" and \
                instr.operands and instr.operands[0] in params:
            slot = int(instr.attributes.get("index", 0))
            if slot in loop_ctx:
                carried = loop_ctx[slot]
                if carried > ready:
                    ready = carried
                    blocker = self._slot_def(comp, slot)
                return ready, blocker

        for op in instr.operands:
            q = f"{comp.name}::{op}"
            consider(op, env.get(q, t0))
        # Synchronization waits (barrier / waitcnt semantics).
        for waited in instr.sync.waits:
            q = f"{comp.name}::{waited}"
            consider(waited, env.get(q, t0))
        return ready, blocker

    def _slot_def(self, comp, slot: int) -> Optional[Instruction]:
        root = comp.root
        if root is not None and root.opcode == "tuple" and \
                slot < len(root.operands):
            return comp.get(root.operands[slot])
        return root

    def _issue_cycles(self, instr: Instruction, env, profile, issue_at, mult,
                      depth, board=None) -> float:
        if instr.opcode == "while":
            return self._simulate_while(instr, env, profile, issue_at, mult,
                                        depth, board)
        if instr.opcode in ("call", "conditional"):
            return self._simulate_called(instr, env, profile, issue_at, mult,
                                         depth, board)
        if instr.op_class is OpClass.COLLECTIVE and self.sync is not None \
                and not getattr(self.sync, "async_collectives", True):
            return self.hw.latency_cycles(instr)
        return self.hw.issue_cycles(instr)

    def _latency_cycles(self, instr: Instruction, env, profile, issue_at,
                        mult, depth) -> float:
        if instr.opcode in ("while", "call", "conditional"):
            # completion == end of the simulated body; issue_cycles covered it
            return self._last_control_cost
        return self.hw.latency_cycles(instr)

    _last_control_cost: float = 0.0

    def _simulate_called(self, instr: Instruction, env, profile, issue_at,
                         mult, depth, board=None) -> float:
        end = issue_at
        for cname in instr.called_computations:
            callee = self.module.computations.get(cname)
            if callee is None or callee.kind in _SKIP_KINDS:
                continue
            sub_end = self._simulate(callee, issue_at, env, mult, profile,
                                     depth + 1, board=board)
            end = max(end, sub_end)
        self._last_control_cost = end - issue_at
        return end - issue_at

    def _simulate_while(self, instr: Instruction, env, profile, issue_at,
                        mult, depth, board=None) -> float:
        body = None
        for cname in instr.called_computations:
            c = self.module.computations.get(cname)
            if c is not None and c.kind == "loop_body":
                body = c
        if body is None:
            self._last_control_cost = 0.0
            return 0.0
        trips = max(1, instr.trip_count)

        # Pass A (warm-up): no loop-carried availability info.  Runs on a
        # forked scoreboard and a scratch issue-pressure collector so
        # warm-up allocations/contention cannot pollute the steady-state
        # pressure stats.
        warm = StallProfile(hw_name=self.hw.name, clock_hz=self.hw.clock_hz)
        env_a: Dict[str, float] = {}
        saved_istate = self._istate
        saved_wavestate = self._wavestate
        self._istate = _IssueState(self.issue)
        if saved_wavestate is not None:
            self._wavestate = _OccState(self.occupancy, self.issue.queues)
        try:
            end_a = self._simulate(body, issue_at, env_a, 1.0, warm,
                                   depth + 1, loop_ctx={},
                                   board=board.fork() if board is not None
                                   else None)
        finally:
            self._istate = saved_istate
            self._wavestate = saved_wavestate
        makespan_a = max(end_a - issue_at, 1.0)

        # Steady-state loop context: slot value available at
        # (producer completion in previous iteration) - body makespan.
        loop_ctx: Dict[int, float] = {}
        root = body.root
        if root is not None and root.opcode == "tuple":
            for slot, opname in enumerate(root.operands):
                q = f"{body.name}::{opname}"
                if q in env_a:
                    loop_ctx[slot] = env_a[q] - makespan_a

        # Pass B (steady state), recorded with weight mult * trips.
        env_b: Dict[str, float] = {}
        end_b = self._simulate(body, issue_at, env_b, mult * trips, profile,
                               depth + 1, loop_ctx=loop_ctx, board=board)
        makespan_b = max(end_b - issue_at, 1.0)
        self._last_control_cost = trips * makespan_b
        return self._last_control_cost

    def _seed_unsampled(self, profile: StallProfile) -> None:
        """Retain unsampled producers (paper §III-B): every instruction gets
        a record so address-generation chains can receive blame.  Fusion- and
        combiner-inner instructions execute as part of their caller, so they
        inherit its execution multiplier (Stage-4 pruning must not discard
        them as dead)."""
        mults = self._execution_multipliers()
        for instr in self.module.all_instructions():
            rec = profile.record(instr.qualified_name)
            if rec.exec_count == 0:
                comp = self.module.computations.get(instr.computation)
                if comp is not None and comp.kind in _SKIP_KINDS:
                    rec.exec_count = mults.get(instr.computation, 1.0)

    def _execution_multipliers(self) -> Dict[str, float]:
        mults: Dict[str, float] = {}

        def visit(comp_name: str, mult: float, depth: int) -> None:
            if depth > 16 or comp_name not in self.module.computations:
                return
            mults[comp_name] = max(mults.get(comp_name, 0.0), mult)
            for instr in self.module.computations[comp_name].instructions:
                inner = mult * (instr.trip_count if instr.opcode == "while"
                                else 1)
                for callee in instr.called_computations:
                    visit(callee, inner, depth + 1)

        if self.module.entry:
            visit(self.module.entry, 1.0, 0)
        return mults


def sample(module: Module, hw: HardwareModel) -> StallProfile:
    return VirtualSampler(module, hw).run()
