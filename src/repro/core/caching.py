"""Cache tiers backing `LeoSession` / `LeoService` (serving-scale storage).

Two building blocks:

  * :class:`LRUCache` — a bounded mapping with least-recently-used
    eviction.  The session's parse/graph/analysis caches were unbounded
    dicts before; at serving scale ("millions of users") an analyzer that
    never forgets a trace is a memory leak.  ``capacity=None`` keeps the
    legacy unbounded behavior.
  * :class:`DiskCache` — a content-addressed on-disk tier (sha256 key ->
    gzipped artifact) shared across processes.  Parsed ``Module``s are
    stored as gzipped pickles, :class:`~repro.core.report.Diagnosis`
    results as gzipped JSON, so a warm cache directory lets a *second
    process* re-run an analysis with zero HLO parses (asserted in
    ``tests/test_service.py``).

Writes are atomic (tmp file + ``os.replace``), so concurrent writers on
the same key are safe: last writer wins with an intact artifact either
way.
"""
from __future__ import annotations

import gzip
import json
import os
import pickle
import tempfile
import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, Iterator, MutableMapping, Optional

#: Bump when the pickled Module layout changes incompatibly; stale
#: artifacts are treated as misses, never as errors.
MODULE_ARTIFACT_FORMAT = 1


class LRUCache(MutableMapping):
    """Bounded mapping with LRU eviction and an eviction counter.

    ``capacity=None`` disables eviction (legacy unbounded behavior);
    ``on_evict(key, value)`` lets the owner drop secondary indexes that
    reference the evicted entry.
    """

    def __init__(self, capacity: Optional[int] = None,
                 on_evict: Optional[Callable[[Any, Any], None]] = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"LRU capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.evictions = 0
        self._on_evict = on_evict
        self._data: "OrderedDict[Any, Any]" = OrderedDict()

    def __getitem__(self, key: Any) -> Any:
        value = self._data[key]          # KeyError propagates
        self._data.move_to_end(key)
        return value

    def __setitem__(self, key: Any, value: Any) -> None:
        self._data[key] = value
        self._data.move_to_end(key)
        while self.capacity is not None and len(self._data) > self.capacity:
            old_key, old_value = self._data.popitem(last=False)
            self.evictions += 1
            if self._on_evict is not None:
                self._on_evict(old_key, old_value)

    def __delitem__(self, key: Any) -> None:
        del self._data[key]

    def __iter__(self) -> Iterator[Any]:
        return iter(self._data)

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: Any) -> bool:
        return key in self._data

    def __repr__(self) -> str:
        cap = "inf" if self.capacity is None else self.capacity
        return (f"LRUCache({len(self._data)}/{cap}, "
                f"evictions={self.evictions})")


class DiskCacheStats:
    """Hit/miss/write counters for the on-disk tier (thread-safe)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.module_hits = 0
        self.module_misses = 0
        self.diagnosis_hits = 0
        self.diagnosis_misses = 0
        self.writes = 0

    def bump(self, field: str, by: int = 1) -> None:
        with self._lock:
            setattr(self, field, getattr(self, field) + by)

    def as_dict(self) -> Dict[str, int]:
        return {
            "module_hits": self.module_hits,
            "module_misses": self.module_misses,
            "diagnosis_hits": self.diagnosis_hits,
            "diagnosis_misses": self.diagnosis_misses,
            "writes": self.writes,
        }


class DiskCache:
    """Content-addressed artifact store: ``<root>/<kind>/<k[:2]>/<k>.gz``.

    Keys are sha256 hex digests computed by the caller (the session's
    ``module_key`` / the service's diagnosis key), so identical content
    always lands on the same path regardless of which process wrote it.
    Corrupt or format-incompatible artifacts read as misses.
    """

    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        self.stats = DiskCacheStats()

    def _path(self, kind: str, key: str, ext: str) -> str:
        return os.path.join(self.root, kind, key[:2], f"{key}{ext}")

    def _write_atomic(self, path: str, payload: bytes) -> None:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(payload)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self.stats.bump("writes")

    # -- parsed modules (gzipped pickle) ---------------------------------------

    def load_module(self, key: str):
        path = self._path("modules", key, ".pkl.gz")
        try:
            with gzip.open(path, "rb") as f:
                payload = pickle.load(f)
            if payload.get("format") != MODULE_ARTIFACT_FORMAT:
                raise ValueError("stale module artifact format")
            module = payload["module"]
        except (OSError, ValueError, KeyError, EOFError,
                pickle.UnpicklingError, AttributeError):
            self.stats.bump("module_misses")
            return None
        self.stats.bump("module_hits")
        return module

    def store_module(self, key: str, module: Any) -> None:
        payload = pickle.dumps(
            {"format": MODULE_ARTIFACT_FORMAT, "module": module},
            protocol=pickle.HIGHEST_PROTOCOL)
        self._write_atomic(self._path("modules", key, ".pkl.gz"),
                           gzip.compress(payload))

    # -- diagnoses (gzipped JSON) ----------------------------------------------

    def load_diagnosis(self, key: str):
        from .report import Diagnosis, SCHEMA_VERSION
        path = self._path("diagnoses", key, ".json.gz")
        try:
            with gzip.open(path, "rt", encoding="utf-8") as f:
                data = json.load(f)
            if data.get("schema_version") != SCHEMA_VERSION:
                raise ValueError("stale diagnosis schema")
            diag = Diagnosis.from_dict(data)
        except (OSError, ValueError, KeyError, TypeError):
            self.stats.bump("diagnosis_misses")
            return None
        self.stats.bump("diagnosis_hits")
        return diag

    def store_diagnosis(self, key: str, diagnosis: Any) -> None:
        self._write_atomic(
            self._path("diagnoses", key, ".json.gz"),
            gzip.compress(diagnosis.to_json().encode("utf-8")))

    # -- maintenance -----------------------------------------------------------

    def clear(self) -> None:
        import shutil
        for kind in ("modules", "diagnoses"):
            shutil.rmtree(os.path.join(self.root, kind), ignore_errors=True)

    def __repr__(self) -> str:
        return f"DiskCache({self.root!r}, {self.stats.as_dict()})"
