"""Cache tiers backing `LeoSession` / `LeoService` (serving-scale storage).

Two building blocks:

  * :class:`LRUCache` — a bounded mapping with least-recently-used
    eviction.  The session's parse/graph/analysis caches were unbounded
    dicts before; at serving scale ("millions of users") an analyzer that
    never forgets a trace is a memory leak.  ``capacity=None`` keeps the
    legacy unbounded behavior.
  * :class:`DiskCache` — a content-addressed on-disk tier (sha256 key ->
    gzipped artifact) shared across processes.  Parsed ``Module``s are
    stored as gzipped pickles, :class:`~repro.core.report.Diagnosis`
    results as gzipped JSON, so a warm cache directory lets a *second
    process* re-run an analysis with zero HLO parses (asserted in
    ``tests/test_service.py``).

The disk tier supports bounded growth: ``max_bytes`` caps the total
artifact size (oldest-accessed evicted first; hits refresh mtime so the
policy is LRU-ish across processes) and ``ttl_seconds`` expires idle
artifacts.  A sweep runs opportunistically every ``sweep_interval``
writes — ``<outdir>/.leo_cache`` no longer grows without bound.

Writes are atomic (tmp file + ``os.replace``), so concurrent writers on
the same key are safe: last writer wins with an intact artifact either
way.

Multi-process serving (``repro.serve.pool``) shares one cache root
across N forked workers, which adds two cross-process obligations:

  * sweeps coordinate through an advisory ``flock`` on
    ``<root>/.sweep.lock`` so only one *process* compacts at a time —
    an opportunistic sweep that finds the file lock held skips, exactly
    like the in-process non-blocking path;
  * the mtime scan and the tmp-file publish tolerate a concurrently
    exiting/clearing process: paths that vanish between listing and
    ``stat`` are skipped, and a ``mkstemp`` whose parent directory was
    just removed recreates it and retries once.
"""
from __future__ import annotations

import gzip
import json
import os
import pickle
import tempfile
import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, Iterator, List, MutableMapping, \
    Optional, Tuple

try:                # POSIX only; on other platforms sweeps fall back to
    import fcntl    # in-process coordination (the threading lock).
except ImportError:             # pragma: no cover - non-POSIX
    fcntl = None  # type: ignore[assignment]

#: Bump when the pickled Module layout changes incompatibly; stale
#: artifacts are treated as misses, never as errors.
MODULE_ARTIFACT_FORMAT = 1


class LRUCache(MutableMapping):
    """Bounded mapping with LRU eviction and an eviction counter.

    ``capacity=None`` disables eviction (legacy unbounded behavior);
    ``on_evict(key, value)`` lets the owner drop secondary indexes that
    reference the evicted entry.
    """

    def __init__(self, capacity: Optional[int] = None,
                 on_evict: Optional[Callable[[Any, Any], None]] = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"LRU capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.evictions = 0
        self._on_evict = on_evict
        self._data: "OrderedDict[Any, Any]" = OrderedDict()

    def __getitem__(self, key: Any) -> Any:
        value = self._data[key]          # KeyError propagates
        self._data.move_to_end(key)
        return value

    def __setitem__(self, key: Any, value: Any) -> None:
        self._data[key] = value
        self._data.move_to_end(key)
        while self.capacity is not None and len(self._data) > self.capacity:
            old_key, old_value = self._data.popitem(last=False)
            self.evictions += 1
            if self._on_evict is not None:
                self._on_evict(old_key, old_value)

    def __delitem__(self, key: Any) -> None:
        del self._data[key]

    def __iter__(self) -> Iterator[Any]:
        return iter(self._data)

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: Any) -> bool:
        return key in self._data

    def __repr__(self) -> str:
        cap = "inf" if self.capacity is None else self.capacity
        return (f"LRUCache({len(self._data)}/{cap}, "
                f"evictions={self.evictions})")


class DiskCacheStats:
    """Hit/miss/write counters for the on-disk tier (thread-safe)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.module_hits = 0
        self.module_misses = 0
        self.diagnosis_hits = 0
        self.diagnosis_misses = 0
        self.writes = 0
        self.sweeps = 0
        self.evictions = 0          # artifacts removed by cap or TTL
        self.bytes_evicted = 0

    def bump(self, field: str, by: int = 1) -> None:
        with self._lock:
            setattr(self, field, getattr(self, field) + by)

    def as_dict(self) -> Dict[str, int]:
        return {
            "module_hits": self.module_hits,
            "module_misses": self.module_misses,
            "diagnosis_hits": self.diagnosis_hits,
            "diagnosis_misses": self.diagnosis_misses,
            "writes": self.writes,
            "sweeps": self.sweeps,
            "evictions": self.evictions,
            "bytes_evicted": self.bytes_evicted,
        }


class DiskCache:
    """Content-addressed artifact store: ``<root>/<kind>/<k[:2]>/<k>.gz``.

    Keys are sha256 hex digests computed by the caller (the session's
    ``module_key`` / the service's diagnosis key), so identical content
    always lands on the same path regardless of which process wrote it.
    Corrupt or format-incompatible artifacts read as misses.

    ``max_bytes`` / ``ttl_seconds`` bound the tier: a sweep (every
    ``sweep_interval`` writes, or on explicit :meth:`sweep`) first drops
    artifacts idle longer than the TTL, then removes oldest-accessed
    artifacts until the total size fits the cap.  Hits refresh the
    artifact mtime (best-effort), so eviction order approximates LRU even
    across processes.
    """

    def __init__(self, root: str, max_bytes: Optional[int] = None,
                 ttl_seconds: Optional[float] = None,
                 sweep_interval: int = 64):
        self.root = os.path.abspath(root)
        self.max_bytes = max_bytes
        self.ttl_seconds = ttl_seconds
        self.sweep_interval = max(1, sweep_interval)
        self.stats = DiskCacheStats()
        # _counter_lock guards only the cheap write counter; _sweep_lock
        # serializes sweeps.  Writers never block behind a running sweep —
        # they bump the counter and move on (a due sweep that finds the
        # lock taken is simply skipped; the next due write retries).
        self._counter_lock = threading.Lock()
        self._sweep_lock = threading.Lock()
        self._writes_since_sweep = 0
        # Cross-process sweep coordination: advisory flock on a lockfile
        # at the cache root (see module docstring).
        self._sweep_lock_path = os.path.join(self.root, ".sweep.lock")

    def _path(self, kind: str, key: str, ext: str) -> str:
        return os.path.join(self.root, kind, key[:2], f"{key}{ext}")

    @staticmethod
    def _touch(path: str) -> None:
        try:
            os.utime(path, None)
        except OSError:
            pass

    def _sweep_file_lock(self, blocking: bool) -> Optional[int]:
        """Acquire the cross-process sweep lock.  Returns an fd to pass
        to :meth:`_sweep_file_unlock`, ``-1`` when flock is unavailable
        (non-POSIX: proceed, in-process lock already held), or ``None``
        when non-blocking and another process holds it."""
        if fcntl is None:               # pragma: no cover - non-POSIX
            return -1
        try:
            os.makedirs(self.root, exist_ok=True)
            fd = os.open(self._sweep_lock_path,
                         os.O_CREAT | os.O_RDWR, 0o644)
        except OSError:
            return -1   # can't create the lockfile: sweep uncoordinated
        flags = fcntl.LOCK_EX if blocking else fcntl.LOCK_EX | fcntl.LOCK_NB
        try:
            fcntl.flock(fd, flags)
        except OSError:
            os.close(fd)
            return None
        return fd

    @staticmethod
    def _sweep_file_unlock(fd: Optional[int]) -> None:
        if fd is None or fd < 0:
            return
        try:
            os.close(fd)    # closing the fd releases the flock
        except OSError:     # pragma: no cover - close on valid fd
            pass

    def _write_atomic(self, path: str, payload: bytes) -> None:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        try:
            fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                       suffix=".tmp")
        except FileNotFoundError:
            # A concurrent clear()/eviction removed the freshly created
            # directory; recreate and retry once.
            os.makedirs(os.path.dirname(path), exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                       suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(payload)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self.stats.bump("writes")
        if self.max_bytes is None and self.ttl_seconds is None:
            return
        with self._counter_lock:
            self._writes_since_sweep += 1
            due = self._writes_since_sweep >= self.sweep_interval
            if due:
                self._writes_since_sweep = 0
        if due:
            self.sweep(blocking=False)

    # -- parsed modules (gzipped pickle) ---------------------------------------

    def load_module(self, key: str):
        path = self._path("modules", key, ".pkl.gz")
        try:
            with gzip.open(path, "rb") as f:
                payload = pickle.load(f)
            if payload.get("format") != MODULE_ARTIFACT_FORMAT:
                raise ValueError("stale module artifact format")
            module = payload["module"]
        except (OSError, ValueError, KeyError, EOFError,
                pickle.UnpicklingError, AttributeError):
            self.stats.bump("module_misses")
            return None
        self.stats.bump("module_hits")
        self._touch(path)   # refresh LRU position for the sweeper
        return module

    def store_module(self, key: str, module: Any) -> None:
        payload = pickle.dumps(
            {"format": MODULE_ARTIFACT_FORMAT, "module": module},
            protocol=pickle.HIGHEST_PROTOCOL)
        self._write_atomic(self._path("modules", key, ".pkl.gz"),
                           gzip.compress(payload))

    # -- diagnoses (gzipped JSON) ----------------------------------------------

    def load_diagnosis(self, key: str):
        from .report import Diagnosis
        path = self._path("diagnoses", key, ".json.gz")
        try:
            with gzip.open(path, "rt", encoding="utf-8") as f:
                data = json.load(f)
            # from_dict migrates any supported older schema generation
            # forward (e.g. v1 payloads gain an explicit "not recorded"
            # sync_resources default) and rejects unknown generations.
            diag = Diagnosis.from_dict(data)
        except (OSError, ValueError, KeyError, TypeError):
            self.stats.bump("diagnosis_misses")
            return None
        self.stats.bump("diagnosis_hits")
        self._touch(path)
        return diag

    def store_diagnosis(self, key: str, diagnosis: Any) -> None:
        self._write_atomic(
            self._path("diagnoses", key, ".json.gz"),
            gzip.compress(diagnosis.to_json().encode("utf-8")))

    # -- maintenance -----------------------------------------------------------

    def _artifacts(self) -> List[Tuple[float, int, str]]:
        """(mtime, size, path) for every stored artifact."""
        out: List[Tuple[float, int, str]] = []
        for kind in ("modules", "diagnoses"):
            base = os.path.join(self.root, kind)
            for dirpath, _, files in os.walk(base):
                for name in files:
                    if not name.endswith(".gz"):
                        continue
                    path = os.path.join(dirpath, name)
                    try:
                        st = os.stat(path)
                    except FileNotFoundError:
                        # A concurrently-exiting process (its final
                        # flush-sweep, or a clear()) unlinked the path
                        # between listing and stat: skip and continue.
                        continue
                    except OSError:
                        continue
                    out.append((st.st_mtime, st.st_size, path))
        return out

    def _evict(self, path: str, size: int) -> bool:
        try:
            os.unlink(path)
        except OSError:
            return False
        self.stats.bump("evictions")
        self.stats.bump("bytes_evicted", size)
        return True

    def total_bytes(self) -> int:
        return sum(size for _, size, _ in self._artifacts())

    def sweep(self, now: Optional[float] = None,
              blocking: bool = True) -> Dict[str, int]:
        """TTL-expire idle artifacts, then enforce the size cap
        oldest-accessed first.  Safe to call concurrently / cross-process:
        a racing unlink simply counts as someone else's eviction.  With
        ``blocking=False`` (the opportunistic write-path mode), a sweep
        already in progress — in this process (threading lock) or in any
        other process sharing the root (``.sweep.lock`` flock) — is
        skipped instead of waited on, so only one worker compacts."""
        if self.max_bytes is None and self.ttl_seconds is None:
            return {"evicted": 0, "bytes_freed": 0}
        if not self._sweep_lock.acquire(blocking=blocking):
            return {"evicted": 0, "bytes_freed": 0, "skipped": 1}
        lock_fd = self._sweep_file_lock(blocking)
        if lock_fd is None:
            self._sweep_lock.release()
            return {"evicted": 0, "bytes_freed": 0, "skipped": 1}
        now = time.time() if now is None else now
        evicted = freed = 0
        try:
            self.stats.bump("sweeps")
            artifacts = sorted(self._artifacts())   # oldest mtime first
            if self.ttl_seconds is not None:
                cutoff = now - self.ttl_seconds
                keep: List[Tuple[float, int, str]] = []
                for mtime, size, path in artifacts:
                    if mtime < cutoff and self._evict(path, size):
                        evicted += 1
                        freed += size
                    else:
                        keep.append((mtime, size, path))
                artifacts = keep
            if self.max_bytes is not None:
                total = sum(size for _, size, _ in artifacts)
                for mtime, size, path in artifacts:
                    if total <= self.max_bytes:
                        break
                    if self._evict(path, size):
                        evicted += 1
                        freed += size
                        total -= size
        finally:
            self._sweep_file_unlock(lock_fd)
            self._sweep_lock.release()
        return {"evicted": evicted, "bytes_freed": freed}

    def flush(self) -> Dict[str, int]:
        """Final blocking sweep — the graceful-drain hook.  Waits for any
        in-progress opportunistic sweep, then enforces TTL + size bounds
        so a terminating server leaves the on-disk tier within budget."""
        return self.sweep(blocking=True)

    def clear(self) -> None:
        import shutil
        for kind in ("modules", "diagnoses"):
            shutil.rmtree(os.path.join(self.root, kind), ignore_errors=True)

    def __repr__(self) -> str:
        return f"DiskCache({self.root!r}, {self.stats.as_dict()})"
