"""Four-stage pruning pipeline (§III-C).

The initial dependency graph is conservative; four sequential stages remove
false dependencies.  Synchronization-tracing edges (``mem_barrier`` /
``mem_waitcnt`` / ``mem_swsb``) are exempt from Stage 1 and Stage 3 — they
are compiler-verified dependencies (§III-E).

Stage 1  Opcode constraints: an edge is compatible only if the producer's
         opcode class can cause one of the stall classes actually observed
         at the consumer (e.g. consumer shows only memory stalls -> edges
         from compute producers are removed).
Stage 2  Barrier constraints: a producer that *sets* a barrier the consumer
         does not *wait* on cannot be the consumer's blocking dependency
         through that barrier (NVIDIA B1-B6 in the paper; async start/done
         pairs here).
Stage 3  Latency constraints: if enough issue cycles separate producer from
         consumer on *all* CFG paths, the producer's latency is pipeline-
         hidden and the edge is pruned.  Valid (non-hidden) paths are kept
         on the edge for blame's distance factor.
Stage 4  Execution constraints: edges from instructions with zero execution
         count are (optionally) pruned.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from .cfg import PathInfo
from .depgraph import DependencyGraph, Edge
from .hwmodel import HardwareModel
from .isa import (
    Instruction,
    OpClass,
    StallClass,
    STALL_COMPATIBLE_PRODUCERS,
)
from .sampler import StallProfile

# Stall fraction below which an observed stall class is ignored for
# compatibility purposes (noise floor).
_STALL_NOISE_FLOOR = 0.02


@dataclass
class PruneStats:
    initial_edges: int = 0
    pruned_by_stage: Dict[str, int] = field(default_factory=dict)
    surviving_edges: int = 0

    def record(self, stage: str) -> None:
        self.pruned_by_stage[stage] = self.pruned_by_stage.get(stage, 0) + 1


class Pruner:
    def __init__(self, graph: DependencyGraph, profile: StallProfile,
                 hw: HardwareModel,
                 prune_unexecuted: bool = True):
        self.graph = graph
        self.profile = profile
        self.hw = hw
        self.prune_unexecuted = prune_unexecuted

    def run(self) -> PruneStats:
        stats = PruneStats(initial_edges=len(self.graph.edges))
        for edge in self.graph.edges:
            if not edge.alive:
                continue
            if self._stage1_opcode(edge):
                edge.pruned_by = "opcode"
                stats.record("opcode")
                continue
            if self._stage2_barrier(edge):
                edge.pruned_by = "barrier"
                stats.record("barrier")
                continue
            if self._stage3_latency(edge):
                edge.pruned_by = "latency"
                stats.record("latency")
                continue
            if self._stage4_execution(edge):
                edge.pruned_by = "execution"
                stats.record("execution")
                continue
        stats.surviving_edges = sum(1 for e in self.graph.edges if e.alive)
        return stats

    # -- stage 1 ----------------------------------------------------------------

    def _stage1_opcode(self, edge: Edge) -> bool:
        if edge.kind.is_sync:
            return False
        consumer = self.graph.instruction(edge.consumer)
        producer = self.graph.instruction(edge.producer)
        if consumer is None or producer is None:
            return False
        rec = self.profile.records.get(edge.consumer)
        if rec is None or rec.latency_samples <= 0:
            return False  # nothing observed: stay conservative
        observed = [cls for cls, cyc in rec.stall_breakdown.items()
                    if cyc / rec.latency_samples > _STALL_NOISE_FLOOR]
        if not observed:
            return False
        for cls in observed:
            compatible = STALL_COMPATIBLE_PRODUCERS.get(cls)
            if compatible is None or producer.op_class in compatible:
                return False  # at least one observed class is compatible
        return True

    # -- stage 2 ----------------------------------------------------------------

    def _stage2_barrier(self, edge: Edge) -> bool:
        if edge.kind.is_sync:
            return False
        producer = self.graph.instruction(edge.producer)
        consumer = self.graph.instruction(edge.consumer)
        if producer is None or consumer is None:
            return False
        sets = set(producer.sync.sets)
        if not sets or producer.op_class is not OpClass.SYNC_SET:
            return False
        # A register edge from an async start is only real if the consumer
        # waits on that barrier (otherwise the value is not yet legal).
        return not sets & set(consumer.sync.waits)

    # -- stage 3 ----------------------------------------------------------------

    def _stage3_latency(self, edge: Edge) -> bool:
        if edge.kind.is_sync:
            return False
        producer = self.graph.instruction(edge.producer)
        if producer is None or not edge.paths:
            return False
        latency = self.hw.latency_cycles(producer)
        valid = [p for p in edge.paths if p.issue_cycles < latency]
        if valid:
            edge.paths = valid  # keep non-hidden paths for distance factor
            return False
        return True

    # -- stage 4 ----------------------------------------------------------------

    def _stage4_execution(self, edge: Edge) -> bool:
        if not self.prune_unexecuted:
            return False
        rec = self.profile.records.get(edge.producer)
        return rec is not None and rec.exec_count == 0


def prune(graph: DependencyGraph, profile: StallProfile,
          hw: HardwareModel, prune_unexecuted: bool = True) -> PruneStats:
    return Pruner(graph, profile, hw, prune_unexecuted).run()
