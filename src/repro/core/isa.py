"""Unified instruction model for LEO's cross-backend analysis.

LEO (the paper) parses three vendor ISAs (NVIDIA SASS, AMD GCN, Intel Xe) into
one instruction representation before slicing.  Our TPU/XLA adaptation keeps
the same shape: two front-ends — optimized HLO text (`hlo_parser.py`) and
jaxprs including Pallas kernel bodies (`jaxpr_frontend.py`) — lower into the
`Instruction`/`Computation`/`Module` model defined here.  Everything
downstream (CCT, dependency graph, pruning, blame) is front-end agnostic,
which is precisely the paper's "unified analysis layer" claim (§III).
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


class OpClass(enum.Enum):
    """Coarse opcode classification (paper §III-C stage 1 operates on these)."""

    MATMUL = "matmul"              # MXU work: dot, convolution, grouped matmul
    COMPUTE = "compute"            # VPU elementwise / transcendental work
    MEMORY_LOAD = "memory_load"    # HBM reads: gather, dynamic-slice, parameter fetch
    MEMORY_STORE = "memory_store"  # HBM writes: scatter, dynamic-update-slice
    DATA_MOVEMENT = "data_movement"  # copy/transpose/reshape/bitcast/broadcast
    COLLECTIVE = "collective"      # synchronous collectives
    SYNC_SET = "sync_set"          # async *-start ops, dma_start (sets a "barrier")
    SYNC_WAIT = "sync_wait"        # async *-done ops, dma_wait (waits on a "barrier")
    CONTROL = "control"            # while / conditional / call
    FUSION = "fusion"              # XLA fusion node (costed by inner ops)
    PARAMETER = "parameter"
    CONSTANT = "constant"
    TUPLE = "tuple"                # tuple / get-tuple-element glue
    REDUCE = "reduce"              # reductions (VPU, often latency-critical)
    OTHER = "other"


class StallClass(enum.Enum):
    """Unified stall taxonomy (paper §II-D: vendor taxonomies map into this)."""

    NONE = "none"
    MEM_DEP = "mem_dep"                  # waiting on an HBM access
    EXEC_DEP = "exec_dep"                # waiting on a compute producer
    SYNC_WAIT = "sync_wait"              # waiting at an explicit sync (async-done)
    SYNC_RESOURCE = "sync_resource"      # finite sync resource exhausted
                                         # (barrier slot / waitcnt counter /
                                         # SWSB token oversubscription §III-E)
    COLLECTIVE_WAIT = "collective_wait"  # waiting on inter-chip communication
    FETCH = "fetch"                      # instruction fetch / program order
    PIPE_BUSY = "pipe_busy"              # execution resource busy (throughput bound)
    NOT_SELECTED = "not_selected"        # ready but scheduler picked other work
    OCCUPANCY_LIMITED = "occupancy_limited"  # latency only partially hidden:
                                         # too few co-resident waves to cover
                                         # the remainder (failed latency hiding)
    SELF = "self"                        # self-blame bucket (no surviving edge)


class SyncKind(enum.Enum):
    """Vendor-specific synchronization mechanisms (paper §III-E), TPU analogues.

    BARRIER  — HLO async start/done pairs      (NVIDIA B1-B6 analogue)
    WAITCNT  — Pallas DMA semaphore counters   (AMD s_waitcnt analogue)
    TOKEN    — XLA token-threaded dependencies (Intel SWSB analogue)
    """

    BARRIER = "barrier"
    WAITCNT = "waitcnt"
    TOKEN = "token"


# Dependency edge types.  The three `mem_*` types are sync-tracing edges that
# bypass opcode and latency pruning (paper §III-E "unified framework").
class EdgeKind(enum.Enum):
    REG_RAW = "reg_raw"            # SSA/register read-after-write
    PREDICATE = "predicate"        # guard predicate dependency
    LOOP_CARRIED = "loop_carried"  # while-loop back-edge (reaching def across iterations)
    MEM_BARRIER = "mem_barrier"    # via HLO async start/done pair
    MEM_WAITCNT = "mem_waitcnt"    # via Pallas DMA semaphore counter
    MEM_SWSB = "mem_swsb"          # via token threading

    @property
    def is_sync(self) -> bool:
        return self in (EdgeKind.MEM_BARRIER, EdgeKind.MEM_WAITCNT, EdgeKind.MEM_SWSB)


_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "f4e2m1fn": 1,
    "token": 0, "opaque": 0,
}


@dataclass(frozen=True)
class ShapeInfo:
    """Parsed HLO shape: scalar/array or tuple (then `elements` is set)."""

    dtype: str = "f32"
    dims: Tuple[int, ...] = ()
    elements: Optional[Tuple["ShapeInfo", ...]] = None  # tuple shapes

    @property
    def is_tuple(self) -> bool:
        return self.elements is not None

    @property
    def num_elements(self) -> int:
        if self.is_tuple:
            return sum(e.num_elements for e in self.elements)
        return int(math.prod(self.dims)) if self.dims else 1

    @property
    def byte_size(self) -> int:
        if self.is_tuple:
            return sum(e.byte_size for e in self.elements)
        return self.num_elements * _DTYPE_BYTES.get(self.dtype, 4)

    def __str__(self) -> str:  # pragma: no cover - debug aid
        if self.is_tuple:
            return "(" + ", ".join(str(e) for e in self.elements) + ")"
        return f"{self.dtype}[{','.join(map(str, self.dims))}]"


@dataclass
class SyncInfo:
    """Synchronization semantics attached to an instruction (§III-E).

    `sets` / `waits` hold abstract barrier/token/counter identifiers.  For
    HLO async pairs the identifier is the start op's name; for Pallas DMA
    semaphores it is the semaphore value name; for tokens the token value
    name.  `counter` carries the s_waitcnt-style outstanding-count semantics
    (wait until in-flight <= counter) when known.
    """

    kind: Optional[SyncKind] = None
    sets: Tuple[str, ...] = ()
    waits: Tuple[str, ...] = ()
    counter: Optional[int] = None


@dataclass
class Instruction:
    """One machine-level operation in the unified model."""

    name: str                       # SSA id ("%foo.1" -> "foo.1")
    opcode: str                     # raw opcode string
    op_class: OpClass
    shape: ShapeInfo
    operands: Tuple[str, ...]       # operand instruction names (same computation)
    computation: str                # owning computation name
    index: int                      # program order within computation
    attributes: Dict[str, str] = field(default_factory=dict)
    # Source attribution (paper: DWARF; here: HLO metadata / jaxpr source_info)
    op_name: str = ""               # scoped name, e.g. "jit(step)/transformer/layer/attn/dot"
    source_file: str = ""
    source_line: int = 0
    # Cost-model annotations (filled by the parser; consumed by the sampler)
    flops: float = 0.0
    bytes_read: float = 0.0
    bytes_written: float = 0.0
    raw_bytes_read: float = 0.0   # pre-zeroing cost (fusion-inner ops keep
                                  # their granule-penalized reads here)
    # Collective annotations
    comm_bytes: float = 0.0         # bytes moved over ICI (per participating chip)
    replica_groups: str = ""
    # Control-flow annotations
    called_computations: Tuple[str, ...] = ()
    trip_count: int = 1             # for while ops (estimated / hinted)
    # Predicate operands (subset of `operands` that act as guards)
    predicate_operands: Tuple[str, ...] = ()
    sync: SyncInfo = field(default_factory=SyncInfo)
    is_root: bool = False

    @property
    def qualified_name(self) -> str:
        return f"{self.computation}::{self.name}"

    @property
    def is_memory(self) -> bool:
        return self.op_class in (OpClass.MEMORY_LOAD, OpClass.MEMORY_STORE)

    @property
    def is_communication(self) -> bool:
        return self.op_class in (OpClass.COLLECTIVE, OpClass.SYNC_SET, OpClass.SYNC_WAIT) \
            and self.comm_bytes > 0

    def scope_path(self) -> Tuple[str, ...]:
        """CCT path components from the scoped op_name metadata."""
        if not self.op_name:
            return ()
        return tuple(p for p in self.op_name.split("/") if p)


@dataclass
class Computation:
    """A computation (HLO computation / jaxpr): ordered instruction list."""

    name: str
    instructions: List[Instruction] = field(default_factory=list)
    kind: str = "plain"  # entry | fusion | loop_body | loop_cond | branch | reduce | plain
    parent_op: str = ""  # qualified name of the op that calls this computation

    _by_name: Dict[str, Instruction] = field(default_factory=dict, repr=False)

    def add(self, instr: Instruction) -> None:
        instr.index = len(self.instructions)
        self.instructions.append(instr)
        self._by_name[instr.name] = instr

    def get(self, name: str) -> Optional[Instruction]:
        return self._by_name.get(name)

    @property
    def root(self) -> Optional[Instruction]:
        for instr in reversed(self.instructions):
            if instr.is_root:
                return instr
        return self.instructions[-1] if self.instructions else None

    @property
    def parameters(self) -> List[Instruction]:
        return [i for i in self.instructions if i.op_class is OpClass.PARAMETER]


@dataclass
class Module:
    """A parsed module: the unit LEO analyzes (one compiled program)."""

    name: str
    computations: Dict[str, Computation] = field(default_factory=dict)
    entry: str = ""
    source: str = "hlo"  # hlo | jaxpr

    def add_computation(self, comp: Computation) -> None:
        self.computations[comp.name] = comp

    @property
    def entry_computation(self) -> Computation:
        return self.computations[self.entry]

    def all_instructions(self) -> Iterable[Instruction]:
        for comp in self.computations.values():
            yield from comp.instructions

    def find(self, qualified: str) -> Optional[Instruction]:
        comp_name, _, instr_name = qualified.partition("::")
        comp = self.computations.get(comp_name)
        return comp.get(instr_name) if comp else None

    def total_flops(self, trip_aware: bool = True) -> float:
        """Sum of per-op flops, expanding while-loop trip counts."""
        return self._comp_flops(self.entry, 1.0, trip_aware, set())

    def _comp_flops(self, comp_name: str, mult: float, trip_aware: bool,
                    stack: set) -> float:
        if comp_name in stack or comp_name not in self.computations:
            return 0.0
        stack = stack | {comp_name}
        total = 0.0
        for instr in self.computations[comp_name].instructions:
            total += mult * instr.flops
            inner_mult = mult * (instr.trip_count if trip_aware else 1)
            for callee in instr.called_computations:
                total += self._comp_flops(callee, inner_mult, trip_aware, stack)
        return total


# --- opcode classification tables -----------------------------------------

_COLLECTIVE_OPCODES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
}
_ASYNC_START = {
    "all-reduce-start", "all-gather-start", "collective-permute-start",
    "copy-start", "send", "async-start", "reduce-scatter-start",
    "all-to-all-start",
}
_ASYNC_DONE = {
    "all-reduce-done", "all-gather-done", "collective-permute-done",
    "copy-done", "recv", "send-done", "recv-done", "async-done",
    "reduce-scatter-done", "all-to-all-done",
}
_MEMORY_LOAD_OPCODES = {"gather", "dynamic-slice", "slice", "iota"}
_MEMORY_STORE_OPCODES = {"scatter", "dynamic-update-slice"}
_DATA_MOVEMENT_OPCODES = {
    "copy", "transpose", "reshape", "bitcast", "bitcast-convert",
    "broadcast", "concatenate", "reverse", "pad", "convert",
}
_CONTROL_OPCODES = {"while", "conditional", "call", "custom-call"}
_TUPLE_OPCODES = {"tuple", "get-tuple-element", "optimization-barrier", "after-all"}
_REDUCE_OPCODES = {"reduce", "reduce-window", "sort", "select-and-scatter", "topk"}
_MATMUL_OPCODES = {"dot", "convolution", "ragged-dot"}


def classify_opcode(opcode: str) -> OpClass:
    if opcode in _MATMUL_OPCODES:
        return OpClass.MATMUL
    if opcode in _ASYNC_START:
        return OpClass.SYNC_SET
    if opcode in _ASYNC_DONE:
        return OpClass.SYNC_WAIT
    if opcode in _COLLECTIVE_OPCODES:
        return OpClass.COLLECTIVE
    if opcode in _MEMORY_LOAD_OPCODES:
        return OpClass.MEMORY_LOAD
    if opcode in _MEMORY_STORE_OPCODES:
        return OpClass.MEMORY_STORE
    if opcode in _DATA_MOVEMENT_OPCODES:
        return OpClass.DATA_MOVEMENT
    if opcode in _CONTROL_OPCODES:
        return OpClass.CONTROL
    if opcode in _TUPLE_OPCODES:
        return OpClass.TUPLE
    if opcode in _REDUCE_OPCODES:
        return OpClass.REDUCE
    if opcode == "fusion":
        return OpClass.FUSION
    if opcode == "parameter":
        return OpClass.PARAMETER
    if opcode == "constant":
        return OpClass.CONSTANT
    return OpClass.COMPUTE


# Stall-class compatibility used by Stage-1 opcode pruning (§III-C.1): which
# producer OpClasses can plausibly cause which observed stall class.
STALL_COMPATIBLE_PRODUCERS: Dict[StallClass, Tuple[OpClass, ...]] = {
    StallClass.MEM_DEP: (
        OpClass.MEMORY_LOAD, OpClass.MEMORY_STORE, OpClass.DATA_MOVEMENT,
        OpClass.PARAMETER, OpClass.FUSION, OpClass.SYNC_SET, OpClass.SYNC_WAIT,
    ),
    StallClass.EXEC_DEP: (
        OpClass.MATMUL, OpClass.COMPUTE, OpClass.REDUCE, OpClass.FUSION,
        OpClass.CONTROL,
    ),
    StallClass.COLLECTIVE_WAIT: (
        OpClass.COLLECTIVE, OpClass.SYNC_SET, OpClass.SYNC_WAIT,
    ),
    StallClass.SYNC_WAIT: (
        OpClass.SYNC_SET, OpClass.SYNC_WAIT, OpClass.COLLECTIVE,
        OpClass.MEMORY_LOAD, OpClass.MEMORY_STORE,
    ),
    StallClass.SYNC_RESOURCE: (
        OpClass.SYNC_SET, OpClass.SYNC_WAIT, OpClass.COLLECTIVE,
        OpClass.MEMORY_LOAD, OpClass.MEMORY_STORE, OpClass.DATA_MOVEMENT,
    ),
    # Scheduler-contention classes are caused by the issue arbiter, not by
    # any data producer: no producer OpClass can explain them, so an edge
    # whose consumer shows ONLY these classes is Stage-1 prunable (the
    # stall self-blames into the scheduler-contention evidence channel).
    StallClass.NOT_SELECTED: (),
    StallClass.PIPE_BUSY: (),
    # Occupancy-limited stall is a property of the wave residency the
    # kernel achieved, not of any producer: the latency-hiding budget ran
    # out, so the exposed remainder self-blames into the occupancy
    # evidence channel.
    StallClass.OCCUPANCY_LIMITED: (),
}
