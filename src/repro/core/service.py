"""`LeoService`: the serving-grade analysis API.

Where :class:`~repro.core.session.LeoSession` is an in-process cache,
``LeoService`` is the production surface a profiler-adjacent analyzer
needs to serve heavy traffic:

  * **typed requests** — :class:`AnalyzeRequest` is a versioned,
    JSON-round-trippable request schema (what a queue or RPC layer
    carries), and every answer is a serializable
    :class:`~repro.core.report.Diagnosis`;
  * **bounded caches** — the session tiers run with LRU capacities by
    default, plus a diagnosis LRU in front of the pipeline;
  * **on-disk persistence** — pass ``cache_dir=`` and parsed modules +
    diagnoses are content-addressed onto disk (sha256 -> gzip), so a
    second process re-running the same trace performs zero HLO parses;
  * **concurrent fan-out** — ``analyze_batch`` / ``compare_backends`` /
    ``diagnose_batch`` run over a shared thread pool; the session's
    single-flight caches keep the parse-once invariant under concurrency
    (stats-asserted in ``tests/test_service.py``).

::

    svc = LeoService(cache_dir="experiments/.leo_cache")
    diag = svc.diagnose(hlo_text, backend="tpu_v5e")     # Diagnosis
    per_vendor = svc.compare_backends(hlo_text)          # concurrent
    svc.submit(AnalyzeRequest(hlo_text=hlo, backend="amd_mi300a"))
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
import time
import warnings
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

from .backends import BackendLike, resolve_backend
from .caching import DiskCache, LRUCache
from .isa import Module
from .passes import LeoAnalysis, Pipeline
from .report import SCHEMA_VERSION, Diagnosis
from .session import LeoSession, ModuleLike, SessionStats

#: Bump when analysis *semantics* change without a schema change (pass
#: internals, blame weighting, recommendation rules): part of the disk
#: diagnosis key, so old cache_dir artifacts read as misses, never as
#: stale answers.  Backend constant changes are fingerprinted
#: automatically (see `LeoService._diagnosis_key`).
#: v2: the sampler now drives a SyncModel scoreboard (finite §III-E sync
#: resources serialize), changing stall profiles for oversubscribed
#: programs.
#: v3: multi-stream issue model — the sampler interleaves instructions
#: across the backend's issue queues (per-queue sync scoreboards,
#: NOT_SELECTED/PIPE_BUSY contention), changing stall profiles and
#: makespans for every multi-queue backend.
#: v4: the optional advisor (what-if replay) rides the diagnosis; the
#: `advise` knob joins the key list so advice-carrying artifacts never
#: answer advice-free requests (or vice versa).
#: v5: the optional rewrite loop (equivalence-checked HLO rewrites with
#: realized speedups) rides the diagnosis; the `rewrite` knob joins the
#: key list under the same never-alias rule as `advise`.  The `occupancy`
#: knob (schema v6) deliberately did NOT bump this: it appends to the key
#: only when engaged (see `DiagnoseOptions.key_suffix`), so every
#: pre-existing knob combination keeps its byte-identical key and a warm
#: cache_dir survives the upgrade.
DIAGNOSIS_KEY_VERSION = 5


#: (caller, kwarg-names) pairs already warned about — legacy boolean
#: kwargs warn once per call site shape, not once per request.
_LEGACY_KWARG_WARNED: set = set()


def _warn_legacy_kwargs(caller: str, given: Dict[str, Any]) -> None:
    key = (caller, tuple(sorted(given)))
    if key in _LEGACY_KWARG_WARNED:
        return
    _LEGACY_KWARG_WARNED.add(key)
    args = ", ".join(f"{k}={v!r}" for k, v in sorted(given.items()))
    warnings.warn(
        f"{caller}: keyword(s) {', '.join(sorted(given))} are deprecated; "
        f"pass options=DiagnoseOptions({args}) instead "
        f"(the keywords are removed two minor releases after v6)",
        DeprecationWarning, stacklevel=4)


@dataclass(frozen=True)
class DiagnoseOptions:
    """The typed request surface: every analysis knob in one frozen,
    hashable value — the single source of truth for both the diagnosis
    cache key (:meth:`key_fields` / :meth:`key_suffix`) and the wire
    fields (:meth:`wire_fields`), so the service, the HTTP client, and
    the queue protocol can never drift apart one boolean at a time.

    ``occupancy=True`` engages the backend's native wave-residency model
    (:meth:`Backend.with_occupancy`): stalls that co-resident waves
    would cover are hidden, the remainder reclassifies as
    ``OCCUPANCY_LIMITED``, and the Diagnosis gains its schema-v6
    ``occupancy`` section.  Single-wave parts (TPUs) analyze
    identically with the knob on — there is no residency to raise."""

    n_chains: int = 5
    prune_unexecuted: bool = True
    advise: bool = False
    rewrite: bool = False
    occupancy: bool = False

    def validate(self) -> None:
        if self.n_chains < 1:
            raise ValueError("n_chains must be >= 1")

    def key_fields(self) -> List[Any]:
        """The cache-key components every generation has carried, in
        their historical order — byte-identity with pre-v6 keys."""
        return [self.n_chains, self.prune_unexecuted, self.advise,
                self.rewrite]

    def key_suffix(self) -> List[Any]:
        """Appended after the version/pipeline tail, and ONLY when
        non-default: a default-occupancy request hashes exactly like a
        pre-v6 one, so warm disk caches keep answering."""
        return ["occupancy"] if self.occupancy else []

    def wire_fields(self) -> Dict[str, Any]:
        """The flat request-dict fields (an ``occupancy``-unaware peer's
        ``from_dict`` ignores the new key)."""
        return {
            "n_chains": self.n_chains,
            "prune_unexecuted": self.prune_unexecuted,
            "advise": self.advise,
            "rewrite": self.rewrite,
            "occupancy": self.occupancy,
        }

    @classmethod
    def from_wire(cls, data: Dict[str, Any]) -> "DiagnoseOptions":
        return cls(
            n_chains=data.get("n_chains", 5),
            prune_unexecuted=data.get("prune_unexecuted", True),
            advise=data.get("advise", False),
            rewrite=data.get("rewrite", False),
            occupancy=data.get("occupancy", False),
        )

    @classmethod
    def coalesce(cls, options: Optional["DiagnoseOptions"], caller: str,
                 **legacy: Any) -> "DiagnoseOptions":
        """Resolve an ``options=`` argument against the deprecated
        boolean kwargs: explicit options win (mixing raises), legacy
        kwargs warn once per call-site shape and build an equivalent
        options value, neither yields the defaults."""
        given = {k: v for k, v in legacy.items() if v is not None}
        if options is not None:
            if given:
                raise TypeError(
                    f"{caller}: pass options=DiagnoseOptions(...) or the "
                    f"deprecated keyword(s) {sorted(given)}, not both")
            return options
        if not given:
            return cls()
        _warn_legacy_kwargs(caller, given)
        return cls(**given)


@dataclass(init=False)
class AnalyzeRequest:
    """One unit of service work: a program plus analysis knobs.

    ``backend=None`` targets the service default; set ``backends`` to fan
    the same program across several vendor models in one request (the
    Observation-1 shape).  The analysis knobs live in one typed
    :class:`DiagnoseOptions` value (``options=``); the old flat boolean
    kwargs still construct (warn-once shims) and the wire layout keeps
    the flat fields, so queued requests and older peers interoperate.
    The schema is versioned and JSON-round-trips, so requests can ride a
    queue between processes.
    """

    hlo_text: str = ""
    backend: Optional[str] = None
    backends: Optional[List[str]] = None
    hints: Optional[Dict[str, Any]] = None
    options: DiagnoseOptions = field(default_factory=DiagnoseOptions)
    request_id: Optional[str] = None
    schema_version: int = SCHEMA_VERSION

    def __init__(self, hlo_text: str = "",
                 backend: Optional[str] = None,
                 backends: Optional[List[str]] = None,
                 hints: Optional[Dict[str, Any]] = None,
                 options: Optional[DiagnoseOptions] = None,
                 request_id: Optional[str] = None,
                 schema_version: int = SCHEMA_VERSION, *,
                 n_chains: Optional[int] = None,
                 prune_unexecuted: Optional[bool] = None,
                 advise: Optional[bool] = None,
                 rewrite: Optional[bool] = None,
                 occupancy: Optional[bool] = None):
        self.hlo_text = hlo_text
        self.backend = backend
        self.backends = backends
        self.hints = hints
        self.options = DiagnoseOptions.coalesce(
            options, "AnalyzeRequest", n_chains=n_chains,
            prune_unexecuted=prune_unexecuted, advise=advise,
            rewrite=rewrite, occupancy=occupancy)
        self.request_id = request_id
        self.schema_version = schema_version

    # legacy read accessors: the knobs' single home is .options
    @property
    def n_chains(self) -> int:
        return self.options.n_chains

    @property
    def prune_unexecuted(self) -> bool:
        return self.options.prune_unexecuted

    @property
    def advise(self) -> bool:
        return self.options.advise

    @property
    def rewrite(self) -> bool:
        return self.options.rewrite

    @property
    def occupancy(self) -> bool:
        return self.options.occupancy

    def validate(self) -> None:
        if not self.hlo_text:
            raise ValueError("AnalyzeRequest.hlo_text must be non-empty")
        if self.backend is not None and self.backends is not None:
            raise ValueError(
                "set AnalyzeRequest.backend or .backends, not both")
        if self.schema_version != SCHEMA_VERSION:
            raise ValueError(
                f"AnalyzeRequest schema_version {self.schema_version} != "
                f"{SCHEMA_VERSION}")
        self.options.validate()

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "schema_version": self.schema_version,
            "hlo_text": self.hlo_text,
            "backend": self.backend,
            "backends": self.backends,
            "hints": self.hints,
        }
        out.update(self.options.wire_fields())
        out["request_id"] = self.request_id
        return out

    @classmethod
    def from_dict(cls, data: Dict[str, Any]) -> "AnalyzeRequest":
        return cls(
            hlo_text=data.get("hlo_text", ""),
            backend=data.get("backend"),
            backends=data.get("backends"),
            hints=data.get("hints"),
            options=DiagnoseOptions.from_wire(data),
            request_id=data.get("request_id"),
            schema_version=data.get("schema_version", 0),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=False)

    @classmethod
    def from_json(cls, payload: str) -> "AnalyzeRequest":
        return cls.from_dict(json.loads(payload))


class LeoService:
    """Bounded-cache, disk-persistent, concurrent analysis service.

    The service owns a :class:`LeoSession` (exposed as ``.session`` for
    callers that need raw ``LeoAnalysis`` artifacts) and adds the typed
    request/diagnosis surface on top.  Default cache capacities are
    serving-grade bounds rather than the session's legacy ``None``
    (unbounded).
    """

    def __init__(self, pipeline: Optional[Pipeline] = None,
                 backends: Optional[Sequence[BackendLike]] = None,
                 hints: Optional[dict] = None,
                 default_backend: BackendLike = "tpu_v5e",
                 parse_cache_size: Optional[int] = 64,
                 graph_cache_size: Optional[int] = 256,
                 analysis_cache_size: Optional[int] = 512,
                 diagnosis_cache_size: Optional[int] = 512,
                 cache_dir: Optional[str] = None,
                 disk_cache_max_bytes: Optional[int] = None,
                 disk_cache_ttl_seconds: Optional[float] = None,
                 max_workers: int = 8,
                 metrics: Optional[Any] = None):
        # disk_cache_max_bytes / _ttl_seconds bound the on-disk tier (size
        # cap enforced oldest-accessed-first, idle TTL); None keeps the
        # legacy unbounded behavior.
        self.disk_cache = DiskCache(
            cache_dir, max_bytes=disk_cache_max_bytes,
            ttl_seconds=disk_cache_ttl_seconds) if cache_dir else None
        self.session = LeoSession(
            pipeline=pipeline, backends=backends, hints=hints,
            default_backend=default_backend,
            parse_cache_size=parse_cache_size,
            graph_cache_size=graph_cache_size,
            analysis_cache_size=analysis_cache_size,
            disk_cache=self.disk_cache)
        self.max_workers = max_workers
        self._diagnoses: LRUCache = LRUCache(diagnosis_cache_size)
        self._lock = threading.Lock()
        self._pool: Optional[ThreadPoolExecutor] = None
        self.diagnosis_hits = 0
        self.diagnosis_misses = 0
        # optional repro.serve.metrics.MetricsRegistry (typed Any: the
        # core layer must not import the serving layer).  None keeps the
        # hot path allocation- and branch-cheap.
        self.metrics = metrics
        self._m_diagnoses = self._m_cache = None
        self._m_parse = self._m_pipeline = self._m_advisor = None
        self._m_rewrite = None
        if metrics is not None:
            self._m_diagnoses = metrics.counter(
                "leo_diagnoses_total",
                "Diagnoses served (cache hits included), per backend.",
                labelnames=("backend",))
            self._m_cache = metrics.counter(
                "leo_cache_requests_total",
                "Diagnosis cache lookups per tier and outcome.",
                labelnames=("tier", "result"))
            self._m_parse = metrics.histogram(
                "leo_parse_seconds",
                "HLO parse latency (session cache hits land sub-ms).")
            self._m_pipeline = metrics.histogram(
                "leo_pipeline_seconds",
                "Full analysis pipeline latency on diagnosis misses.")
            self._m_advisor = metrics.histogram(
                "leo_advisor_seconds",
                "What-if advisor latency on advise=True diagnosis misses.")
            self._m_rewrite = metrics.histogram(
                "leo_rewrite_seconds",
                "Rewrite-loop latency on rewrite=True diagnosis misses.")
            g = metrics.gauge(
                "leo_session_cache_hits",
                "Session single-flight cache hit counters, per op.",
                labelnames=("op",))
            g.set_function(lambda: float(self.session.stats.parse_hits),
                           op="parse")
            g.set_function(lambda: float(self.session.stats.analyze_hits),
                           op="analyze")
            if self.disk_cache is not None:
                db = metrics.gauge(
                    "leo_disk_cache_bytes",
                    "Bytes currently held by the on-disk cache tier.")
                db.set_function(
                    lambda: float(self.disk_cache.total_bytes()))

    # -- plumbing --------------------------------------------------------------

    @property
    def stats(self) -> SessionStats:
        return self.session.stats

    def stats_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = dict(self.session.stats.as_dict())
        out["pid"] = os.getpid()    # which pool worker answered /stats
        out["cache_evictions"] = self.session.cache_evictions
        out["diagnosis_hits"] = self.diagnosis_hits
        out["diagnosis_misses"] = self.diagnosis_misses
        if self.disk_cache is not None:
            out["disk"] = self.disk_cache.stats.as_dict()
        return out

    def _executor(self) -> Optional[ThreadPoolExecutor]:
        """The shared pool — or None when already on a pool worker (a
        nested fan-out must run inline, otherwise bounded workers waiting
        on tasks that cannot be scheduled deadlock the pool)."""
        if threading.current_thread().name.startswith("leo-service"):
            return None
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.max_workers,
                    thread_name_prefix="leo-service")
            return self._pool

    def close(self) -> None:
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)

    def flush(self) -> Dict[str, int]:
        """Flush the on-disk tier (final blocking sweep) — called by the
        serving front-end on graceful drain.  No-op without a
        ``cache_dir``."""
        if self.disk_cache is not None:
            return self.disk_cache.flush()
        return {"evicted": 0, "bytes_freed": 0}

    def __enter__(self) -> "LeoService":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    def _fan_out(self, call, items: Sequence[Any]) -> List[Any]:
        """Run ``call(item)`` per item — on the pool when one is available
        (never nested inside a pool worker), serially otherwise.  Results
        come back in item order; the first failure propagates."""
        items = list(items)
        pool = self._executor() if len(items) > 1 else None
        if pool is None:
            return [call(it) for it in items]
        futs = [pool.submit(call, it) for it in items]
        return [f.result() for f in futs]

    # -- raw-analysis surface (LeoAnalysis out) --------------------------------

    def parse(self, hlo_text: str, hints: Optional[dict] = None) -> Module:
        if self._m_parse is None:
            return self.session.parse(hlo_text, hints=hints)
        t0 = time.monotonic()
        module = self.session.parse(hlo_text, hints=hints)
        self._m_parse.observe(time.monotonic() - t0)
        return module

    def analyze(self, program: ModuleLike, **kwargs: Any) -> LeoAnalysis:
        return self.session.analyze(program, **kwargs)

    def analyze_batch(self, programs: Iterable[ModuleLike], *,
                      backend: Optional[BackendLike] = None,
                      **kwargs: Any) -> List[LeoAnalysis]:
        """Concurrent fan-out: each program analyzed on the thread pool.

        The session's single-flight caches make duplicate programs in the
        batch collapse to one parse / one pipeline run."""
        return self._fan_out(
            lambda p: self.session.analyze(p, backend=backend, **kwargs),
            programs)

    def compare_backends(self, program: ModuleLike, *,
                         backends: Optional[Sequence[BackendLike]] = None,
                         hints: Optional[dict] = None,
                         **kwargs: Any) -> Dict[str, LeoAnalysis]:
        """Observation-1 fan-out, concurrently: same program, every
        backend, one parse (single-flighted under the pool)."""
        targets = [resolve_backend(b) for b in backends] \
            if backends is not None else self.session.backends
        results = self._fan_out(
            lambda b: self.session.analyze(program, backend=b, hints=hints,
                                           **kwargs), targets)
        return {b.name: r for b, r in zip(targets, results)}

    # -- diagnosis surface (serializable Diagnosis out) ------------------------

    def _diagnosis_key(self, program: ModuleLike, backend: Any,
                       hints: Optional[dict],
                       options: DiagnoseOptions) -> Optional[str]:
        """Content key for a diagnosis; None for identity-keyed Modules
        (not content-hashable, so never disk-cached).

        The key fingerprints the *backend descriptor contents* (hardware
        constants, taxonomy, sync knobs) rather than just its name, so
        recalibrating e.g. ``nvidia_gh200``'s HBM bandwidth invalidates
        every diagnosis cached under the old constants instead of
        silently serving stale estimates from a warm ``cache_dir``.
        ``DIAGNOSIS_KEY_VERSION`` covers analysis-code changes that keys
        cannot see (pass internals, recommendation rules): bump it when
        their semantics change.  The Diagnosis SCHEMA_VERSION is
        deliberately NOT part of the key: schema-only bumps keep hitting
        the old artifacts, which ``Diagnosis.from_dict`` migrates forward
        (a warm cache survives a schema bump).  ``options`` supplies its
        own components (:meth:`DiagnoseOptions.key_fields` in the
        historical positions, :meth:`~DiagnoseOptions.key_suffix` only
        when non-default), so every pre-v6 knob combination hashes
        byte-identically to what it always did."""
        if isinstance(program, Module):
            return None
        mkey = self.session.module_key(program, hints)
        backend_fp = repr((backend.name, backend.vendor, backend.hw,
                           sorted((k.value, v) for k, v
                                  in backend.stall_taxonomy.items()),
                           backend.sync))
        h = hashlib.sha256()
        h.update(json.dumps([
            mkey, backend_fp, *options.key_fields(),
            DIAGNOSIS_KEY_VERSION,
            self.session.pipeline.names,
            *options.key_suffix(),
        ]).encode())
        return h.hexdigest()

    def diagnose(self, program: ModuleLike, *,
                 backend: Optional[BackendLike] = None,
                 hints: Optional[dict] = None,
                 options: Optional[DiagnoseOptions] = None,
                 n_chains: Optional[int] = None,
                 prune_unexecuted: Optional[bool] = None,
                 advise: Optional[bool] = None,
                 rewrite: Optional[bool] = None,
                 occupancy: Optional[bool] = None) -> Diagnosis:
        """Analyze and return the serializable :class:`Diagnosis`,
        consulting the memory and disk diagnosis tiers first — a warm
        disk tier answers without parsing or running the pipeline.
        Analysis knobs ride one typed ``options=DiagnoseOptions(...)``
        value; the flat keyword forms still work as warn-once
        deprecation shims.

        ``options.advise`` additionally runs the what-if advisor
        (:mod:`repro.advisor`) on cache misses and lands ranked,
        speedup-priced advice in the Diagnosis ``advice`` section
        (schema v4); advice-carrying artifacts are cached under their
        own key, so toggling the knob never serves a stale shape.

        ``options.rewrite`` closes the loop (:mod:`repro.rewrite`): the
        top advice is lowered to equivalence-checked HLO rewrites, each
        rewritten text is re-analyzed through this same session, and the
        ``rewrites`` section (schema v5) lands predicted-vs-realized
        speedups.  The advisor runs internally either way, but the
        ``advice`` section is only recorded when ``advise`` is set — the
        two knobs key the caches independently.

        ``options.occupancy`` engages the backend's native wave-residency
        model (``backend.with_occupancy()``) before analysis: the
        Diagnosis gains the schema-v6 ``occupancy`` section, and the
        derived ``@wN-...`` backend name keys the session caches so an
        occupancy analysis can never alias a plain one.  Single-wave
        parts analyze unchanged (they have no residency to raise)."""
        opts = DiagnoseOptions.coalesce(
            options, "LeoService.diagnose", n_chains=n_chains,
            prune_unexecuted=prune_unexecuted, advise=advise,
            rewrite=rewrite, occupancy=occupancy)
        opts.validate()
        b = resolve_backend(backend) if backend is not None \
            else self.session.default_backend
        if opts.occupancy and b.native_occupancy.multi_wave \
                and not b.occupancy.multi_wave:
            b = b.with_occupancy()
        dkey = self._diagnosis_key(program, b, hints, opts)
        # cached entries are returned as copies: a caller mutating its
        # Diagnosis (e.g. inserting a pipeline-level recommendation, as
        # benchmarks/harness.py does) must not poison the shared cache
        if dkey is not None:
            with self._lock:
                cached = self._diagnoses.get(dkey)
                if cached is not None:
                    self.diagnosis_hits += 1
            if self._m_cache is not None:
                self._m_cache.inc(tier="diagnosis_memory",
                                  result="hit" if cached is not None
                                  else "miss")
            if cached is not None:
                if self._m_diagnoses is not None:
                    self._m_diagnoses.inc(backend=b.name)
                return cached.copy()
            if self.disk_cache is not None:
                diag = self.disk_cache.load_diagnosis(dkey)
                if self._m_cache is not None:
                    self._m_cache.inc(tier="diagnosis_disk",
                                      result="hit" if diag is not None
                                      else "miss")
                if diag is not None:
                    with self._lock:
                        self.diagnosis_hits += 1
                        self._diagnoses[dkey] = diag
                    if self._m_diagnoses is not None:
                        self._m_diagnoses.inc(backend=b.name)
                    return diag.copy()
        with self._lock:
            self.diagnosis_misses += 1
        if self._m_parse is not None and isinstance(program, str):
            # warm the session parse tier through the timed parse() so
            # the parse histogram sees serving-path data; analyze() below
            # still keys its caches by content, not Module identity
            self.parse(program, hints=hints)
        t0 = time.monotonic()
        analysis = self.session.analyze(
            program, backend=b, hints=hints, n_chains=opts.n_chains,
            prune_unexecuted=opts.prune_unexecuted)
        if self._m_pipeline is not None:
            self._m_pipeline.observe(time.monotonic() - t0)
        diag = Diagnosis.from_analysis(analysis, max_chains=opts.n_chains)
        rep = None
        if opts.advise or opts.rewrite:
            # lazy: repro.advisor imports core, so core must not import
            # it at module scope (and advice-free serving never pays it)
            from ..advisor import Advisor, advice_section
            t1 = time.monotonic()
            rep = Advisor().report(
                analysis.module, b,
                profile=analysis.profile, blame=analysis.blame)
            if self._m_advisor is not None:
                self._m_advisor.observe(time.monotonic() - t1)
            if opts.advise:
                diag.advice = advice_section(rep.advice, rep)
        if opts.rewrite:
            # same lazy-import rule as the advisor; verification samples
            # the module re-parsed from each rewritten text directly
            # (identical makespan to a full session.analyze by the
            # round-trip guarantee, without paying a cold pipeline per
            # rewrite — the bench rewrite-overhead gate holds it < 4x)
            from ..rewrite import RewriteLoop, rewrites_section
            t2 = time.monotonic()
            rw = RewriteLoop().run(
                analysis.module, b, hints=hints,
                profile=analysis.profile, blame=analysis.blame,
                advisor_report=rep)
            if self._m_rewrite is not None:
                self._m_rewrite.observe(time.monotonic() - t2)
            diag.rewrites = rewrites_section(rw)
        if dkey is not None:
            with self._lock:
                self._diagnoses[dkey] = diag.copy()
            if self.disk_cache is not None:
                self.disk_cache.store_diagnosis(dkey, diag)
        if self._m_diagnoses is not None:
            self._m_diagnoses.inc(backend=b.name)
        return diag

    def submit(self, request: AnalyzeRequest
               ) -> Union[Diagnosis, Dict[str, Diagnosis]]:
        """Serve one typed request.  Returns a single ``Diagnosis``, or a
        ``{backend: Diagnosis}`` map when the request names ``backends``."""
        request.validate()
        if request.backends is not None:
            return self.diagnose_fanout(
                request.hlo_text, backends=request.backends,
                hints=request.hints, options=request.options)
        return self.diagnose(
            request.hlo_text, backend=request.backend, hints=request.hints,
            options=request.options)

    def submit_async(self, request: AnalyzeRequest) -> Future:
        """`submit` as a Future — the non-blocking shape a queue-driven
        front-end (e.g. ``repro.launch.analysis_server``) consumes.  Runs
        on the shared pool; degrades to an already-resolved Future when
        called from a pool worker (same no-nesting rule as `_fan_out`)."""
        request.validate()
        pool = self._executor()
        if pool is not None:
            return pool.submit(self.submit, request)
        fut: Future = Future()
        try:
            fut.set_result(self.submit(request))
        except Exception as e:  # noqa: BLE001 - future carries the failure
            fut.set_exception(e)
        return fut

    def diagnose_batch(self, requests: Sequence[AnalyzeRequest]
                       ) -> List[Union[Diagnosis, Dict[str, Diagnosis]]]:
        """Concurrent typed-request batch (order-preserving)."""
        requests = list(requests)
        for r in requests:
            r.validate()
        return self._fan_out(self.submit, requests)

    def diagnose_fanout(self, program: ModuleLike, *,
                        backends: Optional[Sequence[BackendLike]] = None,
                        hints: Optional[dict] = None,
                        options: Optional[DiagnoseOptions] = None,
                        n_chains: Optional[int] = None,
                        prune_unexecuted: Optional[bool] = None,
                        advise: Optional[bool] = None,
                        rewrite: Optional[bool] = None,
                        occupancy: Optional[bool] = None
                        ) -> Dict[str, Diagnosis]:
        """``compare_backends`` with serializable results."""
        opts = DiagnoseOptions.coalesce(
            options, "LeoService.diagnose_fanout", n_chains=n_chains,
            prune_unexecuted=prune_unexecuted, advise=advise,
            rewrite=rewrite, occupancy=occupancy)
        targets = [resolve_backend(b) for b in backends] \
            if backends is not None else self.session.backends
        results = self._fan_out(
            lambda b: self.diagnose(program, backend=b, hints=hints,
                                    options=opts), targets)
        return {b.name: r for b, r in zip(targets, results)}

    def __repr__(self) -> str:
        disk = self.disk_cache.root if self.disk_cache is not None else None
        return (f"LeoService(session={self.session!r}, disk={disk!r}, "
                f"workers={self.max_workers})")
