"""Cross-vendor synchronization tracing (§III-E), TPU/XLA analogues.

Purely register-based tracing dead-ends at synchronization instructions,
which expose no data operands for the memory traffic they wait on.  The
paper adds vendor-specific edges; we implement all three mechanisms against
their exact XLA/Pallas counterparts:

* ``mem_barrier``  (NVIDIA B1-B6 analogue): HLO async pairs.  A ``*-start``
  op *sets* a barrier named by itself; the matching ``*-done`` op *waits* on
  it.  We link done -> start and, crucially, done -> the start's *data
  producers*, so a slice through ``all-gather-done`` reaches the tensor that
  was gathered.
* ``mem_waitcnt``  (AMD ``s_waitcnt`` analogue): Pallas DMA-semaphore
  counters in kernel jaxprs.  ``dma_wait(sem, allow_outstanding=N)`` drains
  the in-flight DMA count to N; we scan backward for the (M-N) *oldest*
  pending DMA starts on that semaphore, stopping at epoch boundaries where a
  prior wait already drained it — the paper's exact algorithm.
* ``mem_swsb``     (Intel SWSB analogue): XLA token threading.  Ops that
  consume a ``token[]`` value wait on the op that produced that token
  (``after-all`` merges are traversed to all their sources).

All three produce typed edges that are exempt from opcode and latency
pruning (they are compiler-verified dependencies).

When the backend carries a :class:`~repro.core.backends.SyncModel`, each
sync edge is additionally annotated with the *concrete resource instance*
it consumed ("B3", "vmcnt", "$5"): a logical scoreboard replay assigns
every set identifier to a physical instance the same way the sampler's
stateful scoreboard does, so edge annotations, SYNC_RESOURCE stall events
and the Diagnosis ``sync_resources`` section all name the same hardware.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

from .cfg import PathInfo
from .depgraph import DependencyGraph, Edge
from .isa import EdgeKind, Instruction, Module, OpClass, SyncKind

#: (kind, computation, tag) -> physical instance name, from the replay.
#: Tags are computation-scoped, mirroring the sampler's scoreboard keys.
ResourceAssignment = Dict[Tuple[SyncKind, str, str], str]

# Computation kinds the sampler never schedules as independent streams
# (mirrors sampler._SKIP_KINDS); the replay still visits them afterwards
# so their edges (e.g. Pallas DMA streams inside fusions) get annotated.
_SKIP_KINDS = ("fusion", "reduce", "loop_cond")


def assign_sync_resources(module: Module, sync,
                          queues: int = 1) -> ResourceAssignment:
    """Replay the module's sync ops against a logical scoreboard, mapping
    every set identifier to the physical resource instance it lands on.

    The replay follows the sampler's execution order — entry computation,
    recursing into called computations at their call sites — so instance
    assignments match the dynamic scoreboard's and the edge annotations
    name the same hardware as the SYNC_RESOURCE stall events.  ``queues``
    must match the backend's issue-queue count: the replay itself issues
    everything on queue 0 (it has no port-assignment model), but the
    scoreboard's queue-scoped pools then mint instance names in the same
    ``q<i>:...`` namespace the multi-queue pressure report uses, so even
    computations only the replay reaches (fusion bodies) get annotations
    that exist in the report.
    """
    if sync is None or not getattr(sync, "pools", ()):
        return {}
    board = sync.scoreboard(queues=queues)
    assign: ResourceAssignment = {}
    visited: Set[str] = set()

    def walk(comp_name: str, depth: int) -> None:
        if depth > 32 or comp_name in visited:
            return
        visited.add(comp_name)
        comp = module.computations.get(comp_name)
        if comp is None:
            return
        for instr in comp.instructions:
            si = instr.sync
            if si.kind is not None:
                for tag in si.waits:
                    board.retire(si.kind, f"{comp.name}::{tag}",
                                 drain_to=si.counter)
                for tag in si.sets:
                    acq = board.acquire(si.kind, f"{comp.name}::{tag}",
                                        consumer=instr.qualified_name)
                    if acq is not None:
                        assign[(si.kind, comp.name, tag)] = acq.instance
            for callee in instr.called_computations:
                c = module.computations.get(callee)
                if c is not None and c.kind not in _SKIP_KINDS:
                    walk(callee, depth + 1)

    if module.entry:
        walk(module.entry, 0)
    for comp in module.computations.values():   # unreached (fusion bodies…)
        walk(comp.name, 0)
    return assign


def add_sync_edges(graph: DependencyGraph, sync=None,
                   assignment: Optional[ResourceAssignment] = None,
                   queues: int = 1) -> int:
    """Extend `graph` with §III-E synchronization edges.  Returns # added.

    ``sync`` (a backend ``SyncModel``) enables per-edge resource-instance
    annotation via :func:`assign_sync_resources` (``queues`` = the
    backend's issue-queue count, so replay-minted names share the
    report's namespace).  ``assignment`` — the sampler's
    dynamically-recorded tag->instance map
    (``StallProfile.sync_assignment``) — overlays the static replay where
    present, so under a multi-queue issue model the edge annotations name
    the exact per-queue instance the dynamic scoreboard used; computations
    the sampler never schedules (fusion bodies) keep the replay's
    assignment.
    """
    assign = assign_sync_resources(graph.module, sync, queues=queues)
    if assignment:
        assign.update(assignment)
    n = 0
    n += _trace_barriers(graph, assign)
    n += _trace_waitcnt(graph, assign)
    n += _trace_tokens(graph, assign)
    return n


def _existing(graph: DependencyGraph) -> Set[Tuple[str, str, EdgeKind]]:
    return {(e.producer, e.consumer, e.kind) for e in graph.edges}


def _add(graph: DependencyGraph, seen: Set[Tuple[str, str, EdgeKind]],
         producer: Instruction, consumer: Instruction, kind: EdgeKind,
         path: Optional[PathInfo] = None,
         resource: Optional[str] = None) -> int:
    key = (producer.qualified_name, consumer.qualified_name, kind)
    if key in seen or producer is consumer:
        return 0
    seen.add(key)
    if path is None:
        dist = abs(consumer.index - producer.index) \
            if producer.computation == consumer.computation else 1.0
        path = PathInfo(instr_count=max(dist - 1, 0.0), issue_cycles=0.0,
                        kind="sync")
    graph.add(Edge(producer=producer.qualified_name,
                   consumer=consumer.qualified_name, kind=kind, paths=[path],
                   resource=resource))
    return 1


# -- NVIDIA-barrier analogue: HLO async pairs -------------------------------

def _trace_barriers(graph: DependencyGraph,
                    assign: ResourceAssignment) -> int:
    module = graph.module
    seen = _existing(graph)
    n = 0
    for comp in module.computations.values():
        starts: Dict[str, Instruction] = {
            i.name: i for i in comp.instructions
            if i.op_class is OpClass.SYNC_SET}
        for instr in comp.instructions:
            if instr.op_class is not OpClass.SYNC_WAIT:
                continue
            for waited in instr.sync.waits:
                start = starts.get(waited) or comp.get(waited)
                if start is None:
                    continue
                res = assign.get((SyncKind.BARRIER, comp.name, waited))
                n += _add(graph, seen, start, instr, EdgeKind.MEM_BARRIER,
                          resource=res)
                # Reach *through* the start to the memory/data producers the
                # transfer actually depends on (the paper's goal: identify
                # the memory accesses causing synchronization stalls).
                for op in start.operands:
                    producer = comp.get(op)
                    if producer is not None and producer.op_class not in (
                            OpClass.TUPLE, OpClass.CONSTANT):
                        n += _add(graph, seen, producer, instr,
                                  EdgeKind.MEM_BARRIER, resource=res)
    return n


# -- AMD s_waitcnt analogue: DMA semaphore counters --------------------------

def _trace_waitcnt(graph: DependencyGraph,
                   assign: ResourceAssignment) -> int:
    """Counted-semaphore tracing for Pallas-style DMA streams.

    Instructions carry SyncInfo(kind=WAITCNT): DMA starts *set* a counter id
    (semaphore name); waits carry ``counter=N`` = allowed outstanding count.
    For each wait we scan backward collecting pending starts on the same
    counter since the last epoch boundary (a prior wait that drained to <=
    our target), then blame the (M-N) oldest — exactly §III-E.
    """
    module = graph.module
    seen = _existing(graph)
    n = 0
    for comp in module.computations.values():
        for wi, instr in enumerate(comp.instructions):
            si = instr.sync
            if si.kind is not SyncKind.WAITCNT or not si.waits:
                continue
            allow = si.counter if si.counter is not None else 0
            for sem in si.waits:
                pending: List[Instruction] = []
                for prev in comp.instructions[:wi]:
                    psync = prev.sync
                    if psync.kind is not SyncKind.WAITCNT:
                        continue
                    if sem in psync.sets and not psync.waits:
                        pending.append(prev)
                    elif sem in psync.waits:
                        # epoch boundary: a prior wait drained the counter
                        drained_to = psync.counter or 0
                        pending = pending[len(pending) - drained_to:] \
                            if drained_to < len(pending) else pending
                        if drained_to == 0:
                            pending = []
                m = len(pending)
                res = assign.get((SyncKind.WAITCNT, comp.name, sem))
                blamed = pending[: max(0, m - allow)]  # the oldest (M-N)
                for start in blamed:
                    n += _add(graph, seen, start, instr, EdgeKind.MEM_WAITCNT,
                              resource=res)
                    for op in start.operands:
                        producer = comp.get(op)
                        if producer is not None and producer.op_class not in (
                                OpClass.TUPLE, OpClass.CONSTANT):
                            n += _add(graph, seen, producer, instr,
                                      EdgeKind.MEM_WAITCNT, resource=res)
    return n


# -- Intel SWSB analogue: token threading ------------------------------------

def _trace_tokens(graph: DependencyGraph,
                  assign: ResourceAssignment) -> int:
    module = graph.module
    seen = _existing(graph)
    n = 0
    for comp in module.computations.values():
        token_producers: Dict[str, Instruction] = {}
        for instr in comp.instructions:
            if instr.sync.kind is SyncKind.TOKEN and instr.sync.sets:
                for t in instr.sync.sets:
                    token_producers[t] = instr
        for instr in comp.instructions:
            waits: List[str] = []
            if instr.sync.kind is SyncKind.TOKEN:
                waits.extend(instr.sync.waits)
            # Any op consuming a token-typed value waits on its producer
            # (the SWSB-token analogue covers send/recv token threading).
            for op in instr.operands:
                producer = comp.get(op)
                if producer is not None and (
                        producer.shape.dtype == "token" or
                        producer.opcode == "after-all"):
                    waits.append(op)
            if not waits:
                continue
            frontier = list(waits)
            visited: Set[str] = set()
            while frontier:
                t = frontier.pop()
                if t in visited:
                    continue
                visited.add(t)
                producer = token_producers.get(t) or comp.get(t)
                if producer is None or producer is instr:
                    continue
                if producer.opcode == "after-all":
                    # merge node: traverse to all joined sources
                    frontier.extend(producer.operands)
                    continue
                n += _add(graph, seen, producer, instr, EdgeKind.MEM_SWSB,
                          resource=assign.get((SyncKind.TOKEN, comp.name,
                                               t)))
    return n
