"""Jaxpr front-end: jaxprs (incl. Pallas kernel bodies) -> unified Module.

The paper's AMD path traces `s_waitcnt` counters through GCN disassembly.
Our counted-semaphore analogue lives in Pallas kernels: explicit
`make_async_copy` DMAs signal semaphores (`dma_start`) that `dma_wait`
drains — a literal in-flight-memory-op counter.  This front-end converts a
jaxpr (obtained via `jax.make_jaxpr` on a function, descending through
`pallas_call` / `scan` / `while` / `cond` / `pjit` sub-jaxprs) into the same
`Module` model the HLO parser emits, so the whole LEO pipeline — dependency
graph, §III-E waitcnt tracing, pruning, blame — runs unchanged on kernels.

Source attribution comes from each eqn's `source_info` traceback (the DWARF
analogue is *exact* here: real file/line of the kernel author's code).
"""
from __future__ import annotations

import itertools
from typing import Any, Dict, List, Optional, Tuple

from .isa import (
    Computation,
    Instruction,
    Module,
    OpClass,
    ShapeInfo,
    SyncInfo,
    SyncKind,
)

_DTYPE_SHORT = {
    "float32": "f32", "float64": "f64", "float16": "f16", "bfloat16": "bf16",
    "int64": "s64", "int32": "s32", "int16": "s16", "int8": "s8",
    "uint64": "u64", "uint32": "u32", "uint16": "u16", "uint8": "u8",
    "bool": "pred", "complex64": "c64", "complex128": "c128",
    "float8_e4m3fn": "f8e4m3fn", "float8_e5m2": "f8e5m2",
}

_PRIM_CLASS = {
    "dot_general": OpClass.MATMUL,
    "conv_general_dilated": OpClass.MATMUL,
    "reduce_sum": OpClass.REDUCE, "reduce_max": OpClass.REDUCE,
    "reduce_min": OpClass.REDUCE, "reduce_prod": OpClass.REDUCE,
    "reduce_and": OpClass.REDUCE, "reduce_or": OpClass.REDUCE,
    "argmax": OpClass.REDUCE, "argmin": OpClass.REDUCE,
    "cumsum": OpClass.REDUCE, "cumlogsumexp": OpClass.REDUCE,
    "gather": OpClass.MEMORY_LOAD, "dynamic_slice": OpClass.MEMORY_LOAD,
    "scatter": OpClass.MEMORY_STORE, "scatter-add": OpClass.MEMORY_STORE,
    "scatter_add": OpClass.MEMORY_STORE,
    "dynamic_update_slice": OpClass.MEMORY_STORE,
    "broadcast_in_dim": OpClass.DATA_MOVEMENT,
    "transpose": OpClass.DATA_MOVEMENT, "reshape": OpClass.DATA_MOVEMENT,
    "convert_element_type": OpClass.DATA_MOVEMENT,
    "squeeze": OpClass.DATA_MOVEMENT, "slice": OpClass.MEMORY_LOAD,
    "concatenate": OpClass.DATA_MOVEMENT, "pad": OpClass.DATA_MOVEMENT,
    "rev": OpClass.DATA_MOVEMENT, "copy": OpClass.DATA_MOVEMENT,
    "iota": OpClass.MEMORY_LOAD, "select_n": OpClass.COMPUTE,
    "scan": OpClass.CONTROL, "while": OpClass.CONTROL,
    "cond": OpClass.CONTROL, "pjit": OpClass.CONTROL,
    "closed_call": OpClass.CONTROL, "custom_jvp_call": OpClass.CONTROL,
    "custom_vjp_call": OpClass.CONTROL, "remat2": OpClass.CONTROL,
    "checkpoint": OpClass.CONTROL, "pallas_call": OpClass.CONTROL,
    "custom_vjp_call_jaxpr": OpClass.CONTROL,
    "psum": OpClass.COLLECTIVE, "all_gather": OpClass.COLLECTIVE,
    "reduce_scatter": OpClass.COLLECTIVE, "ppermute": OpClass.COLLECTIVE,
    "all_to_all": OpClass.COLLECTIVE, "pmax": OpClass.COLLECTIVE,
    # Pallas / state primitives
    "get": OpClass.MEMORY_LOAD, "masked_load": OpClass.MEMORY_LOAD,
    "load": OpClass.MEMORY_LOAD,
    "swap": OpClass.MEMORY_STORE, "masked_swap": OpClass.MEMORY_STORE,
    "store": OpClass.MEMORY_STORE, "addupdate": OpClass.MEMORY_STORE,
    "dma_start": OpClass.SYNC_SET, "dma_wait": OpClass.SYNC_WAIT,
    "copy_start": OpClass.SYNC_SET, "copy_wait": OpClass.SYNC_WAIT,
    "semaphore_signal": OpClass.SYNC_SET,
    "semaphore_wait": OpClass.SYNC_WAIT,
}

_TRANSCENDENTAL_PRIMS = {
    "exp", "log", "tanh", "logistic", "erf", "rsqrt", "sqrt", "sin", "cos",
    "pow", "integer_pow", "log1p", "expm1", "cbrt",
}

# VMEM-resident ref traffic is ~20x faster than HBM; scale bytes so the
# shared hwmodel prices it sensibly inside kernels.
_VMEM_BYTE_SCALE = 0.05

_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr",
                    "branches", "fun_jaxpr")


def _short_dtype(aval) -> str:
    return _DTYPE_SHORT.get(str(getattr(aval, "dtype", "f32")), "f32")


def _aval_shape(aval) -> ShapeInfo:
    dims = tuple(int(d) for d in getattr(aval, "shape", ()) or ())
    # Ref avals wrap an inner aval
    inner = getattr(aval, "inner_aval", None)
    if inner is not None:
        return _aval_shape(inner)
    return ShapeInfo(dtype=_short_dtype(aval), dims=dims)


def _source_of(eqn) -> Tuple[str, int, str]:
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info.traceback)
        if frame is not None:
            return (frame.file_name, frame.start_line,
                    frame.function_name or "")
    except Exception:
        pass
    return ("", 0, "")


class JaxprConverter:
    def __init__(self):
        self._counter = itertools.count()
        self._comp_counter = itertools.count()

    def convert(self, closed_jaxpr, name: str = "jaxpr",
                scope: str = "") -> Module:
        module = Module(name=name, source="jaxpr")
        entry_name = self._convert_jaxpr(module, closed_jaxpr.jaxpr,
                                         kind="entry", scope=scope or name)
        module.entry = entry_name
        return module

    # -- internals --------------------------------------------------------------

    def _convert_jaxpr(self, module: Module, jaxpr, kind: str,
                       scope: str) -> str:
        comp_name = f"c{next(self._comp_counter)}_{kind}"
        comp = Computation(name=comp_name, kind=kind)
        module.add_computation(comp)
        names: Dict[Any, str] = {}

        for i, v in enumerate(list(jaxpr.constvars) + list(jaxpr.invars)):
            pname = self._name(names, v)
            instr = Instruction(
                name=pname, opcode="parameter",
                op_class=OpClass.PARAMETER, shape=_aval_shape(v.aval),
                operands=(), computation=comp_name, index=0,
                attributes={"literal": str(i)}, op_name=scope)
            instr.bytes_read = float(instr.shape.byte_size)
            comp.add(instr)

        self._emit_eqns(module, comp, jaxpr, names, scope, guard=None)

        for ov in reversed(jaxpr.outvars):
            if not hasattr(ov, "val") and ov in names:
                root = comp.get(names[ov])
                if root is not None:
                    root.is_root = True
                    break
        return comp_name

    def _name(self, names: Dict[Any, str], v) -> str:
        if v not in names:
            names[v] = f"v{next(self._counter)}"
        return names[v]

    def _literal(self, comp: Computation, scope: str, value,
                 shape: ShapeInfo = None) -> str:
        lit = Instruction(
            name=f"lit{next(self._counter)}", opcode="constant",
            op_class=OpClass.CONSTANT,
            shape=shape or ShapeInfo(dtype="f32", dims=()),
            operands=(), computation=comp.name, index=0,
            attributes={"literal": str(value)}, op_name=scope)
        comp.add(lit)
        return lit.name

    def _operand_names(self, comp: Computation, names: Dict[Any, str],
                       eqn, scope: str) -> List[str]:
        out: List[str] = []
        for iv in eqn.invars:
            if hasattr(iv, "val"):  # Literal
                out.append(self._literal(comp, scope, iv.val))
            else:
                out.append(self._name(names, iv))
        return out

    def _emit_eqns(self, module: Module, comp: Computation, jaxpr,
                   names: Dict[Any, str], scope: str,
                   guard: Optional[str]) -> None:
        comp_name = comp.name
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            if prim == "cond" and eqn.params.get("branches") is not None:
                self._inline_cond(module, comp, eqn, names, scope)
                continue
            operands = self._operand_names(comp, names, eqn, scope)
            out_var = eqn.outvars[0] if eqn.outvars else None
            shape = _aval_shape(out_var.aval) if out_var is not None and \
                hasattr(out_var, "aval") else ShapeInfo()
            src_file, src_line, fn = _source_of(eqn)
            op_class = _PRIM_CLASS.get(prim, OpClass.COMPUTE)

            called: List[str] = []
            trip = 1
            for pkey in _SUBJAXPR_PARAMS:
                sub = eqn.params.get(pkey)
                if sub is None:
                    continue
                subs = sub if isinstance(sub, (list, tuple)) else [sub]
                for sj in subs:
                    inner = getattr(sj, "jaxpr", sj)
                    if not hasattr(inner, "eqns"):
                        continue
                    sub_kind = "loop_body" if prim in ("scan", "while") and \
                        pkey in ("jaxpr", "body_jaxpr") else \
                        ("branch" if pkey == "branches" else "called")
                    child_scope = f"{scope}/{fn or prim}"
                    called.append(self._convert_jaxpr(module, inner, sub_kind,
                                                      child_scope))
            if prim == "scan":
                trip = int(eqn.params.get("length", 1) or 1)
                op_class = OpClass.CONTROL

            attributes: Dict[str, str] = {}
            if guard is not None:
                attributes["guard"] = guard
            instr = Instruction(
                name=self._name(names, out_var) if out_var is not None and
                not hasattr(out_var, "val") else f"o{next(self._counter)}",
                opcode=prim, op_class=op_class, shape=shape,
                operands=tuple(operands), computation=comp_name, index=0,
                attributes=attributes,
                op_name=f"{scope}/{fn}" if fn else scope,
                source_file=src_file, source_line=src_line,
                called_computations=tuple(called), trip_count=trip)
            self._annotate(comp, instr, eqn)
            comp.add(instr)
            for oi, extra in enumerate(eqn.outvars[1:], start=1):
                alias = Instruction(
                    name=self._name(names, extra),
                    opcode="get-tuple-element", op_class=OpClass.TUPLE,
                    shape=_aval_shape(extra.aval) if hasattr(extra, "aval")
                    else ShapeInfo(),
                    operands=(instr.name,), computation=comp_name, index=0,
                    attributes={"index": str(oi)}, op_name=instr.op_name)
                comp.add(alias)

    def _inline_cond(self, module: Module, comp: Computation, eqn,
                     names: Dict[Any, str], scope: str) -> None:
        """Inline `cond` branches (pl.when and friends) so counted-semaphore
        timelines stay linear within one computation; the guard predicate is
        recorded on each inlined instruction (the paper's P0-P6 guard
        tracking) and a select joins branch results (union at joins)."""
        ops = self._operand_names(comp, names, eqn, scope)
        pred, args = ops[0], ops[1:]
        branch_outs: List[List[Optional[str]]] = []
        for closed in eqn.params.get("branches", ()):
            sub = getattr(closed, "jaxpr", closed)
            consts = getattr(closed, "consts", ())
            sub_names: Dict[Any, str] = {}
            for cv, cval in zip(sub.constvars, consts):
                sub_names[cv] = self._literal(comp, scope, "<const>",
                                              _aval_shape(cv.aval))
            for bv, name in zip(sub.invars, args):
                sub_names[bv] = name
            self._emit_eqns(module, comp, sub, sub_names, scope, guard=pred)
            outs: List[Optional[str]] = []
            for ov in sub.outvars:
                if hasattr(ov, "val"):
                    outs.append(self._literal(comp, scope, ov.val))
                else:
                    outs.append(sub_names.get(ov))
            branch_outs.append(outs)
        for oi, ov in enumerate(eqn.outvars):
            srcs = [bo[oi] for bo in branch_outs
                    if oi < len(bo) and bo[oi] is not None]
            sel = Instruction(
                name=self._name(names, ov), opcode="select",
                op_class=OpClass.COMPUTE,
                shape=_aval_shape(ov.aval) if hasattr(ov, "aval")
                else ShapeInfo(),
                operands=tuple([pred] + srcs), computation=comp.name,
                index=0, op_name=scope)
            comp.add(sel)

    def _annotate(self, comp: Computation, instr: Instruction, eqn) -> None:
        prim = eqn.primitive.name
        out_elems = instr.shape.num_elements
        if prim == "dot_general":
            dnums = eqn.params.get("dimension_numbers")
            k = 1
            lhs_aval = eqn.invars[0].aval if hasattr(eqn.invars[0], "aval") \
                else None
            if dnums is not None and lhs_aval is not None:
                (lc, _), _ = dnums
                for d in lc:
                    k *= int(lhs_aval.shape[d])
            instr.flops = 2.0 * out_elems * k
        elif instr.op_class is OpClass.REDUCE:
            in_elems = sum(int(v.aval.size) for v in eqn.invars
                           if hasattr(v, "aval") and hasattr(v.aval, "size"))
            instr.flops = float(max(in_elems, out_elems))
        elif instr.op_class is OpClass.COMPUTE:
            per = 8.0 if prim in _TRANSCENDENTAL_PRIMS else 1.0
            instr.flops = per * out_elems

        in_bytes = 0.0
        for v in eqn.invars:
            if hasattr(v, "aval"):
                in_bytes += _aval_shape(v.aval).byte_size
        instr.bytes_read = in_bytes
        instr.bytes_written = float(instr.shape.byte_size)

        # Pallas ref traffic is VMEM-speed; DMA is true HBM traffic.
        if prim in ("get", "swap", "masked_load", "masked_swap", "load",
                    "store", "addupdate"):
            instr.bytes_read *= _VMEM_BYTE_SCALE
            instr.bytes_written *= _VMEM_BYTE_SCALE
        if prim in ("dma_start", "copy_start"):
            sem = self._sem_operand(eqn, instr)
            instr.sync = SyncInfo(kind=SyncKind.WAITCNT,
                                  sets=(sem,) if sem else (instr.name,))
        elif prim in ("dma_wait", "copy_wait"):
            sem = self._sem_operand(eqn, instr)
            instr.sync = SyncInfo(kind=SyncKind.WAITCNT,
                                  waits=(sem,) if sem else (), counter=0)
            instr.bytes_read = 0.0
            instr.bytes_written = 0.0
        elif prim == "semaphore_signal":
            instr.sync = SyncInfo(kind=SyncKind.WAITCNT,
                                  sets=(instr.operands[0],)
                                  if instr.operands else ())
        elif prim == "semaphore_wait":
            instr.sync = SyncInfo(kind=SyncKind.WAITCNT,
                                  waits=(instr.operands[0],)
                                  if instr.operands else (), counter=0)

    def _sem_operand(self, eqn, instr: Instruction) -> Optional[str]:
        """The semaphore ref operand names the waitcnt counter.

        Pallas semaphore refs print as ``Ref<semaphore_mem>{dma_sem[n]}`` —
        match on the aval string so views/indexers are never mistaken for
        the counter."""
        for v, name in zip(eqn.invars, instr.operands):
            if hasattr(v, "val"):
                continue  # literals are never semaphores
            aval = getattr(v, "aval", None)
            if aval is not None and ("semaphore" in str(aval).lower() or
                                     "sem[" in str(aval).lower()):
                return name
        return instr.operands[-1] if instr.operands else None


def from_jaxpr(closed_jaxpr, name: str = "jaxpr", scope: str = "") -> Module:
    return JaxprConverter().convert(closed_jaxpr, name=name, scope=scope)


def from_function(fn, *example_args, name: Optional[str] = None,
                  **jaxpr_kwargs) -> Module:
    import jax
    cj = jax.make_jaxpr(fn, **jaxpr_kwargs)(*example_args)
    return from_jaxpr(cj, name=name or getattr(fn, "__name__", "fn"))
