"""LEO end-to-end pipeline (paper §III-A's 5-phase workflow).

  1. Data collection   — HLO text (the "disassembly") + virtual PC sampling
                         (or an externally supplied measured profile).
  2. Binary analysis   — parse computations/instructions, classify opcodes,
                         recover source attribution from metadata.
  3. Dependency graph  — CCT dependency graph from SSA/region dataflow,
                         extended with synchronization edges (§III-E).
  4. Four-stage pruning— opcode / barrier / latency / execution (§III-C).
  5. Blame attribution — inverse-distance four-factor weighting (§III-D).

`analyze_hlo` is the main entry; `LeoAnalysis` carries every intermediate so
benchmarks (coverage, context-format studies) can introspect the pipeline.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .blame import BlameResult, attribute_blame
from .cct import CCTNode, build_cct
from .coverage import CoverageReport, single_dependency_coverage
from .depgraph import DependencyGraph, build_dependency_graph
from .hlo_parser import parse_hlo
from .hwmodel import HardwareModel, TPU_V5E
from .isa import Module
from .pruning import PruneStats, prune
from .sampler import StallProfile, sample
from .slicing import StallChain, top_chains
from .sync_trace import add_sync_edges


@dataclass
class LeoAnalysis:
    module: Module
    hw: HardwareModel
    profile: StallProfile
    graph: DependencyGraph
    prune_stats: PruneStats
    blame: BlameResult
    chains: List[StallChain]
    coverage_before: CoverageReport
    coverage_after: CoverageReport
    cct: CCTNode
    sync_edges_added: int = 0
    analysis_seconds: float = 0.0

    @property
    def estimated_step_seconds(self) -> float:
        return self.profile.makespan_seconds

    def top_root_causes(self, n: int = 10):
        return self.blame.top_root_causes(n)

    def summary(self) -> str:
        lines = [
            f"LEO analysis [{self.hw.name}] module={self.module.name}",
            f"  instructions={sum(len(c.instructions) for c in self.module.computations.values())}"
            f" edges={self.prune_stats.initial_edges}"
            f" (+{self.sync_edges_added} sync)"
            f" -> {self.prune_stats.surviving_edges} after pruning "
            f"{dict(self.prune_stats.pruned_by_stage)}",
            f"  est. step time: {self.estimated_step_seconds*1e3:.3f} ms, "
            f"total stall cycles: {self.profile.total_stall_cycles:,.0f}",
            f"  single-dep coverage: {self.coverage_before.coverage:.0%} -> "
            f"{self.coverage_after.coverage:.0%}",
            "  top root causes:",
        ]
        for q, cycles in self.top_root_causes(5):
            instr = self.module.find(q)
            where = instr.op_name if instr is not None else ""
            lines.append(f"    {cycles:14,.0f} cyc  {q}  [{where}]")
        if self.blame.self_blame:
            top_self = sorted(self.blame.self_blame, key=lambda s: -s.cycles)[:3]
            lines.append("  self-blame:")
            for s in top_self:
                lines.append(f"    {s.cycles:14,.0f} cyc  {s.qualified}  "
                             f"({s.subcategory})")
        return "\n".join(lines)


def analyze_module(module: Module, hw: HardwareModel = TPU_V5E,
                   profile: Optional[StallProfile] = None,
                   n_chains: int = 5,
                   prune_unexecuted: bool = True) -> LeoAnalysis:
    t0 = time.perf_counter()
    if profile is None:
        profile = sample(module, hw)                      # phase 1 (virtual)
    graph = build_dependency_graph(module, hw)            # phase 3a
    coverage_before = single_dependency_coverage(graph)
    n_sync = add_sync_edges(graph)                        # phase 3b (§III-E)
    prune_stats = prune(graph, profile, hw,
                        prune_unexecuted=prune_unexecuted)  # phase 4
    coverage_after = single_dependency_coverage(graph)
    blame = attribute_blame(graph, profile, hw)           # phase 5
    chains = top_chains(graph, profile, blame, n=n_chains)
    cct = build_cct(module, profile)
    return LeoAnalysis(
        module=module, hw=hw, profile=profile, graph=graph,
        prune_stats=prune_stats, blame=blame, chains=chains,
        coverage_before=coverage_before, coverage_after=coverage_after,
        cct=cct, sync_edges_added=n_sync,
        analysis_seconds=time.perf_counter() - t0)


def analyze_hlo(hlo_text: str, hw: HardwareModel = TPU_V5E,
                hints: Optional[dict] = None,
                **kwargs) -> LeoAnalysis:
    module = parse_hlo(hlo_text, hints=hints)
    return analyze_module(module, hw, **kwargs)


def cross_backend_analyze(hlo_text: str,
                          hw_models: Optional[List[HardwareModel]] = None,
                          hints: Optional[dict] = None
                          ) -> Dict[str, LeoAnalysis]:
    """Observation-1 driver: same program, every backend model.

    Returns per-backend analyses so callers can diff dominant bottlenecks —
    the paper's "the same kernel exhibits fundamentally different bottlenecks
    across architectures" experiment.
    """
    from .hwmodel import HARDWARE_MODELS
    models = hw_models or list(HARDWARE_MODELS.values())
    module = parse_hlo(hlo_text, hints=hints)
    return {hw.name: analyze_module(module, hw) for hw in models}
