"""Legacy entry points — thin shims over the composable pass pipeline.

The seed's monolithic 5-phase ``analyze_module`` now lives as named,
reorderable passes in ``repro.core.passes`` (sample -> depgraph ->
coverage -> sync_edges -> prune -> blame -> chains -> cct), with backends in
``repro.core.backends`` and the cached facade in ``repro.core.session``.

These wrappers keep every seed call site working and produce results
identical to the pipeline path (they *are* the pipeline path, minus the
session caches):

    analyze_hlo(text, hw=...)      == LeoSession().analyze(text, backend=...)
    analyze_module(module, hw=...) == DEFAULT_PIPELINE.analyze(module, ...)
    cross_backend_analyze(text)    == LeoSession().compare_backends(text)

New code should prefer ``LeoSession`` (caching, batching, multi-backend
fan-out) or a custom ``Pipeline`` (extra/removed/reordered passes).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Union

from .backends import BackendLike, resolve_backend
from .hlo_parser import parse_hlo
from .hwmodel import HardwareModel, TPU_V5E
from .isa import Module
from .passes import DEFAULT_PIPELINE, LeoAnalysis
from .sampler import StallProfile

__all__ = ["LeoAnalysis", "analyze_hlo", "analyze_module",
           "cross_backend_analyze"]


def analyze_module(module: Module, hw: BackendLike = TPU_V5E,
                   profile: Optional[StallProfile] = None,
                   n_chains: int = 5,
                   prune_unexecuted: bool = True) -> LeoAnalysis:
    """Single-module analysis on one backend (hw may be a HardwareModel,
    a registered backend name, or a Backend descriptor)."""
    return DEFAULT_PIPELINE.analyze(module, resolve_backend(hw),
                                    profile=profile, n_chains=n_chains,
                                    prune_unexecuted=prune_unexecuted)


def analyze_hlo(hlo_text: str, hw: BackendLike = TPU_V5E,
                hints: Optional[dict] = None,
                **kwargs) -> LeoAnalysis:
    module = parse_hlo(hlo_text, hints=hints)
    return analyze_module(module, hw, **kwargs)


def cross_backend_analyze(hlo_text: str,
                          hw_models: Optional[Sequence[BackendLike]] = None,
                          hints: Optional[dict] = None
                          ) -> Dict[str, LeoAnalysis]:
    """Observation-1 driver: same program, every backend model.

    Defaults to every *registered* backend (3 TPU generations plus the
    NVIDIA/AMD/Intel-class descriptors), so the divergence the paper reports
    across genuinely different vendors shows up out of the box.  Parses the
    HLO exactly once via a transient session.
    """
    from .session import LeoSession
    session = LeoSession(backends=hw_models, hints=hints)
    return session.compare_backends(hlo_text)
